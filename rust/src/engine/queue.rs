//! Deterministic priority event queue.
//!
//! A binary heap of [`Scheduled`] envelopes ordered by (time, seq).
//! Supports O(log n) push/pop and lazy cancellation (cancelled ids are
//! skipped on pop) — the flow simulator reschedules completion events
//! whenever link shares change, so cancellation must be cheap.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use super::event::{EventId, Scheduled};
use crate::util::units::Time;

/// The deterministic (time, seq)-ordered event heap.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Scheduled<T>>>,
    cancelled: HashSet<EventId>,
    next_seq: u64,
    /// Events pushed so far (statistic for the perf report).
    pub pushed: u64,
    /// Events popped so far (statistic for the perf report).
    pub popped: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            cancelled: HashSet::new(),
            next_seq: 0,
            pushed: 0,
            popped: 0,
        }
    }

    /// An empty queue with pre-reserved heap capacity.
    pub fn with_capacity(n: usize) -> Self {
        let mut q = Self::new();
        q.heap.reserve(n);
        q
    }

    /// Schedule `payload` at absolute time `time`.
    pub fn push(&mut self, time: Time, payload: T) -> EventId {
        let id = EventId(self.next_seq);
        self.next_seq += 1;
        self.pushed += 1;
        self.heap.push(Reverse(Scheduled { time, id, payload }));
        id
    }

    /// Cancel a previously scheduled event (lazy: skipped on pop).
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id);
    }

    /// Pop the earliest non-cancelled event.
    pub fn pop(&mut self) -> Option<Scheduled<T>> {
        while let Some(Reverse(ev)) = self.heap.pop() {
            if self.cancelled.remove(&ev.id) {
                continue;
            }
            self.popped += 1;
            return Some(ev);
        }
        None
    }

    /// Earliest pending (non-cancelled) event time without popping.
    pub fn peek_time(&mut self) -> Option<Time> {
        while let Some(Reverse(ev)) = self.heap.peek() {
            if self.cancelled.contains(&ev.id) {
                let Reverse(ev) = self.heap.pop().unwrap();
                self.cancelled.remove(&ev.id);
                continue;
            }
            return Some(ev.time);
        }
        None
    }

    /// True when no non-cancelled event remains.
    pub fn is_empty(&mut self) -> bool {
        self.peek_time().is_none()
    }

    /// Pending (possibly including not-yet-skipped cancelled) events.
    pub fn len_approx(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Time(30), "c");
        q.push(Time(10), "a");
        q.push(Time(20), "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Time(7), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().payload, i);
        }
    }

    #[test]
    fn cancellation_skips_events() {
        let mut q = EventQueue::new();
        let _a = q.push(Time(1), "a");
        let b = q.push(Time(2), "b");
        q.push(Time(3), "c");
        q.cancel(b);
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_time_skips_cancelled_head() {
        let mut q = EventQueue::new();
        let a = q.push(Time(1), "a");
        q.push(Time(5), "b");
        q.cancel(a);
        assert_eq!(q.peek_time(), Some(Time(5)));
        assert_eq!(q.pop().unwrap().payload, "b");
    }

    #[test]
    fn counters_track_throughput() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(Time(i), i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.pushed, 10);
        assert_eq!(q.popped, 10);
    }
}
