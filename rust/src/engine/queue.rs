//! Deterministic priority event queue.
//!
//! A binary heap of [`Scheduled`] envelopes ordered by (time, seq) with
//! **generation-stamped slab cancellation**: each pending event occupies
//! one slot of a dense `Vec<u32>` of generation counters, and its
//! [`EventId`] is the `(slot, generation)` pair. Cancelling bumps the
//! slot's generation (O(1), no allocation); a popped envelope whose
//! generation no longer matches is stale and is skipped, returning its
//! slot to the free list. The flow simulator reschedules completion
//! events whenever link shares change, so cancellation must be cheap —
//! and, unlike the seed's lazy `HashSet<EventId>`, the slab's memory is
//! bounded by the *peak concurrent* envelope count, not by the total
//! number of cancellations in the run (cancelling an id that already
//! fired is a no-op rather than a permanent set entry).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::event::{EventId, Scheduled};
use crate::util::units::Time;

/// The deterministic (time, seq)-ordered event heap.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<Scheduled<T>>>,
    /// Current generation per slab slot; an envelope is live iff its
    /// id's generation matches. One `u32` per peak-concurrent envelope.
    gens: Vec<u32>,
    /// Recycled slot indices.
    free: Vec<u32>,
    /// Live (scheduled, not cancelled, not yet popped) events.
    live: usize,
    next_seq: u64,
    /// Events pushed so far (statistic for the perf report).
    pub pushed: u64,
    /// Events popped so far (statistic for the perf report).
    pub popped: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            gens: Vec::new(),
            free: Vec::new(),
            live: 0,
            next_seq: 0,
            pushed: 0,
            popped: 0,
        }
    }

    /// An empty queue with pre-reserved heap and slab capacity (sized
    /// from compiled op/flow counts by the scheduler so steady-state
    /// pushes never reallocate).
    pub fn with_capacity(n: usize) -> Self {
        let mut q = Self::new();
        q.heap.reserve(n);
        q.gens.reserve(n);
        q.free.reserve(n);
        q
    }

    /// Schedule `payload` at absolute time `time`.
    pub fn push(&mut self, time: Time, payload: T) -> EventId {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.gens.push(0);
                (self.gens.len() - 1) as u32
            }
        };
        let id = EventId { slot, gen: self.gens[slot as usize] };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pushed += 1;
        self.live += 1;
        self.heap.push(Reverse(Scheduled { time, seq, id, payload }));
        id
    }

    /// Cancel a previously scheduled event. O(1): bumps the slot's
    /// generation so the pending envelope becomes stale (its slot is
    /// recycled when it surfaces on the heap). Cancelling an event that
    /// already fired — or cancelling twice — is a no-op.
    pub fn cancel(&mut self, id: EventId) {
        let g = &mut self.gens[id.slot as usize];
        if *g == id.gen {
            *g = g.wrapping_add(1);
            self.live -= 1;
        }
    }

    /// Pop the earliest non-cancelled event.
    pub fn pop(&mut self) -> Option<Scheduled<T>> {
        while let Some(Reverse(ev)) = self.heap.pop() {
            let slot = ev.id.slot as usize;
            if self.gens[slot] != ev.id.gen {
                // cancelled: the stale envelope has left the heap, so
                // the slot can be reused
                self.free.push(ev.id.slot);
                continue;
            }
            self.gens[slot] = self.gens[slot].wrapping_add(1); // consume
            self.free.push(ev.id.slot);
            self.live -= 1;
            self.popped += 1;
            return Some(ev);
        }
        None
    }

    /// Earliest pending (non-cancelled) event time without popping.
    pub fn peek_time(&mut self) -> Option<Time> {
        while let Some(Reverse(ev)) = self.heap.peek() {
            if self.gens[ev.id.slot as usize] != ev.id.gen {
                let Reverse(ev) = self.heap.pop().unwrap();
                self.free.push(ev.id.slot);
                continue;
            }
            return Some(ev.time);
        }
        None
    }

    /// True when no non-cancelled event remains.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Live (scheduled, non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.live
    }

    /// Pending (possibly including not-yet-skipped cancelled) events.
    pub fn len_approx(&self) -> usize {
        self.heap.len()
    }

    /// Slab slots ever allocated — the queue's cancellation-tracking
    /// footprint, bounded by the peak concurrent envelope count (the
    /// regression tests pin this; the seed's cancelled set grew with
    /// every cancel of an already-fired id).
    pub fn slab_len(&self) -> usize {
        self.gens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Time(30), "c");
        q.push(Time(10), "a");
        q.push(Time(20), "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Time(7), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop().unwrap().payload, i);
        }
    }

    #[test]
    fn cancellation_skips_events() {
        let mut q = EventQueue::new();
        let _a = q.push(Time(1), "a");
        let b = q.push(Time(2), "b");
        q.push(Time(3), "c");
        q.cancel(b);
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_time_skips_cancelled_head() {
        let mut q = EventQueue::new();
        let a = q.push(Time(1), "a");
        q.push(Time(5), "b");
        q.cancel(a);
        assert_eq!(q.peek_time(), Some(Time(5)));
        assert_eq!(q.pop().unwrap().payload, "b");
    }

    #[test]
    fn counters_track_throughput() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(Time(i), i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.pushed, 10);
        assert_eq!(q.popped, 10);
    }

    #[test]
    fn pending_tracks_live_events() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        let a = q.push(Time(1), 1);
        let _b = q.push(Time(2), 2);
        assert_eq!(q.pending(), 2);
        q.cancel(a);
        assert_eq!(q.pending(), 1);
        assert!(!q.is_empty());
        q.pop();
        assert_eq!(q.pending(), 0);
        assert!(q.is_empty());
    }

    #[test]
    fn cancel_after_fire_does_not_leak() {
        // regression (seed bug): cancelling an id that already fired
        // left it in the cancelled set forever. The slab must neither
        // grow nor corrupt the slot's next occupant.
        let mut q = EventQueue::new();
        let mut fired = Vec::new();
        for round in 0..1000u64 {
            let id = q.push(Time(round), round);
            assert_eq!(q.pop().unwrap().payload, round);
            fired.push(id);
            // cancel every id that ever fired, repeatedly
            for &old in &fired {
                q.cancel(old);
            }
        }
        assert_eq!(q.slab_len(), 1, "slab grew with fired-id cancels");
        assert_eq!(q.pending(), 0);
        // the slot is still usable
        q.push(Time(5000), 42);
        assert_eq!(q.pop().unwrap().payload, 42);
    }

    #[test]
    fn slab_bounded_by_peak_concurrency() {
        let mut q = EventQueue::new();
        for wave in 0..50u64 {
            let ids: Vec<_> = (0..64).map(|i| q.push(Time(wave * 100 + i), i)).collect();
            // cancel half, pop the rest
            for id in ids.iter().step_by(2) {
                q.cancel(*id);
            }
            while q.pop().is_some() {}
        }
        assert!(q.slab_len() <= 64, "slab {} > peak concurrency 64", q.slab_len());
        assert_eq!(q.pushed, 50 * 64);
    }

    #[test]
    fn reused_slot_does_not_resurrect_cancelled_event() {
        let mut q = EventQueue::new();
        let a = q.push(Time(10), "a");
        q.cancel(a);
        // a's slot is still occupied by the stale envelope; new pushes
        // take fresh slots until it drains, then recycle it
        q.push(Time(1), "b");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert!(q.pop().is_none(), "cancelled event resurfaced");
        q.push(Time(2), "c");
        assert_eq!(q.pop().unwrap().payload, "c");
        // stale-a and b slots both recycled
        assert!(q.slab_len() <= 2);
    }

    #[test]
    fn double_cancel_is_noop() {
        let mut q = EventQueue::new();
        let a = q.push(Time(1), 1);
        q.push(Time(2), 2);
        q.cancel(a);
        q.cancel(a);
        assert_eq!(q.pending(), 1);
        assert_eq!(q.pop().unwrap().payload, 2);
        assert!(q.pop().is_none());
    }
}
