//! Execution timeline recording (the paper's "event logs over the
//! distributed execution timeline", §4.2 System layer).
//!
//! Records are (rank, category, label, start, end) tuples; the recorder
//! can summarize per-category busy time and export CSV for inspection.

use crate::util::stats::Samples;
use crate::util::units::Time;

/// Coarse activity classes of the execution timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceCategory {
    /// A compute op running on a GPU.
    Compute,
    /// A collective (blocked or transferring).
    Communication,
    /// Resharding traffic (component C2).
    Resharding,
    /// Pipeline idle time.
    PipelineBubble,
    /// Anything else.
    Other,
}

impl TraceCategory {
    /// Lower-case display name.
    pub fn name(self) -> &'static str {
        match self {
            TraceCategory::Compute => "compute",
            TraceCategory::Communication => "comm",
            TraceCategory::Resharding => "reshard",
            TraceCategory::PipelineBubble => "bubble",
            TraceCategory::Other => "other",
        }
    }
}

/// One busy interval of one rank.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// The global rank the interval belongs to.
    pub rank: u32,
    /// Activity class.
    pub category: TraceCategory,
    /// Human-readable op/collective label.
    pub label: String,
    /// Interval start (simulation time).
    pub start: Time,
    /// Interval end (simulation time).
    pub end: Time,
}

/// Accumulates timeline records. Can be disabled (all pushes dropped)
/// for perf runs where only aggregate stats matter.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    /// All recorded intervals, in push order.
    pub records: Vec<TraceRecord>,
    /// When false, `record` calls are dropped.
    pub enabled: bool,
}

impl TraceRecorder {
    /// A recorder, enabled or disabled.
    pub fn new(enabled: bool) -> Self {
        TraceRecorder { records: Vec::new(), enabled }
    }

    /// Push one busy interval (no-op when disabled).
    pub fn record(
        &mut self,
        rank: u32,
        category: TraceCategory,
        label: impl Into<String>,
        start: Time,
        end: Time,
    ) {
        if self.enabled {
            self.records.push(TraceRecord { rank, category, label: label.into(), start, end });
        }
    }

    /// Total busy time per category across all ranks.
    pub fn busy_by_category(&self, cat: TraceCategory) -> Time {
        Time(self
            .records
            .iter()
            .filter(|r| r.category == cat)
            .map(|r| (r.end - r.start).as_ps())
            .sum())
    }

    /// Duration samples for one category (e.g. per-flow FCTs).
    pub fn durations(&self, cat: TraceCategory) -> Samples {
        let mut s = Samples::new();
        s.extend(
            self.records
                .iter()
                .filter(|r| r.category == cat)
                .map(|r| (r.end - r.start).as_secs()),
        );
        s
    }

    /// Makespan across all records.
    pub fn makespan(&self) -> Time {
        Time(self.records.iter().map(|r| r.end.as_ps()).max().unwrap_or(0))
    }

    /// Chrome-trace (chrome://tracing / Perfetto) JSON export: one
    /// "complete" event per record, rank as tid.
    pub fn chrome_trace(&self) -> String {
        use crate::util::json::Json;
        let events: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.label.clone())),
                    ("cat", Json::Str(r.category.name().into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Num(r.start.as_us())),
                    ("dur", Json::Num((r.end - r.start).as_us())),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(r.rank as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("traceEvents", Json::Arr(events))]).to_string()
    }

    /// CSV export (`rank,category,label,start_ns,end_ns`).
    pub fn csv(&self) -> String {
        let mut s = String::from("rank,category,label,start_ns,end_ns\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{:.3},{:.3}\n",
                r.rank,
                r.category.name(),
                r.label,
                r.start.as_ns(),
                r.end.as_ns()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_drops_records() {
        let mut t = TraceRecorder::new(false);
        t.record(0, TraceCategory::Compute, "x", Time(0), Time(10));
        assert!(t.records.is_empty());
    }

    #[test]
    fn busy_time_sums_per_category() {
        let mut t = TraceRecorder::new(true);
        t.record(0, TraceCategory::Compute, "a", Time(0), Time(10));
        t.record(1, TraceCategory::Compute, "b", Time(5), Time(25));
        t.record(0, TraceCategory::Communication, "c", Time(10), Time(12));
        assert_eq!(t.busy_by_category(TraceCategory::Compute), Time(30));
        assert_eq!(t.busy_by_category(TraceCategory::Communication), Time(2));
    }

    #[test]
    fn makespan_is_latest_end() {
        let mut t = TraceRecorder::new(true);
        t.record(0, TraceCategory::Compute, "a", Time(0), Time(10));
        t.record(1, TraceCategory::Communication, "b", Time(3), Time(99));
        assert_eq!(t.makespan(), Time(99));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = TraceRecorder::new(true);
        t.record(2, TraceCategory::Resharding, "rs", Time::from_ns(1.0), Time::from_ns(2.0));
        let csv = t.csv();
        assert!(csv.starts_with("rank,category,label"));
        assert!(csv.contains("2,reshard,rs,1.000,2.000"));
    }

    #[test]
    fn chrome_trace_is_valid_json_with_events() {
        let mut t = TraceRecorder::new(true);
        t.record(1, TraceCategory::Compute, "mlp-fwd", Time::from_us(1.0), Time::from_us(3.0));
        t.record(2, TraceCategory::Communication, "tp-ar", Time::from_us(2.0), Time::from_us(5.0));
        let json = crate::util::json::Json::parse(&t.chrome_trace()).unwrap();
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(events[0].get("tid").unwrap().as_u64().unwrap(), 1);
        assert!((events[1].get("dur").unwrap().as_f64().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn durations_collects_samples() {
        let mut t = TraceRecorder::new(true);
        t.record(0, TraceCategory::Communication, "f1", Time(0), Time::from_secs(1.0));
        t.record(0, TraceCategory::Communication, "f2", Time(0), Time::from_secs(3.0));
        let mut s = t.durations(TraceCategory::Communication);
        assert_eq!(s.len(), 2);
        assert!((s.max() - 3.0).abs() < 1e-9);
    }
}
