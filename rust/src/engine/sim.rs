//! The event loop: advances the clock, dispatches payloads to a handler
//! which may schedule further events.

use super::event::Scheduled;
use super::queue::EventQueue;
use crate::util::units::Time;

/// Engine = queue + clock + safety limits.
#[derive(Debug)]
pub struct Engine<T> {
    /// The underlying event queue (exposed for perf statistics).
    pub queue: EventQueue<T>,
    now: Time,
    /// Abort knob against runaway event cascades (0 = unlimited).
    pub max_events: u64,
    processed: u64,
}

impl<T> Default for Engine<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Engine<T> {
    /// A fresh engine with an empty queue at time zero.
    pub fn new() -> Self {
        Engine { queue: EventQueue::new(), now: Time::ZERO, max_events: 0, processed: 0 }
    }

    /// A fresh engine whose queue pre-reserves capacity for `n`
    /// concurrent events (see [`EventQueue::with_capacity`]) — the
    /// scheduler sizes this from compiled op/flow counts so the hot
    /// loop never grows the heap.
    pub fn with_capacity(n: usize) -> Self {
        Engine { queue: EventQueue::with_capacity(n), now: Time::ZERO, max_events: 0, processed: 0 }
    }

    /// The current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Events dispatched so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` after a delay from the current time.
    pub fn schedule_in(&mut self, delay: Time, payload: T) -> super::event::EventId {
        self.queue.push(self.now + delay, payload)
    }

    /// Schedule at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, time: Time, payload: T) -> super::event::EventId {
        debug_assert!(time >= self.now, "scheduling into the past: {time} < {}", self.now);
        self.queue.push(time, payload)
    }

    /// The timestamp of the next pending event without popping it
    /// (`None` when the queue is drained). Lets manual-loop callers
    /// decide *before* dispatch whether an external cutoff — an
    /// injected fault ([`crate::system::failure`]) or the
    /// branch-and-bound incumbent cutoff
    /// ([`crate::system::scheduler::Scheduler::cutoff`], DESIGN.md
    /// §29) — fires first, without perturbing the clock or the
    /// processed-event count. Peek-before-dispatch is what makes a
    /// run that *completes* under a finite cutoff bit-identical to
    /// the cutoff-free run.
    pub fn peek_time(&mut self) -> Option<Time> {
        self.queue.peek_time()
    }

    /// Pop the next event and advance the clock — the manual-loop
    /// alternative to [`Engine::run`] for callers whose handler needs
    /// `&mut` access to state that also owns the engine reference.
    pub fn step(&mut self) -> Option<Scheduled<T>> {
        let ev = self.queue.pop()?;
        debug_assert!(ev.time >= self.now, "time went backwards");
        self.now = ev.time;
        self.processed += 1;
        Some(ev)
    }

    /// Run until the queue drains. The handler receives the engine so it
    /// can schedule follow-up events and read the clock.
    pub fn run(&mut self, mut handler: impl FnMut(&mut Engine<T>, Scheduled<T>)) -> anyhow::Result<Time> {
        self.run_until(Time::MAX, &mut handler)
    }

    /// Run until the queue drains or the clock passes `deadline`.
    /// Returns the final clock value.
    pub fn run_until(
        &mut self,
        deadline: Time,
        handler: &mut impl FnMut(&mut Engine<T>, Scheduled<T>),
    ) -> anyhow::Result<Time> {
        while let Some(t) = self.queue.peek_time() {
            if t > deadline {
                break;
            }
            let ev = self.queue.pop().unwrap();
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.now = ev.time;
            self.processed += 1;
            if self.max_events > 0 && self.processed > self.max_events {
                anyhow::bail!(
                    "event budget exceeded ({} events) — runaway cascade? now={}",
                    self.max_events,
                    self.now
                );
            }
            handler(self, ev);
        }
        Ok(self.now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(Time(10), 1);
        e.schedule_at(Time(5), 2);
        let mut seen = Vec::new();
        e.run(|eng, ev| seen.push((eng.now().as_ps(), ev.payload))).unwrap();
        assert_eq!(seen, vec![(5, 2), (10, 1)]);
        assert_eq!(e.now(), Time(10));
    }

    #[test]
    fn handler_can_schedule_follow_ups() {
        let mut e: Engine<u32> = Engine::new();
        e.schedule_at(Time(1), 0);
        let mut count = 0;
        e.run(|eng, ev| {
            count += 1;
            if ev.payload < 5 {
                eng.schedule_in(Time(2), ev.payload + 1);
            }
        })
        .unwrap();
        assert_eq!(count, 6);
        assert_eq!(e.now(), Time(11));
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule_at(Time(i * 10), i as u32);
        }
        let mut seen = 0;
        e.run_until(Time(45), &mut |_, _| seen += 1).unwrap();
        assert_eq!(seen, 5);
        // remaining events still pending
        assert!(!e.queue.is_empty());
    }

    #[test]
    fn event_budget_guards_runaway() {
        let mut e: Engine<u32> = Engine::new();
        e.max_events = 100;
        e.schedule_at(Time(1), 0);
        let res = e.run(|eng, ev| {
            eng.schedule_in(Time(1), ev.payload); // infinite cascade
        });
        assert!(res.is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e: Engine<u64> = Engine::new();
            for i in 0..50 {
                e.schedule_at(Time(i % 7), i);
            }
            let mut order = Vec::new();
            e.run(|_, ev| order.push(ev.payload)).unwrap();
            order
        };
        assert_eq!(run(), run());
    }
}
