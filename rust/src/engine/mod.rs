//! Deterministic discrete-event simulation core (system S1).
//!
//! The engine is generic over the event payload so the system layer
//! (compute/pipeline events) and the network layer (flow events) can
//! share one implementation. Determinism contract: events at equal
//! timestamps dispatch in insertion order (a monotone sequence number
//! breaks ties), so a given configuration always produces an identical
//! timeline.

pub mod event;
pub mod queue;
pub mod sim;
pub mod trace;

pub use event::EventId;
pub use queue::EventQueue;
pub use sim::Engine;
pub use trace::{TraceCategory, TraceRecord, TraceRecorder};
