//! Event identity and scheduling envelope.

use crate::util::units::Time;

/// Identity of a scheduled event: a slab slot in the owning
/// [`crate::engine::EventQueue`] plus a generation stamp distinguishing
/// successive occupants of that slot. Cancellation and staleness checks
/// are O(1) slab probes — no hash set — and ids of fired or cancelled
/// events occupy no memory (the seed kept cancelled ids in a `HashSet`
/// for the life of the run).
///
/// `EventId` deliberately does **not** implement `Ord`: slot numbers are
/// recycled, so ids carry no temporal order. Deterministic (time, seq)
/// ordering lives in [`Scheduled::seq`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

/// A payload scheduled at a simulation time. Ordering: by time, then by
/// insertion sequence (deterministic tie-break).
#[derive(Debug, Clone)]
pub struct Scheduled<T> {
    /// Absolute simulation time the event fires at.
    pub time: Time,
    /// Insertion sequence number (the deterministic tie-break; strictly
    /// monotone per queue, never recycled).
    pub seq: u64,
    /// Slab identity of the event (for cancellation / staleness checks).
    pub id: EventId,
    /// The caller-defined event payload.
    pub payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(time: Time, seq: u64) -> Scheduled<()> {
        Scheduled { time, seq, id: EventId { slot: 0, gen: 0 }, payload: () }
    }

    #[test]
    fn ordering_by_time_then_seq() {
        let a = sched(Time(5), 1);
        let b = sched(Time(5), 2);
        let c = sched(Time(4), 9);
        assert!(c < a);
        assert!(a < b);
    }
}
