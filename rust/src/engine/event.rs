//! Event identity and scheduling envelope.

use crate::util::units::Time;

/// Unique id of a scheduled event (its insertion sequence number).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EventId(pub u64);

/// A payload scheduled at a simulation time. Ordering: by time, then by
/// insertion sequence (deterministic tie-break).
#[derive(Debug, Clone)]
pub struct Scheduled<T> {
    /// Absolute simulation time the event fires at.
    pub time: Time,
    /// Insertion sequence number (the deterministic tie-break).
    pub id: EventId,
    /// The caller-defined event payload.
    pub payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.id == other.id
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.cmp(&other.time).then(self.id.cmp(&other.id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_by_time_then_seq() {
        let a = Scheduled { time: Time(5), id: EventId(1), payload: () };
        let b = Scheduled { time: Time(5), id: EventId(2), payload: () };
        let c = Scheduled { time: Time(4), id: EventId(9), payload: () };
        assert!(c < a);
        assert!(a < b);
    }
}
