//! Stubs for the PJRT runtime when the `pjrt` cargo feature is off:
//! identical API surface, clear error at load time instead of an `xla`
//! crate (and XLA C++ runtime) dependency.

use std::path::Path;

use super::COLL_FIELDS;
use crate::compute::table::CostEvaluator;

fn unavailable<T>() -> anyhow::Result<T> {
    anyhow::bail!(
        "the PJRT cost backend requires building hetsim with `--features pjrt` \
         (which needs the `xla` crate and `make artifacts`); \
         use the native backend instead"
    )
}

/// Stub of the artifact-backed per-layer cost model.
#[derive(Debug)]
pub struct PjrtCostModel;

impl PjrtCostModel {
    pub fn load() -> anyhow::Result<Self> {
        unavailable()
    }

    pub fn load_from(_dir: &Path) -> anyhow::Result<Self> {
        unavailable()
    }
}

impl CostEvaluator for PjrtCostModel {
    fn evaluate_batch(
        &mut self,
        _layers: &[[f32; 10]],
        _gpus: &[[f32; 8]],
    ) -> anyhow::Result<Vec<f32>> {
        unavailable()
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

/// Stub of the artifact-backed alpha-beta collective estimator.
#[derive(Debug)]
pub struct PjrtCollModel;

impl PjrtCollModel {
    pub fn load() -> anyhow::Result<Self> {
        unavailable()
    }

    pub fn load_from(_dir: &Path) -> anyhow::Result<Self> {
        unavailable()
    }

    pub fn evaluate(&self, _rows: &[[f32; COLL_FIELDS]]) -> anyhow::Result<Vec<f32>> {
        unavailable()
    }
}
