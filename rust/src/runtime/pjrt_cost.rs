//! PJRT-backed cost evaluators: the production [`CostEvaluator`] that
//! runs the AOT-compiled Layer-1/2 cost graphs.

use std::path::Path;

use super::{artifacts_dir, Executable, Runtime};
use crate::compute::table::CostEvaluator;

// Artifact batch geometry lives in `super` so it is available without
// the `pjrt` feature; re-exported here for back-compat paths.
pub use super::{COLL_FIELDS, COLL_ROWS, COST_ROWS, GPU_FIELDS, LAYER_FIELDS};

/// Executes `artifacts/cost_model.hlo.txt`.
pub struct PjrtCostModel {
    exe: Executable,
}

impl std::fmt::Debug for PjrtCostModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtCostModel").field("source", &self.exe.source).finish()
    }
}

fn check_manifest(dir: &Path) -> anyhow::Result<()> {
    let mpath = dir.join("manifest.json");
    if !mpath.exists() {
        return Ok(()); // older artifact sets: geometry asserted at execute
    }
    let text = std::fs::read_to_string(&mpath)?;
    let v = crate::util::json::Json::parse(&text)?;
    let cm = v.req("cost_model")?;
    anyhow::ensure!(cm.req_u64("rows")? as usize == COST_ROWS, "cost rows mismatch");
    anyhow::ensure!(cm.req_u64("layer_fields")? as usize == LAYER_FIELDS, "layer fields mismatch");
    anyhow::ensure!(cm.req_u64("gpu_fields")? as usize == GPU_FIELDS, "gpu fields mismatch");
    let co = v.req("coll_model")?;
    anyhow::ensure!(co.req_u64("rows")? as usize == COLL_ROWS, "coll rows mismatch");
    anyhow::ensure!(co.req_u64("coll_fields")? as usize == COLL_FIELDS, "coll fields mismatch");
    Ok(())
}

impl PjrtCostModel {
    /// Load from the default artifacts directory.
    pub fn load() -> anyhow::Result<Self> {
        let dir = artifacts_dir()?;
        Self::load_from(&dir)
    }

    pub fn load_from(dir: &Path) -> anyhow::Result<Self> {
        check_manifest(dir)?;
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo_text(&dir.join("cost_model.hlo.txt"))?;
        Ok(PjrtCostModel { exe })
    }
}

impl CostEvaluator for PjrtCostModel {
    fn evaluate_batch(&mut self, layers: &[[f32; 10]], gpus: &[[f32; 8]]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(layers.len() == gpus.len(), "row-aligned inputs required");
        anyhow::ensure!(layers.len() <= COST_ROWS, "batch exceeds artifact rows");
        // zero-pad to the artifact's static shape
        let mut lbuf = vec![0f32; COST_ROWS * LAYER_FIELDS];
        let mut gbuf = vec![0f32; COST_ROWS * GPU_FIELDS];
        for (i, row) in layers.iter().enumerate() {
            lbuf[i * LAYER_FIELDS..(i + 1) * LAYER_FIELDS].copy_from_slice(row);
        }
        for (i, row) in gpus.iter().enumerate() {
            gbuf[i * GPU_FIELDS..(i + 1) * GPU_FIELDS].copy_from_slice(row);
        }
        let out = self.exe.run_f32(&[
            (&lbuf, COST_ROWS, LAYER_FIELDS),
            (&gbuf, COST_ROWS, GPU_FIELDS),
        ])?;
        anyhow::ensure!(out.len() == COST_ROWS, "unexpected output length {}", out.len());
        Ok(out[..layers.len()].to_vec())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Executes `artifacts/coll_model.hlo.txt` (the alpha-beta collective
/// estimator used by the Sailor-like analytical baseline).
pub struct PjrtCollModel {
    exe: Executable,
}

impl std::fmt::Debug for PjrtCollModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtCollModel").field("source", &self.exe.source).finish()
    }
}

impl PjrtCollModel {
    pub fn load() -> anyhow::Result<Self> {
        let dir = artifacts_dir()?;
        Self::load_from(&dir)
    }

    pub fn load_from(dir: &Path) -> anyhow::Result<Self> {
        check_manifest(dir)?;
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo_text(&dir.join("coll_model.hlo.txt"))?;
        Ok(PjrtCollModel { exe })
    }

    /// rows: up to COLL_ROWS descriptors
    /// `[algo, nranks, size_bytes, bw, latency_s, extra_hops, 0, 0]`.
    pub fn evaluate(&self, rows: &[[f32; COLL_FIELDS]]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(rows.len() <= COLL_ROWS, "batch exceeds artifact rows");
        let mut buf = vec![0f32; COLL_ROWS * COLL_FIELDS];
        for (i, row) in rows.iter().enumerate() {
            buf[i * COLL_FIELDS..(i + 1) * COLL_FIELDS].copy_from_slice(row);
        }
        let out = self.exe.run_f32(&[(&buf, COLL_ROWS, COLL_FIELDS)])?;
        anyhow::ensure!(out.len() == COLL_ROWS, "unexpected output length {}", out.len());
        Ok(out[..rows.len()].to_vec())
    }
}
