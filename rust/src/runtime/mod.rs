//! PJRT runtime (system S11): loads the AOT artifacts produced by
//! `make artifacts` and executes them on the PJRT CPU client via the
//! `xla` crate. This is the only place Rust touches XLA; Python is never
//! on the simulation path.
//!
//! The whole XLA surface is gated behind the **`pjrt` cargo feature**
//! (the `xla` crate and its native XLA runtime are not part of the
//! default build — add the dependency and enable the feature to use
//! it). Without the feature, [`PjrtCostModel`] / [`PjrtCollModel`] are
//! stubs with the same API that fail at load time with a clear message,
//! so every caller and the `--backend pjrt` CLI path still compile.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py`):
//! jax ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly.

#[cfg(feature = "pjrt")]
pub mod pjrt_cost;

#[cfg(feature = "pjrt")]
pub use pjrt_cost::{PjrtCollModel, PjrtCostModel};

#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtCollModel, PjrtCostModel};

use std::path::PathBuf;

/// Artifact batch geometry — must match `python/compile/model.py`
/// (asserted against artifacts/manifest.json on load).
pub const COST_ROWS: usize = 256;
pub const LAYER_FIELDS: usize = 10;
pub const GPU_FIELDS: usize = 8;
pub const COLL_ROWS: usize = 512;
pub const COLL_FIELDS: usize = 8;

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub source: PathBuf,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("source", &self.source).finish()
    }
}

/// Thin wrapper over the PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime").field("platform", &self.client.platform_name()).finish()
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client creation failed: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text from `path`, compile, return the executable.
    pub fn load_hlo_text(&self, path: &std::path::Path) -> anyhow::Result<Executable> {
        anyhow::ensure!(
            path.exists(),
            "artifact {} not found — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {} failed: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {} failed: {e}", path.display()))?;
        Ok(Executable { exe, source: path.to_path_buf() })
    }
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with f32 matrix inputs `(data, rows, cols)`. The artifact
    /// returns a 1-tuple (lowered with `return_tuple=True`); we unwrap
    /// it and return the flat f32 output.
    pub fn run_f32(&self, inputs: &[(&[f32], usize, usize)]) -> anyhow::Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, rows, cols) in inputs {
            anyhow::ensure!(data.len() == rows * cols, "input shape mismatch");
            let lit = xla::Literal::vec1(data)
                .reshape(&[*rows as i64, *cols as i64])
                .map_err(|e| anyhow::anyhow!("reshape failed: {e}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute failed: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback failed: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow::anyhow!("untuple failed: {e}"))?;
        tuple.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec failed: {e}"))
    }
}

/// Locate the artifacts directory: `$HETSIM_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/`.
pub fn artifacts_dir() -> anyhow::Result<PathBuf> {
    if let Ok(dir) = std::env::var("HETSIM_ARTIFACTS") {
        let p = PathBuf::from(dir);
        anyhow::ensure!(p.is_dir(), "HETSIM_ARTIFACTS={} is not a directory", p.display());
        return Ok(p);
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("cost_model.hlo.txt").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            anyhow::bail!(
                "artifacts/ not found (run `make artifacts`, or set HETSIM_ARTIFACTS)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trip tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` and `--features pjrt`). Here: path
    // resolution and stub behaviour only.

    #[test]
    fn artifacts_dir_env_override_rejects_missing() {
        // Use a scoped fake env var via direct call.
        std::env::set_var("HETSIM_ARTIFACTS", "/definitely/not/here");
        let r = artifacts_dir();
        std::env::remove_var("HETSIM_ARTIFACTS");
        assert!(r.is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn load_missing_artifact_errors() {
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_hlo_text(std::path::Path::new("/no/such/file.hlo.txt")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_cost_model_errors_with_guidance() {
        let err = PjrtCostModel::load().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = PjrtCollModel::load().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
