//! JSON config loader: the file-based face of abstractions A1/A2
//! (`hetsim simulate --config FILE`).
//!
//! # Scenario format
//!
//! A scenario file is one JSON object with three required sections
//! (`model`, `cluster`, `parallelism`) plus optional `fabric`,
//! `schedule`, `fold`, `faults`, `serving` and `seed`. Unknown keys
//! are ignored.
//!
//! ```json
//! {
//!   "model": "gpt-6.7b",
//!   "cluster": {"arch": "hetero", "ampere_nodes": 8, "hopper_nodes": 8},
//!   "fabric": "rail",
//!   "parallelism": {"tp": 4, "pp": 1, "dp": 32},
//!   "schedule": "1f1b",
//!   "seed": 42
//! }
//! ```
//!
//! ## `model` — required
//!
//! Either a preset name (`"gpt-6.7b"`, `"gpt-13b"`, `"mixtral-8x7b"`,
//! `"llama2-70b"` — the paper's Table 6, see
//! [`crate::config::presets::model`]) or an inline object:
//!
//! | key | required | default | meaning |
//! |-----|----------|---------|---------|
//! | `name` | no | `"custom"` | display name |
//! | `num_layers` | yes | — | transformer blocks |
//! | `hidden_size` | yes | — | model dimension |
//! | `num_heads` | yes | — | attention heads (must divide `hidden_size`) |
//! | `ffn_hidden` | yes | — | MLP inner dimension |
//! | `seq_len` | yes | — | training sequence length |
//! | `max_pos_embeddings` | no | `seq_len` | positional table size |
//! | `vocab_size` | no | `50257` | embedding rows |
//! | `num_experts` | no | — | MoE expert count (presence enables MoE) |
//! | `top_k` | no | `2` | MoE routed experts per token |
//! | `gated_mlp` | no | `false` | SwiGLU-style 3-matrix MLP |
//! | `global_batch` | yes | — | samples per iteration |
//! | `micro_batch` | yes | — | microbatch size |
//! | `grad_dtype_bytes` | no | `4` | gradient dtype width |
//! | `dtype_bytes` | no | `2` | parameter/activation dtype width |
//!
//! ## `cluster` — required
//!
//! One of:
//! * shorthand string — `"ampere:16"` / `"hopper:4"` / `"volta:2"` /
//!   `"blackwell:2"` (N nodes of 8 GPUs; bare `"hopper"` means 16
//!   nodes), `"hetero:A,H"` (A ampere + H hopper nodes), or `"fig3"`
//!   (the paper's Fig-3 cluster: one 4×H100 node + one 4×A100 node).
//!   Node counts take an optional `@G` suffix overriding the 8-GPU
//!   node size: `"ampere:2@4"` is two 4-GPU Ampere nodes,
//!   `"hetero:1@4,1"` is one 4-GPU Ampere node beside one 8-GPU
//!   Hopper node (mixed node sizes are first-class, DESIGN.md §24);
//! * `{"arch": "hetero", "ampere_nodes": 8, "hopper_nodes": 8}` —
//!   both node counts default to 8;
//! * `{"arch": "custom", "node_archs": ["ampere", "hopper@4", ...],
//!   "name": "mymix"}` — one entry per node for arbitrary mixes, each
//!   with an optional `@G` GPU-count suffix;
//! * `{"arch": "<preset>", "nodes": 16}` — homogeneous preset cluster.
//!
//! ## `fabric` — optional, default `"rail"`
//!
//! Inter-node fabric shape ([`crate::config::cluster::FabricSpec`],
//! DESIGN.md §24): `"rail"` (the paper's rail-only design — the
//! default, byte-identical to the pre-fabric simulator), `"switch"`
//! (one non-blocking switch), or `"spine:S,OS"` (two-tier leaf/spine
//! with `S` spines and oversubscription `OS`; `OS` defaults to 1 when
//! omitted). An object form `{"kind": "leafspine", "spines": 2,
//! "oversubscription": 4}` is also accepted.
//!
//! ## `parallelism` — required
//!
//! Either the classic grid — `{"tp": T, "pp": P, "dp": D}`, all three
//! required, `T × P × D` equal to the cluster's GPU count at build
//! time — or **explicit per-group TP degrees** (the paper's Fig-3
//! shape, [`crate::workload::partition::plan_variable_tp`]):
//!
//! ```json
//! {"groups": [{"tp": [3, 1]}, {"tp": [4]}]}
//! ```
//!
//! One `groups` entry per cluster node, in rank order; each entry's
//! `tp` array lists the TP degree of every pipeline stage on that node
//! and must sum to the node's GPU count. TP degrees need not match
//! across groups — mismatches trigger gradient resharding (paper §3).
//! Layers and batch are split proportionally to compute power (the
//! heterogeneity-aware partitioner); the derived `tp`/`pp`/`dp` of a
//! per-group scenario are the informational maxima.
//!
//! ## `schedule` — optional, default `"gpipe"`
//!
//! Pipeline schedule for every device group: `"gpipe"`, `"1f1b"` or
//! `"interleaved:V"` (V ≥ 2 virtual-pipeline chunks per stage). See
//! [`crate::workload::schedule`].
//!
//! ## `fold` — optional, default `"off"`
//!
//! Symmetry folding ([`crate::system::fold`], DESIGN.md §25):
//! `"auto"` simulates one representative device group per equivalence
//! class (bit-identical results, large speedups at high DP), `"off"`
//! is byte-identical to the pre-folding simulator.
//!
//! ## `faults` — optional
//!
//! Deterministic fault injection ([`crate::system::failure`],
//! DESIGN.md §26). An object with any of:
//!
//! * `"events"` — array of `{"at_s": seconds, "kind": "node_fail" |
//!   "nic_fail" | "link_fail" | "straggler", "node": index,
//!   "mult": factor}` (`mult` only for stragglers, ≥ 1). Fail-stop
//!   kinds abort the iteration at `at_s`; stragglers multiply the
//!   node's compute times.
//! * `"checkpoint"` — `{"interval_iters", "write_gbps",
//!   "restart_warmup_s"}` overriding the checkpoint/restore cost model
//!   used for goodput accounting.
//! * `"mtbf"` — `{"horizon_s", "scale"}`: materialize a per-arch
//!   MTBF-driven schedule over the cluster, seeded by the scenario's
//!   `seed` (or the fault object's own `"seed"` key).
//! * `"repair"` — `{"nic_s", "link_s"}` mean repair windows in seconds
//!   for the repairable fault classes (defaults 600 / 300,
//!   [`crate::system::failure::RepairSpec`]). A NIC or link fault
//!   inside its repair window no longer fail-stops outright: the flow
//!   model kills the faulted links and reroutes around them, running
//!   degraded until repair
//!   ([`crate::system::failure::DegradedModel`]); the iteration
//!   aborts only when no route survives.
//! * `"domains"` — `{"rack_size", "mtbf_hours", "horizon_s",
//!   "scale"}`: correlated failure domains (DESIGN.md §28).
//!   Consecutive `rack_size`-node racks share a blast domain (PDU /
//!   top-of-rack class hardware), and one domain event fails the
//!   whole rack at the same instant
//!   ([`crate::system::failure::domain_schedule`]). `rack_size` and
//!   `horizon_s` are required; `mtbf_hours` defaults to 4380 (half a
//!   year) and `scale` to 1, with the same nested-thinning subset
//!   guarantee across scales as `"mtbf"`.
//! * `"monte_carlo"` — `{"trajectories"}` (1–4096): how many seeded
//!   fault trajectories goodput analysis averages over
//!   ([`crate::report::goodput::monte_carlo`]); trajectory sets nest
//!   as the count grows.
//!
//! A spec with no events and all-default knobs is normalized away —
//! the simulation is byte-identical to one without the key.
//!
//! ## `serving` — optional
//!
//! Inference serving workload ([`crate::workload::serve`],
//! DESIGN.md §27), run via `hetsim serve-sim --config` or
//! [`crate::Simulation::run_serve`]. An object with at least one of:
//!
//! * `"requests"` — explicit trace: array of `{"arrival_s": seconds,
//!   "prompt_tokens": count, "output_tokens": count, "weight": w}`
//!   (`weight` optional, default 1; feeds the `wsrpt` policy).
//! * `"poisson"` — seeded open-loop arrivals: `{"rate_per_s",
//!   "horizon_s", "scale", "prompt_tokens", "output_tokens"}`
//!   (`rate_per_s` required; `scale` multiplies the rate in
//!   `[0, 16]` with nested-thinning subset semantics across scales;
//!   token counts are per-request means, drawn in `[0.5, 1.5)×mean`).
//!
//! Plus optional scheduler knobs: `"policy"` (`"fifo" | "srpt" |
//! "wsrpt"`, default fifo), `"max_batch"` (default 32), `"kv_frac"`
//! (fraction of post-weights GPU memory usable for KV cache, default
//! 0.8) and `"seed"` (defaults to the scenario's `seed`). A spec that
//! generates no requests is normalized away — the simulation is
//! byte-identical to one without the key.
//!
//! ## `seed` — optional, default `42`
//!
//! Seeds stochastic extensions — the MTBF fault-schedule draw and the
//! serving Poisson arrival draw; everything else in the simulator is
//! deterministic.
//!
//! Complete, loadable examples ship at
//! `rust/examples/scenario_hetero_1f1b.json` (grid parallelism),
//! `rust/examples/scenario_variable_tp.json` (per-group TP, the Fig-3
//! deployment), `rust/examples/scenario_spine_mixed_nodes.json`
//! (mixed node sizes on an oversubscribed leaf/spine fabric),
//! `rust/examples/scenario_faults.json` (the canonical fault-injection
//! scenario behind the resilience golden test),
//! `rust/examples/scenario_correlated_faults.json` (repairable NIC and
//! link outages, rack-level failure domains and Monte-Carlo goodput)
//! and `rust/examples/scenario_serving.json` (the canonical serving
//! scenario: Poisson arrivals plus pinned requests on a mixed
//! cluster); the doctests below parse them on every `cargo test`, so
//! the examples and this documentation cannot rot apart:
//!
//! ```
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_hetero_1f1b.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! assert_eq!(s.model.name, "GPT-6.7B");
//! assert_eq!(s.cluster.total_gpus(), 16);
//! assert_eq!((s.parallelism.tp, s.parallelism.pp, s.parallelism.dp), (4, 2, 2));
//! assert_eq!(s.schedule, hetsim::workload::schedule::ScheduleKind::OneFOneB);
//! assert!(s.per_group_tp.is_none());
//! ```
//!
//! ```
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_variable_tp.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! assert_eq!(s.cluster.total_gpus(), 8); // fig3: 4×H100 + 4×A100
//! assert_eq!(s.per_group_tp, Some(vec![vec![3, 1], vec![4]]));
//! // derived informational maxima: max TP, max pipeline depth, groups
//! assert_eq!((s.parallelism.tp, s.parallelism.pp, s.parallelism.dp), (4, 2, 2));
//! // the spec it builds is the paper's Fig-3 rank layout
//! let fw = hetsim::workload::partition::plan_variable_tp(
//!     &s.model, &s.cluster, s.per_group_tp.as_deref().unwrap(), true).unwrap();
//! assert_eq!(fw.groups[0].stages[0].ranks, vec![0, 1, 2]);
//! ```
//!
//! ```
//! use hetsim::config::cluster::FabricSpec;
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_spine_mixed_nodes.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! // one 4-GPU A100 node beside one 8-GPU H100 node …
//! assert_eq!(s.cluster.total_gpus(), 12);
//! assert_eq!(s.cluster.uniform_gpus_per_node(), None);
//! // … on a 2-spine leaf/spine fabric oversubscribed 4:1
//! assert_eq!(s.cluster.fabric, FabricSpec::LeafSpine { spines: 2, oversubscription: 4.0 });
//! // per-node TP splits matching each node's actual GPU count
//! assert_eq!(s.per_group_tp, Some(vec![vec![4], vec![4, 4]]));
//! ```
//!
//! ```
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_faults.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! let faults = s.faults.expect("the canonical fault scenario injects faults");
//! // a straggler from iteration start plus a mid-iteration fail-stop
//! assert_eq!(faults.events.len(), 2);
//! assert!(faults.events.iter().any(|e| e.kind.name() == "straggler"));
//! assert!(faults.events.iter().any(|e| e.kind.is_fail_stop()));
//! assert_eq!(faults.checkpoint.interval_iters, 16);
//! ```
//!
//! ```
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_correlated_faults.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! let faults = s.faults.expect("the correlated-fault scenario injects faults");
//! // two explicit repairable outages, plus any drawn rack-level events
//! assert!(faults.events.iter().any(|e| e.kind.name() == "nic_fail"));
//! assert!(faults.events.iter().any(|e| e.kind.name() == "link_fail"));
//! assert_eq!(faults.repair.nic_s, 120.0);
//! assert_eq!(faults.domains.unwrap().rack_size, 2);
//! assert_eq!(faults.monte_carlo, 8);
//! ```
//!
//! ```
//! use hetsim::workload::serve::ServePolicy;
//! let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_serving.json");
//! let text = std::fs::read_to_string(path).unwrap();
//! let s = hetsim::config::loader::load_scenario(&text).unwrap();
//! let serving = s.serving.expect("the canonical serving scenario carries traffic");
//! assert_eq!(serving.policy, ServePolicy::Srpt);
//! // two pinned requests on top of the Poisson arrivals
//! assert_eq!(serving.requests.len(), 2);
//! assert_eq!(serving.poisson.as_ref().unwrap().rate_per_s, 4.0);
//! assert_eq!(serving.seed, 7, "serving seed defaults to the scenario seed");
//! assert!(!serving.materialize().is_empty());
//! ```

use crate::config::cluster::{ClusterSpec, FabricSpec};
use crate::config::framework::ParallelismSpec;
use crate::config::model::{ModelSpec, MoeSpec};
use crate::config::presets;
use crate::system::failure::FaultSpec;
use crate::system::fold::FoldMode;
use crate::util::json::Json;
use crate::workload::schedule::ScheduleKind;
use crate::workload::serve::ServeSpec;

/// A fully-described simulation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Model hyperparameters (Table 6 fields).
    pub model: ModelSpec,
    /// Cluster / host-topology description (Table 5 fields).
    pub cluster: ClusterSpec,
    /// Parallelism degrees to deploy. For per-group TP scenarios these
    /// are the derived informational maxima; `per_group_tp` is
    /// authoritative.
    pub parallelism: ParallelismSpec,
    /// Explicit per-group TP degrees (one split per cluster node, the
    /// `parallelism.groups[].tp` form), when the scenario uses them.
    pub per_group_tp: Option<Vec<Vec<u32>>>,
    /// Pipeline schedule for every device group.
    pub schedule: ScheduleKind,
    /// Symmetry-folding mode ([`crate::system::fold`]).
    pub fold: FoldMode,
    /// Injected fault schedule ([`crate::system::failure`]), when the
    /// scenario carries a `"faults"` key with at least one event.
    pub faults: Option<FaultSpec>,
    /// Serving workload ([`crate::workload::serve`]), when the scenario
    /// carries a `"serving"` key that generates at least one request
    /// source.
    pub serving: Option<ServeSpec>,
    /// Seeds stochastic extensions (the MTBF fault-schedule draw and
    /// the serving Poisson draw); everything else in the simulator is
    /// deterministic.
    pub seed: u64,
}

/// Read and parse a scenario file (see the module docs for the format).
pub fn load_scenario_file(path: &std::path::Path) -> anyhow::Result<Scenario> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    load_scenario(&text)
}

/// Parse a scenario from JSON text (see the module docs for the format).
pub fn load_scenario(text: &str) -> anyhow::Result<Scenario> {
    let v = Json::parse(text)?;
    let model = parse_model(v.req("model")?)?;
    let mut cluster = parse_cluster(v.req("cluster")?)?;
    if let Some(f) = v.get("fabric") {
        cluster.fabric = parse_fabric(f)?;
    }
    let pv = v.req("parallelism")?;
    let per_group_tp = parse_per_group_tp(pv)?;
    let parallelism = match &per_group_tp {
        Some(splits) => ParallelismSpec {
            tp: splits.iter().flatten().copied().max().unwrap_or(1),
            pp: splits.iter().map(Vec::len).max().unwrap_or(1) as u32,
            dp: splits.len() as u32,
        },
        None => parse_parallelism(pv)?,
    };
    let schedule: ScheduleKind = v.opt_str("schedule", "gpipe").parse()?;
    let fold = FoldMode::parse(v.opt_str("fold", "off"))?;
    let seed = v.opt_u64("seed", 42);
    model.validate()?;
    cluster.validate()?;
    // parsed after cluster validation: event node indices are checked
    // against the resolved cluster; an eventless spec normalizes away
    let faults = match v.get("faults") {
        Some(f) => Some(FaultSpec::from_json(f, &cluster, seed)?).filter(|s| !s.is_empty()),
        None => None,
    };
    let serving = match v.get("serving") {
        Some(s) => Some(ServeSpec::from_json(s, seed)?).filter(|s| !s.is_empty()),
        None => None,
    };
    Ok(Scenario { model, cluster, parallelism, per_group_tp, schedule, fold, faults, serving, seed })
}

/// Parse the `model` section: a preset name or an inline Table-6
/// object.
pub fn parse_model(v: &Json) -> anyhow::Result<ModelSpec> {
    if let Some(name) = v.as_str() {
        return presets::model(name);
    }
    // inline object; start from defaults for optional training fields
    let moe = match v.get("num_experts") {
        Some(n) => Some(MoeSpec {
            num_experts: n.as_u64().unwrap_or(0) as u32,
            top_k: v.opt_u64("top_k", 2) as u32,
        }),
        None => None,
    };
    Ok(ModelSpec {
        name: v.opt_str("name", "custom").to_string(),
        num_layers: v.req_u64("num_layers")? as u32,
        hidden_size: v.req_u64("hidden_size")?,
        num_heads: v.req_u64("num_heads")? as u32,
        ffn_hidden: v.req_u64("ffn_hidden")?,
        seq_len: v.req_u64("seq_len")?,
        max_pos_embeddings: v.opt_u64("max_pos_embeddings", v.req_u64("seq_len")?),
        vocab_size: v.opt_u64("vocab_size", 50257),
        moe,
        gated_mlp: v.get("gated_mlp").and_then(|b| b.as_bool()).unwrap_or(false),
        global_batch: v.req_u64("global_batch")?,
        micro_batch: v.req_u64("micro_batch")?,
        grad_dtype_bytes: v.opt_u64("grad_dtype_bytes", 4),
        dtype_bytes: v.opt_u64("dtype_bytes", 2),
    })
}

/// Split an optional `@G` node-size suffix off a count or architecture
/// token: `"2@4"` → (`"2"`, `Some(4)`), `"hopper"` → (`"hopper"`, `None`).
fn split_gpn(token: &str) -> anyhow::Result<(&str, Option<u32>)> {
    match token.split_once('@') {
        None => Ok((token.trim(), None)),
        Some((head, g)) => {
            let g: u32 = g.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad node-size suffix '@{g}' in '{token}' (expected @<gpus>)")
            })?;
            anyhow::ensure!(g >= 1, "node size in '{token}' must be >= 1");
            Ok((head.trim(), Some(g)))
        }
    }
}

/// Parse the `cluster` section: a shorthand string or an inline object
/// (see the module docs for the accepted shapes). Node counts accept an
/// `@G` suffix overriding the default 8-GPU node size.
pub fn parse_cluster(v: &Json) -> anyhow::Result<ClusterSpec> {
    if let Some(name) = v.as_str() {
        // the paper's Fig-3 cluster: one 4×H100 node + one 4×A100 node
        if name == "fig3" {
            return crate::workload::partition::fig3_cluster();
        }
        // "hetero:A[@G],H[@G]" shorthand: A ampere nodes + H hopper nodes
        if let Some(rest) = name.strip_prefix("hetero:") {
            let (a, h) = rest.split_once(',').ok_or_else(|| {
                anyhow::anyhow!("hetero shorthand is 'hetero:<ampere>,<hopper>', got '{name}'")
            })?;
            let (a, ga) = split_gpn(a)?;
            let (h, gh) = split_gpn(h)?;
            let (a, h): (u32, u32) = (a.parse()?, h.parse()?);
            let mut c = presets::cluster_hetero(a, h)?;
            for (i, n) in c.nodes.iter_mut().enumerate() {
                let g = if (i as u32) < a { ga } else { gh };
                if let Some(g) = g {
                    n.gpus_per_node = g;
                }
            }
            return Ok(c);
        }
        // "ampere:16" / "ampere:2@4" shorthand
        let (arch, n) = name.split_once(':').unwrap_or((name, "16"));
        let (n, gpn) = split_gpn(n)?;
        let mut c = presets::cluster(arch, n.parse()?)?;
        if let Some(g) = gpn {
            for node in &mut c.nodes {
                node.gpus_per_node = g;
            }
        }
        return Ok(c);
    }
    let arch = v.req_str("arch")?;
    match arch {
        "hetero" => presets::cluster_hetero(
            v.opt_u64("ampere_nodes", 8) as u32,
            v.opt_u64("hopper_nodes", 8) as u32,
        ),
        "custom" => {
            // explicit per-node architecture list, optional @G sizes
            let list = v
                .req("node_archs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("node_archs must be an array"))?;
            let mut nodes = Vec::new();
            for a in list {
                let entry =
                    a.as_str().ok_or_else(|| anyhow::anyhow!("node_archs entries are strings"))?;
                let (arch, gpn) = split_gpn(entry)?;
                let c = presets::cluster(arch, 1)?;
                let mut node = c.nodes[0].clone();
                if let Some(g) = gpn {
                    node.gpus_per_node = g;
                }
                nodes.push(node);
            }
            let mut c = presets::cluster("ampere", 1)?;
            c.name = v.opt_str("name", "custom").to_string();
            c.nodes = nodes;
            Ok(c)
        }
        _ => presets::cluster(arch, v.opt_u64("nodes", 16) as u32),
    }
}

/// Parse the optional `fabric` section: a shorthand string
/// (`"rail" | "switch" | "spine:S,OS"`, [`FabricSpec::parse`]) or an
/// object `{"kind": "rail" | "switch" | "leafspine", "spines": S,
/// "oversubscription": OS}`.
pub fn parse_fabric(v: &Json) -> anyhow::Result<FabricSpec> {
    if let Some(s) = v.as_str() {
        return FabricSpec::parse(s);
    }
    let kind = v.req_str("kind")?;
    let f = match kind {
        "rail" => FabricSpec::RailOnly,
        "switch" => FabricSpec::SingleSwitch,
        "leafspine" | "spine" => {
            // present-but-malformed values must error, not silently
            // fall back to defaults (a wrong fabric would be simulated)
            let spines = match v.get("spines") {
                None => 1,
                Some(s) => s.as_u64().ok_or_else(|| {
                    anyhow::anyhow!("fabric.spines must be an unsigned integer")
                })? as u32,
            };
            let oversubscription = match v.get("oversubscription") {
                None => 1.0,
                Some(o) => o.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("fabric.oversubscription must be a number")
                })?,
            };
            FabricSpec::LeafSpine { spines, oversubscription }
        }
        other => anyhow::bail!("unknown fabric kind '{other}' (rail | switch | leafspine)"),
    };
    f.validate()?;
    Ok(f)
}

/// Parse the `parallelism` section (`tp`, `pp`, `dp`, all required).
pub fn parse_parallelism(v: &Json) -> anyhow::Result<ParallelismSpec> {
    Ok(ParallelismSpec {
        tp: v.req_u64("tp")? as u32,
        pp: v.req_u64("pp")? as u32,
        dp: v.req_u64("dp")? as u32,
    })
}

/// Parse the per-group TP form of the `parallelism` section
/// (`{"groups": [{"tp": [3, 1]}, ...]}`); `Ok(None)` when the section
/// uses the classic grid form instead.
pub fn parse_per_group_tp(v: &Json) -> anyhow::Result<Option<Vec<Vec<u32>>>> {
    let Some(groups) = v.get("groups") else {
        return Ok(None);
    };
    let list = groups
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("parallelism.groups must be an array"))?;
    anyhow::ensure!(!list.is_empty(), "parallelism.groups is empty");
    let mut splits = Vec::with_capacity(list.len());
    for (i, g) in list.iter().enumerate() {
        let tps = g
            .req("tp")
            .map_err(|_| anyhow::anyhow!("parallelism.groups[{i}] needs a \"tp\" array"))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("parallelism.groups[{i}].tp must be an array"))?;
        anyhow::ensure!(!tps.is_empty(), "parallelism.groups[{i}].tp is empty");
        let mut split = Vec::with_capacity(tps.len());
        for t in tps {
            let tp = t.as_u64().ok_or_else(|| {
                anyhow::anyhow!("parallelism.groups[{i}].tp entries must be unsigned ints")
            })?;
            anyhow::ensure!(
                (1..=u64::from(u32::MAX)).contains(&tp),
                "parallelism.groups[{i}]: TP degree {tp} out of range (>= 1, fits u32)"
            );
            split.push(tp as u32);
        }
        splits.push(split);
    }
    Ok(Some(splits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_scenario() {
        let s = load_scenario(
            r#"{"model": "gpt-6.7b",
                "cluster": {"arch": "hopper", "nodes": 16},
                "parallelism": {"tp": 4, "pp": 1, "dp": 32}}"#,
        )
        .unwrap();
        assert_eq!(s.model.name, "GPT-6.7B");
        assert_eq!(s.cluster.total_gpus(), 128);
        assert_eq!(s.parallelism.world_size(), 128);
        assert_eq!(s.seed, 42);
    }

    #[test]
    fn hetero_cluster_scenario() {
        let s = load_scenario(
            r#"{"model": "gpt-13b",
                "cluster": {"arch": "hetero", "ampere_nodes": 16, "hopper_nodes": 16},
                "parallelism": {"tp": 8, "pp": 1, "dp": 32},
                "seed": 7}"#,
        )
        .unwrap();
        assert!(!s.cluster.is_homogeneous());
        assert_eq!(s.seed, 7);
    }

    #[test]
    fn inline_model() {
        let s = load_scenario(
            r#"{"model": {"name": "tiny", "num_layers": 4, "hidden_size": 512,
                          "num_heads": 8, "ffn_hidden": 2048, "seq_len": 128,
                          "global_batch": 32, "micro_batch": 2},
                "cluster": "ampere:1",
                "parallelism": {"tp": 2, "pp": 2, "dp": 2}}"#,
        )
        .unwrap();
        assert_eq!(s.model.num_layers, 4);
        assert_eq!(s.cluster.total_gpus(), 8);
    }

    #[test]
    fn inline_moe_model() {
        let m = parse_model(
            &Json::parse(
                r#"{"num_layers": 8, "hidden_size": 1024, "num_heads": 16,
                    "ffn_hidden": 4096, "seq_len": 256, "global_batch": 64,
                    "micro_batch": 4, "num_experts": 8, "top_k": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(m.moe.unwrap().num_experts, 8);
    }

    #[test]
    fn hetero_shorthand_cluster() {
        let c = parse_cluster(&Json::Str("hetero:1,1".into())).unwrap();
        assert!(!c.is_homogeneous());
        assert_eq!(c.total_gpus(), 16);
        let c = parse_cluster(&Json::Str("hetero:2, 3".into())).unwrap();
        assert_eq!(c.nodes.len(), 5);
        assert!(parse_cluster(&Json::Str("hetero:2".into())).is_err());
    }

    #[test]
    fn custom_node_list() {
        let c = parse_cluster(
            &Json::parse(r#"{"arch": "custom", "node_archs": ["ampere", "hopper", "ampere"]}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.gpu_types(), vec!["A100", "H100"]);
    }

    #[test]
    fn fold_key_parsed_with_off_default() {
        let base = r#"{"model": "gpt-6.7b", "cluster": "hopper:4",
            "parallelism": {"tp": 8, "pp": 1, "dp": 4}%FOLD%}"#;
        let s = load_scenario(&base.replace("%FOLD%", "")).unwrap();
        assert_eq!(s.fold, FoldMode::Off);
        let s = load_scenario(&base.replace("%FOLD%", r#", "fold": "auto""#)).unwrap();
        assert_eq!(s.fold, FoldMode::Auto);
        assert!(load_scenario(&base.replace("%FOLD%", r#", "fold": "always""#)).is_err());
    }

    #[test]
    fn schedule_key_parsed_with_gpipe_default() {
        let base = r#"{"model": "gpt-6.7b", "cluster": "hetero:1,1",
            "parallelism": {"tp": 4, "pp": 2, "dp": 2}%SCHED%}"#;
        let s = load_scenario(&base.replace("%SCHED%", "")).unwrap();
        assert_eq!(s.schedule, ScheduleKind::GPipe);
        let s =
            load_scenario(&base.replace("%SCHED%", r#", "schedule": "1f1b""#)).unwrap();
        assert_eq!(s.schedule, ScheduleKind::OneFOneB);
        let s = load_scenario(&base.replace("%SCHED%", r#", "schedule": "interleaved:4""#))
            .unwrap();
        assert_eq!(s.schedule, ScheduleKind::Interleaved1F1B { vpp: 4 });
        assert!(load_scenario(&base.replace("%SCHED%", r#", "schedule": "zigzag""#)).is_err());
    }

    #[test]
    fn example_config_loads() {
        // the file the module docs point at must stay loadable
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_hetero_1f1b.json");
        let s = load_scenario_file(std::path::Path::new(path)).unwrap();
        assert_eq!(s.parallelism.world_size(), s.cluster.total_gpus());
        assert_eq!(s.schedule, ScheduleKind::OneFOneB);
    }

    #[test]
    fn variable_tp_example_config_builds_the_fig3_spec() {
        // the per-group-TP reference example must stay loadable AND
        // buildable into a valid framework spec
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/scenario_variable_tp.json");
        let s = load_scenario_file(std::path::Path::new(path)).unwrap();
        let splits = s.per_group_tp.clone().unwrap();
        assert_eq!(splits, vec![vec![3, 1], vec![4]]);
        let fw = crate::workload::partition::plan_variable_tp(
            &s.model, &s.cluster, &splits, true,
        )
        .unwrap();
        fw.validate(&s.model, &s.cluster).unwrap();
    }

    #[test]
    fn per_group_tp_scenarios_parse_and_derive_maxima() {
        let s = load_scenario(
            r#"{"model": "fig3", "cluster": "fig3",
                "parallelism": {"groups": [{"tp": [3, 1]}, {"tp": [4]}]}}"#,
        )
        .unwrap();
        assert_eq!(s.per_group_tp, Some(vec![vec![3, 1], vec![4]]));
        assert_eq!((s.parallelism.tp, s.parallelism.pp, s.parallelism.dp), (4, 2, 2));
        // malformed group lists are rejected with clear errors
        for bad in [
            r#"{"groups": []}"#,
            r#"{"groups": [{"tp": []}]}"#,
            r#"{"groups": [{"tp": [0, 4]}]}"#,
            r#"{"groups": [{"pp": 2}]}"#,
            // does not fit u32: must error, not silently truncate
            r#"{"groups": [{"tp": [4294967297, 1]}, {"tp": [4]}]}"#,
        ] {
            let text = format!(
                r#"{{"model": "fig3", "cluster": "fig3", "parallelism": {bad}}}"#
            );
            assert!(load_scenario(&text).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn fig3_cluster_shorthand() {
        let c = parse_cluster(&Json::Str("fig3".into())).unwrap();
        assert_eq!(c.total_gpus(), 8);
        assert!(!c.is_homogeneous());
        assert_eq!(c.uniform_gpus_per_node(), Some(4));
    }

    #[test]
    fn node_size_suffix_on_shorthands() {
        let c = parse_cluster(&Json::Str("ampere:2@4".into())).unwrap();
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(c.uniform_gpus_per_node(), Some(4));
        let c = parse_cluster(&Json::Str("hetero:1@4,1".into())).unwrap();
        assert_eq!(c.total_gpus(), 12);
        assert_eq!(c.nodes[0].gpus_per_node, 4);
        assert_eq!(c.nodes[1].gpus_per_node, 8);
        c.validate().unwrap();
        let c = parse_cluster(
            &Json::parse(r#"{"arch": "custom", "node_archs": ["ampere@4", "hopper"]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.nodes[0].gpus_per_node, 4);
        assert_eq!(c.nodes[1].gpus_per_node, 8);
        for bad in ["ampere:2@0", "ampere:2@x", "hetero:1@,1"] {
            assert!(parse_cluster(&Json::Str(bad.into())).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn fabric_key_parses_both_forms() {
        let base = r#"{"model": "gpt-6.7b", "cluster": "hetero:1,1",
            "parallelism": {"tp": 4, "pp": 2, "dp": 2}%FAB%}"#;
        let s = load_scenario(&base.replace("%FAB%", "")).unwrap();
        assert_eq!(s.cluster.fabric, FabricSpec::RailOnly);
        let s = load_scenario(&base.replace("%FAB%", r#", "fabric": "switch""#)).unwrap();
        assert_eq!(s.cluster.fabric, FabricSpec::SingleSwitch);
        let s = load_scenario(&base.replace("%FAB%", r#", "fabric": "spine:2,4""#)).unwrap();
        assert_eq!(
            s.cluster.fabric,
            FabricSpec::LeafSpine { spines: 2, oversubscription: 4.0 }
        );
        let s = load_scenario(&base.replace(
            "%FAB%",
            r#", "fabric": {"kind": "leafspine", "spines": 3, "oversubscription": 2}"#,
        ))
        .unwrap();
        assert_eq!(
            s.cluster.fabric,
            FabricSpec::LeafSpine { spines: 3, oversubscription: 2.0 }
        );
        assert!(load_scenario(&base.replace("%FAB%", r#", "fabric": "mesh""#)).is_err());
        assert!(load_scenario(&base.replace("%FAB%", r#", "fabric": "spine:0""#)).is_err());
        // present-but-malformed object values error instead of
        // silently simulating a default fabric
        for bad in [
            r#", "fabric": {"kind": "leafspine", "spines": "4"}"#,
            r#", "fabric": {"kind": "leafspine", "spines": 2.5}"#,
            r#", "fabric": {"kind": "leafspine", "spines": 2, "oversubscription": "2"}"#,
            r#", "fabric": {"kind": "leafspine", "spines": 0}"#,
        ] {
            assert!(load_scenario(&base.replace("%FAB%", bad)).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn faults_key_parsed_and_eventless_spec_normalized_away() {
        let base = r#"{"model": "gpt-6.7b", "cluster": "hopper:2",
            "parallelism": {"tp": 8, "pp": 1, "dp": 2}%F%}"#;
        let s = load_scenario(&base.replace("%F%", "")).unwrap();
        assert!(s.faults.is_none());
        let s = load_scenario(&base.replace(
            "%F%",
            r#", "faults": {"events": [{"at_s": 1.5, "kind": "node_fail", "node": 1}]}"#,
        ))
        .unwrap();
        let f = s.faults.unwrap();
        assert_eq!(f.events.len(), 1);
        assert_eq!(f.seed, 42, "fault seed defaults to the scenario seed");
        // a checkpoint-only spec injects nothing → normalized to None
        let s = load_scenario(
            &base.replace("%F%", r#", "faults": {"checkpoint": {"interval_iters": 8}}"#),
        )
        .unwrap();
        assert!(s.faults.is_none());
        // event node indices are validated against the resolved cluster
        assert!(load_scenario(&base.replace(
            "%F%",
            r#", "faults": {"events": [{"at_s": 1.0, "kind": "node_fail", "node": 9}]}"#,
        ))
        .is_err());
    }

    #[test]
    fn serving_key_parsed_and_empty_spec_normalized_away() {
        let base = r#"{"model": "gpt-6.7b", "cluster": "hetero:1,1",
            "parallelism": {"tp": 8, "pp": 1, "dp": 2}, "seed": 11%S%}"#;
        let s = load_scenario(&base.replace("%S%", "")).unwrap();
        assert!(s.serving.is_none());
        let s = load_scenario(&base.replace(
            "%S%",
            r#", "serving": {"policy": "wsrpt",
                "poisson": {"rate_per_s": 2.5, "horizon_s": 3.0},
                "requests": [{"arrival_s": 0.5, "prompt_tokens": 64, "output_tokens": 8}]}"#,
        ))
        .unwrap();
        let spec = s.serving.unwrap();
        assert_eq!(spec.policy, crate::workload::serve::ServePolicy::Wsrpt);
        assert_eq!(spec.requests.len(), 1);
        assert_eq!(spec.seed, 11, "serving seed defaults to the scenario seed");
        // a zero-rate scale still counts as a Poisson source; a
        // malformed spec is an error, not a silent default
        assert!(load_scenario(
            &base.replace("%S%", r#", "serving": {"poisson": {"rate_per_s": "fast"}}"#)
        )
        .is_err());
        assert!(load_scenario(&base.replace("%S%", r#", "serving": {}"#)).is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(load_scenario(r#"{"model": "gpt-6.7b"}"#).is_err());
        assert!(load_scenario(r#"{"model": "nope", "cluster": "ampere:1",
            "parallelism": {"tp":1,"pp":1,"dp":8}}"#)
            .is_err());
    }
}
