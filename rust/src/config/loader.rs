//! JSON config loader: the file-based face of abstractions A1/A2.
//!
//! A scenario file bundles model + cluster + parallelism:
//!
//! ```json
//! {
//!   "model": "gpt-6.7b",                 // preset name, or inline object
//!   "cluster": {"arch": "hetero", "ampere_nodes": 8, "hopper_nodes": 8},
//!   "parallelism": {"tp": 4, "pp": 1, "dp": 32},
//!   "seed": 42
//! }
//! ```
//!
//! Inline model objects accept the Table-6 field names; inline clusters
//! accept per-node architecture lists for arbitrary mixes.

use crate::config::cluster::ClusterSpec;
use crate::config::framework::ParallelismSpec;
use crate::config::model::{ModelSpec, MoeSpec};
use crate::config::presets;
use crate::util::json::Json;

/// A fully-described simulation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub model: ModelSpec,
    pub cluster: ClusterSpec,
    pub parallelism: ParallelismSpec,
    pub seed: u64,
}

pub fn load_scenario_file(path: &std::path::Path) -> anyhow::Result<Scenario> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    load_scenario(&text)
}

pub fn load_scenario(text: &str) -> anyhow::Result<Scenario> {
    let v = Json::parse(text)?;
    let model = parse_model(v.req("model")?)?;
    let cluster = parse_cluster(v.req("cluster")?)?;
    let parallelism = parse_parallelism(v.req("parallelism")?)?;
    let seed = v.opt_u64("seed", 42);
    model.validate()?;
    cluster.validate()?;
    Ok(Scenario { model, cluster, parallelism, seed })
}

pub fn parse_model(v: &Json) -> anyhow::Result<ModelSpec> {
    if let Some(name) = v.as_str() {
        return presets::model(name);
    }
    // inline object; start from defaults for optional training fields
    let moe = match v.get("num_experts") {
        Some(n) => Some(MoeSpec {
            num_experts: n.as_u64().unwrap_or(0) as u32,
            top_k: v.opt_u64("top_k", 2) as u32,
        }),
        None => None,
    };
    Ok(ModelSpec {
        name: v.opt_str("name", "custom").to_string(),
        num_layers: v.req_u64("num_layers")? as u32,
        hidden_size: v.req_u64("hidden_size")?,
        num_heads: v.req_u64("num_heads")? as u32,
        ffn_hidden: v.req_u64("ffn_hidden")?,
        seq_len: v.req_u64("seq_len")?,
        max_pos_embeddings: v.opt_u64("max_pos_embeddings", v.req_u64("seq_len")?),
        vocab_size: v.opt_u64("vocab_size", 50257),
        moe,
        gated_mlp: v.get("gated_mlp").and_then(|b| b.as_bool()).unwrap_or(false),
        global_batch: v.req_u64("global_batch")?,
        micro_batch: v.req_u64("micro_batch")?,
        grad_dtype_bytes: v.opt_u64("grad_dtype_bytes", 4),
        dtype_bytes: v.opt_u64("dtype_bytes", 2),
    })
}

pub fn parse_cluster(v: &Json) -> anyhow::Result<ClusterSpec> {
    if let Some(name) = v.as_str() {
        // "hetero:A,H" shorthand: A ampere nodes + H hopper nodes
        if let Some(rest) = name.strip_prefix("hetero:") {
            let (a, h) = rest.split_once(',').ok_or_else(|| {
                anyhow::anyhow!("hetero shorthand is 'hetero:<ampere>,<hopper>', got '{name}'")
            })?;
            return presets::cluster_hetero(a.trim().parse()?, h.trim().parse()?);
        }
        // "ampere:16" shorthand
        let (arch, n) = name.split_once(':').unwrap_or((name, "16"));
        return presets::cluster(arch, n.parse()?);
    }
    let arch = v.req_str("arch")?;
    match arch {
        "hetero" => presets::cluster_hetero(
            v.opt_u64("ampere_nodes", 8) as u32,
            v.opt_u64("hopper_nodes", 8) as u32,
        ),
        "custom" => {
            // explicit per-node architecture list
            let list = v
                .req("node_archs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("node_archs must be an array"))?;
            let mut nodes = Vec::new();
            for a in list {
                let arch =
                    a.as_str().ok_or_else(|| anyhow::anyhow!("node_archs entries are strings"))?;
                let c = presets::cluster(arch, 1)?;
                nodes.push(c.nodes[0].clone());
            }
            let mut c = presets::cluster("ampere", 1)?;
            c.name = v.opt_str("name", "custom").to_string();
            c.nodes = nodes;
            Ok(c)
        }
        _ => presets::cluster(arch, v.opt_u64("nodes", 16) as u32),
    }
}

pub fn parse_parallelism(v: &Json) -> anyhow::Result<ParallelismSpec> {
    Ok(ParallelismSpec {
        tp: v.req_u64("tp")? as u32,
        pp: v.req_u64("pp")? as u32,
        dp: v.req_u64("dp")? as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_scenario() {
        let s = load_scenario(
            r#"{"model": "gpt-6.7b",
                "cluster": {"arch": "hopper", "nodes": 16},
                "parallelism": {"tp": 4, "pp": 1, "dp": 32}}"#,
        )
        .unwrap();
        assert_eq!(s.model.name, "GPT-6.7B");
        assert_eq!(s.cluster.total_gpus(), 128);
        assert_eq!(s.parallelism.world_size(), 128);
        assert_eq!(s.seed, 42);
    }

    #[test]
    fn hetero_cluster_scenario() {
        let s = load_scenario(
            r#"{"model": "gpt-13b",
                "cluster": {"arch": "hetero", "ampere_nodes": 16, "hopper_nodes": 16},
                "parallelism": {"tp": 8, "pp": 1, "dp": 32},
                "seed": 7}"#,
        )
        .unwrap();
        assert!(!s.cluster.is_homogeneous());
        assert_eq!(s.seed, 7);
    }

    #[test]
    fn inline_model() {
        let s = load_scenario(
            r#"{"model": {"name": "tiny", "num_layers": 4, "hidden_size": 512,
                          "num_heads": 8, "ffn_hidden": 2048, "seq_len": 128,
                          "global_batch": 32, "micro_batch": 2},
                "cluster": "ampere:1",
                "parallelism": {"tp": 2, "pp": 2, "dp": 2}}"#,
        )
        .unwrap();
        assert_eq!(s.model.num_layers, 4);
        assert_eq!(s.cluster.total_gpus(), 8);
    }

    #[test]
    fn inline_moe_model() {
        let m = parse_model(
            &Json::parse(
                r#"{"num_layers": 8, "hidden_size": 1024, "num_heads": 16,
                    "ffn_hidden": 4096, "seq_len": 256, "global_batch": 64,
                    "micro_batch": 4, "num_experts": 8, "top_k": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(m.moe.unwrap().num_experts, 8);
    }

    #[test]
    fn hetero_shorthand_cluster() {
        let c = parse_cluster(&Json::Str("hetero:1,1".into())).unwrap();
        assert!(!c.is_homogeneous());
        assert_eq!(c.total_gpus(), 16);
        let c = parse_cluster(&Json::Str("hetero:2, 3".into())).unwrap();
        assert_eq!(c.nodes.len(), 5);
        assert!(parse_cluster(&Json::Str("hetero:2".into())).is_err());
    }

    #[test]
    fn custom_node_list() {
        let c = parse_cluster(
            &Json::parse(r#"{"arch": "custom", "node_archs": ["ampere", "hopper", "ampere"]}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.gpu_types(), vec!["A100", "H100"]);
    }

    #[test]
    fn missing_fields_error() {
        assert!(load_scenario(r#"{"model": "gpt-6.7b"}"#).is_err());
        assert!(load_scenario(r#"{"model": "nope", "cluster": "ampere:1",
            "parallelism": {"tp":1,"pp":1,"dp":8}}"#)
            .is_err());
    }
}
