//! Cluster and host topology description (paper abstraction **A2**,
//! Table 5): GPU compute capability plus per-interconnect bandwidth and
//! delay parameters for NVLink, PCIe and the NIC.

use crate::util::units::{Bandwidth, Time};

/// Compact dense rank index: a global rank in cluster order, used as a
/// direct `Vec` index by the scheduler / workload / network hot paths
/// instead of `HashMap<u32, _>` keys. `RankIdx::NONE` is the vacant
/// sentinel for ranks without a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankIdx(pub u32);

impl RankIdx {
    /// Vacant sentinel (no rank).
    pub const NONE: RankIdx = RankIdx(u32::MAX);

    /// The rank as a `Vec` index.
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }

    /// True for the vacant sentinel.
    #[inline]
    pub fn is_none(self) -> bool {
        self.0 == u32::MAX
    }
}

/// GPU compute descriptor. The `eff_*` factors calibrate the roofline
/// cost model to the paper's measured Fig-5 ratios and MUST mirror
/// `GPU_PRESETS` in `python/compile/model.py` (cross-checked by
/// `rust/tests/integration_runtime.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// GPU model name, e.g. `H100`.
    pub name: String,
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Memory capacity, bytes.
    pub mem_capacity: u64,
    /// Roofline efficiency for MLP-shaped GEMMs.
    pub eff_mlp: f64,
    /// Roofline efficiency for attention-shaped GEMMs.
    pub eff_attn: f64,
    /// Roofline efficiency for embedding lookups.
    pub eff_embed: f64,
    /// Achievable fraction of peak memory bandwidth.
    pub eff_mem: f64,
    /// Kernel launch overhead, seconds.
    pub launch_overhead: f64,
}

impl GpuSpec {
    /// Pack into the 8-field GPU descriptor row the AOT cost model expects.
    pub fn descriptor_row(&self) -> [f32; 8] {
        [
            self.peak_flops as f32,
            self.mem_bw as f32,
            self.eff_mlp as f32,
            self.eff_attn as f32,
            self.eff_embed as f32,
            self.eff_mem as f32,
            self.launch_overhead as f32,
            0.0,
        ]
    }

    /// Relative compute power (used by the non-uniform partitioner);
    /// normalized to A100-class = 1.0 via peak FLOPs.
    pub fn compute_power(&self) -> f64 {
        self.peak_flops * self.eff_mlp
    }
}

/// Interconnect descriptor for one node architecture (paper Table 5).
/// Bandwidths are unidirectional; delays are per traversal.
#[derive(Debug, Clone, PartialEq)]
pub struct InterconnectSpec {
    /// NVLink per-GPU bandwidth (through NVSwitch).
    pub nvlink_bw: Bandwidth,
    /// NVLink per-traversal delay.
    pub nvlink_delay: Time,
    /// PCIe bandwidth GPU <-> PCIe switch.
    pub pcie_bw: Bandwidth,
    /// One PCIe trip latency (inter-node paths pay it twice: GPU->switch
    /// and switch->NIC, per paper §5).
    pub pcie_latency: Time,
    /// NIC line rate.
    pub nic_bw: Bandwidth,
    /// NIC packet-processing delay per traversal.
    pub nic_processing_delay: Time,
    /// Human label, e.g. "ConnectX-6".
    pub nic_name: String,
}

/// One physical server: `gpus_per_node` identical GPUs + one NIC per GPU
/// (rail-optimized, paper Fig 2). Node sizes need not match across the
/// cluster (e.g. 4-GPU Ampere nodes beside 8-GPU Hopper nodes).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// The GPU model every slot of this node carries.
    pub gpu: GpuSpec,
    /// Intra-node and NIC interconnect parameters.
    pub interconnect: InterconnectSpec,
    /// GPU slots (and rail NICs) on this node.
    pub gpus_per_node: u32,
}

/// Inter-node fabric shape: how the per-node NICs reach each other
/// across nodes. [`crate::network::topology::Topology::build`] lowers
/// this into the concrete switch/link graph; `RailOnly` reproduces the
/// paper's Fig-2 rail design byte-identically and is the default.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FabricSpec {
    /// One rail switch per local rank (paper Fig 2): NIC `g` of every
    /// node hangs off rail switch `g`; cross-rail traffic takes an
    /// NVLink hop first. Full bisection along each rail.
    #[default]
    RailOnly,
    /// One non-blocking switch connecting every NIC: any NIC reaches
    /// any NIC in one switch traversal, no rail alignment needed.
    SingleSwitch,
    /// Two-tier leaf/spine: each node's NICs share a leaf switch whose
    /// uplinks to the `spines` spine switches carry the node's
    /// aggregate NIC bandwidth divided by `spines ×
    /// oversubscription` — `oversubscription > 1` models a
    /// bandwidth-tapered (blocking) fabric.
    LeafSpine {
        /// Spine switch count (≥ 1).
        spines: u32,
        /// Uplink taper factor (1.0 = non-blocking, > 1 = blocking).
        oversubscription: f64,
    },
}

impl FabricSpec {
    /// Parse the CLI / scenario shorthand: `rail`, `switch`, or
    /// `spine:S[,OS]` (S spines, oversubscription OS, default 1).
    pub fn parse(s: &str) -> anyhow::Result<FabricSpec> {
        match s {
            "rail" => Ok(FabricSpec::RailOnly),
            "switch" => Ok(FabricSpec::SingleSwitch),
            other => {
                let Some(rest) = other.strip_prefix("spine:") else {
                    anyhow::bail!(
                        "unknown fabric '{other}' (expected rail | switch | spine:S[,OS])"
                    );
                };
                let (spines, os) = match rest.split_once(',') {
                    Some((s, o)) => (s.trim().parse()?, o.trim().parse()?),
                    None => (rest.trim().parse()?, 1.0),
                };
                let f = FabricSpec::LeafSpine { spines, oversubscription: os };
                f.validate()?;
                Ok(f)
            }
        }
    }

    /// Display name in the same shorthand grammar [`FabricSpec::parse`]
    /// accepts.
    pub fn name(&self) -> String {
        match self {
            FabricSpec::RailOnly => "rail".into(),
            FabricSpec::SingleSwitch => "switch".into(),
            FabricSpec::LeafSpine { spines, oversubscription } => {
                format!("spine:{spines},{oversubscription}")
            }
        }
    }

    /// Structural invariants (positive spine count, positive taper).
    pub fn validate(&self) -> anyhow::Result<()> {
        if let FabricSpec::LeafSpine { spines, oversubscription } = self {
            anyhow::ensure!(*spines >= 1, "leaf/spine fabric needs at least 1 spine");
            anyhow::ensure!(
                *oversubscription > 0.0 && oversubscription.is_finite(),
                "oversubscription must be positive and finite (got {oversubscription})"
            );
        }
        Ok(())
    }
}

/// The training cluster: an ordered list of nodes (possibly mixed
/// architectures and node sizes) plus the inter-node fabric shape and
/// switch parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Display name, e.g. `hetero-1a1h`.
    pub name: String,
    /// Nodes in global-rank order (possibly mixed architectures).
    pub nodes: Vec<NodeSpec>,
    /// Inter-node fabric shape (rail-only, single switch, leaf/spine).
    pub fabric: FabricSpec,
    /// Rail/aggregation switch port bandwidth.
    pub switch_bw: Bandwidth,
    /// Switch forwarding delay.
    pub switch_delay: Time,
}

impl ClusterSpec {
    /// World size: total GPUs across all nodes.
    pub fn total_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.gpus_per_node).sum()
    }

    /// The common GPUs-per-node count when every node has the same
    /// size, `None` on mixed-node-size clusters. The explicit
    /// replacement for the old `gpus_per_node()` (which silently
    /// returned the *first* node's count): callers must now say whether
    /// they need the uniform count, the [`Self::min_gpus_per_node`]
    /// floor or the [`Self::gcd_gpus_per_node`] alignment divisor.
    pub fn uniform_gpus_per_node(&self) -> Option<u32> {
        let first = self.nodes.first()?.gpus_per_node;
        self.nodes.iter().all(|n| n.gpus_per_node == first).then_some(first)
    }

    /// Smallest node size (0 for an empty cluster) — the intra-node TP
    /// ceiling every node can honour.
    pub fn min_gpus_per_node(&self) -> u32 {
        self.nodes.iter().map(|n| n.gpus_per_node).min().unwrap_or(0)
    }

    /// Greatest common divisor of all node sizes (0 for an empty
    /// cluster). Any TP degree dividing it keeps contiguous TP blocks
    /// inside node boundaries even when node sizes differ, and the
    /// world size is always divisible by it.
    pub fn gcd_gpus_per_node(&self) -> u32 {
        fn gcd(a: u32, b: u32) -> u32 {
            if b == 0 {
                a
            } else {
                gcd(b, a % b)
            }
        }
        self.nodes.iter().map(|n| n.gpus_per_node).fold(0, gcd)
    }

    /// Node index and local rank for a global rank (paper §2 rank rules).
    pub fn locate(&self, global_rank: u32) -> Option<(u32, u32)> {
        let mut base = 0;
        for (i, n) in self.nodes.iter().enumerate() {
            if global_rank < base + n.gpus_per_node {
                return Some((i as u32, global_rank - base));
            }
            base += n.gpus_per_node;
        }
        None
    }

    /// The node hosting a global rank — [`ClusterSpec::locate`] without
    /// the local-rank half. [`crate::network::topology::Topology`]'s
    /// prefix-sum rank mapping is defined to agree with this for every
    /// rank (enforced by `rust/tests/integration_fabric.rs`).
    pub fn node_of_rank(&self, global_rank: u32) -> Option<u32> {
        self.locate(global_rank).map(|(n, _)| n)
    }

    /// Exclusive prefix sums of node sizes, length `nodes + 1`:
    /// `starts[n]..starts[n + 1]` is node `n`'s global rank range. The
    /// shared basis of rank↔(node, local) mapping for clusters with
    /// non-uniform node sizes.
    pub fn node_starts(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.nodes.len() + 1);
        let mut base = 0;
        v.push(0);
        for n in &self.nodes {
            base += n.gpus_per_node;
            v.push(base);
        }
        v
    }

    /// The node at `idx` (panics when out of range).
    pub fn node(&self, idx: u32) -> &NodeSpec {
        &self.nodes[idx as usize]
    }

    /// The GPU spec hosting a global rank, if the rank exists.
    pub fn gpu_of_rank(&self, global_rank: u32) -> Option<&GpuSpec> {
        self.locate(global_rank).map(|(n, _)| &self.nodes[n as usize].gpu)
    }

    /// Dense per-rank node-index table: `table[rank.idx()]` replaces the
    /// O(nodes) scan of [`ClusterSpec::locate`] on hot paths.
    pub fn rank_nodes(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.total_gpus() as usize);
        for (i, n) in self.nodes.iter().enumerate() {
            v.extend(std::iter::repeat(i as u32).take(n.gpus_per_node as usize));
        }
        v
    }

    /// True if all nodes share one GPU model (the SimAI assumption the
    /// paper relaxes).
    pub fn is_homogeneous(&self) -> bool {
        self.nodes.windows(2).all(|w| w[0].gpu.name == w[1].gpu.name)
    }

    /// Distinct GPU model names, in first-appearance order.
    pub fn gpu_types(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for n in &self.nodes {
            if !seen.contains(&n.gpu.name.as_str()) {
                seen.push(n.gpu.name.as_str());
            }
        }
        seen
    }

    /// Validate structural invariants (non-empty, positive per-node GPU
    /// counts and rates, well-formed fabric parameters). Mixed node
    /// sizes are valid on every fabric — the topology builder maps
    /// ranks through prefix sums, not a uniform divisor.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.nodes.is_empty(), "cluster has no nodes");
        self.fabric.validate()?;
        for (i, n) in self.nodes.iter().enumerate() {
            anyhow::ensure!(n.gpus_per_node > 0, "node {i}: gpus_per_node must be positive");
            anyhow::ensure!(n.gpu.peak_flops > 0.0, "node {i}: peak_flops must be positive");
            anyhow::ensure!(n.gpu.mem_bw > 0.0, "node {i}: mem_bw must be positive");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn locate_ranks() {
        let c = presets::cluster("ampere", 2).unwrap();
        assert_eq!(c.locate(0), Some((0, 0)));
        assert_eq!(c.locate(7), Some((0, 7)));
        assert_eq!(c.locate(8), Some((1, 0)));
        assert_eq!(c.locate(15), Some((1, 7)));
        assert_eq!(c.locate(16), None);
    }

    #[test]
    fn homogeneous_detection() {
        assert!(presets::cluster("ampere", 2).unwrap().is_homogeneous());
        assert!(presets::cluster("hopper", 2).unwrap().is_homogeneous());
        assert!(!presets::cluster_hetero(2, 2).unwrap().is_homogeneous());
    }

    #[test]
    fn hetero_has_both_types() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        let types = c.gpu_types();
        assert!(types.contains(&"A100") && types.contains(&"H100"));
        assert_eq!(c.total_gpus(), 32);
    }

    #[test]
    fn descriptor_row_mirrors_spec() {
        let c = presets::cluster("hopper", 1).unwrap();
        let row = c.nodes[0].gpu.descriptor_row();
        assert_eq!(row[0], 989.0e12_f32);
        assert_eq!(row[1], 3350.0e9_f32);
    }

    #[test]
    fn compute_power_orders_generations() {
        let a = presets::gpu("A100").unwrap();
        let h = presets::gpu("H100").unwrap();
        assert!(h.compute_power() > a.compute_power());
    }

    #[test]
    fn mixed_node_sizes_validate_and_locate() {
        let mut c = presets::cluster_hetero(1, 1).unwrap();
        c.nodes[1].gpus_per_node = 4;
        c.validate().unwrap();
        assert_eq!(c.total_gpus(), 12);
        assert_eq!(c.uniform_gpus_per_node(), None);
        assert_eq!(c.min_gpus_per_node(), 4);
        assert_eq!(c.gcd_gpus_per_node(), 4);
        assert_eq!(c.node_starts(), vec![0, 8, 12]);
        assert_eq!(c.locate(7), Some((0, 7)));
        assert_eq!(c.locate(8), Some((1, 0)));
        assert_eq!(c.locate(11), Some((1, 3)));
        assert_eq!(c.locate(12), None);
        for r in 0..12 {
            assert_eq!(c.node_of_rank(r), c.locate(r).map(|(n, _)| n));
        }
    }

    #[test]
    fn zero_sized_node_rejected() {
        let mut c = presets::cluster_hetero(1, 1).unwrap();
        c.nodes[1].gpus_per_node = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn uniform_gpus_per_node_on_uniform_clusters() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        assert_eq!(c.uniform_gpus_per_node(), Some(8));
        assert_eq!(c.gcd_gpus_per_node(), 8);
    }

    #[test]
    fn fabric_shorthand_parses_and_roundtrips() {
        assert_eq!(FabricSpec::parse("rail").unwrap(), FabricSpec::RailOnly);
        assert_eq!(FabricSpec::parse("switch").unwrap(), FabricSpec::SingleSwitch);
        assert_eq!(
            FabricSpec::parse("spine:2,4").unwrap(),
            FabricSpec::LeafSpine { spines: 2, oversubscription: 4.0 }
        );
        assert_eq!(
            FabricSpec::parse("spine:3").unwrap(),
            FabricSpec::LeafSpine { spines: 3, oversubscription: 1.0 }
        );
        for bad in ["fat-tree", "spine:0", "spine:2,-1", "spine:2,0"] {
            assert!(FabricSpec::parse(bad).is_err(), "{bad} accepted");
        }
        for f in ["rail", "switch", "spine:2,4"] {
            assert_eq!(FabricSpec::parse(f).unwrap().name(), f);
        }
    }

    #[test]
    fn table5_interconnect_values() {
        // Paper Table 5 spot checks.
        let a = presets::cluster("ampere", 1).unwrap();
        let ic = &a.nodes[0].interconnect;
        assert!((ic.nvlink_bw.gbps() - 4800.0).abs() < 1e-6);
        assert!((ic.nvlink_delay.as_ns() - 30.66).abs() < 0.01);
        assert!((ic.pcie_latency.as_ns() - 287.5).abs() < 0.01);
        assert!((ic.nic_processing_delay.as_ns() - 368.0).abs() < 0.01);
        let h = presets::cluster("hopper", 1).unwrap();
        let ic = &h.nodes[0].interconnect;
        assert!((ic.nvlink_bw.gbps() - 7200.0).abs() < 1e-6);
        assert!((ic.nvlink_delay.as_ns() - 20.44).abs() < 0.01);
        assert!((ic.pcie_latency.as_ns() - 143.75).abs() < 0.01);
    }
}
