//! Framework parameters (paper abstraction **A1**): custom device
//! groups, hybrid parallelism degrees and the parallelism→device-group
//! mapping, including non-uniform batch shares, layer splits and
//! variable TP degrees (paper Fig 3).

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::workload::schedule::ScheduleKind;

/// Paper-style device-group description:
/// `DG = {(gpu_type_1, count_1), ..., (gpu_type_N, count_N)}`.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceGroupSpec {
    /// `(gpu type, count)` pairs forming the group.
    pub members: Vec<(String, u32)>,
}

impl DeviceGroupSpec {
    /// Total GPU count across all member types.
    pub fn total(&self) -> u32 {
        self.members.iter().map(|(_, c)| c).sum()
    }

    /// Paper notation, e.g. `(HH,A)` for 2×H100 + 1×A100.
    pub fn label(&self) -> String {
        let parts: Vec<String> = self
            .members
            .iter()
            .map(|(t, c)| {
                let letter = t.chars().next().unwrap_or('?');
                std::iter::repeat(letter).take(*c as usize).collect::<String>()
            })
            .collect();
        format!("({})", parts.join(","))
    }
}

/// Base (uniform) parallelism degrees.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismSpec {
    /// Tensor-parallel degree (ranks per pipeline stage).
    pub tp: u32,
    /// Pipeline-parallel degree (stages per device group).
    pub pp: u32,
    /// Data-parallel degree (device groups / model replicas).
    pub dp: u32,
}

impl ParallelismSpec {
    /// Total ranks this parallelism occupies: `tp × pp × dp`.
    pub fn world_size(&self) -> u32 {
        self.tp * self.pp * self.dp
    }
}

/// One pipeline stage: the TP group computing one model slice.
#[derive(Debug, Clone, PartialEq)]
pub struct StagePlan {
    /// Global ranks forming the TP group (len == tp degree).
    pub ranks: Vec<u32>,
    /// Transformer blocks assigned to this stage.
    pub num_layers: u32,
    /// Whether this stage also hosts the embedding layer.
    pub has_embedding: bool,
}

impl StagePlan {
    /// This stage's TP degree (its rank count).
    pub fn tp(&self) -> u32 {
        self.ranks.len() as u32
    }
}

/// One device group = one pipeline = one DP replica (paper §3:
/// "a device group refers to a collection of GPU nodes that divide the
/// model for a given batch size to form a pipeline").
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceGroupPlan {
    /// Group id (equals the DP replica index in uniform mappings).
    pub id: u32,
    /// Pipeline stages in order; each stage is one TP group.
    pub stages: Vec<StagePlan>,
    /// Samples of the global batch this replica trains per iteration
    /// (non-uniform across groups in heterogeneous deployments).
    pub batch_share: u64,
    /// Microbatch size this group runs.
    pub micro_batch: u64,
}

impl DeviceGroupPlan {
    /// Pipeline depth of this group.
    pub fn pp(&self) -> u32 {
        self.stages.len() as u32
    }

    /// All global ranks in the group, stage-major.
    pub fn ranks(&self) -> Vec<u32> {
        self.stages.iter().flat_map(|s| s.ranks.iter().copied()).collect()
    }

    /// Microbatches this group runs per iteration (≥ 1).
    pub fn num_microbatches(&self) -> u64 {
        (self.batch_share / self.micro_batch.max(1)).max(1)
    }
}

/// Split `total` into `parts` non-negative integers that sum to `total`
/// and differ by at most one (earlier parts take the remainder).
pub fn split_evenly(total: u64, parts: u64) -> Vec<u64> {
    let parts = parts.max(1);
    let base = total / parts;
    let rem = total % parts;
    (0..parts).map(|i| base + u64::from(i < rem)).collect()
}

/// Full framework specification: the parallelism→device mapping for the
/// whole cluster plus the pipeline schedule every group runs.
#[derive(Debug, Clone, PartialEq)]
pub struct FrameworkSpec {
    /// One plan per device group (= DP replica).
    pub groups: Vec<DeviceGroupPlan>,
    /// Degrees this spec was derived from (informational for reports).
    pub base: ParallelismSpec,
    /// Pipeline schedule ordering each group's microbatches
    /// ([`ScheduleKind::GPipe`] reproduces the seed behavior exactly).
    pub schedule: ScheduleKind,
}

impl FrameworkSpec {
    /// Uniform mapping (the homogeneous SimAI behaviour): contiguous
    /// rank blocks, equal layer splits, equal batch shares.
    /// Rank layout follows Megatron order: TP fastest, then PP, then DP.
    pub fn uniform(
        model: &ModelSpec,
        cluster: &ClusterSpec,
        par: ParallelismSpec,
    ) -> anyhow::Result<FrameworkSpec> {
        anyhow::ensure!(
            par.world_size() == cluster.total_gpus(),
            "parallelism world size {} != cluster GPUs {}",
            par.world_size(),
            cluster.total_gpus()
        );
        anyhow::ensure!(
            model.num_layers % par.pp == 0,
            "uniform mapping needs layers {} divisible by pp {}",
            model.num_layers,
            par.pp
        );
        let layers_per_stage = model.num_layers / par.pp;
        // Distribute the global batch as evenly as possible (the paper's
        // own Table-6 configs, e.g. 976 over DP=32, do not divide).
        let shares = split_evenly(model.global_batch, par.dp as u64);
        let mut groups = Vec::new();
        for d in 0..par.dp {
            let mut stages = Vec::new();
            for p in 0..par.pp {
                let base = d * par.pp * par.tp + p * par.tp;
                let ranks: Vec<u32> = (base..base + par.tp).collect();
                stages.push(StagePlan {
                    ranks,
                    num_layers: layers_per_stage,
                    has_embedding: p == 0,
                });
            }
            groups.push(DeviceGroupPlan {
                id: d,
                stages,
                batch_share: shares[d as usize],
                micro_batch: model.micro_batch,
            });
        }
        let spec = FrameworkSpec { groups, base: par, schedule: ScheduleKind::GPipe };
        spec.validate(model, cluster)?;
        Ok(spec)
    }

    /// Replace the pipeline schedule (builder-style).
    pub fn with_schedule(mut self, schedule: ScheduleKind) -> FrameworkSpec {
        self.schedule = schedule;
        self
    }

    /// One-line human-readable shape of the mapping, e.g. the paper's
    /// Fig-3 plan renders as
    /// `DG0[TP=3x75L -> TP=1x5L] b16 | DG1[TP=4x80L] b8`.
    /// Used by planner reports and the refinement trajectory.
    pub fn summary(&self) -> String {
        let groups: Vec<String> = self
            .groups
            .iter()
            .map(|g| {
                let stages: Vec<String> = g
                    .stages
                    .iter()
                    .map(|s| format!("TP={}x{}L", s.tp(), s.num_layers))
                    .collect();
                format!("DG{}[{}] b{}", g.id, stages.join(" -> "), g.batch_share)
            })
            .collect();
        groups.join(" | ")
    }

    /// Total ranks mapped across all groups.
    pub fn total_ranks(&self) -> usize {
        self.groups.iter().map(|g| g.ranks().len()).sum()
    }

    /// Canonical identity string of the full mapping: schedule, base
    /// degrees, and every group's batch share, microbatch size and
    /// per-stage (layers, embedding, ranks). Two specs produce the same
    /// fingerprint iff they generate the same workload — the planner's
    /// [`crate::simulator::EvalContext`] keys its compiled-workload and
    /// score caches on it, which is what makes re-scoring a revisited
    /// refinement state free.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = String::with_capacity(64 + 16 * self.total_ranks());
        let _ = write!(
            s,
            "{}|tp{}pp{}dp{}",
            self.schedule.name(),
            self.base.tp,
            self.base.pp,
            self.base.dp
        );
        for g in &self.groups {
            let _ = write!(s, "|g{}b{}m{}", g.id, g.batch_share, g.micro_batch);
            for st in &g.stages {
                let _ = write!(s, ";{}L{}", st.num_layers, if st.has_embedding { "e" } else { "" });
                for r in &st.ranks {
                    let _ = write!(s, ",{r}");
                }
            }
        }
        s
    }

    /// Data-parallel degree (number of device groups).
    pub fn dp(&self) -> u32 {
        self.groups.len() as u32
    }

    /// Validation invariants (used by property tests too):
    /// ranks unique and within the cluster; batch shares sum to the
    /// global batch; every group covers all model layers; every group
    /// has exactly one embedding stage.
    pub fn validate(&self, model: &ModelSpec, cluster: &ClusterSpec) -> anyhow::Result<()> {
        anyhow::ensure!(!self.groups.is_empty(), "no device groups");
        self.schedule.validate()?;
        let mut seen = std::collections::HashSet::new();
        for g in &self.groups {
            anyhow::ensure!(!g.stages.is_empty(), "group {} has no stages", g.id);
            let mut layers = 0;
            let mut embeds = 0;
            for s in &g.stages {
                anyhow::ensure!(!s.ranks.is_empty(), "empty TP group in group {}", g.id);
                layers += s.num_layers;
                embeds += s.has_embedding as u32;
                for r in &s.ranks {
                    anyhow::ensure!(seen.insert(*r), "rank {r} assigned twice");
                    anyhow::ensure!(
                        *r < cluster.total_gpus(),
                        "rank {r} outside cluster of {} GPUs",
                        cluster.total_gpus()
                    );
                }
            }
            anyhow::ensure!(
                layers == model.num_layers,
                "group {} covers {layers} layers, model has {}",
                g.id,
                model.num_layers
            );
            anyhow::ensure!(embeds == 1, "group {} has {embeds} embedding stages", g.id);
            anyhow::ensure!(g.batch_share > 0, "group {} has zero batch share", g.id);
        }
        let total_batch: u64 = self.groups.iter().map(|g| g.batch_share).sum();
        anyhow::ensure!(
            total_batch == model.global_batch,
            "batch shares sum to {total_batch}, global batch is {}",
            model.global_batch
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn uniform_gpt67_layout() {
        // Table 6: GPT-6.7B world=128, TP=4 PP=1 DP=32
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap();
        let par = ParallelismSpec { tp: 4, pp: 1, dp: 32 };
        let f = FrameworkSpec::uniform(&m, &c, par).unwrap();
        assert_eq!(f.groups.len(), 32);
        assert_eq!(f.total_ranks(), 128);
        assert_eq!(f.groups[0].stages[0].ranks, vec![0, 1, 2, 3]);
        assert_eq!(f.groups[1].stages[0].ranks, vec![4, 5, 6, 7]);
        // 976 = 32*30 + 16: first 16 groups take 31, the rest 30
        assert_eq!(f.groups[0].batch_share, 31);
        assert_eq!(f.groups[31].batch_share, 30);
        let total: u64 = f.groups.iter().map(|g| g.batch_share).sum();
        assert_eq!(total, 976);
    }

    #[test]
    fn split_evenly_conserves_and_balances() {
        for (total, parts) in [(976u64, 32u64), (10, 3), (5, 8), (0, 4), (7, 1)] {
            let s = split_evenly(total, parts);
            assert_eq!(s.iter().sum::<u64>(), total);
            let mx = *s.iter().max().unwrap();
            let mn = *s.iter().min().unwrap();
            assert!(mx - mn <= 1, "{s:?}");
        }
    }

    #[test]
    fn uniform_pipeline_ranks_megatron_order() {
        let mut m = presets::model("llama2-70b").unwrap();
        m.global_batch = 64;
        let c = presets::cluster("ampere", 8).unwrap(); // 64 GPUs
        let par = ParallelismSpec { tp: 4, pp: 4, dp: 4 };
        let f = FrameworkSpec::uniform(&m, &c, par).unwrap();
        // group 0 stage 1 starts after stage 0's TP block
        assert_eq!(f.groups[0].stages[1].ranks, vec![4, 5, 6, 7]);
        // only stage 0 has the embedding
        assert!(f.groups[0].stages[0].has_embedding);
        assert!(!f.groups[0].stages[1].has_embedding);
        assert_eq!(f.groups[0].pp(), 4);
    }

    #[test]
    fn world_size_mismatch_rejected() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 2).unwrap(); // 16 GPUs
        let par = ParallelismSpec { tp: 4, pp: 1, dp: 32 };
        assert!(FrameworkSpec::uniform(&m, &c, par).is_err());
    }

    #[test]
    fn validate_catches_duplicate_ranks() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap();
        let par = ParallelismSpec { tp: 4, pp: 1, dp: 32 };
        let mut f = FrameworkSpec::uniform(&m, &c, par).unwrap();
        f.groups[1].stages[0].ranks = vec![0, 1, 2, 3];
        assert!(f.validate(&m, &c).is_err());
    }

    #[test]
    fn validate_catches_batch_mismatch() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap();
        let par = ParallelismSpec { tp: 4, pp: 1, dp: 32 };
        let mut f = FrameworkSpec::uniform(&m, &c, par).unwrap();
        f.groups[0].batch_share += 1;
        assert!(f.validate(&m, &c).is_err());
    }

    #[test]
    fn fingerprint_distinguishes_mappings() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap();
        let par = ParallelismSpec { tp: 4, pp: 1, dp: 32 };
        let a = FrameworkSpec::uniform(&m, &c, par).unwrap();
        assert_eq!(a.fingerprint(), a.fingerprint());
        let mut b = a.clone();
        b.groups[0].batch_share -= 1;
        b.groups[1].batch_share += 1;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let s = a
            .clone()
            .with_schedule(crate::workload::schedule::ScheduleKind::OneFOneB);
        assert_ne!(a.fingerprint(), s.fingerprint());
        let mut layers = a.clone();
        layers.groups[0].stages[0].num_layers += 1;
        assert_ne!(a.fingerprint(), layers.fingerprint());
    }

    #[test]
    fn device_group_label_matches_paper_notation() {
        let dg = DeviceGroupSpec {
            members: vec![("H100".into(), 2), ("A100".into(), 1)],
        };
        assert_eq!(dg.label(), "(HH,A)");
        assert_eq!(dg.total(), 3);
    }
}
