//! Input description layer (paper abstractions **A1** and **A2**).
//!
//! The user feeds the simulator three descriptions (paper §4.2):
//! 1. *Model parameters* ([`model::ModelSpec`], Table 6),
//! 2. *Framework parameters* ([`framework::FrameworkSpec`]: device
//!    groups, parallelism degrees, parallelism→group mapping),
//! 3. *Heterogeneous host & cluster topology*
//!    ([`cluster::ClusterSpec`], Table 5).
//!
//! [`presets`] carries the paper's exact Table 5/6 configurations;
//! [`loader`] reads the same structures from JSON files.

pub mod cluster;
pub mod framework;
pub mod loader;
pub mod model;
pub mod presets;

pub use cluster::{ClusterSpec, FabricSpec, GpuSpec, InterconnectSpec, NodeSpec};
pub use framework::{DeviceGroupSpec, FrameworkSpec, ParallelismSpec};
pub use model::{LayerKind, ModelSpec};
