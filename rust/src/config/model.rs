//! Model description (paper Table 6): transformer / MoE hyperparameters
//! plus training configuration.

/// The layer taxonomy used across the workload and compute layers.
/// Codes match the Python side (`python/compile/model.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Token/position embedding lookup (and the tied output projection).
    Embedding,
    /// Self-attention block.
    Attention,
    /// Dense MLP block.
    Mlp,
    /// Mixture-of-experts MLP block.
    Moe,
    /// Everything else per block (layernorm, residual, dropout).
    Other,
}

impl LayerKind {
    /// Numeric code used in the AOT cost-model feature rows.
    pub fn code(self) -> f32 {
        match self {
            LayerKind::Embedding => 0.0,
            LayerKind::Attention => 1.0,
            LayerKind::Mlp => 2.0,
            LayerKind::Moe => 3.0,
            LayerKind::Other => 4.0,
        }
    }

    /// Lower-case display name.
    pub fn name(self) -> &'static str {
        match self {
            LayerKind::Embedding => "embedding",
            LayerKind::Attention => "attention",
            LayerKind::Mlp => "mlp",
            LayerKind::Moe => "moe",
            LayerKind::Other => "other",
        }
    }
}

/// MoE configuration (Mixtral-style token-choice routing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoeSpec {
    /// Experts per MoE layer.
    pub num_experts: u32,
    /// Experts each token is routed to.
    pub top_k: u32,
}

/// Model + training hyperparameters (paper Table 6).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Display name, e.g. `GPT-6.7B`.
    pub name: String,
    /// Transformer block count.
    pub num_layers: u32,
    /// Model (embedding) dimension.
    pub hidden_size: u64,
    /// Attention heads (must divide `hidden_size`).
    pub num_heads: u32,
    /// MLP inner dimension.
    pub ffn_hidden: u64,
    /// Training sequence length.
    pub seq_len: u64,
    /// Positional-embedding table size.
    pub max_pos_embeddings: u64,
    /// Vocabulary size (embedding rows).
    pub vocab_size: u64,
    /// MoE routing parameters (`None` = dense model).
    pub moe: Option<MoeSpec>,
    /// Gated (SwiGLU-style, 3-matrix) MLP — true for Llama/Mixtral,
    /// false for GPT's 2-matrix MLP. Affects parameter accounting.
    pub gated_mlp: bool,
    /// Samples per training iteration across all DP replicas.
    pub global_batch: u64,
    /// Samples per microbatch.
    pub micro_batch: u64,
    /// Gradient dtype bytes (paper's DP sizes imply fp32 grads).
    pub grad_dtype_bytes: u64,
    /// Parameter/activation dtype bytes (bf16).
    pub dtype_bytes: u64,
}

impl ModelSpec {
    /// Approximate parameter count (standard transformer accounting).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden_size;
        let ffn = self.ffn_hidden;
        let attn = 4 * h * h; // QKVO
        let mats = if self.gated_mlp { 3 } else { 2 };
        let mlp = match self.moe {
            Some(m) => (m.num_experts as u64) * mats * h * ffn + h * (m.num_experts as u64),
            None => mats * h * ffn,
        };
        let per_layer = attn + mlp + 4 * h; // + layernorm/bias terms
        let embed = self.vocab_size * h;
        self.num_layers as u64 * per_layer + embed
    }

    /// Parameters resident on one GPU for a (tp, pp) sharding.
    pub fn params_per_gpu(&self, tp: u32, pp: u32) -> u64 {
        self.param_count() / (tp.max(1) as u64 * pp.max(1) as u64)
    }

    /// Gradient bytes exchanged by DP synchronization per GPU.
    pub fn grad_bytes_per_gpu(&self, tp: u32, pp: u32) -> u64 {
        self.params_per_gpu(tp, pp) * self.grad_dtype_bytes
    }

    /// Number of microbatches a DP replica processes per iteration.
    pub fn microbatches_per_replica(&self, dp: u32) -> u64 {
        (self.global_batch / (dp.max(1) as u64 * self.micro_batch)).max(1)
    }

    /// The per-transformer-block layer kinds (attention + mlp/moe + other).
    pub fn block_kinds(&self) -> Vec<LayerKind> {
        let mlp = if self.moe.is_some() { LayerKind::Moe } else { LayerKind::Mlp };
        vec![LayerKind::Attention, mlp, LayerKind::Other]
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.num_layers > 0, "num_layers must be positive");
        anyhow::ensure!(self.hidden_size > 0, "hidden_size must be positive");
        anyhow::ensure!(
            self.hidden_size % self.num_heads as u64 == 0,
            "hidden_size {} not divisible by heads {}",
            self.hidden_size,
            self.num_heads
        );
        anyhow::ensure!(self.micro_batch > 0, "micro_batch must be positive");
        anyhow::ensure!(
            self.global_batch >= self.micro_batch,
            "global_batch {} < micro_batch {}",
            self.global_batch,
            self.micro_batch
        );
        if let Some(m) = &self.moe {
            anyhow::ensure!(m.top_k > 0 && m.top_k <= m.num_experts, "bad MoE top_k");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn gpt67_param_count_near_6_7b() {
        let m = presets::model("gpt-6.7b").unwrap();
        let p = m.param_count() as f64;
        assert!((6.0e9..8.0e9).contains(&p), "{p}");
    }

    #[test]
    fn gpt13_param_count_near_13b() {
        let m = presets::model("gpt-13b").unwrap();
        let p = m.param_count() as f64;
        assert!((12.0e9..15.0e9).contains(&p), "{p}");
    }

    #[test]
    fn mixtral_param_count_near_46b() {
        // 8x7B ~= 46.7B total parameters
        let m = presets::model("mixtral-8x7b").unwrap();
        let p = m.param_count() as f64;
        assert!((40.0e9..50.0e9).contains(&p), "{p}");
    }

    #[test]
    fn llama70_param_count_near_70b() {
        let m = presets::model("llama2-70b").unwrap();
        let p = m.param_count() as f64;
        assert!((60.0e9..80.0e9).contains(&p), "{p}");
    }

    #[test]
    fn grad_bytes_shrink_with_sharding() {
        let m = presets::model("llama2-70b").unwrap();
        assert!(m.grad_bytes_per_gpu(8, 8) < m.grad_bytes_per_gpu(1, 1) / 32);
    }

    #[test]
    fn table1_dp_size_about_4_4_gb() {
        // Paper Table 1: Llama-2 70B, TP=8 PP=8 -> 4.4 GB fp32 grads/GPU
        let m = presets::model("llama2-70b").unwrap();
        let gb = m.grad_bytes_per_gpu(8, 8) as f64 / 1e9;
        assert!((3.8..5.0).contains(&gb), "{gb}");
    }

    #[test]
    fn microbatch_accounting() {
        let m = presets::model("gpt-6.7b").unwrap();
        // Table 6: gb=976, dp=32, mbs=8 -> floor(976/256)=3 microbatches
        assert_eq!(m.microbatches_per_replica(32), 3);
    }

    #[test]
    fn validate_catches_bad_heads() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_heads = 33;
        assert!(m.validate().is_err());
    }

    #[test]
    fn moe_block_uses_moe_kind() {
        let m = presets::model("mixtral-8x7b").unwrap();
        assert!(m.block_kinds().contains(&LayerKind::Moe));
        let d = presets::model("gpt-6.7b").unwrap();
        assert!(d.block_kinds().contains(&LayerKind::Mlp));
    }
}
