//! Named presets: the paper's exact Table 5 (cluster) and Table 6
//! (model) configurations, plus Llama-2 70B (Tables 1 and Fig 3).
//!
//! GPU efficiency factors MUST mirror `GPU_PRESETS` in
//! `python/compile/model.py` — `rust/tests/integration_runtime.rs`
//! cross-checks the AOT artifact against these values.

use crate::config::cluster::{ClusterSpec, FabricSpec, GpuSpec, InterconnectSpec, NodeSpec};
use crate::config::model::{ModelSpec, MoeSpec};
use crate::util::units::{Bandwidth, Time};

/// GPU compute presets (datasheet peak numbers + calibrated roofline
/// efficiencies; see DESIGN.md §4 Substitutions).
pub fn gpu(name: &str) -> anyhow::Result<GpuSpec> {
    match name {
        "A100" => Ok(GpuSpec {
            name: "A100".into(),
            peak_flops: 312.0e12,
            mem_bw: 1555.0e9,
            mem_capacity: 40 * 1024 * 1024 * 1024,
            eff_mlp: 0.55,
            eff_attn: 0.50,
            eff_embed: 0.0200,
            eff_mem: 0.75,
            launch_overhead: 4.5e-6,
        }),
        "H100" => Ok(GpuSpec {
            name: "H100".into(),
            peak_flops: 989.0e12,
            mem_bw: 3350.0e9,
            mem_capacity: 80 * 1024 * 1024 * 1024,
            eff_mlp: 0.55,
            eff_attn: 0.305,
            eff_embed: 0.3352,
            eff_mem: 0.78,
            launch_overhead: 4.5e-6,
        }),
        // Extension presets beyond the paper's Table 5: one generation
        // older (Volta) and one newer (Blackwell) for wider sweeps.
        // Efficiencies follow the same calibration methodology.
        "V100" => Ok(GpuSpec {
            name: "V100".into(),
            peak_flops: 125.0e12, // fp16 tensor core
            mem_bw: 900.0e9,
            mem_capacity: 32 * 1024 * 1024 * 1024,
            eff_mlp: 0.50,
            eff_attn: 0.55,
            eff_embed: 0.015,
            eff_mem: 0.72,
            launch_overhead: 5.0e-6,
        }),
        "B200" => Ok(GpuSpec {
            name: "B200".into(),
            peak_flops: 2250.0e12, // dense bf16
            mem_bw: 8000.0e9,
            mem_capacity: 192 * 1024 * 1024 * 1024,
            eff_mlp: 0.55,
            eff_attn: 0.25, // small GEMMs under-fill the larger MXU
            eff_embed: 0.40,
            eff_mem: 0.80,
            launch_overhead: 4.5e-6,
        }),
        _ => anyhow::bail!("unknown GPU preset '{name}' (known: A100, H100, V100, B200)"),
    }
}

/// Interconnect presets, exactly paper Table 5.
pub fn interconnect(arch: &str) -> anyhow::Result<InterconnectSpec> {
    match arch {
        "ampere" => Ok(InterconnectSpec {
            nvlink_bw: Bandwidth::from_gbps(4800.0), // NVLink Gen 3
            nvlink_delay: Time::from_ns(30.66),
            pcie_bw: Bandwidth::from_gbps(512.0), // PCIe Gen 4
            pcie_latency: Time::from_ns(287.5),   // one trip; paths pay 2x
            nic_bw: Bandwidth::from_gbps(200.0),  // ConnectX-6
            nic_processing_delay: Time::from_ns(368.0),
            nic_name: "ConnectX-6".into(),
        }),
        "hopper" => Ok(InterconnectSpec {
            nvlink_bw: Bandwidth::from_gbps(7200.0), // NVLink Gen 4
            nvlink_delay: Time::from_ns(20.44),
            pcie_bw: Bandwidth::from_gbps(1024.0), // PCIe Gen 5
            pcie_latency: Time::from_ns(143.75),
            nic_bw: Bandwidth::from_gbps(200.0), // Intel E830-CQDA2
            nic_processing_delay: Time::from_ns(368.0),
            nic_name: "E830-CQDA2".into(),
        }),
        "volta" => Ok(InterconnectSpec {
            nvlink_bw: Bandwidth::from_gbps(2400.0), // NVLink Gen 2
            nvlink_delay: Time::from_ns(61.33),      // 9200*8/1200
            pcie_bw: Bandwidth::from_gbps(256.0),    // PCIe Gen 3
            pcie_latency: Time::from_ns(575.0),
            nic_bw: Bandwidth::from_gbps(100.0), // ConnectX-5
            nic_processing_delay: Time::from_ns(450.0),
            nic_name: "ConnectX-5".into(),
        }),
        "blackwell" => Ok(InterconnectSpec {
            nvlink_bw: Bandwidth::from_gbps(14400.0), // NVLink Gen 5
            nvlink_delay: Time::from_ns(10.22),
            pcie_bw: Bandwidth::from_gbps(2048.0), // PCIe Gen 6
            pcie_latency: Time::from_ns(71.88),
            nic_bw: Bandwidth::from_gbps(400.0), // ConnectX-7
            nic_processing_delay: Time::from_ns(300.0),
            nic_name: "ConnectX-7".into(),
        }),
        _ => anyhow::bail!(
            "unknown interconnect preset '{arch}' (known: ampere, hopper, volta, blackwell)"
        ),
    }
}

fn node(arch: &str) -> anyhow::Result<NodeSpec> {
    let (g, ic) = match arch {
        "volta" => (gpu("V100")?, interconnect("volta")?),
        "ampere" => (gpu("A100")?, interconnect("ampere")?),
        "hopper" => (gpu("H100")?, interconnect("hopper")?),
        "blackwell" => (gpu("B200")?, interconnect("blackwell")?),
        _ => anyhow::bail!("unknown node architecture '{arch}'"),
    };
    Ok(NodeSpec { gpu: g, interconnect: ic, gpus_per_node: 8 })
}

/// Homogeneous cluster of `num_nodes` 8-GPU nodes ("ampere"/"hopper").
pub fn cluster(arch: &str, num_nodes: u32) -> anyhow::Result<ClusterSpec> {
    let n = node(arch)?;
    Ok(ClusterSpec {
        name: format!("{arch}-{num_nodes}n"),
        nodes: vec![n; num_nodes as usize],
        fabric: FabricSpec::RailOnly,
        switch_bw: Bandwidth::from_gbps(400.0),
        switch_delay: Time::from_ns(300.0),
    })
}

/// Heterogeneous cluster: `ampere_nodes` A100 nodes followed by
/// `hopper_nodes` H100 nodes (paper Fig 6 uses 50:50).
pub fn cluster_hetero(ampere_nodes: u32, hopper_nodes: u32) -> anyhow::Result<ClusterSpec> {
    let mut nodes = Vec::new();
    nodes.extend(std::iter::repeat(node("ampere")?).take(ampere_nodes as usize));
    nodes.extend(std::iter::repeat(node("hopper")?).take(hopper_nodes as usize));
    Ok(ClusterSpec {
        name: format!("hetero-{ampere_nodes}a{hopper_nodes}h"),
        nodes,
        fabric: FabricSpec::RailOnly,
        switch_bw: Bandwidth::from_gbps(400.0),
        switch_delay: Time::from_ns(300.0),
    })
}

/// Interconnect-only heterogeneity (the paper's Fig-6 configuration:
/// "the Ampere and Hopper configuration refers to only the interconnect
/// simulation"): every node carries the same GPU (`gpu_name`), but the
/// first `first_nodes` use the `first_arch` interconnect and the rest
/// use `second_arch`.
pub fn cluster_hetero_interconnect(
    gpu_name: &str,
    first_arch: &str,
    first_nodes: u32,
    second_arch: &str,
    second_nodes: u32,
) -> anyhow::Result<ClusterSpec> {
    let g = gpu(gpu_name)?;
    let mut nodes = Vec::new();
    for (arch, count) in [(first_arch, first_nodes), (second_arch, second_nodes)] {
        let ic = interconnect(arch)?;
        nodes.extend(
            std::iter::repeat(NodeSpec { gpu: g.clone(), interconnect: ic, gpus_per_node: 8 })
                .take(count as usize),
        );
    }
    Ok(ClusterSpec {
        name: format!("ic-hetero-{first_arch}{first_nodes}-{second_arch}{second_nodes}"),
        nodes,
        fabric: FabricSpec::RailOnly,
        switch_bw: Bandwidth::from_gbps(400.0),
        switch_delay: Time::from_ns(300.0),
    })
}

/// Model presets, exactly paper Table 6 plus Llama-2 70B.
pub fn model(name: &str) -> anyhow::Result<ModelSpec> {
    match name {
        "gpt-6.7b" => Ok(ModelSpec {
            name: "GPT-6.7B".into(),
            num_layers: 32,
            hidden_size: 4096,
            num_heads: 32,
            ffn_hidden: 16384,
            seq_len: 2048,
            max_pos_embeddings: 2048,
            vocab_size: 50257,
            moe: None,
            gated_mlp: false,
            global_batch: 976,
            micro_batch: 8,
            grad_dtype_bytes: 4,
            dtype_bytes: 2,
        }),
        "gpt-13b" => Ok(ModelSpec {
            name: "GPT-13B".into(),
            num_layers: 40,
            hidden_size: 5120,
            num_heads: 40,
            ffn_hidden: 20480,
            seq_len: 2048,
            max_pos_embeddings: 2048,
            vocab_size: 50257,
            moe: None,
            gated_mlp: false,
            global_batch: 976,
            micro_batch: 8,
            grad_dtype_bytes: 4,
            dtype_bytes: 2,
        }),
        "mixtral-8x7b" => Ok(ModelSpec {
            name: "Mixtral-8x7B".into(),
            num_layers: 32,
            hidden_size: 4096,
            num_heads: 32,
            ffn_hidden: 14336,
            seq_len: 2048,
            max_pos_embeddings: 131072,
            vocab_size: 32000,
            moe: Some(MoeSpec { num_experts: 8, top_k: 2 }),
            gated_mlp: true,
            global_batch: 1152,
            micro_batch: 4,
            grad_dtype_bytes: 4,
            dtype_bytes: 2,
        }),
        "llama2-70b" => Ok(ModelSpec {
            name: "Llama-2-70B".into(),
            num_layers: 80,
            hidden_size: 8192,
            num_heads: 64,
            ffn_hidden: 28672,
            seq_len: 4096,
            max_pos_embeddings: 4096,
            vocab_size: 32000,
            moe: None,
            gated_mlp: true,
            // Table 1 deployment: world 2048, TP=8, PP=8, DP=32. The
            // paper does not state the batch; 1120/4 reproduces its
            // reported TP collective frequency (~350/iter, see bench).
            global_batch: 1120,
            micro_batch: 4,
            grad_dtype_bytes: 4,
            dtype_bytes: 2,
        }),
        // Fig-3 batch configuration of Llama-2 70B (global batch 24,
        // microbatch 1) — the model half of the paper's Fig-3 scenario
        // (`hetsim plan --model fig3 --cluster fig3`).
        "fig3" => {
            let mut m = model("llama2-70b")?;
            m.global_batch = 24;
            m.micro_batch = 1;
            Ok(m)
        }
        _ => anyhow::bail!(
            "unknown model preset '{name}' (known: gpt-6.7b, gpt-13b, mixtral-8x7b, \
             llama2-70b, fig3)"
        ),
    }
}

/// The paper's Table 6 deployment (TP, PP, DP) for a model preset.
pub fn deployment(name: &str) -> anyhow::Result<crate::config::framework::ParallelismSpec> {
    use crate::config::framework::ParallelismSpec;
    match name {
        "gpt-6.7b" => Ok(ParallelismSpec { tp: 4, pp: 1, dp: 32 }),
        "gpt-13b" => Ok(ParallelismSpec { tp: 8, pp: 1, dp: 32 }),
        "mixtral-8x7b" => Ok(ParallelismSpec { tp: 2, pp: 1, dp: 64 }),
        "llama2-70b" => Ok(ParallelismSpec { tp: 8, pp: 8, dp: 32 }),
        _ => anyhow::bail!("no deployment preset for '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_models_validate() {
        for name in ["gpt-6.7b", "gpt-13b", "mixtral-8x7b", "llama2-70b"] {
            let m = model(name).unwrap();
            m.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn table6_world_sizes() {
        for (name, world) in [("gpt-6.7b", 128), ("gpt-13b", 256), ("mixtral-8x7b", 128)] {
            assert_eq!(deployment(name).unwrap().world_size(), world, "{name}");
        }
        assert_eq!(deployment("llama2-70b").unwrap().world_size(), 2048);
    }

    #[test]
    fn clusters_validate() {
        cluster("ampere", 16).unwrap().validate().unwrap();
        cluster("hopper", 16).unwrap().validate().unwrap();
        cluster_hetero(8, 8).unwrap().validate().unwrap();
    }

    #[test]
    fn unknown_names_error() {
        assert!(gpu("GTX1080").is_err());
        assert!(model("gpt-99b").is_err());
        assert!(cluster("pascal", 2).is_err());
    }

    #[test]
    fn extension_presets_ordered_by_generation() {
        // Fig-1 of the paper: FLOPS grows ~3x/year, interconnect ~1.4x
        let gens = ["V100", "A100", "H100", "B200"];
        let specs: Vec<_> = gens.iter().map(|g| gpu(g).unwrap()).collect();
        for w in specs.windows(2) {
            assert!(w[1].peak_flops > w[0].peak_flops);
            assert!(w[1].mem_bw > w[0].mem_bw);
        }
        let ics = ["volta", "ampere", "hopper", "blackwell"];
        let specs: Vec<_> = ics.iter().map(|a| interconnect(a).unwrap()).collect();
        for w in specs.windows(2) {
            assert!(w[1].nvlink_bw > w[0].nvlink_bw);
            assert!(w[1].nvlink_delay < w[0].nvlink_delay);
        }
    }

    #[test]
    fn extension_clusters_build_and_validate() {
        for arch in ["volta", "blackwell"] {
            let c = cluster(arch, 2).unwrap();
            c.validate().unwrap();
            crate::network::topology::Topology::build(&c).unwrap();
        }
    }

    #[test]
    fn gpu_presets_mirror_python() {
        // Values must equal python/compile/model.py GPU_PRESETS.
        let a = gpu("A100").unwrap();
        assert_eq!(a.peak_flops, 312.0e12);
        assert_eq!(a.mem_bw, 1555.0e9);
        assert_eq!(a.eff_mlp, 0.55);
        assert_eq!(a.eff_attn, 0.50);
        assert_eq!(a.eff_embed, 0.0200);
        assert_eq!(a.eff_mem, 0.75);
        let h = gpu("H100").unwrap();
        assert_eq!(h.peak_flops, 989.0e12);
        assert_eq!(h.eff_attn, 0.305);
        assert_eq!(h.eff_embed, 0.3352);
        assert_eq!(h.eff_mem, 0.78);
    }
}
