//! `hetsim bench` — the planner-throughput benchmark behind the repo's
//! perf trajectory (DESIGN.md §23).
//!
//! Runs the two ladders the acceptance criteria track — the Fig-3
//! plan+refine ladder (`--model fig3 --cluster fig3 --refine --mb-limit
//! 0`) and the `hetero:a,h` ladder — plus a raw engine-throughput case
//! and a fabric-build + routing-throughput case (leaf/spine over mixed
//! node sizes, DESIGN.md §24), and emits machine-readable
//! `BENCH_plan.json` (candidates/sec,
//! events/sec, wall-clock). CI runs `hetsim bench --quick --baseline
//! rust/benches/baseline_plan.json`, uploads the JSON as an artifact
//! and fails when candidates/sec regresses more than the factor (1.5×
//! by default) against the committed baseline.
//!
//! The baseline numbers are deliberately conservative floors (slow CI
//! runners must pass); the gate exists to catch order-of-magnitude
//! regressions of the zero-rebuild evaluation path, not ±10% noise.
//!
//! A resilience case rides along (DESIGN.md §26): `goodput_sweep` runs
//! the `hetsim plan --goodput` pipeline — candidate search, then an
//! effective-goodput walk over an MTBF fault schedule with survivor
//! re-plans — on the fig3 and `hetero:1,1` scenarios, gated on
//! plans/sec. Its Monte-Carlo sibling `goodput_mc` (DESIGN.md §28)
//! scores every ranked fig3 plan over 16 correlated-fault trajectories
//! — the `hetsim plan --objective goodput-ci` hot path — gated on
//! trajectories/sec.
//!
//! A serving case rides along too (DESIGN.md §27): `serve_throughput`
//! runs the `hetsim serve-sim` pipeline — seeded Poisson trace,
//! continuous-batching event loop with KV admission — on `hetero:1,1`,
//! gated on completed requests/sec (events/sec counts engine steps,
//! informational).
//!
//! Two symmetry-folding suites ride on top (DESIGN.md §25):
//!
//! * `fold_speedup` — the same DP-heavy scenario evaluated with
//!   `fold=off` and `fold=auto`; its gated metric is the folded /
//!   unfolded candidate-throughput **ratio** (machine-independent), so
//!   the committed floor directly encodes the ≥10x acceptance bar.
//! * `fold_ladder_{4k,32k,100k}` — a rank-scaling ladder of leaf/spine
//!   clusters up to 100k ranks, runnable only because folding collapses
//!   the op streams and DP flow sets. Gated on events/sec **and** a
//!   peak-RSS ceiling (`peak_rss_max_bytes` in the baseline): scale
//!   regressions show up as memory blowups long before they time out.
//!   Peak RSS is the process high-water mark (`VmHWM`), which only
//!   grows — the ladder runs last and ascending so each rung's reading
//!   is attributable to it.

use std::time::Instant;

use crate::config::cluster::FabricSpec;
use crate::config::framework::ParallelismSpec;
use crate::config::presets;
use crate::planner::{search, search_bnb, PlanOptions};
use crate::report::goodput::{annotate, SweepOptions};
use crate::simulator::SimulationBuilder;
use crate::system::fold::FoldMode;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::aicb::WorkloadOptions;
use crate::workload::partition::{fig3_cluster, fig3_model};

/// One benchmark case's measurements.
#[derive(Debug, Clone)]
pub struct BenchCase {
    /// Stable case name (the baseline-matching key).
    pub name: String,
    /// Wall-clock seconds of the whole case.
    pub wall_s: f64,
    /// Candidate evaluations performed (ranked + failed + refinement
    /// evaluations; 0 for non-planning cases).
    pub candidates: u64,
    /// `candidates / wall_s` — the headline planner-throughput metric.
    pub candidates_per_sec: f64,
    /// Discrete events processed. For planning cases this counts the
    /// *ranked* candidates' iterations only (refinement/baseline
    /// evaluations don't expose their event counts), so it understates
    /// the true event volume — informational; the gate for planning
    /// cases is candidates/sec. Non-planning cases count everything.
    pub events: u64,
    /// `events / wall_s` — engine throughput under this case (same
    /// ranked-only caveat for planning cases).
    pub events_per_sec: f64,
    /// Peak RSS (`VmHWM`, bytes) sampled after the case finished; 0
    /// when not sampled or unavailable (non-Linux). The kernel counter
    /// is a process-lifetime high-water mark, so readings are
    /// monotonically non-decreasing across cases.
    pub peak_rss_bytes: u64,
    /// `peak_rss_bytes / simulated ranks` for scale-ladder cases (0
    /// otherwise) — the per-rank memory footprint the ladder gates.
    pub bytes_per_rank: f64,
    /// Human-readable context for the table output.
    pub detail: String,
}

fn case(name: &str, wall_s: f64, candidates: u64, events: u64, detail: String) -> BenchCase {
    let wall = wall_s.max(f64::MIN_POSITIVE);
    BenchCase {
        name: name.to_string(),
        wall_s,
        candidates,
        candidates_per_sec: candidates as f64 / wall,
        events,
        events_per_sec: events as f64 / wall,
        peak_rss_bytes: 0,
        bytes_per_rank: 0.0,
        detail,
    }
}

/// Peak RSS of this process in bytes (`VmHWM` from
/// `/proc/self/status`). Returns 0 where procfs is unavailable.
fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 =
                rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Run one plan/refine ladder and fold it into a [`BenchCase`].
fn plan_case(
    name: &str,
    model: &crate::config::model::ModelSpec,
    cluster: &crate::config::cluster::ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<BenchCase> {
    let t0 = Instant::now();
    let rep = search(model, cluster, opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let refine_evals = rep.refined.as_ref().map(|r| r.evaluations).unwrap_or(0);
    let candidates = (rep.ranked.len() + rep.failed.len()) as u64 + refine_evals;
    let events: u64 = rep.ranked.iter().map(|ev| ev.events_processed).sum();
    let detail = format!(
        "{} ranked, {} pruned, {} refine evals, best {}",
        rep.ranked.len(),
        rep.pruned.len(),
        refine_evals,
        rep.refined
            .as_ref()
            .map(|r| r.refined_time.human())
            .unwrap_or_else(|| rep.best().iteration_time.human()),
    );
    Ok(case(name, wall, candidates, events, detail))
}

/// Run the bench suite. `quick` shrinks refinement budgets for CI
/// smoke; `threads` = worker threads per ladder (0 = all cores).
pub fn run(quick: bool, threads: usize) -> anyhow::Result<Vec<BenchCase>> {
    let mut out = Vec::new();

    // 1. candidate sweep on the hetero:1,1 preset (the `hetsim plan`
    //    default scenario)
    let m = presets::model("gpt-6.7b")?;
    let c = presets::cluster_hetero(1, 1)?;
    let sweep_opts = PlanOptions {
        microbatch_limit: Some(if quick { 1 } else { 2 }),
        threads,
        refine_steps: 0,
        fold: FoldMode::Off,
    };
    out.push(plan_case("plan_hetero", &m, &c, &sweep_opts)?);

    // 2. hetero:a,h refine ladder (layer-split polish under the
    //    default microbatch cap)
    let refine_opts = PlanOptions {
        microbatch_limit: Some(1),
        threads,
        refine_steps: if quick { 2 } else { 8 },
        fold: FoldMode::Off,
    };
    out.push(plan_case("refine_hetero", &m, &c, &refine_opts)?);

    // 3. Fig-3 refine ladder at full batch (the acceptance scenario:
    //    `plan --model fig3 --cluster fig3 --refine --mb-limit 0`)
    let fm = fig3_model()?;
    let fc = fig3_cluster()?;
    let fig3_opts = PlanOptions {
        microbatch_limit: None,
        threads,
        refine_steps: if quick { 4 } else { 16 },
        fold: FoldMode::Off,
    };
    out.push(plan_case("refine_fig3", &fm, &fc, &fig3_opts)?);

    // 4. raw engine throughput: repeated iterations of one prepared
    //    simulation (no planning, pure event loop)
    let sim = SimulationBuilder::new(m.clone(), c.clone())
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(WorkloadOptions {
            microbatch_limit: Some(2),
            ..Default::default()
        })
        .build()?;
    let iters = if quick { 3 } else { 10 };
    let t0 = Instant::now();
    let mut events = 0u64;
    for _ in 0..iters {
        events += sim.run_iteration()?.events_processed;
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(case(
        "engine_events",
        wall,
        0,
        events,
        format!("{iters} prepared iterations"),
    ));

    // 5. fabric build + routing throughput: repeatedly build the
    //    leaf/spine topology of a mixed-node-size cluster and assemble
    //    every src→dst route (the per-flow hot path of the fluid
    //    simulator). Gated on events/sec (= routes/sec) like the
    //    engine case.
    let mut fc2 = presets::cluster_hetero(2, 2)?;
    fc2.nodes[0].gpus_per_node = 4;
    fc2.nodes[1].gpus_per_node = 4;
    fc2.fabric =
        crate::config::cluster::FabricSpec::LeafSpine { spines: 4, oversubscription: 2.0 };
    let reps = if quick { 20 } else { 100 };
    let t0 = Instant::now();
    let mut routes = 0u64;
    let mut hops = 0u64;
    for _ in 0..reps {
        let topo = crate::network::topology::Topology::build(&fc2)?;
        let world = topo.total_gpus();
        for s in 0..world {
            for d in 0..world {
                hops += crate::network::routing::route(&topo, s, d).hops() as u64;
                routes += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(case(
        "fabric_routing",
        wall,
        0,
        routes,
        format!(
            "{reps} leaf/spine builds of {} ({} GPUs), all-pairs routes, {hops} hops",
            fc2.name,
            fc2.total_gpus()
        ),
    ));

    // 6. goodput sweep (DESIGN.md §26): plan search + MTBF-schedule
    //    goodput annotation (with survivor re-plans) on fig3 and
    //    hetero:1,1 — the `hetsim plan --goodput` hot path. Gated on
    //    plans/sec; events counts the ranked candidates' iterations.
    out.push(goodput_sweep_case(threads)?);

    // 7. Monte-Carlo goodput (DESIGN.md §28): plan search + 16
    //    correlated-fault trajectories per ranked plan, scored by the
    //    ci95 lower bound — the `hetsim plan --objective goodput-ci`
    //    hot path. Gated on trajectories/sec.
    out.push(goodput_mc_case(threads)?);

    // 8. serving throughput (DESIGN.md §27): the `hetsim serve-sim`
    //    pipeline — Poisson trace, continuous-batching loop with KV
    //    admission — gated on completed requests/sec
    out.push(serve_throughput_case(quick, threads)?);

    // 9. bound-guided search head-to-head (DESIGN.md §29): exhaustive
    //    grid vs `--search bnb` on fig3 and hetero:1,1. The case hard-
    //    asserts best-plan identity and strictly-fewer full sims; the
    //    gated metric is the grid/bnb full-simulation *ratio* — a
    //    deterministic, machine-independent count, so the committed
    //    floor encodes the pruning power itself.
    out.push(bnb_speedup_case(threads)?);

    // 10. symmetry-folding head-to-head (DESIGN.md §25): the same
    //    DP-heavy candidate evaluated repeatedly with fold=off and
    //    fold=auto. The gated metric is the throughput *ratio*, so the
    //    baseline floor encodes the ≥10x acceptance bar directly.
    out.push(fold_speedup_case(quick)?);

    // 10. rank-scaling ladder: leaf/spine clusters up to 100k ranks,
    //    fold=auto (unfolded, the 100k DP ring alone is ~2e10 flows —
    //    these rungs exist *because* of folding). Runs last and
    //    ascending so the monotone VmHWM reading is attributable.
    for (name, ranks) in
        [("fold_ladder_4k", 4096u32), ("fold_ladder_32k", 32_768), ("fold_ladder_100k", 100_000)]
    {
        out.push(fold_ladder_case(name, ranks)?);
    }
    Ok(out)
}

/// The `goodput_sweep` case: plan search + goodput annotation under an
/// MTBF fault schedule, on the paper's two reference clusters. The
/// annotation walks every ranked plan and re-runs the planner on each
/// distinct surviving cluster a node loss produces (memoized), so the
/// case measures the full `hetsim plan --goodput` pipeline.
fn goodput_sweep_case(threads: usize) -> anyhow::Result<BenchCase> {
    let scenarios = [
        ("fig3", fig3_model()?, fig3_cluster()?),
        ("hetero:1,1", presets::model("gpt-6.7b")?, presets::cluster_hetero(1, 1)?),
    ];
    let t0 = Instant::now();
    let mut plans = 0u64;
    let mut events = 0u64;
    let mut details = Vec::new();
    for (label, m, c) in &scenarios {
        let popts = PlanOptions {
            microbatch_limit: Some(1),
            threads,
            refine_steps: 0,
            fold: FoldMode::Off,
        };
        let mut rep = search(m, c, &popts)?;
        plans += (rep.ranked.len() + rep.failed.len()) as u64;
        events += rep.ranked.iter().map(|ev| ev.events_processed).sum::<u64>();
        let gopts = SweepOptions {
            plan: popts,
            horizon_s: 86_400.0,
            mtbf_scale: 8.0,
            seed: 42,
            ..Default::default()
        };
        annotate(&mut rep, m, c, &gopts);
        let best = rep.best();
        details.push(format!(
            "{label}: best {} at {:.0} tok/s",
            best.candidate.key(),
            best.goodput.unwrap_or(0.0)
        ));
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(case("goodput_sweep", wall, plans, events, details.join("; ")))
}

/// The `goodput_mc` case: plan search + Monte-Carlo goodput annotation
/// — 16 correlated-fault trajectories per ranked fig3 plan (domain
/// blasts on 2-node racks riding on the per-node MTBF schedule),
/// scored by the ci95 lower bound with memoized survivor re-plans.
/// `candidates` counts trajectories walked (the gated rate); `events`
/// counts the ranked candidates' iterations, as in `goodput_sweep`.
fn goodput_mc_case(threads: usize) -> anyhow::Result<BenchCase> {
    use crate::system::failure::DomainSpec;
    const TRAJECTORIES: u32 = 16;
    let m = fig3_model()?;
    let c = fig3_cluster()?;
    let popts = PlanOptions {
        microbatch_limit: Some(1),
        threads,
        refine_steps: 0,
        fold: FoldMode::Off,
    };
    let t0 = Instant::now();
    let mut rep = search(&m, &c, &popts)?;
    let events = rep.ranked.iter().map(|ev| ev.events_processed).sum::<u64>();
    let gopts = SweepOptions {
        plan: popts,
        horizon_s: 86_400.0,
        mtbf_scale: 8.0,
        seed: 42,
        mc: TRAJECTORIES,
        domains: Some(DomainSpec {
            rack_size: 2,
            mtbf_hours: 800.0,
            horizon_s: 86_400.0,
            scale: 8.0,
        }),
        ..Default::default()
    };
    annotate(&mut rep, &m, &c, &gopts);
    let wall = t0.elapsed().as_secs_f64();
    let trajectories = rep.ranked.len() as u64 * u64::from(TRAJECTORIES);
    let best = rep.best();
    let ci = best.goodput_ci.unwrap_or((0.0, 0.0));
    Ok(case(
        "goodput_mc",
        wall,
        trajectories,
        events,
        format!(
            "fig3: {} plans x {TRAJECTORIES} trajectories, best {} ci95 [{:.0}, {:.0}] tok/s",
            rep.ranked.len(),
            best.candidate.key(),
            ci.0,
            ci.1
        ),
    ))
}

/// The `serve_throughput` case: one `hetsim serve-sim` run — seeded
/// Poisson trace lowered through the roofline cost tables, then the
/// sequential continuous-batching event loop with KV-budget admission.
/// `candidates` counts completed requests (the gated rate), `events`
/// counts engine decision steps (prefill/decode rounds, informational).
fn serve_throughput_case(quick: bool, threads: usize) -> anyhow::Result<BenchCase> {
    use crate::system::serve_scheduler::ServeSim;
    use crate::workload::serve::{PoissonSpec, ServePolicy, ServeSpec};
    let m = presets::model("gpt-6.7b")?;
    let c = presets::cluster_hetero(1, 1)?;
    let spec = ServeSpec {
        poisson: Some(PoissonSpec {
            rate_per_s: 50.0,
            horizon_s: if quick { 10.0 } else { 40.0 },
            scale: 1.0,
            prompt_tokens: 256,
            output_tokens: 32,
        }),
        policy: ServePolicy::Srpt,
        seed: 42,
        ..Default::default()
    };
    let t0 = Instant::now();
    let sim = ServeSim::new(m, c, spec)?;
    let rep = sim.run(threads.max(1))?;
    let wall = t0.elapsed().as_secs_f64();
    Ok(case(
        "serve_throughput",
        wall,
        rep.requests_total,
        rep.events,
        format!(
            "{} requests srpt, {:.0} simulated tok/s, ttft p99 {:.1}ms",
            rep.requests_total,
            rep.goodput_tok_s,
            rep.ttft.p99_s * 1e3
        ),
    ))
}

/// A DP-only scale scenario: a 4-layer GPT-shaped model data-parallel
/// across every rank (`tp = pp = 1`, one microbatch per group), the
/// worst case for per-rank op-stream and DP-flow volume and the best
/// case for symmetry folding (every group is a singleton of one class).
fn scale_scenario(
    arch: &str,
    ranks: u32,
) -> anyhow::Result<(crate::config::model::ModelSpec, crate::config::cluster::ClusterSpec)> {
    anyhow::ensure!(ranks % 8 == 0, "scale scenario needs 8-GPU nodes");
    let mut m = presets::model("gpt-6.7b")?;
    m.num_layers = 4;
    m.global_batch = ranks as u64;
    m.micro_batch = 1;
    let c = presets::cluster(arch, ranks / 8)?;
    Ok((m, c))
}

/// The exhaustive-grid vs `--search bnb` head-to-head behind the
/// `bnb_speedup` gate (DESIGN.md §29). Runs both searches on the fig3
/// and hetero:1,1 acceptance scenarios, hard-asserts that bnb returns
/// the same best plan as the grid while running strictly fewer full
/// simulations, and stores the summed grid/bnb full-simulation ratio
/// in `candidates_per_sec`. That ratio is a deterministic quotient of
/// simulation counts — machine-independent — so the committed baseline
/// floor gates pruning power, not wall-clock noise.
fn bnb_speedup_case(threads: usize) -> anyhow::Result<BenchCase> {
    let scenarios = vec![
        ("fig3", fig3_model()?, fig3_cluster()?),
        ("hetero", presets::model("gpt-6.7b")?, presets::cluster_hetero(1, 1)?),
    ];
    let opts = PlanOptions {
        microbatch_limit: Some(1),
        threads,
        refine_steps: 0,
        fold: FoldMode::Off,
    };
    let t0 = Instant::now();
    let (mut grid_sims, mut bnb_sims, mut events) = (0u64, 0u64, 0u64);
    let mut parts = Vec::new();
    for (name, m, c) in &scenarios {
        let grid = search(m, c, &opts)?;
        let bnb = search_bnb(m, c, &opts)?;
        anyhow::ensure!(
            grid.best().candidate == bnb.best().candidate
                && grid.best().iteration_time == bnb.best().iteration_time,
            "{name}: bnb best {} differs from grid best {}",
            bnb.best().candidate.key(),
            grid.best().candidate.key(),
        );
        let Some(st) = bnb.stats else {
            anyhow::bail!("{name}: bnb report is missing search stats");
        };
        let g = (grid.ranked.len() + grid.failed.len()) as u64;
        anyhow::ensure!(
            (st.full_sims as u64) < g,
            "{name}: bnb ran {} full sims vs grid's {g} — pruned nothing",
            st.full_sims,
        );
        grid_sims += g;
        bnb_sims += st.full_sims as u64;
        events += grid.ranked.iter().map(|ev| ev.events_processed).sum::<u64>();
        events += bnb.ranked.iter().map(|ev| ev.events_processed).sum::<u64>();
        parts.push(format!(
            "{name} {}/{g} sims ({} bound-pruned, {} cutoff-aborted)",
            st.full_sims, st.bound_pruned, st.cutoff_aborted,
        ));
    }
    let wall = t0.elapsed().as_secs_f64().max(f64::MIN_POSITIVE);
    let ratio = grid_sims as f64 / bnb_sims.max(1) as f64;
    Ok(BenchCase {
        name: "bnb_speedup".into(),
        wall_s: wall,
        candidates: grid_sims + bnb_sims,
        candidates_per_sec: ratio,
        events,
        events_per_sec: events as f64 / wall,
        peak_rss_bytes: 0,
        bytes_per_rank: 0.0,
        detail: format!("{} = {ratio:.2}x fewer sims", parts.join("; ")),
    })
}

/// The fold=auto vs fold=off head-to-head behind the `fold_speedup`
/// gate. `candidates_per_sec` of the returned case is the folded /
/// unfolded evaluation-throughput ratio, not a raw rate.
fn fold_speedup_case(quick: bool) -> anyhow::Result<BenchCase> {
    let dp: u32 = if quick { 256 } else { 512 };
    let (m, c) = scale_scenario("hopper", dp)?;
    let (off_evals, auto_evals): (u32, u32) = if quick { (1, 4) } else { (2, 8) };
    let eval = |mode: FoldMode, evals: u32| -> anyhow::Result<(f64, u64)> {
        let t0 = Instant::now();
        let mut events = 0u64;
        for _ in 0..evals {
            let sim = SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(ParallelismSpec { tp: 1, pp: 1, dp })
                .fold(mode)
                .build()?;
            events += sim.run_iteration()?.events_processed;
        }
        Ok((t0.elapsed().as_secs_f64().max(f64::MIN_POSITIVE), events))
    };
    let (off_wall, off_events) = eval(FoldMode::Off, off_evals)?;
    let (auto_wall, auto_events) = eval(FoldMode::Auto, auto_evals)?;
    let off_cps = off_evals as f64 / off_wall;
    let auto_cps = auto_evals as f64 / auto_wall;
    let ratio = auto_cps / off_cps;
    let wall = off_wall + auto_wall;
    let events = off_events + auto_events;
    Ok(BenchCase {
        name: "fold_speedup".into(),
        wall_s: wall,
        candidates: (off_evals + auto_evals) as u64,
        candidates_per_sec: ratio,
        events,
        events_per_sec: events as f64 / wall,
        peak_rss_bytes: 0,
        bytes_per_rank: 0.0,
        detail: format!(
            "dp={dp}: fold=auto {auto_cps:.2} evals/s vs fold=off {off_cps:.3} \
             evals/s = {ratio:.0}x"
        ),
    })
}

/// One rung of the rank-scaling ladder: build + one iteration of a
/// `ranks`-wide leaf/spine cluster with `fold=auto`, gated on
/// events/sec and the peak-RSS ceiling.
fn fold_ladder_case(name: &str, ranks: u32) -> anyhow::Result<BenchCase> {
    let (m, mut c) = scale_scenario("ampere", ranks)?;
    c.fabric = FabricSpec::LeafSpine { spines: 4, oversubscription: 2.0 };
    let t0 = Instant::now();
    let sim = SimulationBuilder::new(m, c)
        .parallelism(ParallelismSpec { tp: 1, pp: 1, dp: ranks })
        .fold(FoldMode::Auto)
        .build()?;
    anyhow::ensure!(sim.folded(), "{name}: fold=auto did not fold the cluster");
    let rep = sim.run_iteration()?;
    let wall = t0.elapsed().as_secs_f64();
    let rss = peak_rss_bytes();
    let mut out = case(
        name,
        wall,
        0,
        rep.events_processed,
        format!("{ranks} ranks leaf/spine, folded iter {}", rep.iteration_time.human()),
    );
    out.peak_rss_bytes = rss;
    out.bytes_per_rank = rss as f64 / ranks as f64;
    Ok(out)
}

/// Render the human-readable table.
pub fn render(cases: &[BenchCase]) -> Table {
    let mut t = Table::new(
        "hetsim bench — planner + engine throughput",
        &["case", "wall", "cand", "cand/s", "events", "events/s", "peak rss", "detail"],
    );
    for c in cases {
        t.row(vec![
            c.name.clone(),
            format!("{:.2}s", c.wall_s),
            c.candidates.to_string(),
            format!("{:.1}", c.candidates_per_sec),
            c.events.to_string(),
            format!("{:.0}", c.events_per_sec),
            if c.peak_rss_bytes == 0 {
                "-".into()
            } else {
                format!("{:.0} MiB", c.peak_rss_bytes as f64 / (1024.0 * 1024.0))
            },
            c.detail.clone(),
        ]);
    }
    t
}

/// Serialize the suite into the `BENCH_plan.json` document.
pub fn to_json(cases: &[BenchCase], quick: bool) -> Json {
    let benchmarks: Vec<Json> = cases
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", Json::Str(c.name.clone())),
                ("wall_s", Json::Num(c.wall_s)),
                ("candidates", Json::Num(c.candidates as f64)),
                ("candidates_per_sec", Json::Num(c.candidates_per_sec)),
                ("events", Json::Num(c.events as f64)),
                ("events_per_sec", Json::Num(c.events_per_sec)),
                ("peak_rss_bytes", Json::Num(c.peak_rss_bytes as f64)),
                ("bytes_per_rank", Json::Num(c.bytes_per_rank)),
                ("detail", Json::Str(c.detail.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("benchmarks", Json::Arr(benchmarks)),
    ])
}

/// Compare a run against a committed baseline document. Returns one
/// message per regression: a case whose candidates/sec (or, for
/// non-planning cases, events/sec) fell more than `factor`× below the
/// baseline value. Cases present on only one side are skipped (the
/// suite may grow).
pub fn check_against_baseline(cases: &[BenchCase], baseline: &Json, factor: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    let Some(bench) = baseline.get("benchmarks").and_then(Json::as_arr) else {
        return vec!["baseline JSON has no 'benchmarks' array".into()];
    };
    for b in bench {
        let Some(name) = b.get("name").and_then(Json::as_str) else { continue };
        let Some(cur) = cases.iter().find(|c| c.name == name) else { continue };
        // planning cases gate on candidates/sec only: an intentional
        // events-per-candidate reduction (goldens re-recorded) must not
        // trip the gate on a strictly faster build. Non-planning cases
        // (candidates == 0) gate on raw engine throughput instead.
        let (key, have) = if cur.candidates > 0 {
            ("candidates_per_sec", cur.candidates_per_sec)
        } else {
            ("events_per_sec", cur.events_per_sec)
        };
        let want = b.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        if want > 0.0 && have * factor < want {
            regressions.push(format!(
                "{name}: {key} {have:.2} is more than {factor}x below baseline {want:.2}"
            ));
        }
        // hard memory ceiling (scale-ladder cases): a peak-RSS breach
        // is an absolute failure, not factor-scaled — per-rank memory
        // blowups surface here long before wall-clock times out
        let ceiling = b.get("peak_rss_max_bytes").and_then(Json::as_f64).unwrap_or(0.0);
        if ceiling > 0.0 && cur.peak_rss_bytes > 0 && cur.peak_rss_bytes as f64 > ceiling {
            regressions.push(format!(
                "{name}: peak RSS {} bytes exceeds the {} byte ceiling",
                cur.peak_rss_bytes, ceiling as u64
            ));
        }
    }
    regressions
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(name: &str, cps: f64, eps: f64) -> BenchCase {
        BenchCase {
            name: name.into(),
            wall_s: 1.0,
            candidates: cps as u64,
            candidates_per_sec: cps,
            events: eps as u64,
            events_per_sec: eps,
            peak_rss_bytes: 0,
            bytes_per_rank: 0.0,
            detail: String::new(),
        }
    }

    #[test]
    fn json_document_is_well_formed() {
        let cases = vec![fake("plan_hetero", 10.0, 1000.0)];
        let doc = to_json(&cases, true);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_u64().unwrap(), 1);
        let b = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].get("name").unwrap().as_str().unwrap(), "plan_hetero");
        assert!(b[0].get("candidates_per_sec").unwrap().as_f64().unwrap() > 9.0);
    }

    #[test]
    fn baseline_gate_flags_large_regressions_only() {
        let baseline = to_json(&[fake("plan_hetero", 10.0, 1000.0)], true);
        // 20% slower: fine under a 1.5x gate
        let ok = check_against_baseline(&[fake("plan_hetero", 8.0, 800.0)], &baseline, 1.5);
        assert!(ok.is_empty(), "{ok:?}");
        // 3x slower: flagged on candidates/sec only (events/sec may
        // legitimately drop when a candidate gets cheaper to simulate)
        let bad = check_against_baseline(&[fake("plan_hetero", 3.0, 300.0)], &baseline, 1.5);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("candidates_per_sec"), "{bad:?}");
        // unknown baseline cases are skipped
        let skip = check_against_baseline(&[fake("other", 1.0, 1.0)], &baseline, 1.5);
        assert!(skip.is_empty());
    }

    #[test]
    fn baseline_gate_checks_events_for_engine_cases() {
        // a non-planning case (candidates == 0) gates on events/sec
        let mut engine = fake("engine_events", 0.0, 100_000.0);
        engine.candidates = 0;
        let baseline = to_json(&[engine.clone()], true);
        let mut slow = engine.clone();
        slow.events_per_sec = 10_000.0;
        let bad = check_against_baseline(&[slow], &baseline, 1.5);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("events_per_sec"), "{bad:?}");
        let ok = check_against_baseline(&[engine], &baseline, 1.5);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn baseline_gate_enforces_memory_ceiling() {
        // hand-build a baseline with a peak_rss_max_bytes ceiling
        let baseline = Json::parse(
            r#"{"benchmarks": [
                {"name": "fold_ladder_100k", "events_per_sec": 10,
                 "peak_rss_max_bytes": 1000000}
            ]}"#,
        )
        .unwrap();
        let mut lad = fake("fold_ladder_100k", 0.0, 100.0);
        lad.candidates = 0;
        lad.peak_rss_bytes = 500_000;
        let ok = check_against_baseline(&[lad.clone()], &baseline, 1.5);
        assert!(ok.is_empty(), "{ok:?}");
        lad.peak_rss_bytes = 2_000_000;
        let bad = check_against_baseline(&[lad.clone()], &baseline, 1.5);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("peak RSS"), "{bad:?}");
        // unsampled RSS (0, e.g. non-Linux) never trips the ceiling
        lad.peak_rss_bytes = 0;
        let skip = check_against_baseline(&[lad], &baseline, 1.5);
        assert!(skip.is_empty(), "{skip:?}");
    }

    #[test]
    fn render_lists_every_case() {
        let t = render(&[fake("a", 1.0, 2.0), fake("b", 3.0, 4.0)]);
        let md = t.markdown();
        assert!(md.contains("| a "));
        assert!(md.contains("| b "));
    }
}
