//! `hetsim bench` — the planner-throughput benchmark behind the repo's
//! perf trajectory (DESIGN.md §23).
//!
//! Runs the two ladders the acceptance criteria track — the Fig-3
//! plan+refine ladder (`--model fig3 --cluster fig3 --refine --mb-limit
//! 0`) and the `hetero:a,h` ladder — plus a raw engine-throughput case
//! and a fabric-build + routing-throughput case (leaf/spine over mixed
//! node sizes, DESIGN.md §24), and emits machine-readable
//! `BENCH_plan.json` (candidates/sec,
//! events/sec, wall-clock). CI runs `hetsim bench --quick --baseline
//! rust/benches/baseline_plan.json`, uploads the JSON as an artifact
//! and fails when candidates/sec regresses more than the factor (1.5×
//! by default) against the committed baseline.
//!
//! The baseline numbers are deliberately conservative floors (slow CI
//! runners must pass); the gate exists to catch order-of-magnitude
//! regressions of the zero-rebuild evaluation path, not ±10% noise.

use std::time::Instant;

use crate::config::framework::ParallelismSpec;
use crate::config::presets;
use crate::planner::{search, PlanOptions};
use crate::simulator::SimulationBuilder;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::aicb::WorkloadOptions;
use crate::workload::partition::{fig3_cluster, fig3_model};

/// One benchmark case's measurements.
#[derive(Debug, Clone)]
pub struct BenchCase {
    /// Stable case name (the baseline-matching key).
    pub name: String,
    /// Wall-clock seconds of the whole case.
    pub wall_s: f64,
    /// Candidate evaluations performed (ranked + failed + refinement
    /// evaluations; 0 for non-planning cases).
    pub candidates: u64,
    /// `candidates / wall_s` — the headline planner-throughput metric.
    pub candidates_per_sec: f64,
    /// Discrete events processed. For planning cases this counts the
    /// *ranked* candidates' iterations only (refinement/baseline
    /// evaluations don't expose their event counts), so it understates
    /// the true event volume — informational; the gate for planning
    /// cases is candidates/sec. Non-planning cases count everything.
    pub events: u64,
    /// `events / wall_s` — engine throughput under this case (same
    /// ranked-only caveat for planning cases).
    pub events_per_sec: f64,
    /// Human-readable context for the table output.
    pub detail: String,
}

fn case(name: &str, wall_s: f64, candidates: u64, events: u64, detail: String) -> BenchCase {
    let wall = wall_s.max(f64::MIN_POSITIVE);
    BenchCase {
        name: name.to_string(),
        wall_s,
        candidates,
        candidates_per_sec: candidates as f64 / wall,
        events,
        events_per_sec: events as f64 / wall,
        detail,
    }
}

/// Run one plan/refine ladder and fold it into a [`BenchCase`].
fn plan_case(
    name: &str,
    model: &crate::config::model::ModelSpec,
    cluster: &crate::config::cluster::ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<BenchCase> {
    let t0 = Instant::now();
    let rep = search(model, cluster, opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let refine_evals = rep.refined.as_ref().map(|r| r.evaluations).unwrap_or(0);
    let candidates = (rep.ranked.len() + rep.failed.len()) as u64 + refine_evals;
    let events: u64 = rep.ranked.iter().map(|ev| ev.events_processed).sum();
    let detail = format!(
        "{} ranked, {} pruned, {} refine evals, best {}",
        rep.ranked.len(),
        rep.pruned.len(),
        refine_evals,
        rep.refined
            .as_ref()
            .map(|r| r.refined_time.human())
            .unwrap_or_else(|| rep.best().iteration_time.human()),
    );
    Ok(case(name, wall, candidates, events, detail))
}

/// Run the bench suite. `quick` shrinks refinement budgets for CI
/// smoke; `threads` = worker threads per ladder (0 = all cores).
pub fn run(quick: bool, threads: usize) -> anyhow::Result<Vec<BenchCase>> {
    let mut out = Vec::new();

    // 1. candidate sweep on the hetero:1,1 preset (the `hetsim plan`
    //    default scenario)
    let m = presets::model("gpt-6.7b")?;
    let c = presets::cluster_hetero(1, 1)?;
    let sweep_opts = PlanOptions {
        microbatch_limit: Some(if quick { 1 } else { 2 }),
        threads,
        refine_steps: 0,
    };
    out.push(plan_case("plan_hetero", &m, &c, &sweep_opts)?);

    // 2. hetero:a,h refine ladder (layer-split polish under the
    //    default microbatch cap)
    let refine_opts = PlanOptions {
        microbatch_limit: Some(1),
        threads,
        refine_steps: if quick { 2 } else { 8 },
    };
    out.push(plan_case("refine_hetero", &m, &c, &refine_opts)?);

    // 3. Fig-3 refine ladder at full batch (the acceptance scenario:
    //    `plan --model fig3 --cluster fig3 --refine --mb-limit 0`)
    let fm = fig3_model()?;
    let fc = fig3_cluster()?;
    let fig3_opts = PlanOptions {
        microbatch_limit: None,
        threads,
        refine_steps: if quick { 4 } else { 16 },
    };
    out.push(plan_case("refine_fig3", &fm, &fc, &fig3_opts)?);

    // 4. raw engine throughput: repeated iterations of one prepared
    //    simulation (no planning, pure event loop)
    let sim = SimulationBuilder::new(m.clone(), c.clone())
        .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        .workload_options(WorkloadOptions {
            microbatch_limit: Some(2),
            ..Default::default()
        })
        .build()?;
    let iters = if quick { 3 } else { 10 };
    let t0 = Instant::now();
    let mut events = 0u64;
    for _ in 0..iters {
        events += sim.run_iteration()?.events_processed;
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(case(
        "engine_events",
        wall,
        0,
        events,
        format!("{iters} prepared iterations"),
    ));

    // 5. fabric build + routing throughput: repeatedly build the
    //    leaf/spine topology of a mixed-node-size cluster and assemble
    //    every src→dst route (the per-flow hot path of the fluid
    //    simulator). Gated on events/sec (= routes/sec) like the
    //    engine case.
    let mut fc2 = presets::cluster_hetero(2, 2)?;
    fc2.nodes[0].gpus_per_node = 4;
    fc2.nodes[1].gpus_per_node = 4;
    fc2.fabric =
        crate::config::cluster::FabricSpec::LeafSpine { spines: 4, oversubscription: 2.0 };
    let reps = if quick { 20 } else { 100 };
    let t0 = Instant::now();
    let mut routes = 0u64;
    let mut hops = 0u64;
    for _ in 0..reps {
        let topo = crate::network::topology::Topology::build(&fc2)?;
        let world = topo.total_gpus();
        for s in 0..world {
            for d in 0..world {
                hops += crate::network::routing::route(&topo, s, d).hops() as u64;
                routes += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(case(
        "fabric_routing",
        wall,
        0,
        routes,
        format!(
            "{reps} leaf/spine builds of {} ({} GPUs), all-pairs routes, {hops} hops",
            fc2.name,
            fc2.total_gpus()
        ),
    ));
    Ok(out)
}

/// Render the human-readable table.
pub fn render(cases: &[BenchCase]) -> Table {
    let mut t = Table::new(
        "hetsim bench — planner + engine throughput",
        &["case", "wall", "cand", "cand/s", "events", "events/s", "detail"],
    );
    for c in cases {
        t.row(vec![
            c.name.clone(),
            format!("{:.2}s", c.wall_s),
            c.candidates.to_string(),
            format!("{:.1}", c.candidates_per_sec),
            c.events.to_string(),
            format!("{:.0}", c.events_per_sec),
            c.detail.clone(),
        ]);
    }
    t
}

/// Serialize the suite into the `BENCH_plan.json` document.
pub fn to_json(cases: &[BenchCase], quick: bool) -> Json {
    let benchmarks: Vec<Json> = cases
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("name", Json::Str(c.name.clone())),
                ("wall_s", Json::Num(c.wall_s)),
                ("candidates", Json::Num(c.candidates as f64)),
                ("candidates_per_sec", Json::Num(c.candidates_per_sec)),
                ("events", Json::Num(c.events as f64)),
                ("events_per_sec", Json::Num(c.events_per_sec)),
                ("detail", Json::Str(c.detail.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("benchmarks", Json::Arr(benchmarks)),
    ])
}

/// Compare a run against a committed baseline document. Returns one
/// message per regression: a case whose candidates/sec (or, for
/// non-planning cases, events/sec) fell more than `factor`× below the
/// baseline value. Cases present on only one side are skipped (the
/// suite may grow).
pub fn check_against_baseline(cases: &[BenchCase], baseline: &Json, factor: f64) -> Vec<String> {
    let mut regressions = Vec::new();
    let Some(bench) = baseline.get("benchmarks").and_then(Json::as_arr) else {
        return vec!["baseline JSON has no 'benchmarks' array".into()];
    };
    for b in bench {
        let Some(name) = b.get("name").and_then(Json::as_str) else { continue };
        let Some(cur) = cases.iter().find(|c| c.name == name) else { continue };
        // planning cases gate on candidates/sec only: an intentional
        // events-per-candidate reduction (goldens re-recorded) must not
        // trip the gate on a strictly faster build. Non-planning cases
        // (candidates == 0) gate on raw engine throughput instead.
        let (key, have) = if cur.candidates > 0 {
            ("candidates_per_sec", cur.candidates_per_sec)
        } else {
            ("events_per_sec", cur.events_per_sec)
        };
        let want = b.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        if want > 0.0 && have * factor < want {
            regressions.push(format!(
                "{name}: {key} {have:.2} is more than {factor}x below baseline {want:.2}"
            ));
        }
    }
    regressions
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(name: &str, cps: f64, eps: f64) -> BenchCase {
        BenchCase {
            name: name.into(),
            wall_s: 1.0,
            candidates: cps as u64,
            candidates_per_sec: cps,
            events: eps as u64,
            events_per_sec: eps,
            detail: String::new(),
        }
    }

    #[test]
    fn json_document_is_well_formed() {
        let cases = vec![fake("plan_hetero", 10.0, 1000.0)];
        let doc = to_json(&cases, true);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_u64().unwrap(), 1);
        let b = parsed.get("benchmarks").unwrap().as_arr().unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].get("name").unwrap().as_str().unwrap(), "plan_hetero");
        assert!(b[0].get("candidates_per_sec").unwrap().as_f64().unwrap() > 9.0);
    }

    #[test]
    fn baseline_gate_flags_large_regressions_only() {
        let baseline = to_json(&[fake("plan_hetero", 10.0, 1000.0)], true);
        // 20% slower: fine under a 1.5x gate
        let ok = check_against_baseline(&[fake("plan_hetero", 8.0, 800.0)], &baseline, 1.5);
        assert!(ok.is_empty(), "{ok:?}");
        // 3x slower: flagged on candidates/sec only (events/sec may
        // legitimately drop when a candidate gets cheaper to simulate)
        let bad = check_against_baseline(&[fake("plan_hetero", 3.0, 300.0)], &baseline, 1.5);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("candidates_per_sec"), "{bad:?}");
        // unknown baseline cases are skipped
        let skip = check_against_baseline(&[fake("other", 1.0, 1.0)], &baseline, 1.5);
        assert!(skip.is_empty());
    }

    #[test]
    fn baseline_gate_checks_events_for_engine_cases() {
        // a non-planning case (candidates == 0) gates on events/sec
        let mut engine = fake("engine_events", 0.0, 100_000.0);
        engine.candidates = 0;
        let baseline = to_json(&[engine.clone()], true);
        let mut slow = engine.clone();
        slow.events_per_sec = 10_000.0;
        let bad = check_against_baseline(&[slow], &baseline, 1.5);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].contains("events_per_sec"), "{bad:?}");
        let ok = check_against_baseline(&[engine], &baseline, 1.5);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn render_lists_every_case() {
        let t = render(&[fake("a", 1.0, 2.0), fake("b", 3.0, 4.0)]);
        let md = t.markdown();
        assert!(md.contains("| a "));
        assert!(md.contains("| b "));
    }
}
