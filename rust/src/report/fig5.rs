//! **Figure 5**: per-layer compute time (Embedding / Attention /
//! MLP-or-MoE) for GPT-6.7B, GPT-13B and Mixtral-8x7B across H100 and
//! A100, one forward+backward pass at the paper's Table-6 deployment.
//!
//! Paper observations this must reproduce:
//! * MLP degradation on A100: 3–4×,
//! * attention degradation: up to 1.9×,
//! * embedding degradation: ~36.1× (but tiny absolute time — a poor
//!   optimization target, §5 Q1).

use crate::compute::cost::LayerWork;
use crate::compute::table::CostTable;
use crate::config::model::LayerKind;
use crate::config::presets;
use crate::util::table::{fmt_sig, Table};

/// Per-layer compute time of one model on H100 vs A100.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    /// Model display name.
    pub model: String,
    /// Layer kind label.
    pub layer: &'static str,
    /// One fwd+bwd pass on H100, milliseconds.
    pub h100_ms: f64,
    /// One fwd+bwd pass on A100, milliseconds.
    pub a100_ms: f64,
    /// A100 / H100 slowdown ratio.
    pub degradation: f64,
}

/// Compute the Fig-5 series through a cost table (native or PJRT).
pub fn compute(table: &mut CostTable) -> anyhow::Result<Vec<Fig5Row>> {
    let mut rows = Vec::new();
    let gpus = [presets::gpu("H100")?, presets::gpu("A100")?];
    for name in ["gpt-6.7b", "gpt-13b", "mixtral-8x7b"] {
        let m = presets::model(name)?;
        let dep = presets::deployment(name)?;
        let (n_experts, top_k) = match m.moe {
            Some(x) => (x.num_experts as f64, x.top_k as f64),
            None => (0.0, 0.0),
        };
        let mlp_kind = if m.moe.is_some() { LayerKind::Moe } else { LayerKind::Mlp };
        let kinds = [
            (LayerKind::Embedding, "embedding"),
            (LayerKind::Attention, "attention"),
            (mlp_kind, if m.moe.is_some() { "moe" } else { "mlp" }),
        ];
        for (kind, label) in kinds {
            let mut per_gpu = [0.0f64; 2];
            for (gi, gpu) in gpus.iter().enumerate() {
                let mut total = 0.0;
                for is_bwd in [false, true] {
                    let work = LayerWork {
                        kind,
                        hidden: m.hidden_size as f64,
                        ffn: m.ffn_hidden as f64,
                        heads: m.num_heads as f64,
                        seq: m.seq_len as f64,
                        mbs: m.micro_batch as f64,
                        n_experts,
                        top_k,
                        tp: dep.tp as f64,
                        is_bwd,
                    };
                    table.register(&work, gpu);
                    table.evaluate()?;
                    total += table.time(&work, gpu)?.as_secs();
                }
                per_gpu[gi] = total * 1e3; // ms
            }
            rows.push(Fig5Row {
                model: m.name.clone(),
                layer: label,
                h100_ms: per_gpu[0],
                a100_ms: per_gpu[1],
                degradation: per_gpu[1] / per_gpu[0],
            });
        }
    }
    Ok(rows)
}

/// Render the rows as the Fig-5 table.
pub fn render(rows: &[Fig5Row]) -> Table {
    let mut t = Table::new(
        "Figure 5 — per-layer compute time, one fwd+bwd pass (paper deployment)",
        &["model", "layer", "H100 (ms)", "A100 (ms)", "A100/H100"],
    );
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.layer.to_string(),
            fmt_sig(r.h100_ms),
            fmt_sig(r.a100_ms),
            format!("{:.2}x", r.degradation),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_matches_paper() {
        let mut table = CostTable::native();
        let rows = compute(&mut table).unwrap();
        assert_eq!(rows.len(), 9); // 3 models x 3 layers
        for r in &rows {
            match r.layer {
                "mlp" | "moe" => {
                    assert!((3.0..4.0).contains(&r.degradation), "{}: {}", r.model, r.degradation)
                }
                "attention" => {
                    assert!((1.5..1.95).contains(&r.degradation), "{}: {}", r.model, r.degradation)
                }
                "embedding" => {
                    assert!((30.0..40.0).contains(&r.degradation), "{}: {}", r.model, r.degradation)
                }
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn embedding_absolute_time_small() {
        let mut table = CostTable::native();
        let rows = compute(&mut table).unwrap();
        for m in ["GPT-6.7B", "GPT-13B"] {
            let emb = rows.iter().find(|r| r.model == m && r.layer == "embedding").unwrap();
            let mlp = rows.iter().find(|r| r.model == m && r.layer == "mlp").unwrap();
            assert!(emb.h100_ms < mlp.h100_ms, "{m}");
        }
    }

    #[test]
    fn render_emits_all_rows() {
        let mut table = CostTable::native();
        let rows = compute(&mut table).unwrap();
        let t = render(&rows);
        assert_eq!(t.rows.len(), 9);
        assert!(t.markdown().contains("Mixtral"));
    }
}
