//! **Figure 6**: FCT distribution (CCDF) of all collective flows in one
//! iteration, for Ampere, Hopper and Ampere+Hopper (50:50) clusters.
//!
//! As in the paper's prototype, this experiment exercises *interconnect*
//! heterogeneity ("the Ampere and Hopper configuration refers to only
//! the interconnect simulation"): identical workloads run over the three
//! interconnect configurations, and the FCT tail shows the impact of
//! mixing NVLink/PCIe generations.
//!
//! The cluster is scaled by `nodes` (paper: 16/32 nodes; default 4 keeps
//! bench runtime sane on one core — the caps are printed, not silent).

use std::collections::HashMap;

use crate::config::framework::ParallelismSpec;
use crate::config::presets;
use crate::simulator::SimulationBuilder;
use crate::util::stats::Samples;
use crate::util::table::{fmt_sig, Table};
use crate::workload::aicb::WorkloadOptions;

/// The three Fig-6 cluster configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterKind {
    /// All-Ampere interconnect.
    Ampere,
    /// All-Hopper interconnect.
    Hopper,
    /// Half Ampere, half Hopper interconnect.
    Hetero5050,
}

impl ClusterKind {
    /// Display name used in the rendered table.
    pub fn name(self) -> &'static str {
        match self {
            ClusterKind::Ampere => "Ampere",
            ClusterKind::Hopper => "Hopper",
            ClusterKind::Hetero5050 => "Ampere+Hopper(50:50)",
        }
    }
}

/// FCT distribution of one (model, cluster) configuration.
#[derive(Debug)]
pub struct Fig6Cell {
    /// Model display name.
    pub model: String,
    /// Cluster configuration.
    pub cluster: ClusterKind,
    /// Median FCT, microseconds.
    pub p50_us: f64,
    /// 99th-percentile FCT, microseconds.
    pub p99_us: f64,
    /// 99.9th-percentile FCT, microseconds.
    pub p999_us: f64,
    /// Maximum FCT, microseconds.
    pub max_us: f64,
    /// Flow-sample count.
    pub flows: usize,
    /// (FCT microseconds, survival probability) CCDF points.
    pub ccdf: Vec<(f64, f64)>,
}

/// One model / one cluster configuration FCT distribution.
pub fn run_cell(
    model_name: &str,
    kind: ClusterKind,
    nodes: u32,
    microbatch_limit: Option<u64>,
) -> anyhow::Result<Fig6Cell> {
    anyhow::ensure!(nodes >= 2 && nodes % 2 == 0, "fig6 needs an even node count >= 2");
    // Paper Fig 6 exercises *interconnect* heterogeneity only ("the
    // Ampere and Hopper configuration refers to only the interconnect
    // simulation"): compute is identical (A100) in all three cells so
    // the FCT differences are attributable to NVLink/PCIe generations.
    let cluster = match kind {
        ClusterKind::Ampere => {
            presets::cluster_hetero_interconnect("A100", "ampere", nodes, "ampere", 0)?
        }
        ClusterKind::Hopper => {
            presets::cluster_hetero_interconnect("A100", "hopper", nodes, "hopper", 0)?
        }
        ClusterKind::Hetero5050 => {
            presets::cluster_hetero_interconnect("A100", "ampere", nodes / 2, "hopper", nodes / 2)?
        }
    };
    let model = presets::model(model_name)?;
    let dep = presets::deployment(model_name)?;
    // keep the paper's TP degree, fill the cluster with DP
    let world = cluster.total_gpus();
    anyhow::ensure!(world % dep.tp == 0, "world {world} not divisible by tp {}", dep.tp);
    let par = ParallelismSpec { tp: dep.tp, pp: 1, dp: world / dep.tp };
    let report = SimulationBuilder::new(model, cluster)
        .parallelism(par)
        .workload_options(WorkloadOptions { microbatch_limit, ..Default::default() })
        .build()?
        .run_iteration()?;
    let mut all: Samples = report.fct_all;
    Ok(Fig6Cell {
        model: report.model_name,
        cluster: kind,
        p50_us: all.percentile(50.0) * 1e6,
        p99_us: all.percentile(99.0) * 1e6,
        p999_us: all.percentile(99.9) * 1e6,
        max_us: all.max() * 1e6,
        flows: all.len(),
        ccdf: all.ccdf(200),
    })
}

/// Full Fig-6 grid: 3 models x 3 cluster kinds.
pub fn compute(
    nodes: u32,
    microbatch_limit: Option<u64>,
    models: &[&str],
) -> anyhow::Result<Vec<Fig6Cell>> {
    let mut cells = Vec::new();
    for m in models {
        for kind in [ClusterKind::Ampere, ClusterKind::Hopper, ClusterKind::Hetero5050] {
            cells.push(run_cell(m, kind, nodes, microbatch_limit)?);
        }
    }
    Ok(cells)
}

/// Render the cells as the Fig-6 summary table.
pub fn render(cells: &[Fig6Cell]) -> Table {
    let mut t = Table::new(
        "Figure 6 — FCT distribution of collective flows (one iteration)",
        &["model", "cluster", "flows", "p50 (us)", "p99 (us)", "p99.9 (us)", "max (us)", "tail vs Ampere"],
    );
    // index Ampere tails for the degradation column
    let mut ampere_tail: HashMap<&str, f64> = HashMap::new();
    for c in cells {
        if c.cluster == ClusterKind::Ampere {
            ampere_tail.insert(c.model.as_str(), c.p999_us);
        }
    }
    for c in cells {
        let vs = ampere_tail
            .get(c.model.as_str())
            .map(|a| format!("{:+.1}%", (c.p999_us / a - 1.0) * 100.0))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            c.model.clone(),
            c.cluster.name().to_string(),
            c.flows.to_string(),
            fmt_sig(c.p50_us),
            fmt_sig(c.p99_us),
            fmt_sig(c.p999_us),
            fmt_sig(c.max_us),
            vs,
        ]);
    }
    t
}

/// CCDF CSV (one curve per model/cluster) for plotting.
pub fn ccdf_csv(cells: &[Fig6Cell]) -> String {
    let mut s = String::from("model,cluster,fct_us,ccdf\n");
    for c in cells {
        for (v, p) in &c.ccdf {
            s.push_str(&format!("{},{},{:.3},{:.6}\n", c.model, c.cluster.name(), v * 1e6, p));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fig6_cell_runs() {
        let cell = run_cell("gpt-6.7b", ClusterKind::Hopper, 2, Some(1)).unwrap();
        assert!(cell.flows > 0);
        assert!(cell.p50_us > 0.0);
        assert!(cell.p999_us >= cell.p50_us);
    }

    #[test]
    fn hetero_tail_at_least_hopper_tail() {
        let hopper = run_cell("gpt-6.7b", ClusterKind::Hopper, 2, Some(1)).unwrap();
        let hetero = run_cell("gpt-6.7b", ClusterKind::Hetero5050, 2, Some(1)).unwrap();
        assert!(
            hetero.p999_us >= hopper.p999_us,
            "hetero {} < hopper {}",
            hetero.p999_us,
            hopper.p999_us
        );
    }

    #[test]
    fn odd_node_count_rejected() {
        assert!(run_cell("gpt-6.7b", ClusterKind::Ampere, 3, Some(1)).is_err());
    }

    #[test]
    fn ccdf_csv_well_formed() {
        let cell = run_cell("gpt-6.7b", ClusterKind::Ampere, 2, Some(1)).unwrap();
        let csv = ccdf_csv(&[cell]);
        assert!(csv.starts_with("model,cluster,fct_us,ccdf\n"));
        assert!(csv.lines().count() > 2);
    }
}
