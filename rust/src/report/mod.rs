//! Regeneration of the paper's evaluation artifacts (system S13):
//! * [`fig5`] — per-layer compute time across GPU generations,
//! * [`fig6`] — FCT distribution of collectives on homogeneous vs
//!   heterogeneous clusters,
//! * [`table1`] — exposed-communication characteristics of DP/TP/PP for
//!   Llama-2 70B.
//!
//! Each module produces a [`crate::util::table::Table`] (markdown to
//! stdout, CSV into `results/`) so EXPERIMENTS.md entries are
//! copy-pasteable and diffs are reviewable.
//!
//! [`bench`] is the odd one out: it measures the *simulator itself*
//! (`hetsim bench`, machine-readable `BENCH_plan.json`) and backs the
//! CI perf-regression gate. [`goodput`] turns fault schedules
//! ([`crate::system::failure`]) into effective-goodput rankings
//! (`hetsim goodput`, DESIGN.md §26). [`serve`] reports serving
//! simulations: goodput, TTFT/TBT, and latency percentiles per device
//! group (`hetsim serve-sim`, DESIGN.md §27).

pub mod bench;
pub mod fig1;
pub mod fig5;
pub mod fig6;
pub mod goodput;
pub mod serve;
pub mod table1;

use std::path::PathBuf;

/// Default results directory (next to the repo root).
pub fn results_dir() -> PathBuf {
    std::env::var("HETSIM_RESULTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("results"))
}
