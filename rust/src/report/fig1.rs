//! **Figure 1**: evolution of AI cluster hardware — the paper's intro
//! motivation that FLOPS grows ~3.0x/year while interconnect bandwidth
//! grows ~1.4x/year, making homogeneous fleet refreshes financially
//! impractical. Regenerated from the generation presets.

use crate::config::presets;
use crate::util::table::{fmt_sig, Table};

/// Release years used for the growth-rate fit.
const GENERATIONS: &[(&str, &str, f64)] = &[
    ("V100", "volta", 2017.0),
    ("A100", "ampere", 2020.0),
    ("H100", "hopper", 2022.0),
    ("B200", "blackwell", 2024.0),
];

/// One hardware generation's headline numbers.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// GPU model name.
    pub gpu: &'static str,
    /// Launch year used for the growth-rate fit.
    pub year: f64,
    /// Peak dense bf16 TFLOPS.
    pub tflops: f64,
    /// HBM bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Aggregate NVLink bandwidth, Gbps.
    pub nvlink_gbps: f64,
    /// NIC line rate, Gbps.
    pub nic_gbps: f64,
}

/// Collect the per-generation rows from the presets.
pub fn compute() -> anyhow::Result<Vec<Fig1Row>> {
    let mut rows = Vec::new();
    for (gpu, arch, year) in GENERATIONS {
        let g = presets::gpu(gpu)?;
        let ic = presets::interconnect(arch)?;
        rows.push(Fig1Row {
            gpu,
            year: *year,
            tflops: g.peak_flops / 1e12,
            mem_bw_gbs: g.mem_bw / 1e9,
            nvlink_gbps: ic.nvlink_bw.gbps(),
            nic_gbps: ic.nic_bw.gbps(),
        });
    }
    Ok(rows)
}

/// Compound annual growth rate between the first and last generation.
pub fn cagr(first: (f64, f64), last: (f64, f64)) -> f64 {
    let (y0, v0) = first;
    let (y1, v1) = last;
    (v1 / v0).powf(1.0 / (y1 - y0))
}

/// Render the rows as the Fig-1 table.
pub fn render(rows: &[Fig1Row]) -> Table {
    let mut t = Table::new(
        "Figure 1 — evolution of AI cluster hardware (per generation preset)",
        &["GPU", "year", "peak TFLOPS", "HBM GB/s", "NVLink Gbps", "NIC Gbps"],
    );
    for r in rows {
        t.row(vec![
            r.gpu.to_string(),
            format!("{:.0}", r.year),
            fmt_sig(r.tflops),
            fmt_sig(r.mem_bw_gbs),
            fmt_sig(r.nvlink_gbps),
            fmt_sig(r.nic_gbps),
        ]);
    }
    t
}

/// The paper's headline growth rates, computed from the presets.
pub fn growth_summary(rows: &[Fig1Row]) -> String {
    let f = rows.first().unwrap();
    let l = rows.last().unwrap();
    let flops = cagr((f.year, f.tflops), (l.year, l.tflops));
    let net = cagr((f.year, f.nvlink_gbps), (l.year, l.nvlink_gbps));
    format!(
        "compute grows {flops:.2}x/year vs interconnect {net:.2}x/year (paper: 3.0x vs 1.4x)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growth_rates_match_paper_shape() {
        let rows = compute().unwrap();
        let f = rows.first().unwrap();
        let l = rows.last().unwrap();
        let flops = cagr((f.year, f.tflops), (l.year, l.tflops));
        let net = cagr((f.year, f.nvlink_gbps), (l.year, l.nvlink_gbps));
        // paper Fig 1: ~3.0x/yr compute vs ~1.4x/yr interconnect
        assert!(flops > net, "compute must outgrow interconnect");
        assert!((1.2..2.2).contains(&net), "net cagr {net}");
        assert!((1.4..3.5).contains(&flops), "flops cagr {flops}");
    }

    #[test]
    fn table_has_all_generations() {
        let rows = compute().unwrap();
        assert_eq!(rows.len(), 4);
        let t = render(&rows);
        assert!(t.markdown().contains("B200"));
        assert!(growth_summary(&rows).contains("x/year"));
    }
}
