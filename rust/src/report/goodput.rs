//! Effective-goodput reporting under failures (DESIGN.md §26, §28).
//!
//! Iteration time alone mispredicts what a plan delivers at scale:
//! MTBF makes failures routine, and a plan that is 5% faster but loses
//! more work per fail-stop (or re-plans onto a worse surviving
//! cluster) can deliver fewer useful tokens per wall-clock second.
//! This module turns a fault schedule ([`crate::system::failure`])
//! plus a plan's simulated iteration time into **effective goodput**:
//!
//! ```text
//! goodput = useful_tokens / horizon_s
//! useful_tokens = Σ productive_span / τ · tokens_per_iter
//! τ = iteration_s · straggler_mult + checkpoint_write_s / interval
//! ```
//!
//! Fault classes are charged differently. A **node loss** charges the
//! *expected* lost work — half a checkpoint interval of iterations at
//! the current effective rate — plus the checkpoint restore time and
//! the fixed restart warmup, then re-runs the planner on the surviving
//! cluster (each [`crate::planner::search`] run shares its
//! [`crate::simulator::EvalContext`] across candidates) and splices
//! the new plan's per-iteration cost, floored at the pre-loss cost so
//! goodput is monotone under event-set inclusion. Same-instant node
//! losses (a correlated [`domain_schedule`] blast) coalesce into
//! **one** incident: one recovery penalty, one replan on the final
//! survivor set. A **NIC or link outage** is repairable: it charges
//! only half an iteration plus the warmup (no checkpoint restore —
//! state survives in device memory), then either runs *degraded*
//! until the [`RepairSpec`] window closes (when the [`DegradedModel`]
//! finds a surviving detour route) or hard-stops until repair (when
//! no route survives, or no model was supplied).
//!
//! The walk itself is sequential and allocation-light, so a goodput
//! figure is deterministic for a given spec regardless of how many
//! worker threads scored the plans. [`monte_carlo`] lifts the walk to
//! N seeded trajectories ([`trajectory_seed`] is index-keyed, so the
//! trajectory set nests as N grows and the result is independent of
//! the thread count), and [`mc_stats`] condenses them into
//! mean / p5 / p95 / 95% confidence bounds for blast-radius-aware
//! ranking (`--objective goodput-ci` scores by [`McGoodput::ci95_lo`]).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::planner::{search, PlanOptions, PlanSearchReport};
use crate::system::failure::{
    domain_schedule, mtbf_schedule, CheckpointSpec, DegradedModel, DomainSpec, FailureDomains,
    FaultClass, FaultEvent, FaultKind, RepairSpec,
};
use crate::util::par::parallel_map;
use crate::util::stats::Samples;
use crate::util::table::Table;
use crate::util::units::Time;

/// Everything the goodput walk needs to know about one plan.
#[derive(Debug, Clone, Copy)]
pub struct GoodputInput<'a> {
    /// The trained model (tokens per iteration, checkpoint bytes).
    pub model: &'a ModelSpec,
    /// The full (pre-failure) cluster the plan was laid out on.
    pub cluster: &'a ClusterSpec,
    /// The plan's simulated per-iteration time on the full cluster.
    pub iteration: Time,
    /// The plan's data-parallel degree: checkpoint writers shard the
    /// state `dp` ways, so larger DP writes checkpoints faster — but
    /// also restarts more state on every fail-stop.
    pub dp: u32,
    /// Checkpoint/restore cost model.
    pub checkpoint: CheckpointSpec,
    /// Repair windows for NIC / link outages.
    pub repair: RepairSpec,
    /// Degraded-mode routing model for the cluster's fabric; `None`
    /// treats unrepaired NIC/link outages as hard stops (no reroute
    /// analysis available).
    pub degraded: Option<&'a DegradedModel>,
    /// Fraction of an iteration spent on exposed communication, in
    /// `[0, 1]` — scales how much a degraded fabric slows the plan
    /// ([`DegradedModel::slowdown`]).
    pub comm_fraction: f64,
    /// Wall-clock horizon to integrate over, in seconds.
    pub horizon_s: f64,
}

/// Effective-goodput accounting for one plan over one fault schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GoodputReport {
    /// Useful tokens per wall-clock second over the horizon — the
    /// headline number plans are ranked by.
    pub goodput_tokens_per_s: f64,
    /// Total useful (non-lost) tokens produced within the horizon.
    pub useful_tokens: f64,
    /// The integration horizon, echoed for rate/total conversions.
    pub horizon_s: f64,
    /// Wall-clock seconds spent on recovery (lost work, restore,
    /// warmup), degraded-mode shortfall, or halted outright.
    pub lost_s: f64,
    /// `1 - lost_s / horizon_s`, clamped to `[0, 1]`.
    pub availability: f64,
    /// Permanent node losses that struck a live node (a correlated
    /// blast counts each member, but charges one incident).
    pub fail_stops: usize,
    /// Repairable NIC/link outages that struck a live node.
    pub link_outages: usize,
    /// Straggler events that slowed a live node.
    pub stragglers: usize,
    /// Loss incidents that triggered a planner re-run on the survivors
    /// (one per coalesced blast, not one per node).
    pub replans: usize,
    /// True when training halted before the horizon (no surviving
    /// nodes, or no feasible plan on the survivors).
    pub halted: bool,
    /// The per-iteration cost in effect at the end of the walk
    /// (≥ the initial cost: re-plans are floored at the pre-loss cost).
    pub final_iteration_s: f64,
}

/// Remove dead nodes, keeping everything else about the cluster.
fn surviving(cluster: &ClusterSpec, alive: &[bool]) -> ClusterSpec {
    let mut c = cluster.clone();
    c.nodes = cluster
        .nodes
        .iter()
        .zip(alive)
        .filter(|(_, a)| **a)
        .map(|(n, _)| n.clone())
        .collect();
    let dead = alive.iter().filter(|a| !**a).count();
    c.name = format!("{}-minus{}", cluster.name, dead);
    c
}

/// Wall-clock / token accrual state for one goodput walk, including
/// the degraded-mode window: `[t, deg_until)` runs at `deg_slow`
/// times the healthy iteration cost, with the shortfall charged to
/// `lost`.
struct WalkAcct {
    tokens_per_iter: f64,
    ckpt_overhead: f64,
    horizon: f64,
    t: f64,
    useful: f64,
    lost: f64,
    deg_until: f64,
    deg_slow: f64,
}

impl WalkAcct {
    fn tau(&self, iter_s: f64, mult: f64) -> f64 {
        (iter_s * mult + self.ckpt_overhead).max(f64::MIN_POSITIVE)
    }

    /// Advance wall-clock to `target` (clamped to the horizon),
    /// accruing useful tokens at the degraded rate while inside the
    /// degraded window and at the healthy rate after it. The degraded
    /// shortfall — time not converted to tokens relative to the
    /// healthy rate — is charged to `lost`, so degraded running never
    /// scores better than healthy running (monotonicity).
    fn advance(&mut self, target: f64, iter_s: f64, mult: f64) {
        let target = target.min(self.horizon);
        if target <= self.t {
            return;
        }
        let healthy = self.tau(iter_s, mult);
        if self.t < self.deg_until {
            let span = target.min(self.deg_until) - self.t;
            let slowed = self.tau(iter_s, mult * self.deg_slow);
            self.useful += span / slowed * self.tokens_per_iter;
            self.lost += span * (1.0 - healthy / slowed);
            self.t += span;
        }
        if target > self.t {
            let span = target - self.t;
            self.useful += span / healthy * self.tokens_per_iter;
            self.t = target;
        }
    }
}

/// Walk a sorted fault schedule over `[0, horizon_s]` and integrate
/// useful tokens. `replan` maps a surviving cluster to its best
/// per-iteration time (`None` = no feasible plan, training halts);
/// callers pass the real planner ([`sweep`] does, memoized per
/// surviving cluster) or a synthetic model (the property tests do).
///
/// Monotonicity: adding events to the schedule never increases the
/// returned goodput — every event only ever adds recovery time,
/// raises the straggler multiplier (max-persistent), widens the
/// degraded window (max-coalesced end and slowdown), or raises the
/// floored iteration cost. Combined with the nested-thinning schedule
/// construction, goodput is monotone non-increasing in the MTBF scale
/// when repair windows are zero; with nonzero repair a node loss can
/// moot a later repairable outage's charge, so the strict guarantee
/// is stated for the zero-repair regime.
pub fn walk(
    input: &GoodputInput<'_>,
    events: &[FaultEvent],
    replan: &mut dyn FnMut(&ClusterSpec) -> Option<Time>,
) -> GoodputReport {
    let ckpt = &input.checkpoint;
    let tokens_per_iter = (input.model.global_batch * input.model.seq_len) as f64;
    // weights + fp32 Adam moments and master copy, sharded dp ways
    let ckpt_bytes = input.model.param_count() as f64 * (input.model.dtype_bytes + 12) as f64;
    let write_s = ckpt_bytes / (ckpt.write_gbps * 1e9 * input.dp.max(1) as f64);
    let mut acct = WalkAcct {
        tokens_per_iter,
        ckpt_overhead: write_s / ckpt.interval_iters as f64,
        horizon: input.horizon_s,
        t: 0.0,
        useful: 0.0,
        lost: 0.0,
        deg_until: 0.0,
        deg_slow: 1.0,
    };

    let mut iter_s = input.iteration.as_secs();
    let mut mult = 1.0f64;
    let mut alive = vec![true; input.cluster.nodes.len()];
    let (mut fail_stops, mut link_outages) = (0usize, 0usize);
    let (mut stragglers, mut replans) = (0usize, 0usize);
    let mut halted = false;

    let mut i = 0usize;
    'events: while i < events.len() {
        let ev = events[i];
        if ev.at_s > input.horizon_s {
            break;
        }
        // if recovery from a previous fault is still in progress, the
        // new fault takes effect once the job is back up
        let fire = ev.at_s.max(acct.t);
        if fire >= input.horizon_s {
            break;
        }
        acct.advance(fire, iter_s, mult);
        match ev.kind {
            FaultKind::Straggler { node, mult: m } => {
                i += 1;
                if !alive[node as usize] {
                    continue; // faults on an already-dead node are moot
                }
                stragglers += 1;
                mult = mult.max(m);
            }
            FaultKind::NodeFail { .. } => {
                // Coalesce a same-instant blast (a correlated failure
                // domain emits one NodeFail per member at a bit-equal
                // timestamp, adjacent after the (at_s, node) sort) into
                // ONE incident: one recovery penalty, one replan on the
                // final survivor set.
                let mut struck = Vec::new();
                while i < events.len() {
                    match events[i].kind {
                        FaultKind::NodeFail { node }
                            if events[i].at_s.to_bits() == ev.at_s.to_bits() =>
                        {
                            if alive[node as usize] {
                                struck.push(node as usize);
                            }
                            i += 1;
                        }
                        _ => break,
                    }
                }
                if struck.is_empty() {
                    continue;
                }
                fail_stops += struck.len();
                // expected lost work: half a checkpoint interval at the
                // current effective rate, plus restore + warmup
                let penalty = 0.5 * ckpt.interval_iters as f64 * acct.tau(iter_s, mult)
                    + write_s
                    + ckpt.restart_warmup_s;
                acct.lost += penalty;
                acct.t += penalty;
                for n in struck {
                    alive[n] = false;
                }
                let rest = surviving(input.cluster, &alive);
                if rest.nodes.is_empty() {
                    halted = true;
                    break 'events;
                }
                replans += 1;
                match replan(&rest) {
                    // floor at the pre-loss cost (monotonicity)
                    Some(new_iter) => iter_s = iter_s.max(new_iter.as_secs()),
                    None => {
                        halted = true;
                        break 'events;
                    }
                }
            }
            FaultKind::NicFail { node } | FaultKind::LinkFail { node } => {
                i += 1;
                if !alive[node as usize] {
                    continue;
                }
                let class = if matches!(ev.kind, FaultKind::NicFail { .. }) {
                    FaultClass::Nic
                } else {
                    FaultClass::Link
                };
                link_outages += 1;
                // the job reconnects from device memory: half an
                // in-flight iteration plus warmup, no checkpoint restore
                let penalty = 0.5 * acct.tau(iter_s, mult) + ckpt.restart_warmup_s;
                acct.lost += penalty;
                acct.t += penalty;
                let repair_end = ev.at_s + input.repair.for_class(class);
                match input.degraded.and_then(|d| d.slowdown(node, class, input.comm_fraction))
                {
                    // a detour route survives: run degraded until repair
                    Some(s) if repair_end > acct.t => {
                        acct.deg_until = acct.deg_until.max(repair_end);
                        acct.deg_slow = acct.deg_slow.max(s);
                    }
                    Some(_) => {} // repaired within the restart penalty
                    // no surviving route (or no reroute model): hard
                    // outage until the repair lands
                    None => {
                        let end = repair_end.min(input.horizon_s);
                        if end > acct.t {
                            acct.lost += end - acct.t;
                            acct.t = end;
                        }
                    }
                }
            }
        }
    }
    if halted {
        acct.lost += (input.horizon_s - acct.t).max(0.0);
        acct.t = input.horizon_s;
    } else {
        acct.advance(input.horizon_s, iter_s, mult);
    }
    GoodputReport {
        goodput_tokens_per_s: acct.useful / input.horizon_s.max(f64::MIN_POSITIVE),
        useful_tokens: acct.useful,
        horizon_s: input.horizon_s,
        lost_s: acct.lost,
        availability: (1.0 - acct.lost / input.horizon_s.max(f64::MIN_POSITIVE)).clamp(0.0, 1.0),
        fail_stops,
        link_outages,
        stragglers,
        replans,
        halted,
        final_iteration_s: iter_s,
    }
}

/// The seed for Monte-Carlo trajectory `index`. Index 0 maps to the
/// base seed itself — a 1-trajectory Monte-Carlo run is bit-identical
/// to the single deterministic walk — and each index's seed is
/// independent of the trajectory count, so the trajectory set for
/// `N = 4` is an exact prefix of the set for `N = 16`.
pub fn trajectory_seed(seed: u64, index: u32) -> u64 {
    seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run [`walk`] over `trajectories` independently drawn fault
/// schedules. `draw(i)` materializes the schedule for trajectory `i`
/// (callers seed it with [`trajectory_seed`]); `replan` must be
/// `Sync` — trajectories run on `threads` workers via
/// [`parallel_map`], and the result vector is index-ordered, so the
/// output is byte-identical for any thread count.
pub fn monte_carlo<D, R>(
    input: &GoodputInput<'_>,
    draw: D,
    trajectories: u32,
    threads: usize,
    replan: R,
) -> Vec<GoodputReport>
where
    D: Fn(u32) -> Vec<FaultEvent> + Sync,
    R: Fn(&ClusterSpec) -> Option<Time> + Sync,
{
    parallel_map(trajectories as usize, threads, |i| {
        let events = draw(i as u32);
        let mut wrap = |rest: &ClusterSpec| replan(rest);
        walk(input, &events, &mut wrap)
    })
}

/// Distribution summary over one plan's Monte-Carlo trajectories.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct McGoodput {
    /// Number of trajectories summarized.
    pub trajectories: usize,
    /// Mean goodput (tokens/s) across trajectories.
    pub mean: f64,
    /// 5th-percentile goodput — the near-worst-case trajectory.
    pub p5: f64,
    /// 95th-percentile goodput — the near-best-case trajectory.
    pub p95: f64,
    /// Lower 95% confidence bound on the mean
    /// (`mean − 1.96·sd/√n`) — the `--objective goodput-ci` score.
    pub ci95_lo: f64,
    /// Upper 95% confidence bound on the mean.
    pub ci95_hi: f64,
    /// Sample standard deviation of per-trajectory goodput.
    pub stddev: f64,
    /// Trajectories that halted before the horizon.
    pub halted: usize,
}

/// Condense Monte-Carlo walk results into mean / p5 / p95 and a 95%
/// confidence interval on the mean.
pub fn mc_stats(reports: &[GoodputReport]) -> McGoodput {
    let mut s = Samples::with_capacity(reports.len());
    for r in reports {
        s.push(r.goodput_tokens_per_s);
    }
    let mean = s.mean();
    let sd = s.stddev();
    let half = if reports.is_empty() { 0.0 } else { 1.96 * sd / (reports.len() as f64).sqrt() };
    McGoodput {
        trajectories: reports.len(),
        mean,
        p5: s.percentile(5.0),
        p95: s.percentile(95.0),
        ci95_lo: mean - half,
        ci95_hi: mean + half,
        stddev: sd,
        halted: reports.iter().filter(|r| r.halted).count(),
    }
}

/// Knobs for [`sweep`] / [`annotate`].
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Planner options for the underlying candidate search (and for
    /// the re-plan runs on surviving clusters).
    pub plan: PlanOptions,
    /// How many top-ranked plans to score for goodput (0 = all).
    pub top: usize,
    /// Wall-clock horizon in seconds (default: one day).
    pub horizon_s: f64,
    /// MTBF failure-rate scale (1.0 = the per-arch table as-is;
    /// clamped at [`crate::system::failure::SCALE_CAP`]).
    pub mtbf_scale: f64,
    /// Seed for the MTBF schedule (and, via [`trajectory_seed`], for
    /// every Monte-Carlo trajectory).
    pub seed: u64,
    /// Checkpoint/restore cost model.
    pub checkpoint: CheckpointSpec,
    /// Repair windows for NIC / link outages.
    pub repair: RepairSpec,
    /// Correlated failure-domain process layered on top of the
    /// per-node MTBF schedule (`None` = independent node faults only).
    pub domains: Option<DomainSpec>,
    /// Monte-Carlo trajectories per plan (0 = one deterministic walk;
    /// ≥ 1 ranks by the lower 95% confidence bound on mean goodput).
    pub mc: u32,
    /// Incumbent-style early stop for Monte-Carlo ranking (DESIGN.md
    /// §29, the `--search bnb` goodput path): stop drawing a plan's
    /// trajectories once even its *best-achievable* mean goodput —
    /// every remaining trajectory scoring the fault-free ceiling —
    /// falls below the best score already ranked. The truncated plan's
    /// partial score is provably below the incumbent, so the winner is
    /// unaffected. Off by default: the exhaustive path stays
    /// byte-identical.
    pub mc_early_stop: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            plan: PlanOptions::default(),
            top: 5,
            horizon_s: 86_400.0,
            mtbf_scale: 1.0,
            seed: 42,
            checkpoint: CheckpointSpec::default(),
            repair: RepairSpec::default(),
            domains: None,
            mc: 0,
            mc_early_stop: false,
        }
    }
}

/// One plan's goodput score in a sweep.
#[derive(Debug, Clone)]
pub struct SweepEntry {
    /// The candidate key (`tp…-pp…-dp…-…`).
    pub plan: String,
    /// Fault-free simulated iteration time.
    pub iteration: Time,
    /// The plan's DP degree (checkpoint sharding width).
    pub dp: u32,
    /// The goodput walk's result for this plan (trajectory 0 when
    /// Monte-Carlo is on — the deterministic base schedule).
    pub goodput: GoodputReport,
    /// Monte-Carlo distribution summary, when `mc ≥ 1`.
    pub mc: Option<McGoodput>,
}

/// The `hetsim goodput` result: top plans re-ranked by effective
/// goodput under an MTBF schedule.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Entries sorted by goodput (lower CI bound under Monte-Carlo),
    /// best first (key tie-break).
    pub entries: Vec<SweepEntry>,
    /// Number of fault events in the materialized base schedule
    /// (trajectory 0 when Monte-Carlo is on).
    pub events: usize,
    /// The integration horizon in seconds.
    pub horizon_s: f64,
    /// The MTBF scale the schedule was drawn at.
    pub mtbf_scale: f64,
}

impl SweepReport {
    /// The goodput-optimal entry.
    pub fn best(&self) -> &SweepEntry {
        &self.entries[0]
    }

    /// Render the ranked goodput table plus a summary line. With
    /// Monte-Carlo entries the table switches to distribution columns
    /// (CI bounds, p5/p95); without them it is byte-identical to the
    /// single-walk rendering.
    pub fn render(&self) -> String {
        if self.entries.iter().any(|e| e.mc.is_some()) {
            return self.render_mc();
        }
        let mut t = Table::new(
            "Effective goodput under MTBF faults",
            &["rank", "plan", "goodput tok/s", "iteration", "avail", "fail-stops", "replans"],
        );
        for (i, e) in self.entries.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                e.plan.clone(),
                format!("{:.1}", e.goodput.goodput_tokens_per_s),
                e.iteration.human(),
                format!("{:.4}", e.goodput.availability),
                e.goodput.fail_stops.to_string(),
                e.goodput.replans.to_string(),
            ]);
        }
        let mut s = t.markdown();
        s.push_str(&format!(
            "\n{} fault events over {:.0}s at {}x MTBF rate | best by goodput: {}\n",
            self.events,
            self.horizon_s,
            self.mtbf_scale,
            self.entries.first().map(|e| e.plan.as_str()).unwrap_or("-"),
        ));
        s
    }

    fn render_mc(&self) -> String {
        let mut t = Table::new(
            "Monte-Carlo effective goodput under MTBF + domain faults",
            &["rank", "plan", "ci95-lo tok/s", "mean tok/s", "p5", "p95", "iteration", "halted"],
        );
        for (i, e) in self.entries.iter().enumerate() {
            let m = e.mc.as_ref().expect("render_mc requires mc entries");
            t.row(vec![
                (i + 1).to_string(),
                e.plan.clone(),
                format!("{:.1}", m.ci95_lo),
                format!("{:.1}", m.mean),
                format!("{:.1}", m.p5),
                format!("{:.1}", m.p95),
                e.iteration.human(),
                format!("{}/{}", m.halted, m.trajectories),
            ]);
        }
        let trajectories =
            self.entries.first().and_then(|e| e.mc.as_ref()).map(|m| m.trajectories).unwrap_or(0);
        let mut s = t.markdown();
        s.push_str(&format!(
            "\n{} trajectories x {} base events over {:.0}s at {}x MTBF rate | best by ci95-lo: {}\n",
            trajectories,
            self.events,
            self.horizon_s,
            self.mtbf_scale,
            self.entries.first().map(|e| e.plan.as_str()).unwrap_or("-"),
        ));
        s
    }
}

/// The planner re-run used when a node loss shrinks the cluster:
/// memoized per surviving-cluster shape so a sweep over many plans —
/// and every Monte-Carlo trajectory — pays for each survivor search
/// once. The cache is compute-outside-lock: concurrent trajectories
/// may race to fill one key, but the search is deterministic, so the
/// raced inserts carry identical values and the result is independent
/// of the thread count.
fn replan_shared<'a>(
    model: &'a ModelSpec,
    opts: &'a PlanOptions,
    cache: &'a Mutex<HashMap<String, Option<Time>>>,
) -> impl Fn(&ClusterSpec) -> Option<Time> + Sync + 'a {
    move |rest: &ClusterSpec| {
        let key: String = rest
            .nodes
            .iter()
            .map(|n| format!("{}x{};", n.gpu.name, n.gpus_per_node))
            .collect();
        if let Some(hit) = cache.lock().unwrap().get(&key) {
            return *hit;
        }
        let val = search(model, rest, opts).ok().map(|r| r.best().iteration_time);
        cache.lock().unwrap().insert(key, val);
        val
    }
}

/// The fault schedule for one trajectory: the per-node MTBF draw,
/// plus the correlated failure-domain draw when domains are
/// configured, merged in `(at_s, node)` order so a domain blast stays
/// adjacent for the walk's same-instant coalescing.
fn draw_trajectory(
    cluster: &ClusterSpec,
    opts: &SweepOptions,
    domains: Option<&FailureDomains>,
    index: u32,
) -> Vec<FaultEvent> {
    let seed = trajectory_seed(opts.seed, index);
    let mut events = mtbf_schedule(cluster, opts.horizon_s, opts.mtbf_scale, seed);
    if let (Some(members), Some(d)) = (domains, opts.domains.as_ref()) {
        events.extend(domain_schedule(cluster, members, d.horizon_s, d.mtbf_hours, d.scale, seed));
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.kind.node().cmp(&b.kind.node())));
    }
    events
}

/// The exposed-communication fraction of an iteration, from the plan
/// evaluation's busy-time accounting: per-rank mean comm-busy time
/// over the iteration time, clamped to `[0, 1]`.
fn comm_fraction(comm_busy: Time, world: u32, iteration: Time) -> f64 {
    let per_rank = comm_busy.as_secs() / world.max(1) as f64;
    (per_rank / iteration.as_secs().max(f64::MIN_POSITIVE)).clamp(0.0, 1.0)
}

/// Trajectories per deterministic early-stop batch: the stop test runs
/// only at batch boundaries, so results are byte-identical for any
/// worker-thread count (the batch composition never depends on
/// scheduling).
const MC_BATCH: u32 = 4;

/// Score one plan under `opts`: a single deterministic walk when
/// `mc == 0`, otherwise `mc` Monte-Carlo trajectories condensed into
/// [`McGoodput`]. Returns the trajectory-0 report plus the summary.
///
/// `incumbent` is the best ranking score seen so far by the caller
/// (only consulted when [`SweepOptions::mc_early_stop`] is on): after
/// each [`MC_BATCH`]-trajectory batch, if the plan's best-achievable
/// final mean — completed sum plus the fault-free ceiling for every
/// remaining trajectory — is already below the incumbent, the
/// remaining trajectories are skipped. The partial mean is ≤ that
/// best-achievable value and the partial `ci95_lo` is ≤ the partial
/// mean, so the truncated score stays below the incumbent and the
/// ranking winner is unchanged; [`McGoodput::trajectories`] records
/// the truncation.
fn score_plan(
    input: &GoodputInput<'_>,
    cluster: &ClusterSpec,
    opts: &SweepOptions,
    domains: Option<&FailureDomains>,
    replan: &(impl Fn(&ClusterSpec) -> Option<Time> + Sync),
    incumbent: Option<f64>,
) -> (GoodputReport, Option<McGoodput>) {
    if opts.mc == 0 {
        let events = draw_trajectory(cluster, opts, domains, 0);
        let mut wrap = |rest: &ClusterSpec| replan(rest);
        return (walk(input, &events, &mut wrap), None);
    }
    if !opts.mc_early_stop || incumbent.is_none() {
        // the exhaustive path, byte-identical to pre-early-stop runs
        let reports = monte_carlo(
            input,
            |i| draw_trajectory(cluster, opts, domains, i),
            opts.mc,
            opts.plan.threads,
            replan,
        );
        let stats = mc_stats(&reports);
        return (reports[0], Some(stats));
    }
    let inc = incumbent.unwrap();
    // per-trajectory goodput can never beat the fault-free walk
    let g_max = walk(input, &[], &mut |_| None).goodput_tokens_per_s;
    let mut reports: Vec<GoodputReport> = Vec::with_capacity(opts.mc as usize);
    let mut done = 0u32;
    while done < opts.mc {
        let count = MC_BATCH.min(opts.mc - done);
        let batch = parallel_map(count as usize, opts.plan.threads, |j| {
            let events = draw_trajectory(cluster, opts, domains, done + j as u32);
            let mut wrap = |rest: &ClusterSpec| replan(rest);
            walk(input, &events, &mut wrap)
        });
        reports.extend(batch);
        done += count;
        if done < opts.mc {
            let sum: f64 = reports.iter().map(|r| r.goodput_tokens_per_s).sum();
            let best_achievable = (sum + (opts.mc - done) as f64 * g_max) / opts.mc as f64;
            if best_achievable < inc {
                break; // provably dominated — stop paying for walks
            }
        }
    }
    let stats = mc_stats(&reports);
    (reports[0], Some(stats))
}

/// The ranking score for one scored plan: the lower 95% CI bound when
/// Monte-Carlo is on, the single walk's goodput otherwise.
fn score_of(goodput: &GoodputReport, mc: &Option<McGoodput>) -> f64 {
    mc.as_ref().map(|m| m.ci95_lo).unwrap_or(goodput.goodput_tokens_per_s)
}

/// Rank plans by effective goodput: run the plan search, materialize
/// the fault schedule(s), walk them for each of the top plans, and
/// sort by goodput — the lower 95% confidence bound on mean goodput
/// when `opts.mc ≥ 1` (blast-radius-aware ranking), the single
/// deterministic walk otherwise. Deterministic across worker-thread
/// counts (the search is; the walks are per-trajectory-sequential and
/// reduced in index order).
pub fn sweep(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &SweepOptions,
) -> anyhow::Result<SweepReport> {
    let rep = search(model, cluster, &opts.plan)?;
    let degraded = DegradedModel::derive(cluster).ok();
    let domains = opts.domains.as_ref().map(|d| FailureDomains::derive(cluster, d.rack_size));
    let base_events = draw_trajectory(cluster, opts, domains.as_ref(), 0).len();
    let top = if opts.top == 0 { rep.ranked.len() } else { opts.top.min(rep.ranked.len()) };
    let cache = Mutex::new(HashMap::new());
    let replan = replan_shared(model, &opts.plan, &cache);
    let mut entries = Vec::with_capacity(top);
    let mut incumbent: Option<f64> = None;
    for ev in rep.ranked.iter().take(top) {
        let world = ev.candidate.par.world_size();
        let input = GoodputInput {
            model,
            cluster,
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            checkpoint: opts.checkpoint,
            repair: opts.repair,
            degraded: degraded.as_ref(),
            comm_fraction: comm_fraction(ev.comm_busy, world, ev.iteration_time),
            horizon_s: opts.horizon_s,
        };
        let (goodput, mc) =
            score_plan(&input, cluster, opts, domains.as_ref(), &replan, incumbent);
        let score = score_of(&goodput, &mc);
        if incumbent.map_or(true, |i| score > i) {
            incumbent = Some(score);
        }
        entries.push(SweepEntry {
            plan: ev.candidate.key(),
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            goodput,
            mc,
        });
    }
    entries.sort_by(|a, b| {
        score_of(&b.goodput, &b.mc)
            .total_cmp(&score_of(&a.goodput, &a.mc))
            .then_with(|| a.plan.cmp(&b.plan))
    });
    Ok(SweepReport {
        entries,
        events: base_events,
        horizon_s: opts.horizon_s,
        mtbf_scale: opts.mtbf_scale,
    })
}

/// Annotate an existing plan-search report with per-plan goodput and
/// re-rank it (the `hetsim plan --objective goodput|goodput-ci`
/// path). The fault-free ranking fields are untouched; only the
/// `goodput` / `goodput_ci` annotations and the order change. With
/// `opts.mc ≥ 1` the ranking score is the lower 95% confidence bound
/// on mean goodput and `goodput_ci` carries both bounds.
pub fn annotate(
    rep: &mut PlanSearchReport,
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &SweepOptions,
) {
    let degraded = DegradedModel::derive(cluster).ok();
    let domains = opts.domains.as_ref().map(|d| FailureDomains::derive(cluster, d.rack_size));
    let cache = Mutex::new(HashMap::new());
    let replan = replan_shared(model, &opts.plan, &cache);
    let mut incumbent: Option<f64> = None;
    for ev in rep.ranked.iter_mut() {
        let world = ev.candidate.par.world_size();
        let input = GoodputInput {
            model,
            cluster,
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            checkpoint: opts.checkpoint,
            repair: opts.repair,
            degraded: degraded.as_ref(),
            comm_fraction: comm_fraction(ev.comm_busy, world, ev.iteration_time),
            horizon_s: opts.horizon_s,
        };
        let (goodput, mc) =
            score_plan(&input, cluster, opts, domains.as_ref(), &replan, incumbent);
        let score = score_of(&goodput, &mc);
        if incumbent.map_or(true, |i| score > i) {
            incumbent = Some(score);
        }
        ev.goodput = Some(score);
        ev.goodput_ci = mc.map(|m| (m.ci95_lo, m.ci95_hi));
    }
    rep.ranked.sort_by(|a, b| {
        b.goodput
            .unwrap_or(0.0)
            .total_cmp(&a.goodput.unwrap_or(0.0))
            .then_with(|| a.candidate.key().cmp(&b.candidate.key()))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::system::failure::FaultEvent;

    fn input<'a>(m: &'a ModelSpec, c: &'a ClusterSpec) -> GoodputInput<'a> {
        GoodputInput {
            model: m,
            cluster: c,
            iteration: Time::from_secs(2.0),
            dp: 4,
            checkpoint: CheckpointSpec::default(),
            repair: RepairSpec::default(),
            degraded: None,
            comm_fraction: 0.25,
            horizon_s: 10_000.0,
        }
    }

    #[test]
    fn fault_free_walk_matches_closed_form() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 1).unwrap();
        let inp = input(&m, &c);
        let g = walk(&inp, &[], &mut |_| None);
        assert_eq!(g.fail_stops + g.link_outages + g.stragglers + g.replans, 0);
        assert!(!g.halted);
        assert_eq!(g.availability, 1.0);
        let tokens_per_iter = (m.global_batch * m.seq_len) as f64;
        let write_s =
            m.param_count() as f64 * (m.dtype_bytes + 12) as f64 / (10.0 * 1e9 * 4.0);
        let tau = 2.0 + write_s / 32.0;
        let expect = 10_000.0 / tau * tokens_per_iter / 10_000.0;
        assert!((g.goodput_tokens_per_s - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn every_fault_kind_reduces_goodput() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let base = walk(&inp, &[], &mut |_| None).goodput_tokens_per_s;
        for kind in [
            FaultKind::NodeFail { node: 0 },
            FaultKind::NicFail { node: 0 },
            FaultKind::LinkFail { node: 1 },
            FaultKind::Straggler { node: 1, mult: 1.5 },
        ] {
            let g = walk(
                &inp,
                &[FaultEvent { at_s: 100.0, kind }],
                &mut |_| Some(Time::from_secs(3.0)),
            );
            assert!(
                g.goodput_tokens_per_s < base,
                "{kind:?}: {} !< {base}",
                g.goodput_tokens_per_s
            );
            assert!(!g.halted);
        }
    }

    #[test]
    fn node_loss_replans_and_infeasible_replan_halts() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let ev = [FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } }];
        let mut seen = Vec::new();
        let g = walk(&inp, &ev, &mut |rest| {
            seen.push(rest.total_gpus());
            Some(Time::from_secs(5.0))
        });
        assert_eq!(seen, vec![8]); // one 8-GPU node survives
        assert_eq!(g.replans, 1);
        assert_eq!(g.final_iteration_s, 5.0); // above the floor, spliced
        let halted = walk(&inp, &ev, &mut |_| None);
        assert!(halted.halted);
        assert!(halted.goodput_tokens_per_s < g.goodput_tokens_per_s);
        assert!(halted.availability < 1.0);
    }

    #[test]
    fn replan_splice_floors_at_the_preloss_cost() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let ev = [FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } }];
        // a replan claiming to be *faster* on fewer nodes is floored
        let g = walk(&inp, &ev, &mut |_| Some(Time::from_secs(0.5)));
        assert_eq!(g.final_iteration_s, 2.0);
    }

    #[test]
    fn faults_on_dead_nodes_are_moot() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let evs = [
            FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } },
            FaultEvent { at_s: 200.0, kind: FaultKind::NicFail { node: 0 } },
            FaultEvent { at_s: 300.0, kind: FaultKind::Straggler { node: 0, mult: 9.0 } },
        ];
        let g = walk(&inp, &evs, &mut |_| Some(Time::from_secs(3.0)));
        assert_eq!(g.fail_stops, 1);
        assert_eq!(g.link_outages, 0);
        assert_eq!(g.stragglers, 0);
    }

    #[test]
    fn link_outage_charges_less_than_node_loss() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let mut inp = input(&m, &c);
        inp.repair = RepairSpec { nic_s: 0.0, link_s: 0.0 };
        let node = walk(
            &inp,
            &[FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } }],
            &mut |_| Some(Time::from_secs(2.0)),
        );
        let nic = walk(
            &inp,
            &[FaultEvent { at_s: 100.0, kind: FaultKind::NicFail { node: 0 } }],
            &mut |_| Some(Time::from_secs(2.0)),
        );
        // a repaired NIC keeps device state: no checkpoint restore, no
        // half-interval of replayed work — strictly cheaper
        assert_eq!(nic.link_outages, 1);
        assert_eq!(nic.fail_stops, 0);
        assert_eq!(nic.replans, 0);
        assert!(nic.lost_s < node.lost_s, "{} !< {}", nic.lost_s, node.lost_s);
        assert!(nic.goodput_tokens_per_s > node.goodput_tokens_per_s);
    }

    #[test]
    fn repairable_link_degrades_instead_of_stopping() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let dm = DegradedModel::derive(&c).unwrap();
        let mut inp = input(&m, &c);
        inp.repair = RepairSpec { nic_s: 3000.0, link_s: 3000.0 };
        inp.comm_fraction = 0.5;
        let ev = [FaultEvent { at_s: 100.0, kind: FaultKind::NicFail { node: 0 } }];
        let mut inp_deg = inp;
        inp_deg.degraded = Some(&dm);
        let degraded = walk(&inp_deg, &ev, &mut |_| None);
        let hard = walk(&inp, &ev, &mut |_| None); // no reroute model
        let mut inp_zero = inp;
        inp_zero.repair = RepairSpec { nic_s: 0.0, link_s: 0.0 };
        let instant = walk(&inp_zero, &ev, &mut |_| None);
        // degraded running beats a hard outage, loses to instant repair
        assert_eq!(degraded.link_outages, 1);
        assert!(!degraded.halted);
        assert!(degraded.goodput_tokens_per_s > hard.goodput_tokens_per_s);
        assert!(degraded.goodput_tokens_per_s < instant.goodput_tokens_per_s);
        assert!(degraded.lost_s > instant.lost_s);
        assert!(degraded.lost_s < hard.lost_s);
    }

    #[test]
    fn same_instant_blast_coalesces_into_one_incident() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(2, 2).unwrap(); // 4 nodes
        let inp = input(&m, &c);
        let blast = [
            FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } },
            FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 1 } },
        ];
        let spread = [
            FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } },
            FaultEvent { at_s: 200.0, kind: FaultKind::NodeFail { node: 1 } },
        ];
        let g_blast = walk(&inp, &blast, &mut |_| Some(Time::from_secs(2.0)));
        let g_spread = walk(&inp, &spread, &mut |_| Some(Time::from_secs(2.0)));
        assert_eq!(g_blast.fail_stops, 2);
        assert_eq!(g_blast.replans, 1); // one incident, one replan
        assert_eq!(g_spread.fail_stops, 2);
        assert_eq!(g_spread.replans, 2);
        assert!(g_blast.lost_s < g_spread.lost_s); // one recovery penalty
    }

    #[test]
    fn monte_carlo_nests_and_matches_single_walk_at_index_zero() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        assert_eq!(trajectory_seed(42, 0), 42);
        let draw = |i: u32| {
            let s = trajectory_seed(42, i);
            mtbf_schedule(&c, inp.horizon_s, 8.0, s)
        };
        let one = monte_carlo(&inp, draw, 1, 1, |_| Some(Time::from_secs(3.0)));
        let four = monte_carlo(&inp, draw, 4, 2, |_| Some(Time::from_secs(3.0)));
        let single = walk(&inp, &draw(0), &mut |_| Some(Time::from_secs(3.0)));
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], single); // N=1 ≡ the deterministic walk
        assert_eq!(four[0], single); // nested: index 0 is shared
        let stats = mc_stats(&four);
        assert_eq!(stats.trajectories, 4);
        assert!(stats.p5 <= stats.mean + 1e-12 && stats.mean <= stats.p95 + 1e-12);
        assert!(stats.ci95_lo <= stats.mean && stats.mean <= stats.ci95_hi);
    }

    #[test]
    fn sweep_ranks_by_goodput_on_a_hetero_cluster() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = SweepOptions {
            plan: PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() },
            top: 3,
            horizon_s: 200_000.0,
            mtbf_scale: 8.0,
            ..Default::default()
        };
        let rep = sweep(&m, &c, &opts).unwrap();
        assert!(rep.entries.len() >= 2, "need >=2 plans, got {}", rep.entries.len());
        for w in rep.entries.windows(2) {
            assert!(
                w[0].goodput.goodput_tokens_per_s >= w[1].goodput.goodput_tokens_per_s
            );
        }
        let text = rep.render();
        assert!(text.contains("goodput"), "{text}");
        // deterministic across thread counts
        let mut opts4 = opts.clone();
        opts4.plan.threads = 4;
        let rep4 = sweep(&m, &c, &opts4).unwrap();
        let fp = |r: &SweepReport| {
            r.entries
                .iter()
                .map(|e| format!("{}={}", e.plan, e.goodput.goodput_tokens_per_s))
                .collect::<Vec<_>>()
                .join("|")
        };
        assert_eq!(fp(&rep), fp(&rep4));
    }

    #[test]
    fn monte_carlo_sweep_ranks_by_ci_lower_bound() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = SweepOptions {
            plan: PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() },
            top: 2,
            horizon_s: 200_000.0,
            mtbf_scale: 8.0,
            domains: Some(DomainSpec {
                rack_size: 1,
                mtbf_hours: 100.0,
                horizon_s: 200_000.0,
                scale: 4.0,
            }),
            mc: 4,
            ..Default::default()
        };
        let rep = sweep(&m, &c, &opts).unwrap();
        assert!(rep.entries.iter().all(|e| e.mc.is_some()));
        for w in rep.entries.windows(2) {
            let (a, b) = (w[0].mc.as_ref().unwrap(), w[1].mc.as_ref().unwrap());
            assert!(a.ci95_lo >= b.ci95_lo);
        }
        let text = rep.render();
        assert!(text.contains("ci95-lo"), "{text}");
        assert!(text.contains("trajectories"), "{text}");
        // byte-identical across thread counts
        let mut opts8 = opts.clone();
        opts8.plan.threads = 8;
        let rep8 = sweep(&m, &c, &opts8).unwrap();
        let fp = |r: &SweepReport| {
            r.entries
                .iter()
                .map(|e| {
                    let mc = e.mc.as_ref().unwrap();
                    format!("{}={}:{}:{}", e.plan, mc.mean, mc.ci95_lo, mc.ci95_hi)
                })
                .collect::<Vec<_>>()
                .join("|")
        };
        assert_eq!(fp(&rep), fp(&rep8));
    }
}
