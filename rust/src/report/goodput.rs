//! Effective-goodput reporting under failures (DESIGN.md §26).
//!
//! Iteration time alone mispredicts what a plan delivers at scale:
//! MTBF makes failures routine, and a plan that is 5% faster but loses
//! more work per fail-stop (or re-plans onto a worse surviving
//! cluster) can deliver fewer useful tokens per wall-clock second.
//! This module turns a fault schedule ([`crate::system::failure`])
//! plus a plan's simulated iteration time into **effective goodput**:
//!
//! ```text
//! goodput = useful_tokens / horizon_s
//! useful_tokens = Σ productive_span / τ · tokens_per_iter
//! τ = iteration_s · straggler_mult + checkpoint_write_s / interval
//! ```
//!
//! Each fail-stop charges the *expected* lost work — half a checkpoint
//! interval of iterations at the current effective rate — plus the
//! checkpoint restore time and the fixed restart warmup. A permanent
//! node loss additionally re-runs the planner on the surviving cluster
//! (each [`crate::planner::search`] run shares its
//! [`crate::simulator::EvalContext`] across candidates) and splices
//! the new plan's per-iteration cost, floored at the pre-loss cost so
//! goodput is monotone under event-set inclusion (the same property
//! [`crate::system::failure::mtbf_schedule`] guarantees on the event
//! side). The walk itself is sequential and allocation-light, so a
//! goodput figure is deterministic for a given spec regardless of how
//! many worker threads scored the plans.

use std::collections::HashMap;

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::planner::{search, PlanOptions, PlanSearchReport};
use crate::system::failure::{mtbf_schedule, CheckpointSpec, FaultEvent, FaultKind};
use crate::util::table::Table;
use crate::util::units::Time;

/// Everything the goodput walk needs to know about one plan.
#[derive(Debug, Clone, Copy)]
pub struct GoodputInput<'a> {
    /// The trained model (tokens per iteration, checkpoint bytes).
    pub model: &'a ModelSpec,
    /// The full (pre-failure) cluster the plan was laid out on.
    pub cluster: &'a ClusterSpec,
    /// The plan's simulated per-iteration time on the full cluster.
    pub iteration: Time,
    /// The plan's data-parallel degree: checkpoint writers shard the
    /// state `dp` ways, so larger DP writes checkpoints faster — but
    /// also restarts more state on every fail-stop.
    pub dp: u32,
    /// Checkpoint/restore cost model.
    pub checkpoint: CheckpointSpec,
    /// Wall-clock horizon to integrate over, in seconds.
    pub horizon_s: f64,
}

/// Effective-goodput accounting for one plan over one fault schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GoodputReport {
    /// Useful tokens per wall-clock second over the horizon — the
    /// headline number plans are ranked by.
    pub goodput_tokens_per_s: f64,
    /// Total useful (non-lost) tokens produced within the horizon.
    pub useful_tokens: f64,
    /// The integration horizon, echoed for rate/total conversions.
    pub horizon_s: f64,
    /// Wall-clock seconds spent on recovery (lost work, restore,
    /// warmup) or halted outright.
    pub lost_s: f64,
    /// `1 - lost_s / horizon_s`, clamped to `[0, 1]`.
    pub availability: f64,
    /// Fail-stop events that actually struck a live node.
    pub fail_stops: usize,
    /// Straggler events that slowed a live node.
    pub stragglers: usize,
    /// Node losses that triggered a planner re-run on the survivors.
    pub replans: usize,
    /// True when training halted before the horizon (no surviving
    /// nodes, or no feasible plan on the survivors).
    pub halted: bool,
    /// The per-iteration cost in effect at the end of the walk
    /// (≥ the initial cost: re-plans are floored at the pre-loss cost).
    pub final_iteration_s: f64,
}

/// Remove dead nodes, keeping everything else about the cluster.
fn surviving(cluster: &ClusterSpec, alive: &[bool]) -> ClusterSpec {
    let mut c = cluster.clone();
    c.nodes = cluster
        .nodes
        .iter()
        .zip(alive)
        .filter(|(_, a)| **a)
        .map(|(n, _)| n.clone())
        .collect();
    let dead = alive.iter().filter(|a| !**a).count();
    c.name = format!("{}-minus{}", cluster.name, dead);
    c
}

/// Walk a sorted fault schedule over `[0, horizon_s]` and integrate
/// useful tokens. `replan` maps a surviving cluster to its best
/// per-iteration time (`None` = no feasible plan, training halts);
/// callers pass the real planner ([`sweep`] does, memoized per
/// surviving cluster) or a synthetic model (the property tests do).
///
/// Monotonicity: adding events to the schedule never increases the
/// returned goodput — every event only ever adds recovery time,
/// raises the straggler multiplier (max-persistent), or raises the
/// floored iteration cost. Combined with the nested-thinning schedule
/// construction, goodput is monotone non-increasing in the MTBF scale.
pub fn walk(
    input: &GoodputInput<'_>,
    events: &[FaultEvent],
    replan: &mut dyn FnMut(&ClusterSpec) -> Option<Time>,
) -> GoodputReport {
    let ckpt = &input.checkpoint;
    let tokens_per_iter = (input.model.global_batch * input.model.seq_len) as f64;
    // weights + fp32 Adam moments and master copy, sharded dp ways
    let ckpt_bytes = input.model.param_count() as f64 * (input.model.dtype_bytes + 12) as f64;
    let write_s = ckpt_bytes / (ckpt.write_gbps * 1e9 * input.dp.max(1) as f64);
    let ckpt_overhead = write_s / ckpt.interval_iters as f64;
    let tau = |iter_s: f64, mult: f64| (iter_s * mult + ckpt_overhead).max(f64::MIN_POSITIVE);

    let mut iter_s = input.iteration.as_secs();
    let mut mult = 1.0f64;
    let mut alive = vec![true; input.cluster.nodes.len()];
    let (mut t, mut useful, mut lost) = (0.0f64, 0.0f64, 0.0f64);
    let (mut fail_stops, mut stragglers, mut replans) = (0usize, 0usize, 0usize);
    let mut halted = false;

    for ev in events {
        if ev.at_s > input.horizon_s {
            break;
        }
        // if recovery from a previous fault is still in progress, the
        // new fault takes effect once the job is back up
        let fire = ev.at_s.max(t);
        if fire >= input.horizon_s {
            break;
        }
        useful += (fire - t) / tau(iter_s, mult) * tokens_per_iter;
        t = fire;
        let node = ev.kind.node() as usize;
        if !alive[node] {
            continue; // faults on an already-dead node are moot
        }
        match ev.kind {
            FaultKind::Straggler { mult: m, .. } => {
                stragglers += 1;
                mult = mult.max(m);
            }
            kind => {
                fail_stops += 1;
                // expected lost work: half a checkpoint interval at the
                // current effective rate, plus restore + warmup
                let penalty = 0.5 * ckpt.interval_iters as f64 * tau(iter_s, mult)
                    + write_s
                    + ckpt.restart_warmup_s;
                lost += penalty;
                t += penalty;
                if matches!(kind, FaultKind::NodeFail { .. }) {
                    alive[node] = false;
                    let rest = surviving(input.cluster, &alive);
                    if rest.nodes.is_empty() {
                        halted = true;
                        break;
                    }
                    replans += 1;
                    match replan(&rest) {
                        // floor at the pre-loss cost (monotonicity)
                        Some(new_iter) => iter_s = iter_s.max(new_iter.as_secs()),
                        None => {
                            halted = true;
                            break;
                        }
                    }
                }
            }
        }
    }
    if halted {
        lost += (input.horizon_s - t).max(0.0);
    } else if t < input.horizon_s {
        useful += (input.horizon_s - t) / tau(iter_s, mult) * tokens_per_iter;
    }
    GoodputReport {
        goodput_tokens_per_s: useful / input.horizon_s.max(f64::MIN_POSITIVE),
        useful_tokens: useful,
        horizon_s: input.horizon_s,
        lost_s: lost,
        availability: (1.0 - lost / input.horizon_s.max(f64::MIN_POSITIVE)).clamp(0.0, 1.0),
        fail_stops,
        stragglers,
        replans,
        halted,
        final_iteration_s: iter_s,
    }
}

/// Knobs for [`sweep`] / [`annotate`].
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Planner options for the underlying candidate search (and for
    /// the re-plan runs on surviving clusters).
    pub plan: PlanOptions,
    /// How many top-ranked plans to score for goodput (0 = all).
    pub top: usize,
    /// Wall-clock horizon in seconds (default: one day).
    pub horizon_s: f64,
    /// MTBF failure-rate scale (1.0 = the per-arch table as-is;
    /// clamped at [`crate::system::failure::SCALE_CAP`]).
    pub mtbf_scale: f64,
    /// Seed for the MTBF schedule.
    pub seed: u64,
    /// Checkpoint/restore cost model.
    pub checkpoint: CheckpointSpec,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            plan: PlanOptions::default(),
            top: 5,
            horizon_s: 86_400.0,
            mtbf_scale: 1.0,
            seed: 42,
            checkpoint: CheckpointSpec::default(),
        }
    }
}

/// One plan's goodput score in a sweep.
#[derive(Debug, Clone)]
pub struct SweepEntry {
    /// The candidate key (`tp…-pp…-dp…-…`).
    pub plan: String,
    /// Fault-free simulated iteration time.
    pub iteration: Time,
    /// The plan's DP degree (checkpoint sharding width).
    pub dp: u32,
    /// The goodput walk's result for this plan.
    pub goodput: GoodputReport,
}

/// The `hetsim goodput` result: top plans re-ranked by effective
/// goodput under an MTBF schedule.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Entries sorted by goodput, best first (key tie-break).
    pub entries: Vec<SweepEntry>,
    /// Number of fault events in the materialized schedule.
    pub events: usize,
    /// The integration horizon in seconds.
    pub horizon_s: f64,
    /// The MTBF scale the schedule was drawn at.
    pub mtbf_scale: f64,
}

impl SweepReport {
    /// The goodput-optimal entry.
    pub fn best(&self) -> &SweepEntry {
        &self.entries[0]
    }

    /// Render the ranked goodput table plus a summary line.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Effective goodput under MTBF faults",
            &["rank", "plan", "goodput tok/s", "iteration", "avail", "fail-stops", "replans"],
        );
        for (i, e) in self.entries.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                e.plan.clone(),
                format!("{:.1}", e.goodput.goodput_tokens_per_s),
                e.iteration.human(),
                format!("{:.4}", e.goodput.availability),
                e.goodput.fail_stops.to_string(),
                e.goodput.replans.to_string(),
            ]);
        }
        let mut s = t.markdown();
        s.push_str(&format!(
            "\n{} fault events over {:.0}s at {}x MTBF rate | best by goodput: {}\n",
            self.events,
            self.horizon_s,
            self.mtbf_scale,
            self.entries.first().map(|e| e.plan.as_str()).unwrap_or("-"),
        ));
        s
    }
}

/// The planner re-run used when a node loss shrinks the cluster:
/// memoized per surviving-cluster shape so a sweep over many plans
/// pays for each survivor search once.
fn replan_cached<'a>(
    model: &'a ModelSpec,
    opts: &'a PlanOptions,
    cache: &'a mut HashMap<String, Option<Time>>,
) -> impl FnMut(&ClusterSpec) -> Option<Time> + 'a {
    move |rest: &ClusterSpec| {
        let key: String = rest
            .nodes
            .iter()
            .map(|n| format!("{}x{};", n.gpu.name, n.gpus_per_node))
            .collect();
        *cache
            .entry(key)
            .or_insert_with(|| search(model, rest, opts).ok().map(|r| r.best().iteration_time))
    }
}

/// Rank plans by effective goodput: run the plan search, materialize
/// an MTBF schedule, walk it for each of the top plans, and sort by
/// goodput. Deterministic across worker-thread counts (the search is;
/// the walk is sequential).
pub fn sweep(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &SweepOptions,
) -> anyhow::Result<SweepReport> {
    let rep = search(model, cluster, &opts.plan)?;
    let events = mtbf_schedule(cluster, opts.horizon_s, opts.mtbf_scale, opts.seed);
    let top = if opts.top == 0 { rep.ranked.len() } else { opts.top.min(rep.ranked.len()) };
    let mut cache = HashMap::new();
    let mut entries = Vec::with_capacity(top);
    for ev in rep.ranked.iter().take(top) {
        let input = GoodputInput {
            model,
            cluster,
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            checkpoint: opts.checkpoint,
            horizon_s: opts.horizon_s,
        };
        let mut replan = replan_cached(model, &opts.plan, &mut cache);
        let goodput = walk(&input, &events, &mut replan);
        entries.push(SweepEntry {
            plan: ev.candidate.key(),
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            goodput,
        });
    }
    entries.sort_by(|a, b| {
        b.goodput
            .goodput_tokens_per_s
            .total_cmp(&a.goodput.goodput_tokens_per_s)
            .then_with(|| a.plan.cmp(&b.plan))
    });
    Ok(SweepReport {
        entries,
        events: events.len(),
        horizon_s: opts.horizon_s,
        mtbf_scale: opts.mtbf_scale,
    })
}

/// Annotate an existing plan-search report with per-plan goodput and
/// re-rank it by goodput (the `hetsim plan --goodput` objective flag).
/// The fault-free ranking fields are untouched; only the `goodput`
/// annotation and the order change.
pub fn annotate(
    rep: &mut PlanSearchReport,
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &SweepOptions,
) {
    let events = mtbf_schedule(cluster, opts.horizon_s, opts.mtbf_scale, opts.seed);
    let mut cache = HashMap::new();
    for ev in rep.ranked.iter_mut() {
        let input = GoodputInput {
            model,
            cluster,
            iteration: ev.iteration_time,
            dp: ev.candidate.par.dp,
            checkpoint: opts.checkpoint,
            horizon_s: opts.horizon_s,
        };
        let mut replan = replan_cached(model, &opts.plan, &mut cache);
        ev.goodput = Some(walk(&input, &events, &mut replan).goodput_tokens_per_s);
    }
    rep.ranked.sort_by(|a, b| {
        b.goodput
            .unwrap_or(0.0)
            .total_cmp(&a.goodput.unwrap_or(0.0))
            .then_with(|| a.candidate.key().cmp(&b.candidate.key()))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::system::failure::FaultEvent;

    fn input<'a>(m: &'a ModelSpec, c: &'a ClusterSpec) -> GoodputInput<'a> {
        GoodputInput {
            model: m,
            cluster: c,
            iteration: Time::from_secs(2.0),
            dp: 4,
            checkpoint: CheckpointSpec::default(),
            horizon_s: 10_000.0,
        }
    }

    #[test]
    fn fault_free_walk_matches_closed_form() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 1).unwrap();
        let inp = input(&m, &c);
        let g = walk(&inp, &[], &mut |_| None);
        assert_eq!(g.fail_stops + g.stragglers + g.replans, 0);
        assert!(!g.halted);
        assert_eq!(g.availability, 1.0);
        let tokens_per_iter = (m.global_batch * m.seq_len) as f64;
        let write_s =
            m.param_count() as f64 * (m.dtype_bytes + 12) as f64 / (10.0 * 1e9 * 4.0);
        let tau = 2.0 + write_s / 32.0;
        let expect = 10_000.0 / tau * tokens_per_iter / 10_000.0;
        assert!((g.goodput_tokens_per_s - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn every_fault_kind_reduces_goodput() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let base = walk(&inp, &[], &mut |_| None).goodput_tokens_per_s;
        for kind in [
            FaultKind::NodeFail { node: 0 },
            FaultKind::NicFail { node: 0 },
            FaultKind::LinkFail { node: 1 },
            FaultKind::Straggler { node: 1, mult: 1.5 },
        ] {
            let g = walk(
                &inp,
                &[FaultEvent { at_s: 100.0, kind }],
                &mut |_| Some(Time::from_secs(3.0)),
            );
            assert!(
                g.goodput_tokens_per_s < base,
                "{kind:?}: {} !< {base}",
                g.goodput_tokens_per_s
            );
            assert!(!g.halted);
        }
    }

    #[test]
    fn node_loss_replans_and_infeasible_replan_halts() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let ev = [FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } }];
        let mut seen = Vec::new();
        let g = walk(&inp, &ev, &mut |rest| {
            seen.push(rest.total_gpus());
            Some(Time::from_secs(5.0))
        });
        assert_eq!(seen, vec![8]); // one 8-GPU node survives
        assert_eq!(g.replans, 1);
        assert_eq!(g.final_iteration_s, 5.0); // above the floor, spliced
        let halted = walk(&inp, &ev, &mut |_| None);
        assert!(halted.halted);
        assert!(halted.goodput_tokens_per_s < g.goodput_tokens_per_s);
        assert!(halted.availability < 1.0);
    }

    #[test]
    fn replan_splice_floors_at_the_preloss_cost() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let ev = [FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } }];
        // a replan claiming to be *faster* on fewer nodes is floored
        let g = walk(&inp, &ev, &mut |_| Some(Time::from_secs(0.5)));
        assert_eq!(g.final_iteration_s, 2.0);
    }

    #[test]
    fn faults_on_dead_nodes_are_moot() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let inp = input(&m, &c);
        let evs = [
            FaultEvent { at_s: 100.0, kind: FaultKind::NodeFail { node: 0 } },
            FaultEvent { at_s: 200.0, kind: FaultKind::NicFail { node: 0 } },
            FaultEvent { at_s: 300.0, kind: FaultKind::Straggler { node: 0, mult: 9.0 } },
        ];
        let g = walk(&inp, &evs, &mut |_| Some(Time::from_secs(3.0)));
        assert_eq!(g.fail_stops, 1);
        assert_eq!(g.stragglers, 0);
    }

    #[test]
    fn sweep_ranks_by_goodput_on_a_hetero_cluster() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = SweepOptions {
            plan: PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() },
            top: 3,
            horizon_s: 200_000.0,
            mtbf_scale: 8.0,
            ..Default::default()
        };
        let rep = sweep(&m, &c, &opts).unwrap();
        assert!(rep.entries.len() >= 2, "need >=2 plans, got {}", rep.entries.len());
        for w in rep.entries.windows(2) {
            assert!(
                w[0].goodput.goodput_tokens_per_s >= w[1].goodput.goodput_tokens_per_s
            );
        }
        let text = rep.render();
        assert!(text.contains("goodput"), "{text}");
        // deterministic across thread counts
        let mut opts4 = opts.clone();
        opts4.plan.threads = 4;
        let rep4 = sweep(&m, &c, &opts4).unwrap();
        let fp = |r: &SweepReport| {
            r.entries
                .iter()
                .map(|e| format!("{}={}", e.plan, e.goodput.goodput_tokens_per_s))
                .collect::<Vec<_>>()
                .join("|")
        };
        assert_eq!(fp(&rep), fp(&rep4));
    }
}
