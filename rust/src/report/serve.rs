//! Serving report: goodput, TTFT/TBT, and request-latency percentiles
//! per device group (DESIGN.md §27).
//!
//! Produced by [`crate::system::serve_scheduler::ServeSim`]; rendered
//! by `hetsim serve-sim`. All rendering goes through
//! [`crate::util::table`] formatting so reports are byte-identical
//! across runs and worker-thread counts — `tests/integration_serve.rs`
//! and the `tests/golden/serve_sim_fig3.txt` golden enforce it.

use crate::util::stats::Samples;
use crate::util::table::{fmt_sig, Table};
use crate::workload::serve::ServePolicy;

/// Latency distribution summary (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    /// Number of samples.
    pub count: usize,
    /// Arithmetic mean, seconds.
    pub mean_s: f64,
    /// Median, seconds.
    pub p50_s: f64,
    /// 95th percentile, seconds.
    pub p95_s: f64,
    /// 99th percentile, seconds.
    pub p99_s: f64,
}

impl LatencyStats {
    /// Summarize a sample set (all zeros when empty — an empty trace
    /// renders, it does not panic).
    pub fn of(samples: &mut Samples) -> LatencyStats {
        LatencyStats {
            count: samples.len(),
            mean_s: samples.mean(),
            p50_s: samples.percentile(50.0),
            p95_s: samples.percentile(95.0),
            p99_s: samples.percentile(99.0),
        }
    }

    fn percentiles_ms(&self) -> String {
        format!(
            "{} / {} / {}",
            fmt_sig(self.p50_s * 1e3),
            fmt_sig(self.p95_s * 1e3),
            fmt_sig(self.p99_s * 1e3)
        )
    }
}

/// Per-device-group serving outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeGroupReport {
    /// Node index backing the group.
    pub node: u32,
    /// GPU model of the group's ranks.
    pub gpu: String,
    /// TP degree (= GPUs on the node).
    pub tp: u32,
    /// Requests completed on this group.
    pub requests: u64,
    /// Output tokens generated on this group.
    pub tokens_out: u64,
    /// Wall-clock the group's engine spent stepping, seconds.
    pub busy_s: f64,
    /// Peak concurrent KV residency, tokens.
    pub kv_peak_tokens: u64,
    /// KV admission budget, tokens.
    pub kv_budget_tokens: u64,
    /// Output tokens per second over the group's active window.
    pub goodput_tok_s: f64,
    /// Time-to-first-token distribution.
    pub ttft: LatencyStats,
    /// Time-between-tokens (decode cadence) distribution.
    pub tbt: LatencyStats,
    /// End-to-end request latency distribution.
    pub latency: LatencyStats,
}

/// The full serving simulation report.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Model served.
    pub model: String,
    /// Cluster name.
    pub cluster: String,
    /// Scheduling policy used.
    pub policy: ServePolicy,
    /// Per-device-group breakdown, in node order.
    pub groups: Vec<ServeGroupReport>,
    /// Requests completed (== requests admitted; conservation is a
    /// tested invariant).
    pub requests_total: u64,
    /// Total output tokens generated.
    pub tokens_out_total: u64,
    /// Time of the last completion, seconds from trace start.
    pub makespan_s: f64,
    /// Cluster-wide output tokens per second over the makespan.
    pub goodput_tok_s: f64,
    /// Cluster-wide time-to-first-token distribution.
    pub ttft: LatencyStats,
    /// Cluster-wide time-between-tokens distribution.
    pub tbt: LatencyStats,
    /// Cluster-wide end-to-end latency distribution.
    pub latency: LatencyStats,
    /// Engine steps executed across all groups.
    pub events: u64,
    /// Cost-model backend that priced the op streams.
    pub evaluator: &'static str,
}

impl ServeReport {
    /// Render the deterministic human-readable report.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!("serving: {} on {} — policy {}", self.model, self.cluster, self.policy.name()),
            &[
                "group",
                "gpu",
                "tp",
                "requests",
                "tokens out",
                "busy (s)",
                "kv peak/budget (tok)",
                "goodput (tok/s)",
                "ttft p50/p95/p99 (ms)",
                "tbt p50/p95/p99 (ms)",
                "latency p50/p95/p99 (ms)",
            ],
        );
        for g in &self.groups {
            t.row(vec![
                format!("node{}", g.node),
                g.gpu.clone(),
                g.tp.to_string(),
                g.requests.to_string(),
                g.tokens_out.to_string(),
                fmt_sig(g.busy_s),
                format!("{}/{}", g.kv_peak_tokens, g.kv_budget_tokens),
                fmt_sig(g.goodput_tok_s),
                g.ttft.percentiles_ms(),
                g.tbt.percentiles_ms(),
                g.latency.percentiles_ms(),
            ]);
        }
        let mut out = t.markdown();
        out.push('\n');
        out.push_str(&format!(
            "requests {} | tokens out {} | makespan {} s | goodput {} tok/s | events {} | evaluator {}\n",
            self.requests_total,
            self.tokens_out_total,
            fmt_sig(self.makespan_s),
            fmt_sig(self.goodput_tok_s),
            self.events,
            self.evaluator,
        ));
        out.push_str(&format!(
            "ttft p50/p95/p99 = {} ms | tbt p50/p95/p99 = {} ms | latency p99 = {} ms\n",
            self.ttft.percentiles_ms(),
            self.tbt.percentiles_ms(),
            fmt_sig(self.latency.p99_s * 1e3),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats_are_zero_and_render() {
        let mut s = Samples::new();
        let stats = LatencyStats::of(&mut s);
        assert_eq!(stats.count, 0);
        assert_eq!(stats.p99_s, 0.0);
        let rep = ServeReport {
            model: "gpt-6.7b".into(),
            cluster: "hetero-1a1h".into(),
            policy: ServePolicy::Fifo,
            groups: vec![],
            requests_total: 0,
            tokens_out_total: 0,
            makespan_s: 0.0,
            goodput_tok_s: 0.0,
            ttft: stats.clone(),
            tbt: stats.clone(),
            latency: stats,
            events: 0,
            evaluator: "native",
        };
        let text = rep.render();
        assert!(text.contains("requests 0"));
        assert!(text.contains("policy fifo"));
    }

    #[test]
    fn stats_of_samples() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        let stats = LatencyStats::of(&mut s);
        assert_eq!(stats.count, 100);
        assert!(stats.p50_s <= stats.p95_s && stats.p95_s <= stats.p99_s);
        assert!((stats.mean_s - 50.5).abs() < 1e-9);
    }
}
