//! **Table 1**: exposed-communication characteristics of DP / TP / PP
//! for Llama-2 70B on 2048 GPUs (TP=8, PP=8, DP=32).
//!
//! Derived from the generated workload itself (not hard-coded): per
//! parallelism kind we count the collectives a representative rank
//! participates in per iteration and average the per-collective payload.
//! Paper values: DP 2/iter @ 4.4 GB, TP 350/iter @ small, PP 8/iter @
//! small.

use std::collections::HashSet;

use crate::config::framework::FrameworkSpec;
use crate::config::presets;
use crate::system::collective::CommKind;
use crate::util::table::Table;
use crate::util::units::ByteSize;
use crate::workload::aicb::{generate, WorkloadOptions};
use crate::workload::op::{Op, Workload};

/// Exposed-communication characteristics of one parallelism dimension.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Parallelism dimension label (DP / TP / PP).
    pub kind: &'static str,
    /// Whether its communication is exposed in the forward pass.
    pub exposed_fwd: bool,
    /// Whether its communication is exposed in the backward pass.
    pub exposed_bwd: bool,
    /// Collectives the observed rank joins per iteration.
    pub freq_per_iter: usize,
    /// Mean payload bytes per collective.
    pub avg_bytes: u64,
}

/// Analyze a workload from the perspective of `rank`.
pub fn analyze(w: &Workload, rank: u32) -> anyhow::Result<Vec<Table1Row>> {
    let prog = w
        .programs
        .iter()
        .find(|p| p.rank == rank)
        .ok_or_else(|| anyhow::anyhow!("rank {rank} not in workload"))?;

    let mut rows = Vec::new();
    for (kind, exposed_fwd, exposed_bwd) in [
        (CommKind::Dp, false, true), // grad sync overlaps fwd, exposed in bwd tail
        (CommKind::Tp, true, false), // Megatron TP allreduce blocks the fwd path
        (CommKind::Pp, true, true),  // stage handoffs block both directions
    ] {
        let ids: HashSet<u64> =
            w.collectives.iter().filter(|c| c.kind == kind).map(|c| c.id).collect();
        let mut freq = 0usize;
        let mut bytes_total: u64 = 0;
        for op in &prog.ops {
            match op {
                Op::Collective { def_id } if ids.contains(def_id) => {
                    freq += 1;
                    bytes_total += w.collective(*def_id).unwrap().bytes_per_rank;
                }
                // PP transfers counted once (sender side; the recv is
                // the same flow's other end)
                Op::Send { bytes, .. } if kind == CommKind::Pp => {
                    freq += 1;
                    bytes_total += bytes;
                }
                _ => {}
            }
        }
        let avg = if freq > 0 { bytes_total / freq.max(1) as u64 } else { 0 };
        rows.push(Table1Row {
            kind: kind.name(),
            exposed_fwd,
            exposed_bwd,
            freq_per_iter: freq,
            avg_bytes: avg,
        });
    }
    Ok(rows)
}

/// Generate the Llama-2 70B Table-1 workload and analyze it.
/// Returns (rows, workload op-count triple) — generation only, no event
/// simulation (2048 simulated ranks).
pub fn compute() -> anyhow::Result<Vec<Table1Row>> {
    let model = presets::model("llama2-70b")?;
    let cluster = presets::cluster("hopper", 256)?; // 2048 GPUs
    let dep = presets::deployment("llama2-70b")?;
    let fw = FrameworkSpec::uniform(&model, &cluster, dep)?;
    let w = generate(&model, &cluster, &fw, &WorkloadOptions::default())?;
    analyze(&w, 0)
}

/// Render the rows in the paper's Table-1 layout.
pub fn render(rows: &[Table1Row]) -> Table {
    let mut t = Table::new(
        "Table 1 — exposed communication of LLM parallelism (Llama-2 70B, 2048 GPUs, TP8/PP8/DP32)",
        &["attribute", "DP", "TP", "PP"],
    );
    let get = |k: &str| rows.iter().find(|r| r.kind == k).unwrap();
    let (dp, tp, pp) = (get("DP"), get("TP"), get("PP"));
    let yn = |b: bool| if b { "Yes" } else { "No" }.to_string();
    t.row(vec![
        "Exposed comm (forward)".into(),
        yn(dp.exposed_fwd),
        yn(tp.exposed_fwd),
        yn(pp.exposed_fwd),
    ]);
    t.row(vec![
        "Exposed comm (backward)".into(),
        yn(dp.exposed_bwd),
        yn(tp.exposed_bwd),
        yn(pp.exposed_bwd),
    ]);
    t.row(vec![
        "Frequency (per iteration)".into(),
        dp.freq_per_iter.to_string(),
        tp.freq_per_iter.to_string(),
        pp.freq_per_iter.to_string(),
    ]);
    t.row(vec![
        "Avg. comm size (per collective)".into(),
        ByteSize(dp.avg_bytes).human(),
        ByteSize(tp.avg_bytes).human(),
        ByteSize(pp.avg_bytes).human(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // full-scale compute() is exercised by the bench; tests use a
    // scaled-down config with identical structure.
    fn small() -> Vec<Table1Row> {
        let mut model = presets::model("llama2-70b").unwrap();
        model.global_batch = 140;
        model.micro_batch = 4;
        let cluster = presets::cluster("hopper", 8).unwrap(); // 64 GPUs
        let dep = crate::config::framework::ParallelismSpec { tp: 8, pp: 4, dp: 2 };
        let fw = FrameworkSpec::uniform(&model, &cluster, dep).unwrap();
        let w = generate(&model, &cluster, &fw, &WorkloadOptions::default()).unwrap();
        analyze(&w, 0).unwrap()
    }

    #[test]
    fn dp_low_frequency_large_payload() {
        let rows = small();
        let dp = rows.iter().find(|r| r.kind == "DP").unwrap();
        let tp = rows.iter().find(|r| r.kind == "TP").unwrap();
        assert!(dp.freq_per_iter < tp.freq_per_iter / 10);
        // DP payloads dominate TP activations by an order of magnitude
        assert!(dp.avg_bytes > 10 * tp.avg_bytes, "{} vs {}", dp.avg_bytes, tp.avg_bytes);
    }

    #[test]
    fn tp_high_frequency_small_payload() {
        let rows = small();
        let tp = rows.iter().find(|r| r.kind == "TP").unwrap();
        // 20 layers on stage 0, 2 allreduce x fwd+bwd x mb
        assert!(tp.freq_per_iter > 100, "{}", tp.freq_per_iter);
        assert!(tp.avg_bytes < (1u64 << 30));
    }

    #[test]
    fn pp_moderate_frequency() {
        let rows = small();
        let pp = rows.iter().find(|r| r.kind == "PP").unwrap();
        let tp = rows.iter().find(|r| r.kind == "TP").unwrap();
        assert!(pp.freq_per_iter > 0);
        assert!(pp.freq_per_iter < tp.freq_per_iter);
    }

    #[test]
    fn render_shape() {
        let t = render(&small());
        assert_eq!(t.rows.len(), 4);
        let md = t.markdown();
        assert!(md.contains("Frequency"));
    }
}
