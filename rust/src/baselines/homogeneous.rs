//! SimAI-like homogeneous baseline: replace every node with a clone of
//! one reference architecture. A homogeneous simulator run on `A100`
//! or `H100` clones brackets the true heterogeneous behaviour; the gap
//! is the error the paper's Table-2 "heterogeneous cluster simulation ✗"
//! rows imply.

use crate::config::cluster::ClusterSpec;

/// Clone `reference` node architecture across the whole cluster.
/// `reference` is an index into `cluster.nodes`.
pub fn homogenize(cluster: &ClusterSpec, reference: usize) -> anyhow::Result<ClusterSpec> {
    anyhow::ensure!(
        reference < cluster.nodes.len(),
        "reference node {reference} out of range ({} nodes)",
        cluster.nodes.len()
    );
    let proto = cluster.nodes[reference].clone();
    Ok(ClusterSpec {
        name: format!("{}-homogenized-{}", cluster.name, proto.gpu.name),
        nodes: vec![proto; cluster.nodes.len()],
        fabric: cluster.fabric,
        switch_bw: cluster.switch_bw,
        switch_delay: cluster.switch_delay,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn homogenized_cluster_is_uniform() {
        let hetero = presets::cluster_hetero(2, 2).unwrap();
        let homo_a = homogenize(&hetero, 0).unwrap();
        assert!(homo_a.is_homogeneous());
        assert_eq!(homo_a.gpu_types(), vec!["A100"]);
        let homo_h = homogenize(&hetero, 2).unwrap();
        assert_eq!(homo_h.gpu_types(), vec!["H100"]);
        assert_eq!(homo_h.total_gpus(), hetero.total_gpus());
    }

    #[test]
    fn out_of_range_reference_rejected() {
        let c = presets::cluster("ampere", 2).unwrap();
        assert!(homogenize(&c, 5).is_err());
    }
}
