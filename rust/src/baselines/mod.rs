//! Baseline comparators (system S12, the Table-2 competitors):
//!
//! * [`homogeneous`] — the SimAI assumption: pretend the cluster is
//!   uniform (every node cloned from a reference architecture) and
//!   simulate that. Comparing against the heterogeneity-aware run
//!   quantifies the error a homogeneous simulator makes on a mixed
//!   cluster.
//! * [`analytical`] — the Sailor-style closed-form estimator: no event
//!   simulation, just roofline compute sums + alpha-beta collective
//!   costs (optionally via the PJRT `coll_model` artifact). Fast but
//!   blind to contention, overlap and pipeline bubbles.

pub mod analytical;
pub mod homogeneous;

pub use analytical::AnalyticalEstimate;
pub use homogeneous::homogenize;
