//! Sailor-like analytical baseline: closed-form iteration-time estimate
//! with no event simulation — per-rank compute sums plus alpha-beta
//! collective costs. Blind to link contention, compute/comm overlap and
//! pipeline bubbles, which is exactly the gap the paper's full-stack
//! simulation closes (Table 2: "full stack training simulation ✗" for
//! Sailor).

use crate::compute::cost::NativeCostModel;
use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::network::routing;
use crate::network::topology::Topology;
use crate::system::collective::{CollectiveAlgo, CollectiveDef};
use crate::util::units::Time;
use crate::workload::op::{Op, Workload};

/// Collective descriptor row for the `coll_model` artifact
/// (`[algo, nranks, size, bw, latency, extra_hops, 0, 0]`), against a
/// prebuilt topology.
///
/// The bottleneck bandwidth is derived from the **actual fabric
/// graph**: the ring-neighbour routes traverse whatever links the
/// configured [`crate::config::cluster::FabricSpec`] materialized —
/// rail switches, the single cluster switch, or leaf/spine uplinks
/// whose capacity is tapered by `spines × oversubscription` — so an
/// oversubscribed spine fabric lowers the estimate exactly as it
/// lowers the simulated flow rates.
pub fn coll_descriptor_with_topology(topo: &Topology, def: &CollectiveDef) -> [f32; 8] {
    // bottleneck bandwidth + worst fixed delay over ring-neighbour routes
    let n = def.ranks.len();
    let mut min_bw = f64::INFINITY;
    let mut max_delay = Time::ZERO;
    for i in 0..n {
        let r = routing::route(topo, def.ranks[i], def.ranks[(i + 1) % n]);
        for l in &r.links {
            min_bw = min_bw.min(topo.link(*l).bw.bytes_per_sec());
        }
        let d = routing::fixed_delay(topo, &r);
        if d > max_delay {
            max_delay = d;
        }
    }
    if !min_bw.is_finite() {
        min_bw = 0.0;
    }
    [
        def.algo.code(),
        n as f32,
        def.bytes_per_rank as f32,
        min_bw as f32,
        max_delay.as_secs() as f32,
        0.0,
        0.0,
        0.0,
    ]
}

/// [`coll_descriptor_with_topology`] with the topology built on the
/// spot. Prefer the `_with_topology` form in any loop — building the
/// fabric graph per collective dominated estimate time on large
/// clusters.
pub fn coll_descriptor(cluster: &ClusterSpec, def: &CollectiveDef) -> anyhow::Result<[f32; 8]> {
    let topo = Topology::build(cluster)?;
    Ok(coll_descriptor_with_topology(&topo, def))
}

/// Native mirror of the coll_model formulas (kept in lockstep with
/// `python/compile/kernels/collective.py`).
pub fn coll_time_native(row: &[f32; 8]) -> f64 {
    let algo = row[0];
    let n = (row[1] as f64).max(1.0);
    let size = row[2] as f64;
    let bw = (row[3] as f64).max(1.0);
    let lat = row[4] as f64;
    let extra = row[5] as f64;
    let steps = n - 1.0;
    let frac = steps / n;
    let t = if algo == CollectiveAlgo::AllReduceRing.code() {
        2.0 * frac * size / bw + 2.0 * steps * lat
    } else if algo == CollectiveAlgo::Broadcast.code() {
        size / bw + (n.log2().ceil()) * lat
    } else if algo == 5.0 {
        // p2p (kernel code 5; no CollectiveAlgo variant — p2p is Op::Send)
        size / bw + lat
    } else {
        frac * size / bw + steps * lat
    };
    t + extra * lat
}

/// The analytical estimate for one iteration of a workload.
#[derive(Debug, Clone)]
pub struct AnalyticalEstimate {
    /// Critical-path compute time (max over ranks of summed compute).
    pub compute: Time,
    /// Summed collective time along the heaviest rank.
    pub communication: Time,
    /// `compute + communication` (no overlap modeled).
    pub total: Time,
}

/// Evaluate a collective's cost in seconds, optionally via the PJRT
/// artifact (falls back to the native mirror).
pub fn collective_seconds(
    cluster: &ClusterSpec,
    defs: &[&CollectiveDef],
    pjrt: Option<&crate::runtime::PjrtCollModel>,
) -> anyhow::Result<Vec<f64>> {
    // one fabric graph for the whole batch, not one per collective
    let topo = Topology::build(cluster)?;
    collective_seconds_with_topology(&topo, defs, pjrt)
}

/// [`collective_seconds`] against a prebuilt topology (the form the
/// planner's bound layer and any estimator loop should use).
pub fn collective_seconds_with_topology(
    topo: &Topology,
    defs: &[&CollectiveDef],
    pjrt: Option<&crate::runtime::PjrtCollModel>,
) -> anyhow::Result<Vec<f64>> {
    let rows: Vec<[f32; 8]> =
        defs.iter().map(|d| coll_descriptor_with_topology(topo, d)).collect();
    match pjrt {
        Some(model) => {
            let mut out = Vec::with_capacity(rows.len());
            for chunk in rows.chunks(crate::runtime::COLL_ROWS) {
                out.extend(model.evaluate(chunk)?.into_iter().map(|t| t as f64));
            }
            Ok(out)
        }
        None => Ok(rows.iter().map(coll_time_native).collect()),
    }
}

/// Closed-form estimate: per-rank sum of compute + collective costs,
/// take the slowest rank (no overlap, no contention).
pub fn estimate(
    workload: &Workload,
    cluster: &ClusterSpec,
    cost: &CostTable,
    pjrt: Option<&crate::runtime::PjrtCollModel>,
) -> anyhow::Result<AnalyticalEstimate> {
    let _ = NativeCostModel; // formulas documented in compute::cost
    // pre-compute collective costs
    let defs: Vec<&CollectiveDef> = workload.collectives.iter().collect();
    let coll_secs = collective_seconds(cluster, &defs, pjrt)?;
    let coll_time: std::collections::HashMap<u64, f64> =
        defs.iter().zip(&coll_secs).map(|(d, t)| (d.id, *t)).collect();

    let mut worst_compute = 0.0f64;
    let mut worst_comm = 0.0f64;
    let mut worst_total = 0.0f64;
    for p in &workload.programs {
        let gpu = cluster
            .gpu_of_rank(p.rank)
            .ok_or_else(|| anyhow::anyhow!("rank {} outside cluster", p.rank))?;
        let mut c = 0.0;
        let mut m = 0.0;
        for op in &p.ops {
            match op {
                Op::Compute { work, .. } => c += cost.time(work, gpu)?.as_secs(),
                Op::Collective { def_id } => m += coll_time.get(def_id).copied().unwrap_or(0.0),
                Op::Send { .. } | Op::Recv { .. } => {}
            }
        }
        worst_compute = worst_compute.max(c);
        worst_comm = worst_comm.max(m);
        worst_total = worst_total.max(c + m);
    }
    Ok(AnalyticalEstimate {
        compute: Time::from_secs(worst_compute),
        communication: Time::from_secs(worst_comm),
        total: Time::from_secs(worst_total),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::{FrameworkSpec, ParallelismSpec};
    use crate::config::presets;
    use crate::system::collective::CommKind;
    use crate::workload::aicb::{generate, register_costs, WorkloadOptions};

    fn setup() -> (crate::config::model::ModelSpec, ClusterSpec, Workload, CostTable) {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 16;
        m.micro_batch = 8;
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let mut t = CostTable::native();
        register_costs(&w, &c, &mut t).unwrap();
        (m, c, w, t)
    }

    #[test]
    fn estimate_is_positive_and_decomposed() {
        let (_, c, w, t) = setup();
        let est = estimate(&w, &c, &t, None).unwrap();
        assert!(est.compute > Time::ZERO);
        assert!(est.communication > Time::ZERO);
        assert!(est.total >= est.compute);
        assert!(est.total >= est.communication);
    }

    #[test]
    fn analytical_close_to_event_sim_without_contention() {
        // With tiny flows and a single node, the event sim and the
        // analytical bound should be the same order of magnitude.
        let (_, c, w, t) = setup();
        let est = estimate(&w, &c, &t, None).unwrap();
        let sched = crate::system::scheduler::Scheduler::new(&w, &c, &t).unwrap();
        let sim = sched.run().unwrap();
        let ratio = sim.iteration_time.as_secs() / est.total.as_secs();
        assert!((0.2..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn coll_descriptor_uses_bottleneck_bandwidth() {
        let c = presets::cluster("ampere", 2).unwrap();
        // inter-node ring: NIC (25 GB/s) is the bottleneck
        let def = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 30,
            kind: CommKind::Dp,
            label: "x".into(),
        };
        let row = coll_descriptor(&c, &def).unwrap();
        assert!((row[3] - 25e9).abs() / 25e9 < 1e-6, "{}", row[3]);
        // intra-node: NVLink 300 GB/s
        let def2 = CollectiveDef { ranks: vec![0, 1], ..def };
        let row2 = coll_descriptor(&c, &def2).unwrap();
        assert!((row2[3] - 300e9).abs() / 300e9 < 1e-6, "{}", row2[3]);
    }

    #[test]
    fn coll_descriptor_is_fabric_aware_single_switch() {
        use crate::config::cluster::FabricSpec;
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = FabricSpec::SingleSwitch;
        let def = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 30,
            kind: CommKind::Dp,
            label: "x".into(),
        };
        // non-blocking switch: the 25 GB/s NIC stays the bottleneck
        let row = coll_descriptor(&c, &def).unwrap();
        assert!((row[3] - 25e9).abs() / 25e9 < 1e-6, "{}", row[3]);
    }

    #[test]
    fn coll_descriptor_is_fabric_aware_leaf_spine() {
        use crate::config::cluster::FabricSpec;
        let def = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 30,
            kind: CommKind::Dp,
            label: "x".into(),
        };
        // non-blocking spine: uplinks carry the node NIC aggregate
        // (8 × 25 GB/s), so the NIC stays the bottleneck
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = FabricSpec::LeafSpine { spines: 1, oversubscription: 1.0 };
        let row = coll_descriptor(&c, &def).unwrap();
        assert!((row[3] - 25e9).abs() / 25e9 < 1e-6, "{}", row[3]);
        // 16× oversubscribed: uplink = 25e9 × 8 / (1 × 16) = 12.5 GB/s
        // — the tapered uplink, not the NIC, now caps the estimate
        c.fabric = FabricSpec::LeafSpine { spines: 1, oversubscription: 16.0 };
        let row = coll_descriptor(&c, &def).unwrap();
        assert!((row[3] - 12.5e9).abs() / 12.5e9 < 1e-6, "{}", row[3]);
        // intra-node traffic never touches the taper
        let def2 = CollectiveDef { ranks: vec![0, 1], ..def };
        let row2 = coll_descriptor(&c, &def2).unwrap();
        assert!((row2[3] - 300e9).abs() / 300e9 < 1e-6, "{}", row2[3]);
    }

    #[test]
    fn native_mirror_matches_kernel_formulas() {
        // spot-check against hand computation: ring allreduce, n=8,
        // 1 GB at 25 GB/s, lat 1us: 2*(7/8)*0.04 + 14e-6
        let row = [0.0, 8.0, 1e9, 25e9, 1e-6, 0.0, 0.0, 0.0];
        let t = coll_time_native(&row);
        let expect = 2.0 * (7.0 / 8.0) * (1e9 / 25e9) + 14.0 * 1e-6;
        // rows are stored f32 (25e9 is not exactly representable)
        assert!((t - expect).abs() / expect < 1e-6);
    }
}
