//! Max-min fair fluid-flow simulation (system S9).
//!
//! Each flow traverses a fixed fabric route; its instantaneous rate
//! is the max-min fair share across the links of that route (progressive
//! filling). Rates are recomputed whenever a flow arrives or departs —
//! the classic fluid approximation of per-packet network simulation,
//! which preserves exactly what the paper's Fig 6 measures: per-flow
//! completion times under link contention and per-hop fixed delays.
//!
//! A flow's completion time = (time for its bytes to drain at the
//! time-varying fair rate) + (sum of fixed per-hop delays: the
//! store-and-forward tail of the last frame through the QbbChannel
//! model).
//!
//! ## Hot-path architecture (§Perf, DESIGN.md §23)
//!
//! The rebalance path performs **no allocation and no hash lookups**:
//!
//! * flows live in a slot slab (`Vec<Option<ActiveFlow>>` + free list);
//!   public [`FlowId`]s stay monotone for record/tag stability, and an
//!   ascending `(id, slot)` index replaces the seed's `HashMap`;
//! * per-link member lists are maintained **incrementally** on flow
//!   start/completion (ascending by id — identical order to the seed's
//!   per-rebalance rebuild), so rebalances never re-walk all routes;
//! * each rebalance is **scoped** to the connected component (under the
//!   shares-a-link relation) of the arriving/departing flows. Max-min
//!   progressive filling decomposes exactly across components — a
//!   component's fix order and float accumulation order are unchanged
//!   by the other components' presence — so scoped rates are
//!   bit-identical to the full recompute, and out-of-scope flows keep
//!   their (identical) rates and pending events. Progress bookkeeping
//!   (`remaining -= rate·dt`) still advances *every* active flow each
//!   rebalance so the floating-point chunking matches the unscoped
//!   computation bit for bit.
//!
//! Self-communication flows (empty routes, infinite rate) belong to no
//! link component; they join every scope so their reschedule cadence
//! matches the unscoped algorithm exactly.

use std::sync::Arc;

use super::routing::{Route, RouteCache};
use super::topology::{LinkId, Topology};
use crate::engine::{Engine, EventId};
use crate::util::units::Time;

/// Monotone identifier of one flow within a [`FlowSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// What the caller wants moved.
#[derive(Debug, Clone, Copy)]
pub struct FlowSpec {
    /// Source global GPU rank.
    pub src: u32,
    /// Destination global GPU rank.
    pub dst: u32,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Caller-defined grouping tag (e.g. collective id).
    pub tag: u64,
}

/// Completed-flow record: the Fig-6 sample unit.
#[derive(Debug, Clone)]
pub struct FlowRecord {
    /// The completed flow's id.
    pub id: FlowId,
    /// Source global GPU rank.
    pub src: u32,
    /// Destination global GPU rank.
    pub dst: u32,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Simulated time the flow started.
    pub start: Time,
    /// Simulated time the flow completed.
    pub end: Time,
    /// The spec's caller-defined grouping tag.
    pub tag: u64,
}

impl FlowRecord {
    /// Flow completion time (`end - start`), the Fig-6 metric.
    pub fn fct(&self) -> Time {
        self.end - self.start
    }
}

#[derive(Debug)]
struct ActiveFlow {
    spec: FlowSpec,
    route: Arc<Route>,
    remaining: f64, // bytes
    rate: f64,      // bytes/s, set by rebalance
    last_update: Time,
    fixed: Time, // per-hop delay tail
    start: Time,
    event: Option<EventId>,
}

/// The fluid network simulator. Holds the (shareable) topology;
/// integrates with any engine event type via a `FlowId -> E`
/// constructor. The topology sits behind an `Arc` so one built graph
/// can back many concurrent simulations (pass an owned `Topology` or a
/// cloned `Arc` — both convert).
#[derive(Debug)]
pub struct FlowSim {
    /// The shared network graph flows are routed over.
    pub topo: Arc<Topology>,
    /// Flow slab; a slot is `Some` while its flow is in flight.
    slots: Vec<Option<ActiveFlow>>,
    free_slots: Vec<u32>,
    next_id: u64,
    /// Records of every completed flow (when `keep_records`).
    pub records: Vec<FlowRecord>,
    /// Set false to skip record-keeping (perf runs).
    pub keep_records: bool,
    rebalances: u64,
    /// Active `(id, slot)` pairs in ascending id order (ids are
    /// monotone, so starts push to the back; completions
    /// binary-search-remove). The deterministic iteration order of
    /// every rebalance.
    ordered: Vec<(u64, u32)>,
    /// Per-link active member lists, ascending by id — maintained
    /// incrementally on start/completion instead of rebuilt per
    /// rebalance.
    link_members: Vec<Vec<(u64, u32)>>,
    /// Active flows with empty routes (self-communication): part of
    /// every rebalance scope (see module docs).
    unrouted: Vec<(u64, u32)>,
    /// Lazily-materialized per-pair routes ([`RouteCache`]): each
    /// distinct (src, dst) is assembled once per simulation run and
    /// shared by every later flow between the endpoints.
    routes: RouteCache,
    /// Links disabled by an in-progress NIC/link repair (DESIGN.md
    /// §28). Empty in healthy runs — the start path then takes the
    /// exact pre-degraded-mode route lookup, so the feature is
    /// zero-cost when off.
    dead_links: Vec<LinkId>,
    // --- reusable scratch (no per-rebalance allocation) ---
    scratch_residual: Vec<f64>, // per link
    link_in_scope: Vec<bool>,   // per link
    scope_links: Vec<u32>,
    flow_in_scope: Vec<bool>, // per slot
    scope_flows: Vec<(u64, u32)>,
    scratch_rate: Vec<f64>,   // per slot
    scratch_fixed: Vec<bool>, // per slot
    seed_links: Vec<u32>,
    bfs_stack: Vec<u32>,
}

impl FlowSim {
    /// Create a simulator over a built topology (owned or shared).
    pub fn new(topo: impl Into<Arc<Topology>>) -> Self {
        let topo = topo.into();
        let nlinks = topo.num_links();
        FlowSim {
            topo,
            slots: Vec::new(),
            free_slots: Vec::new(),
            next_id: 0,
            records: Vec::new(),
            keep_records: true,
            rebalances: 0,
            ordered: Vec::new(),
            link_members: vec![Vec::new(); nlinks],
            unrouted: Vec::new(),
            routes: RouteCache::new(),
            dead_links: Vec::new(),
            scratch_residual: vec![0.0; nlinks],
            link_in_scope: vec![false; nlinks],
            scope_links: Vec::new(),
            flow_in_scope: Vec::new(),
            scope_flows: Vec::new(),
            scratch_rate: Vec::new(),
            scratch_fixed: Vec::new(),
            seed_links: Vec::new(),
            bfs_stack: Vec::new(),
        }
    }

    /// Pre-reserve capacity for `concurrent` simultaneously-active flows
    /// and `total` completion records (the scheduler sizes these from
    /// compiled flow counts so the hot loop never grows the slab).
    pub fn reserve(&mut self, concurrent: usize, total: usize) {
        self.slots.reserve(concurrent);
        self.ordered.reserve(concurrent);
        self.flow_in_scope.reserve(concurrent);
        self.scratch_rate.reserve(concurrent);
        self.scratch_fixed.reserve(concurrent);
        self.scope_flows.reserve(concurrent);
        if self.keep_records {
            self.records.reserve(total);
        }
    }

    /// Enter degraded mode: every future route avoids `dead` via
    /// [`RouteCache::get_avoiding`] detours. The route cache resets so
    /// previously-materialized healthy routes cannot leak into the
    /// degraded run. Callers must pre-check survivability
    /// ([`crate::network::routing::route_avoiding`]) for the endpoint
    /// pairs they will drive — starting a flow with no surviving route
    /// panics. Passing an empty set restores healthy routing.
    pub fn set_dead_links(&mut self, dead: Vec<LinkId>) {
        self.dead_links = dead;
        self.routes = RouteCache::new();
    }

    /// Flows currently in flight.
    pub fn active_count(&self) -> usize {
        self.ordered.len()
    }

    /// Max-min rate recomputations so far (a perf counter).
    pub fn rebalance_count(&self) -> u64 {
        self.rebalances
    }

    /// Slab slots allocated so far (== peak concurrent flows).
    pub fn slab_len(&self) -> usize {
        self.slots.len()
    }

    fn alloc_slot(&mut self) -> usize {
        match self.free_slots.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(None);
                self.flow_in_scope.push(false);
                self.scratch_rate.push(0.0);
                self.scratch_fixed.push(false);
                self.slots.len() - 1
            }
        }
    }

    /// Start one flow; schedules its (tentative) completion event and
    /// returns its id.
    pub fn start<E>(
        &mut self,
        eng: &mut Engine<E>,
        spec: FlowSpec,
        mk: &impl Fn(FlowId) -> E,
    ) -> FlowId {
        let id = FlowId(self.next_id);
        self.start_many_posted(eng, std::slice::from_ref(&spec), None, mk);
        id
    }

    /// Start a batch of flows with a single rate rebalance (used by the
    /// collective executor: one ring step = one batch). Ids are
    /// assigned in slice order from the monotone counter.
    pub fn start_many<E>(
        &mut self,
        eng: &mut Engine<E>,
        specs: &[FlowSpec],
        mk: &impl Fn(FlowId) -> E,
    ) {
        self.start_many_posted(eng, specs, None, mk)
    }

    /// Like [`FlowSim::start_many`], but with per-flow *post* times: the
    /// moment the sender made the data available (<= now). Transfer
    /// physics start now; the recorded FCT is measured from the post
    /// time, so a flow whose collective waited on stragglers carries
    /// that wait in its FCT — matching how SimAI/ns-3 measure per-flow
    /// completion of desynchronized collective sends (paper Fig 6).
    pub fn start_many_posted<E>(
        &mut self,
        eng: &mut Engine<E>,
        specs: &[FlowSpec],
        posted: Option<&[Time]>,
        mk: &impl Fn(FlowId) -> E,
    ) {
        let now = eng.now();
        if let Some(p) = posted {
            debug_assert_eq!(p.len(), specs.len());
        }
        self.seed_links.clear();
        for (i, spec) in specs.iter().enumerate() {
            let start = posted.map(|p| p[i].min(now)).unwrap_or(now);
            let id = self.next_id;
            self.next_id += 1;
            let (route, fixed) = if self.dead_links.is_empty() {
                self.routes.get(&self.topo, spec.src, spec.dst)
            } else {
                self.routes
                    .get_avoiding(&self.topo, spec.src, spec.dst, &self.dead_links)
                    .expect("degraded flow with no surviving route (survivability is pre-checked)")
            };
            let slot = self.alloc_slot();
            for l in &route.links {
                // monotone ids keep the member list ascending
                self.link_members[l.0 as usize].push((id, slot as u32));
                self.seed_links.push(l.0);
            }
            if route.links.is_empty() {
                self.unrouted.push((id, slot as u32));
            }
            self.slots[slot] = Some(ActiveFlow {
                spec: *spec,
                route,
                remaining: spec.bytes as f64,
                rate: 0.0,
                last_update: now,
                fixed,
                start,
                event: None,
            });
            self.ordered.push((id, slot as u32)); // stays sorted
        }
        self.rebalance(eng, mk);
    }

    /// Handle a completion event. Returns `None` for stale events (the
    /// flow was rescheduled); otherwise removes the flow, records its
    /// FCT and rebalances the flows that shared links with it.
    pub fn on_complete<E>(
        &mut self,
        eng: &mut Engine<E>,
        id: FlowId,
        event: EventId,
        mk: &impl Fn(FlowId) -> E,
    ) -> Option<FlowRecord> {
        let pos = self.ordered.binary_search_by_key(&id.0, |&(i, _)| i).ok()?;
        let slot = self.ordered[pos].1 as usize;
        let is_current =
            self.slots[slot].as_ref().map(|f| f.event == Some(event)).unwrap_or(false);
        if !is_current {
            return None; // superseded by a reschedule
        }
        let f = self.slots[slot].take().unwrap();
        self.ordered.remove(pos);
        self.seed_links.clear();
        for l in &f.route.links {
            let members = &mut self.link_members[l.0 as usize];
            if let Ok(p) = members.binary_search_by_key(&id.0, |&(i, _)| i) {
                members.remove(p);
            }
            self.seed_links.push(l.0);
        }
        if f.route.links.is_empty() {
            if let Ok(p) = self.unrouted.binary_search_by_key(&id.0, |&(i, _)| i) {
                self.unrouted.remove(p);
            }
        }
        self.free_slots.push(slot as u32);
        let rec = FlowRecord {
            id,
            src: f.spec.src,
            dst: f.spec.dst,
            bytes: f.spec.bytes,
            start: f.start,
            end: eng.now(),
            tag: f.spec.tag,
        };
        if self.keep_records {
            self.records.push(rec.clone());
        }
        self.rebalance(eng, mk);
        Some(rec)
    }

    /// Advance progress to `now`, recompute max-min rates over the
    /// affected component, reschedule completion events whose estimates
    /// changed. `seed_links` holds the links of the flows that arrived
    /// or departed.
    fn rebalance<E>(&mut self, eng: &mut Engine<E>, mk: &impl Fn(FlowId) -> E) {
        self.rebalances += 1;
        let now = eng.now();
        // 1. advance remaining bytes at the old rates. Every active
        //    flow, not just the scope: identical floating-point
        //    chunking to the unscoped computation (see module docs).
        for &(_, slot) in &self.ordered {
            let f = self.slots[slot as usize].as_mut().unwrap();
            let dt = (now.saturating_sub(f.last_update)).as_secs();
            if dt > 0.0 && f.rate > 0.0 {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
            f.last_update = now;
        }
        // 2. scope: transitive closure of link-sharing from the seed
        self.build_scope();
        // 3. max-min fair rates over the scope
        self.maxmin_scoped();
        // 4. apply + reschedule in ascending-id order (deterministic
        //    event insertion). Out-of-scope flows keep their rates —
        //    the full recompute would reproduce them bit-identically
        //    and then skip the reschedule as unchanged.
        for &(id, slot) in &self.scope_flows {
            let s = slot as usize;
            let new_rate = self.scratch_rate[s];
            let f = self.slots[s].as_mut().unwrap();
            // transfer already drained: the flow is in its fixed-delay
            // tail and its completion event is final — rescheduling here
            // would wrongly re-add the tail from `now`
            if f.remaining <= 0.0 && f.event.is_some() {
                f.rate = new_rate;
                continue;
            }
            // rate unchanged -> the pending completion event is still
            // exact (remaining drained at precisely rate*dt); skip the
            // cancel+push churn (perf: most disjoint flows hit this)
            if f.event.is_some() && f.rate > 0.0 && new_rate.is_finite() {
                let rel = (new_rate - f.rate).abs() / f.rate;
                if rel < 1e-12 {
                    continue;
                }
            }
            f.rate = new_rate;
            let transfer = if f.remaining <= 0.0 {
                Time::ZERO
            } else if new_rate.is_infinite() {
                Time::ZERO
            } else if new_rate <= 0.0 {
                // starved: leave the stale event; a later rebalance will fix it
                continue;
            } else {
                Time::from_secs(f.remaining / new_rate)
            };
            let when = now + transfer + f.fixed;
            if let Some(old) = f.event.take() {
                eng.queue.cancel(old);
            }
            let ev = eng.schedule_at(when, mk(FlowId(id)));
            f.event = Some(ev);
        }
        // 5. reset the scope flags for the next rebalance
        for &l in &self.scope_links {
            self.link_in_scope[l as usize] = false;
        }
        for &(_, slot) in &self.scope_flows {
            self.flow_in_scope[slot as usize] = false;
        }
    }

    /// BFS over the flow–link bipartite graph from the seed links: the
    /// connected component whose rates can change. Fills `scope_links`
    /// (sorted ascending for the deterministic bottleneck scan) and
    /// `scope_flows` (ascending by id).
    fn build_scope(&mut self) {
        self.scope_links.clear();
        self.scope_flows.clear();
        self.bfs_stack.clear();
        for &l in &self.seed_links {
            if !self.link_in_scope[l as usize] {
                self.link_in_scope[l as usize] = true;
                self.bfs_stack.push(l);
            }
        }
        // empty-route flows join every scope (the unscoped algorithm
        // re-examines them on every rebalance)
        for &(_, slot) in &self.unrouted {
            self.flow_in_scope[slot as usize] = true;
        }
        while let Some(l) = self.bfs_stack.pop() {
            self.scope_links.push(l);
            for &(_, slot) in &self.link_members[l as usize] {
                if self.flow_in_scope[slot as usize] {
                    continue;
                }
                self.flow_in_scope[slot as usize] = true;
                let f = self.slots[slot as usize].as_ref().unwrap();
                for l2 in &f.route.links {
                    if !self.link_in_scope[l2.0 as usize] {
                        self.link_in_scope[l2.0 as usize] = true;
                        self.bfs_stack.push(l2.0);
                    }
                }
            }
        }
        for &(id, slot) in &self.ordered {
            if self.flow_in_scope[slot as usize] {
                self.scope_flows.push((id, slot));
            }
        }
        self.scope_links.sort_unstable();
    }

    /// Progressive-filling max-min fair allocation over the scope's
    /// link capacities, writing per-slot rates into `scratch_rate`.
    /// All iteration is over sorted structures so float accumulation
    /// order — and therefore the simulated timeline — is deterministic
    /// and bit-identical to the unscoped computation (per-component
    /// decomposition; see module docs). Uses preallocated per-link and
    /// per-slot scratch arrays — the §Perf optimization that took the
    /// flow simulator from ~1.3k to >10k flows/s, now allocation-free.
    fn maxmin_scoped(&mut self) {
        let mut remaining = 0usize;
        for &(_, slot) in &self.scope_flows {
            let s = slot as usize;
            let f = self.slots[s].as_ref().unwrap();
            if f.route.links.is_empty() {
                self.scratch_rate[s] = f64::INFINITY;
                self.scratch_fixed[s] = true;
            } else {
                // INFINITY until fixed: a flow the filling loop never
                // reaches (impossible while it has links, but kept
                // equivalent to the historical unscoped behavior)
                self.scratch_rate[s] = f64::INFINITY;
                self.scratch_fixed[s] = false;
                remaining += 1;
            }
        }
        for &l in &self.scope_links {
            self.scratch_residual[l as usize] = self.topo.link(LinkId(l)).bw.bytes_per_sec();
        }
        while remaining > 0 {
            // bottleneck link: min residual / unfixed-members
            let mut best: Option<(u32, f64)> = None;
            for &l in &self.scope_links {
                let mem = &self.link_members[l as usize];
                let n = mem.iter().filter(|&&(_, s)| !self.scratch_fixed[s as usize]).count();
                if n == 0 {
                    continue;
                }
                let fair = self.scratch_residual[l as usize] / n as f64;
                if best.map(|(_, b)| fair < b).unwrap_or(true) {
                    best = Some((l, fair));
                }
            }
            let Some((bottleneck, fair)) = best else { break };
            // fix every unfixed flow crossing the bottleneck (member
            // lists are ascending by id: deterministic fix order)
            for &(_, slot) in &self.link_members[bottleneck as usize] {
                let s = slot as usize;
                if self.scratch_fixed[s] {
                    continue;
                }
                self.scratch_fixed[s] = true;
                self.scratch_rate[s] = fair;
                remaining -= 1;
                let f = self.slots[s].as_ref().unwrap();
                for l2 in &f.route.links {
                    self.scratch_residual[l2.0 as usize] -= fair;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::network::topology::Topology;

    #[derive(Debug, Clone, Copy)]
    struct Done(FlowId);

    fn sim(nodes: u32) -> (FlowSim, Engine<Done>) {
        let topo = Topology::build(&presets::cluster("ampere", nodes).unwrap()).unwrap();
        (FlowSim::new(topo), Engine::new())
    }

    #[test]
    fn single_flow_gets_full_link_rate() {
        let (mut fs, mut eng) = sim(2);
        // rank 7 -> 15: rail path bottlenecked by 200 Gbps NIC = 25 GB/s
        let bytes = 25_000_000_000u64; // exactly 1 s at NIC rate
        fs.start(&mut eng, FlowSpec { src: 7, dst: 15, bytes, tag: 0 }, &Done);
        let mut fcts = Vec::new();
        let fs_ref = &mut fs;
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct());
            }
        })
        .unwrap();
        assert_eq!(fcts.len(), 1);
        let secs = fcts[0].as_secs();
        assert!((secs - 1.0).abs() < 0.001, "fct {secs}");
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let (mut fs, mut eng) = sim(2);
        let bytes = 12_500_000_000u64; // 0.5 s alone at 25 GB/s
        // both flows ride rail 7 from node 0 to node 1 -> share NIC 7 up-link
        let specs = [
            FlowSpec { src: 7, dst: 15, bytes, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        assert_eq!(fcts.len(), 2);
        // each gets half the NIC: ~1.0 s
        for f in &fcts {
            assert!((f - 1.0).abs() < 0.01, "fct {f}");
        }
    }

    #[test]
    fn departure_releases_bandwidth() {
        let (mut fs, mut eng) = sim(2);
        // flow A: 12.5 GB, flow B: 25 GB on the same rail.
        // Shared phase: both at 12.5 GB/s. A finishes at t=1 having sent
        // 12.5; B has 12.5 left, now at full 25 GB/s -> +0.5 s = 1.5 s.
        let specs = [
            FlowSpec { src: 7, dst: 15, bytes: 12_500_000_000, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes: 25_000_000_000, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut by_tag = std::collections::HashMap::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                by_tag.insert(rec.tag, rec.fct().as_secs());
            }
        })
        .unwrap();
        assert!((by_tag[&0] - 1.0).abs() < 0.01, "{by_tag:?}");
        assert!((by_tag[&1] - 1.5).abs() < 0.01, "{by_tag:?}");
    }

    #[test]
    fn disjoint_flows_do_not_interact() {
        let (mut fs, mut eng) = sim(2);
        let bytes = 25_000_000_000u64;
        // different rails: local 6 and local 7
        let specs = [
            FlowSpec { src: 6, dst: 14, bytes, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        for f in &fcts {
            assert!((f - 1.0).abs() < 0.01, "fct {f}");
        }
    }

    #[test]
    fn intra_node_flow_uses_nvlink_rate() {
        let (mut fs, mut eng) = sim(1);
        // NVLink unidirectional 2400 Gbps = 300 GB/s
        let bytes = 300_000_000_000u64;
        fs.start(&mut eng, FlowSpec { src: 0, dst: 7, bytes, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        assert!((fcts[0] - 1.0).abs() < 0.001, "fct {}", fcts[0]);
    }

    #[test]
    fn zero_byte_flow_costs_only_fixed_delay() {
        let (mut fs, mut eng) = sim(2);
        fs.start(&mut eng, FlowSpec { src: 7, dst: 15, bytes: 0, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_ns());
            }
        })
        .unwrap();
        let expect = 2.0 * 287.5 + 368.0 + 668.0 + 2.0 * 287.5;
        assert!((fcts[0] - expect).abs() < 0.1, "fct {} vs {expect}", fcts[0]);
    }

    #[test]
    fn self_flow_completes_immediately() {
        let (mut fs, mut eng) = sim(1);
        fs.start(&mut eng, FlowSpec { src: 3, dst: 3, bytes: 1 << 30, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct());
            }
        })
        .unwrap();
        assert_eq!(fcts, vec![Time::ZERO]);
    }

    #[test]
    fn hetero_cluster_slower_nvlink_on_ampere_node() {
        let topo = Topology::build(&presets::cluster_hetero(1, 1).unwrap()).unwrap();
        let mut fs = FlowSim::new(topo);
        let mut eng: Engine<Done> = Engine::new();
        let bytes = 100_000_000_000u64;
        // node 0 = ampere (2400 Gbps uni), node 1 = hopper (3600 Gbps uni)
        let specs = [
            FlowSpec { src: 0, dst: 1, bytes, tag: 0 },  // ampere intra
            FlowSpec { src: 8, dst: 9, bytes, tag: 1 },  // hopper intra
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut by_tag = std::collections::HashMap::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                by_tag.insert(rec.tag, rec.fct().as_secs());
            }
        })
        .unwrap();
        let ratio = by_tag[&0] / by_tag[&1];
        assert!((ratio - 1.5).abs() < 0.01, "ratio {ratio}"); // 3600/2400
    }

    #[test]
    fn records_capture_all_flows() {
        let (mut fs, mut eng) = sim(2);
        let specs: Vec<FlowSpec> =
            (0..8).map(|i| FlowSpec { src: i, dst: 8 + i, bytes: 1_000_000, tag: i as u64 }).collect();
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        eng.run(|e, ev| {
            fs_ref.on_complete(e, ev.payload.0, ev.id, &Done);
        })
        .unwrap();
        assert_eq!(fs.records.len(), 8);
        assert_eq!(fs.active_count(), 0);
    }

    #[test]
    fn flow_slab_slots_are_reused() {
        // waves of flows: the slab must stay bounded by the peak
        // concurrency, not the total flow count
        let (mut fs, mut eng) = sim(2);
        for wave in 0..20u64 {
            let specs: Vec<FlowSpec> = (0..8)
                .map(|i| FlowSpec { src: i, dst: 8 + i, bytes: 1 << 20, tag: wave * 8 + i as u64 })
                .collect();
            fs.start_many(&mut eng, &specs, &Done);
            let fs_ref = &mut fs;
            eng.run(|e, ev| {
                fs_ref.on_complete(e, ev.payload.0, ev.id, &Done);
            })
            .unwrap();
        }
        assert_eq!(fs.records.len(), 160);
        assert!(fs.slab_len() <= 8, "slab {} > peak concurrency 8", fs.slab_len());
        assert_eq!(fs.active_count(), 0);
    }

    #[test]
    fn scoped_rebalance_matches_joint_computation() {
        // two independent rails with staggered arrivals: scoped
        // rebalances must produce the same FCTs as if each pair ran
        // alone (per-component max-min decomposition)
        let run_pair = |stagger: bool| {
            let (mut fs, mut eng) = sim(2);
            let bytes = 12_500_000_000u64;
            let mut specs = vec![
                FlowSpec { src: 6, dst: 14, bytes, tag: 0 },
                FlowSpec { src: 6, dst: 14, bytes: 2 * bytes, tag: 1 },
            ];
            if stagger {
                // an unrelated pair on rail 7, started in the same batch
                specs.push(FlowSpec { src: 7, dst: 15, bytes, tag: 2 });
                specs.push(FlowSpec { src: 7, dst: 15, bytes: 2 * bytes, tag: 3 });
            }
            fs.start_many(&mut eng, &specs, &Done);
            let fs_ref = &mut fs;
            let mut by_tag = std::collections::HashMap::new();
            eng.run(|e, ev| {
                if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                    by_tag.insert(rec.tag, rec.fct());
                }
            })
            .unwrap();
            by_tag
        };
        let alone = run_pair(false);
        let together = run_pair(true);
        // rail-6 FCTs are bit-identical whether or not rail 7 is busy
        assert_eq!(alone[&0], together[&0]);
        assert_eq!(alone[&1], together[&1]);
        // and the rail-7 pair mirrors the rail-6 pair exactly
        assert_eq!(together[&0], together[&2]);
        assert_eq!(together[&1], together[&3]);
    }
}
