//! Max-min fair fluid-flow simulation (system S9).
//!
//! Each flow traverses a fixed rail-only route; its instantaneous rate
//! is the max-min fair share across the links of that route (progressive
//! filling). Rates are recomputed whenever a flow arrives or departs —
//! the classic fluid approximation of per-packet network simulation,
//! which preserves exactly what the paper's Fig 6 measures: per-flow
//! completion times under link contention and per-hop fixed delays.
//!
//! A flow's completion time = (time for its bytes to drain at the
//! time-varying fair rate) + (sum of fixed per-hop delays: the
//! store-and-forward tail of the last frame through the QbbChannel
//! model).

use std::collections::HashMap;
use std::sync::Arc;

use super::routing::{self, Route};
use super::topology::Topology;
use crate::engine::{Engine, EventId};
use crate::util::units::Time;

/// Monotone identifier of one flow within a [`FlowSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub u64);

/// What the caller wants moved.
#[derive(Debug, Clone, Copy)]
pub struct FlowSpec {
    /// Source global GPU rank.
    pub src: u32,
    /// Destination global GPU rank.
    pub dst: u32,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Caller-defined grouping tag (e.g. collective id).
    pub tag: u64,
}

/// Completed-flow record: the Fig-6 sample unit.
#[derive(Debug, Clone)]
pub struct FlowRecord {
    /// The completed flow's id.
    pub id: FlowId,
    /// Source global GPU rank.
    pub src: u32,
    /// Destination global GPU rank.
    pub dst: u32,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Simulated time the flow started.
    pub start: Time,
    /// Simulated time the flow completed.
    pub end: Time,
    /// The spec's caller-defined grouping tag.
    pub tag: u64,
}

impl FlowRecord {
    /// Flow completion time (`end - start`), the Fig-6 metric.
    pub fn fct(&self) -> Time {
        self.end - self.start
    }
}

#[derive(Debug)]
struct ActiveFlow {
    spec: FlowSpec,
    route: Route,
    remaining: f64, // bytes
    rate: f64,      // bytes/s, set by rebalance
    last_update: Time,
    fixed: Time, // per-hop delay tail
    start: Time,
    event: Option<EventId>,
}

/// The fluid network simulator. Holds the (shareable) topology;
/// integrates with any engine event type via a `FlowId -> E`
/// constructor. The topology sits behind an `Arc` so one built graph
/// can back many concurrent simulations (pass an owned `Topology` or a
/// cloned `Arc` — both convert).
#[derive(Debug)]
pub struct FlowSim {
    /// The shared network graph flows are routed over.
    pub topo: Arc<Topology>,
    active: HashMap<FlowId, ActiveFlow>,
    next_id: u64,
    /// Records of every completed flow (when `keep_records`).
    pub records: Vec<FlowRecord>,
    /// Set false to skip record-keeping (perf runs).
    pub keep_records: bool,
    rebalances: u64,
    // --- reusable max-min scratch (perf: avoids per-rebalance allocs) ---
    scratch_residual: Vec<f64>,
    scratch_members: Vec<Vec<FlowId>>,
    scratch_touched: Vec<u32>,
    /// Active flow ids in ascending order (ids are monotone, so starts
    /// push to the back; completions binary-search-remove). Avoids the
    /// per-rebalance collect+sort.
    ordered: Vec<FlowId>,
}

impl FlowSim {
    /// Create a simulator over a built topology (owned or shared).
    pub fn new(topo: impl Into<Arc<Topology>>) -> Self {
        let topo = topo.into();
        let nlinks = topo.num_links();
        FlowSim {
            topo,
            active: HashMap::new(),
            next_id: 0,
            records: Vec::new(),
            keep_records: true,
            rebalances: 0,
            scratch_residual: vec![0.0; nlinks],
            scratch_members: vec![Vec::new(); nlinks],
            scratch_touched: Vec::new(),
            ordered: Vec::new(),
        }
    }

    /// Flows currently in flight.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Max-min rate recomputations so far (a perf counter).
    pub fn rebalance_count(&self) -> u64 {
        self.rebalances
    }

    /// Start one flow; schedules its (tentative) completion event.
    pub fn start<E>(
        &mut self,
        eng: &mut Engine<E>,
        spec: FlowSpec,
        mk: &impl Fn(FlowId) -> E,
    ) -> FlowId {
        self.start_many(eng, std::slice::from_ref(&spec), mk)[0]
    }

    /// Start a batch of flows with a single rate rebalance (used by the
    /// collective executor: one ring step = one batch).
    pub fn start_many<E>(
        &mut self,
        eng: &mut Engine<E>,
        specs: &[FlowSpec],
        mk: &impl Fn(FlowId) -> E,
    ) -> Vec<FlowId> {
        self.start_many_posted(eng, specs, None, mk)
    }

    /// Like [`FlowSim::start_many`], but with per-flow *post* times: the
    /// moment the sender made the data available (<= now). Transfer
    /// physics start now; the recorded FCT is measured from the post
    /// time, so a flow whose collective waited on stragglers carries
    /// that wait in its FCT — matching how SimAI/ns-3 measure per-flow
    /// completion of desynchronized collective sends (paper Fig 6).
    pub fn start_many_posted<E>(
        &mut self,
        eng: &mut Engine<E>,
        specs: &[FlowSpec],
        posted: Option<&[Time]>,
        mk: &impl Fn(FlowId) -> E,
    ) -> Vec<FlowId> {
        let now = eng.now();
        if let Some(p) = posted {
            debug_assert_eq!(p.len(), specs.len());
        }
        let mut ids = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let start = posted.map(|p| p[i].min(now)).unwrap_or(now);
            let id = FlowId(self.next_id);
            self.next_id += 1;
            let route = routing::route(&self.topo, spec.src, spec.dst);
            let fixed = routing::fixed_delay(&self.topo, &route);
            self.active.insert(
                id,
                ActiveFlow {
                    spec: *spec,
                    route,
                    remaining: spec.bytes as f64,
                    rate: 0.0,
                    last_update: now,
                    fixed,
                    start,
                    event: None,
                },
            );
            ids.push(id);
            self.ordered.push(id); // ids are monotone -> stays sorted
        }
        self.rebalance(eng, mk);
        ids
    }

    /// Handle a completion event. Returns `None` for stale events (the
    /// flow was rescheduled); otherwise removes the flow, records its
    /// FCT and rebalances the rest.
    pub fn on_complete<E>(
        &mut self,
        eng: &mut Engine<E>,
        id: FlowId,
        event: EventId,
        mk: &impl Fn(FlowId) -> E,
    ) -> Option<FlowRecord> {
        let is_current = self.active.get(&id).map(|f| f.event == Some(event)).unwrap_or(false);
        if !is_current {
            return None; // superseded by a reschedule
        }
        let f = self.active.remove(&id).unwrap();
        if let Ok(pos) = self.ordered.binary_search(&id) {
            self.ordered.remove(pos);
        }
        let rec = FlowRecord {
            id,
            src: f.spec.src,
            dst: f.spec.dst,
            bytes: f.spec.bytes,
            start: f.start,
            end: eng.now(),
            tag: f.spec.tag,
        };
        if self.keep_records {
            self.records.push(rec.clone());
        }
        self.rebalance(eng, mk);
        Some(rec)
    }

    /// Advance progress to `now`, recompute max-min rates, reschedule
    /// completion events whose estimates changed.
    fn rebalance<E>(&mut self, eng: &mut Engine<E>, mk: &impl Fn(FlowId) -> E) {
        self.rebalances += 1;
        let now = eng.now();
        // 1. advance remaining bytes at the old rates
        for f in self.active.values_mut() {
            let dt = (now.saturating_sub(f.last_update)).as_secs();
            if dt > 0.0 && f.rate > 0.0 {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
            f.last_update = now;
        }
        // 2. max-min fair rates
        let rates = self.maxmin();
        // 3. apply + reschedule (sorted: deterministic event insertion)
        let ids = self.ordered.clone();
        for id in ids {
            let new_rate = rates.get(&id).copied().unwrap_or(f64::INFINITY);
            let f = self.active.get_mut(&id).unwrap();
            // transfer already drained: the flow is in its fixed-delay
            // tail and its completion event is final — rescheduling here
            // would wrongly re-add the tail from `now`
            if f.remaining <= 0.0 && f.event.is_some() {
                f.rate = new_rate;
                continue;
            }
            // rate unchanged -> the pending completion event is still
            // exact (remaining drained at precisely rate*dt); skip the
            // cancel+push churn (perf: most disjoint flows hit this)
            if f.event.is_some() && f.rate > 0.0 && new_rate.is_finite() {
                let rel = (new_rate - f.rate).abs() / f.rate;
                if rel < 1e-12 {
                    continue;
                }
            }
            f.rate = new_rate;
            let transfer = if f.remaining <= 0.0 {
                Time::ZERO
            } else if new_rate.is_infinite() {
                Time::ZERO
            } else if new_rate <= 0.0 {
                // starved: leave the stale event; a later rebalance will fix it
                continue;
            } else {
                Time::from_secs(f.remaining / new_rate)
            };
            let when = now + transfer + f.fixed;
            if let Some(old) = f.event.take() {
                eng.queue.cancel(old);
            }
            let ev = eng.schedule_at(when, mk(id));
            f.event = Some(ev);
        }
    }

    /// Progressive-filling max-min fair allocation over link capacities.
    /// All iteration is over sorted structures so float accumulation
    /// order — and therefore the simulated timeline — is deterministic.
    /// Uses preallocated per-link scratch arrays (indexed by `LinkId`)
    /// instead of maps — the §Perf optimization that took the flow
    /// simulator from ~1.3k to >10k flows/s.
    fn maxmin(&mut self) -> HashMap<FlowId, f64> {
        let mut rates: HashMap<FlowId, f64> =
            HashMap::with_capacity(self.active.len());
        if self.active.is_empty() {
            return rates;
        }
        // reset only the links touched last round
        for l in self.scratch_touched.drain(..) {
            self.scratch_members[l as usize].clear();
        }
        let flow_ids = &self.ordered;
        for id in flow_ids {
            let f = &self.active[id];
            for l in &f.route.links {
                let li = l.0 as usize;
                if self.scratch_members[li].is_empty() {
                    self.scratch_residual[li] = self.topo.link(*l).bw.bytes_per_sec();
                    self.scratch_touched.push(l.0);
                }
                self.scratch_members[li].push(*id);
            }
        }
        // unfixed tracked per-flow via the rates map (fixed = present)
        let mut remaining = 0usize;
        for id in flow_ids {
            if self.active[id].route.links.is_empty() {
                rates.insert(*id, f64::INFINITY);
            } else {
                remaining += 1;
            }
        }
        // touched links sorted for deterministic bottleneck scans
        self.scratch_touched.sort_unstable();
        self.scratch_touched.dedup();
        while remaining > 0 {
            // bottleneck link: min residual / unfixed-members
            let mut best: Option<(u32, f64)> = None;
            for &l in &self.scratch_touched {
                let mem = &self.scratch_members[l as usize];
                let n = mem.iter().filter(|m| !rates.contains_key(m)).count();
                if n == 0 {
                    continue;
                }
                let fair = self.scratch_residual[l as usize] / n as f64;
                if best.map(|(_, b)| fair < b).unwrap_or(true) {
                    best = Some((l, fair));
                }
            }
            let Some((bottleneck, fair)) = best else { break };
            // fix every unfixed flow crossing the bottleneck
            let to_fix: Vec<FlowId> = self.scratch_members[bottleneck as usize]
                .iter()
                .filter(|m| !rates.contains_key(m))
                .copied()
                .collect();
            for id in to_fix {
                rates.insert(id, fair);
                remaining -= 1;
                for l in &self.active[&id].route.links {
                    self.scratch_residual[l.0 as usize] -= fair;
                }
            }
        }
        rates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::network::topology::Topology;

    #[derive(Debug, Clone, Copy)]
    struct Done(FlowId);

    fn sim(nodes: u32) -> (FlowSim, Engine<Done>) {
        let topo = Topology::build(&presets::cluster("ampere", nodes).unwrap()).unwrap();
        (FlowSim::new(topo), Engine::new())
    }

    #[test]
    fn single_flow_gets_full_link_rate() {
        let (mut fs, mut eng) = sim(2);
        // rank 7 -> 15: rail path bottlenecked by 200 Gbps NIC = 25 GB/s
        let bytes = 25_000_000_000u64; // exactly 1 s at NIC rate
        fs.start(&mut eng, FlowSpec { src: 7, dst: 15, bytes, tag: 0 }, &Done);
        let mut fcts = Vec::new();
        let mut fs_ref = &mut fs;
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct());
            }
        })
        .unwrap();
        assert_eq!(fcts.len(), 1);
        let secs = fcts[0].as_secs();
        assert!((secs - 1.0).abs() < 0.001, "fct {secs}");
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let (mut fs, mut eng) = sim(2);
        let bytes = 12_500_000_000u64; // 0.5 s alone at 25 GB/s
        // both flows ride rail 7 from node 0 to node 1 -> share NIC 7 up-link
        let specs = [
            FlowSpec { src: 7, dst: 15, bytes, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        assert_eq!(fcts.len(), 2);
        // each gets half the NIC: ~1.0 s
        for f in &fcts {
            assert!((f - 1.0).abs() < 0.01, "fct {f}");
        }
    }

    #[test]
    fn departure_releases_bandwidth() {
        let (mut fs, mut eng) = sim(2);
        // flow A: 12.5 GB, flow B: 25 GB on the same rail.
        // Shared phase: both at 12.5 GB/s. A finishes at t=1 having sent
        // 12.5; B has 12.5 left, now at full 25 GB/s -> +0.5 s = 1.5 s.
        let specs = [
            FlowSpec { src: 7, dst: 15, bytes: 12_500_000_000, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes: 25_000_000_000, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut by_tag = std::collections::HashMap::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                by_tag.insert(rec.tag, rec.fct().as_secs());
            }
        })
        .unwrap();
        assert!((by_tag[&0] - 1.0).abs() < 0.01, "{by_tag:?}");
        assert!((by_tag[&1] - 1.5).abs() < 0.01, "{by_tag:?}");
    }

    #[test]
    fn disjoint_flows_do_not_interact() {
        let (mut fs, mut eng) = sim(2);
        let bytes = 25_000_000_000u64;
        // different rails: local 6 and local 7
        let specs = [
            FlowSpec { src: 6, dst: 14, bytes, tag: 0 },
            FlowSpec { src: 7, dst: 15, bytes, tag: 1 },
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        for f in &fcts {
            assert!((f - 1.0).abs() < 0.01, "fct {f}");
        }
    }

    #[test]
    fn intra_node_flow_uses_nvlink_rate() {
        let (mut fs, mut eng) = sim(1);
        // NVLink unidirectional 2400 Gbps = 300 GB/s
        let bytes = 300_000_000_000u64;
        fs.start(&mut eng, FlowSpec { src: 0, dst: 7, bytes, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_secs());
            }
        })
        .unwrap();
        assert!((fcts[0] - 1.0).abs() < 0.001, "fct {}", fcts[0]);
    }

    #[test]
    fn zero_byte_flow_costs_only_fixed_delay() {
        let (mut fs, mut eng) = sim(2);
        fs.start(&mut eng, FlowSpec { src: 7, dst: 15, bytes: 0, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct().as_ns());
            }
        })
        .unwrap();
        let expect = 2.0 * 287.5 + 368.0 + 668.0 + 2.0 * 287.5;
        assert!((fcts[0] - expect).abs() < 0.1, "fct {} vs {expect}", fcts[0]);
    }

    #[test]
    fn self_flow_completes_immediately() {
        let (mut fs, mut eng) = sim(1);
        fs.start(&mut eng, FlowSpec { src: 3, dst: 3, bytes: 1 << 30, tag: 0 }, &Done);
        let fs_ref = &mut fs;
        let mut fcts = Vec::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                fcts.push(rec.fct());
            }
        })
        .unwrap();
        assert_eq!(fcts, vec![Time::ZERO]);
    }

    #[test]
    fn hetero_cluster_slower_nvlink_on_ampere_node() {
        let topo = Topology::build(&presets::cluster_hetero(1, 1).unwrap()).unwrap();
        let mut fs = FlowSim::new(topo);
        let mut eng: Engine<Done> = Engine::new();
        let bytes = 100_000_000_000u64;
        // node 0 = ampere (2400 Gbps uni), node 1 = hopper (3600 Gbps uni)
        let specs = [
            FlowSpec { src: 0, dst: 1, bytes, tag: 0 },  // ampere intra
            FlowSpec { src: 8, dst: 9, bytes, tag: 1 },  // hopper intra
        ];
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        let mut by_tag = std::collections::HashMap::new();
        eng.run(|e, ev| {
            if let Some(rec) = fs_ref.on_complete(e, ev.payload.0, ev.id, &Done) {
                by_tag.insert(rec.tag, rec.fct().as_secs());
            }
        })
        .unwrap();
        let ratio = by_tag[&0] / by_tag[&1];
        assert!((ratio - 1.5).abs() < 0.01, "ratio {ratio}"); // 3600/2400
    }

    #[test]
    fn records_capture_all_flows() {
        let (mut fs, mut eng) = sim(2);
        let specs: Vec<FlowSpec> =
            (0..8).map(|i| FlowSpec { src: i, dst: 8 + i, bytes: 1_000_000, tag: i as u64 }).collect();
        fs.start_many(&mut eng, &specs, &Done);
        let fs_ref = &mut fs;
        eng.run(|e, ev| {
            fs_ref.on_complete(e, ev.payload.0, ev.id, &Done);
        })
        .unwrap();
        assert_eq!(fs.records.len(), 8);
        assert_eq!(fs.active_count(), 0);
    }
}
