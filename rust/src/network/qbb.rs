//! The paper's per-hop serialization-delay model (§5):
//!
//! > we compute the PCIe and NVLink delays using the formula
//! > `delay = jumbo_frame_size_bytes * 8 / unidirectional_bw`,
//! > considering a jumbo frame size of 9200 bytes.
//!
//! This is the SimAI ns-3 `QbbChannel` modification reproduced as a
//! plain function; Table 5's delay columns are exactly this formula
//! evaluated at each interconnect's unidirectional bandwidth.

use crate::util::units::{Bandwidth, Time};

/// RoCE jumbo frame size used by the paper.
pub const JUMBO_FRAME_BYTES: u64 = 9200;

/// Serialization delay of one frame at `unidirectional_bw`.
pub fn frame_delay(frame_bytes: u64, unidirectional_bw: Bandwidth) -> Time {
    unidirectional_bw.transfer_time(frame_bytes)
}

/// The paper's Table-5 delays divide the quoted (bidirectional
/// aggregate) NVLink bandwidth by two before applying the formula.
pub fn nvlink_delay_from_aggregate(aggregate_bw: Bandwidth) -> Time {
    frame_delay(JUMBO_FRAME_BYTES, aggregate_bw / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ampere_nvlink_delay_matches_table5() {
        // 9200*8 / 2400 Gbps = 30.66 ns
        let d = nvlink_delay_from_aggregate(Bandwidth::from_gbps(4800.0));
        assert!((d.as_ns() - 30.66).abs() < 0.01, "{}", d.as_ns());
    }

    #[test]
    fn hopper_nvlink_delay_matches_table5() {
        // 9200*8 / 3600 Gbps = 20.44 ns
        let d = nvlink_delay_from_aggregate(Bandwidth::from_gbps(7200.0));
        assert!((d.as_ns() - 20.44).abs() < 0.01, "{}", d.as_ns());
    }

    #[test]
    fn pcie_trip_delays_match_table5() {
        // Gen4: 9200*8/256 Gbps = 287.5 ns (unidirectional 512/2)
        let g4 = frame_delay(JUMBO_FRAME_BYTES, Bandwidth::from_gbps(512.0) / 2.0);
        assert!((g4.as_ns() - 287.5).abs() < 0.01, "{}", g4.as_ns());
        // Gen5: 9200*8/512 Gbps = 143.75 ns
        let g5 = frame_delay(JUMBO_FRAME_BYTES, Bandwidth::from_gbps(1024.0) / 2.0);
        assert!((g5.as_ns() - 143.75).abs() < 0.01, "{}", g5.as_ns());
    }

    #[test]
    fn delay_scales_inverse_with_bandwidth() {
        let fast = frame_delay(9200, Bandwidth::from_gbps(400.0));
        let slow = frame_delay(9200, Bandwidth::from_gbps(200.0));
        assert!((slow.as_ns() / fast.as_ns() - 2.0).abs() < 1e-9);
    }
}
