//! Heterogeneity-aware network layer (system S9/S24, paper component
//! **C4**).
//!
//! Replaces SimAI's ns-3 backend with a flow-level (fluid) network
//! simulator over an explicit, configurable fabric:
//!
//! * [`topology`] — builds the device/link graph from a
//!   [`crate::config::ClusterSpec`]: GPUs, NVSwitch, PCIe channels,
//!   NICs, and the inter-node fabric selected by
//!   [`crate::config::cluster::FabricSpec`] (rail-only switches, one
//!   non-blocking switch, or a two-tier leaf/spine with configurable
//!   oversubscription). Each link carries the Table-5 bandwidth and
//!   fixed per-hop delay; the jumbo-frame serialization-delay formula
//!   from §5 (the modified `QbbChannel`, formerly the separate `qbb`
//!   module) lives alongside the link builder as
//!   [`topology::frame_delay`].
//! * [`routing`] — fabric-dispatched path assembly (paper Fig 2 cases
//!   a–c on the rail fabric, switch/leaf-spine traversals otherwise),
//!   correct for clusters whose nodes carry different GPU counts.
//! * [`flow`] — max-min fair fluid flow simulation producing per-flow
//!   completion times (FCTs, the paper's Fig-6 metric).

pub mod flow;
pub mod routing;
pub mod topology;

pub use flow::{FlowId, FlowRecord, FlowSim};
pub use topology::{LinkId, LinkKind, NodeRef, Topology};
