//! Heterogeneity-aware network layer (system S9, paper component
//! **C4**).
//!
//! Replaces SimAI's ns-3 backend with a flow-level (fluid) network
//! simulator over an explicit rail-only topology:
//!
//! * [`topology`] — builds the device/link graph from a
//!   [`crate::config::ClusterSpec`]: GPUs, NVSwitch, PCIe channels,
//!   NICs and rail switches, each link carrying the Table-5 bandwidth
//!   and fixed per-hop delay (the paper's modified `QbbChannel`).
//! * [`routing`] — rail-only path computation (paper Fig 2 cases a-c).
//! * [`flow`] — max-min fair fluid flow simulation producing per-flow
//!   completion times (FCTs, the paper's Fig-6 metric).
//! * [`qbb`] — the jumbo-frame serialization-delay formula from §5.

pub mod flow;
pub mod qbb;
pub mod routing;
pub mod topology;

pub use flow::{FlowId, FlowRecord, FlowSim};
pub use topology::{LinkId, LinkKind, NodeRef, Topology};
