//! Device/link graph construction for every supported fabric (paper
//! Fig 2 + abstraction A2, generalized per DESIGN.md §24).
//!
//! Per node — `gpus_per_node` GPUs (counts may differ across nodes),
//! one NVSwitch, one NIC per GPU. Links are **directed** with a
//! bandwidth (shared by flows) and a fixed per-hop delay (paid once per
//! flow, the QbbChannel model):
//!
//! * GPU ↔ NVSwitch: NVLink bandwidth / delay.
//! * GPU ↔ its NIC: PCIe bandwidth, delay = 2 PCIe trips (GPU→PCIe
//!   switch→NIC, paper §5) — the dedicated PCI channel of the rail
//!   design, so it is not shared between GPUs.
//!
//! How the NICs reach each other across nodes is the configurable
//! **fabric** ([`crate::config::cluster::FabricSpec`]):
//!
//! * `RailOnly` (default, the paper's Fig-2 model): NIC `g` of every
//!   node hangs off cluster rail switch `g`; byte-identical to the
//!   pre-fabric topology on uniform clusters.
//! * `SingleSwitch`: every NIC hangs off one non-blocking switch.
//! * `LeafSpine { spines, oversubscription }`: each node's NICs share a
//!   leaf switch; each leaf connects to every spine with an uplink
//!   carrying `node NIC aggregate / (spines × oversubscription)` —
//!   `oversubscription > 1` is a blocking (tapered) fabric.
//!
//! Rank ↔ (node, local) mapping is prefix-sum based and agrees with
//! [`ClusterSpec::node_of_rank`] for every rank, so clusters with mixed
//! node sizes are first-class.
//!
//! The topology stores only the device/link graph — **routes are never
//! precomputed here**. Building all-pairs paths is O(ranks²) memory and
//! would dominate the footprint of 100k-rank clusters; instead the flow
//! simulator materializes each (src, dst) path lazily through
//! [`crate::network::routing::RouteCache`] the first time a flow uses
//! it, which keeps topology construction O(devices + links).

use crate::config::cluster::{ClusterSpec, FabricSpec};
use crate::util::units::{Bandwidth, Time};

/// A device in the topology graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeRef {
    /// A GPU slot.
    Gpu {
        /// Hosting node index.
        node: u32,
        /// Local rank within the node.
        local: u32,
    },
    /// The node's NVSwitch.
    NvSwitch {
        /// Hosting node index.
        node: u32,
    },
    /// One rail NIC (one per GPU slot).
    Nic {
        /// Hosting node index.
        node: u32,
        /// Local rank the NIC is railed to.
        local: u32,
    },
    /// The cluster-level rail switch for one local rank (rail-only
    /// fabric).
    RailSwitch {
        /// The local rank (rail index) this switch serves.
        local: u32,
    },
    /// A node's leaf switch (leaf/spine fabric).
    Leaf {
        /// The node this leaf serves.
        node: u32,
    },
    /// A spine switch (leaf/spine fabric), or the single cluster switch
    /// of the single-switch fabric (`idx == 0`).
    Spine {
        /// Spine index.
        idx: u32,
    },
}

/// Physical link class (selects the Table-5 bandwidth/delay pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// GPU ↔ NVSwitch.
    NvLink,
    /// GPU ↔ its rail NIC (dedicated PCIe channel).
    Pcie,
    /// NIC → first-tier switch (rail switch, single switch or leaf).
    NicUp,
    /// First-tier switch → NIC.
    NicDown,
    /// Leaf switch → spine (the oversubscribable uplink).
    LeafUp,
    /// Spine → leaf switch.
    LeafDown,
}

/// Dense link index into [`Topology::links`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub u32);

/// One directed link of the graph.
#[derive(Debug, Clone)]
pub struct Link {
    /// Tail device.
    pub from: NodeRef,
    /// Head device.
    pub to: NodeRef,
    /// Physical link class.
    pub kind: LinkKind,
    /// Bandwidth shared (max-min fairly) by the flows crossing it.
    pub bw: Bandwidth,
    /// Fixed per-hop delay, paid once per flow (QbbChannel model).
    pub delay: Time,
}

/// The built graph plus index structures for O(1) route assembly.
#[derive(Debug)]
pub struct Topology {
    /// All directed links, indexed by [`LinkId`].
    pub links: Vec<Link>,
    /// Node count of the cluster.
    pub num_nodes: u32,
    /// The inter-node fabric this graph was built for (drives
    /// [`crate::network::routing::route`] dispatch).
    pub fabric: FabricSpec,
    /// Per-node GPU counts, in node order.
    node_gpus: Vec<u32>,
    /// Exclusive prefix sums of `node_gpus`, length `num_nodes + 1`
    /// (mirrors [`ClusterSpec::node_starts`]).
    starts: Vec<u32>,
    /// Dense rank → node table for O(1) [`Topology::locate`].
    rank_node: Vec<u32>,
    // index: [starts[node] + local] -> link ids
    gpu_to_nvsw: Vec<LinkId>,
    nvsw_to_gpu: Vec<LinkId>,
    gpu_to_nic: Vec<LinkId>,
    nic_to_gpu: Vec<LinkId>,
    nic_up: Vec<LinkId>,
    nic_down: Vec<LinkId>,
    // leaf/spine uplinks: [node * spines + spine] -> link ids
    leaf_up: Vec<LinkId>,
    leaf_down: Vec<LinkId>,
    /// Spine count (0 unless the fabric is leaf/spine).
    spines: u32,
}

impl Topology {
    /// Build the device/link graph for a (validated) cluster spec under
    /// its configured fabric.
    pub fn build(cluster: &ClusterSpec) -> anyhow::Result<Topology> {
        cluster.validate()?;
        let num_nodes = cluster.nodes.len() as u32;
        let node_gpus: Vec<u32> = cluster.nodes.iter().map(|n| n.gpus_per_node).collect();
        let starts = cluster.node_starts();
        let total = *starts.last().unwrap_or(&0) as usize;
        let mut rank_node = Vec::with_capacity(total);
        for (i, g) in node_gpus.iter().enumerate() {
            rank_node.extend(std::iter::repeat(i as u32).take(*g as usize));
        }
        let fabric = cluster.fabric;
        let spines = match fabric {
            FabricSpec::LeafSpine { spines, .. } => spines,
            _ => 0,
        };
        let mut t = Topology {
            links: Vec::new(),
            num_nodes,
            fabric,
            node_gpus,
            starts,
            rank_node,
            gpu_to_nvsw: Vec::with_capacity(total),
            nvsw_to_gpu: Vec::with_capacity(total),
            gpu_to_nic: Vec::with_capacity(total),
            nic_to_gpu: Vec::with_capacity(total),
            nic_up: Vec::with_capacity(total),
            nic_down: Vec::with_capacity(total),
            leaf_up: Vec::new(),
            leaf_down: Vec::new(),
            spines,
        };
        for (n, spec) in cluster.nodes.iter().enumerate() {
            let n = n as u32;
            let ic = &spec.interconnect;
            for g in 0..spec.gpus_per_node {
                let gpu = NodeRef::Gpu { node: n, local: g };
                let nvsw = NodeRef::NvSwitch { node: n };
                let nic = NodeRef::Nic { node: n, local: g };
                // NVLink both directions (unidirectional share of the
                // aggregate bandwidth each way).
                let nv_bw = ic.nvlink_bw / 2.0;
                let id = t.add(gpu, nvsw, LinkKind::NvLink, nv_bw, ic.nvlink_delay);
                t.gpu_to_nvsw.push(id);
                let id = t.add(nvsw, gpu, LinkKind::NvLink, nv_bw, ic.nvlink_delay);
                t.nvsw_to_gpu.push(id);
                // Dedicated PCIe channel to the NIC: 2 trips of latency.
                let pcie_bw = ic.pcie_bw / 2.0;
                let pcie_delay = Time(ic.pcie_latency.as_ps() * 2);
                let id = t.add(gpu, nic, LinkKind::Pcie, pcie_bw, pcie_delay);
                t.gpu_to_nic.push(id);
                let id = t.add(nic, gpu, LinkKind::Pcie, pcie_bw, pcie_delay);
                t.nic_to_gpu.push(id);
                // NIC <-> first-tier switch: the rail switch of this
                // local rank, the single cluster switch, or the node's
                // leaf — same bandwidth/delay model on every fabric, so
                // RailOnly stays byte-identical to the seed graph.
                let up_sw = match fabric {
                    FabricSpec::RailOnly => NodeRef::RailSwitch { local: g },
                    FabricSpec::SingleSwitch => NodeRef::Spine { idx: 0 },
                    FabricSpec::LeafSpine { .. } => NodeRef::Leaf { node: n },
                };
                let id = t.add(nic, up_sw, LinkKind::NicUp, ic.nic_bw, ic.nic_processing_delay);
                t.nic_up.push(id);
                let down_delay = cluster.switch_delay + ic.nic_processing_delay;
                let id = t.add(up_sw, nic, LinkKind::NicDown, ic.nic_bw, down_delay);
                t.nic_down.push(id);
            }
        }
        // Leaf → spine uplinks, node-major then spine: each carries the
        // node's aggregate NIC bandwidth tapered by spines × OS.
        if let FabricSpec::LeafSpine { spines, oversubscription } = fabric {
            for (n, spec) in cluster.nodes.iter().enumerate() {
                let n = n as u32;
                let ic = &spec.interconnect;
                let uplink_bw = Bandwidth(
                    ic.nic_bw.0 * spec.gpus_per_node as f64
                        / (spines as f64 * oversubscription),
                );
                for s in 0..spines {
                    let leaf = NodeRef::Leaf { node: n };
                    let spine = NodeRef::Spine { idx: s };
                    let id =
                        t.add(leaf, spine, LinkKind::LeafUp, uplink_bw, cluster.switch_delay);
                    t.leaf_up.push(id);
                    let id =
                        t.add(spine, leaf, LinkKind::LeafDown, uplink_bw, cluster.switch_delay);
                    t.leaf_down.push(id);
                }
            }
        }
        Ok(t)
    }

    fn add(&mut self, from: NodeRef, to: NodeRef, kind: LinkKind, bw: Bandwidth, delay: Time) -> LinkId {
        let id = LinkId(self.links.len() as u32);
        self.links.push(Link { from, to, kind, bw, delay });
        id
    }

    fn idx(&self, node: u32, local: u32) -> usize {
        debug_assert!(local < self.node_gpus[node as usize]);
        (self.starts[node as usize] + local) as usize
    }

    /// The link behind an id.
    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0 as usize]
    }

    /// Total directed link count.
    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    /// World size of the underlying cluster.
    pub fn total_gpus(&self) -> u32 {
        *self.starts.last().unwrap_or(&0)
    }

    /// GPU count of one node.
    pub fn node_gpus(&self, node: u32) -> u32 {
        self.node_gpus[node as usize]
    }

    /// Decompose a global rank into (node, local) via the dense
    /// prefix-sum tables — agrees with [`ClusterSpec::locate`] /
    /// [`ClusterSpec::node_of_rank`] for every rank, including on
    /// mixed-node-size clusters.
    pub fn locate(&self, rank: u32) -> (u32, u32) {
        let node = self.rank_node[rank as usize];
        (node, rank - self.starts[node as usize])
    }

    /// Compose a global rank from (node, local).
    pub fn rank_of(&self, node: u32, local: u32) -> u32 {
        self.starts[node as usize] + local
    }

    /// Deterministic index-based spine selection for one (src, dst)
    /// rank pair on the leaf/spine fabric: a Fibonacci hash of the
    /// packed pair, `((src·2³² | dst) · 0x9E3779B97F4A7C15) >> 33 mod
    /// spines`. Simple linear combinations (`a·src + b·dst`) alias the
    /// ring patterns collectives generate (`src = i, dst = i + k`
    /// reduces to a fixed stride that collapses whenever the stride
    /// shares a factor with the spine count), so a multiplicative mix
    /// is used instead — still a pure function of the rank pair, so
    /// the same flow always takes the same spine and the simulated
    /// timeline stays run-to-run deterministic (DESIGN.md §24).
    pub fn spine_for(&self, src_rank: u32, dst_rank: u32) -> u32 {
        debug_assert!(self.spines > 0, "spine_for on a non-leaf/spine fabric");
        let key = (u64::from(src_rank) << 32) | u64::from(dst_rank);
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 33) % u64::from(self.spines)) as u32
    }

    // -- link lookups used by routing -------------------------------------

    /// GPU → NVSwitch link of a slot.
    pub fn l_gpu_to_nvsw(&self, node: u32, local: u32) -> LinkId {
        self.gpu_to_nvsw[self.idx(node, local)]
    }
    /// NVSwitch → GPU link of a slot.
    pub fn l_nvsw_to_gpu(&self, node: u32, local: u32) -> LinkId {
        self.nvsw_to_gpu[self.idx(node, local)]
    }
    /// GPU → rail-NIC link of a slot.
    pub fn l_gpu_to_nic(&self, node: u32, local: u32) -> LinkId {
        self.gpu_to_nic[self.idx(node, local)]
    }
    /// Rail-NIC → GPU link of a slot.
    pub fn l_nic_to_gpu(&self, node: u32, local: u32) -> LinkId {
        self.nic_to_gpu[self.idx(node, local)]
    }
    /// NIC → first-tier switch (egress) link of a slot.
    pub fn l_nic_up(&self, node: u32, local: u32) -> LinkId {
        self.nic_up[self.idx(node, local)]
    }
    /// First-tier switch → NIC (ingress) link of a slot.
    pub fn l_nic_down(&self, node: u32, local: u32) -> LinkId {
        self.nic_down[self.idx(node, local)]
    }
    /// Leaf → spine uplink of a node (leaf/spine fabric only).
    pub fn l_leaf_up(&self, node: u32, spine: u32) -> LinkId {
        self.leaf_up[(node * self.spines + spine) as usize]
    }
    /// Spine → leaf downlink of a node (leaf/spine fabric only).
    pub fn l_leaf_down(&self, node: u32, spine: u32) -> LinkId {
        self.leaf_down[(node * self.spines + spine) as usize]
    }

    /// Every link tied to one NIC slot, in `[host→NIC, NIC→host,
    /// NIC→fabric, fabric→NIC]` order — the shared-fate set a NIC fault
    /// disables (DESIGN.md §28).
    pub fn nic_links(&self, node: u32, local: u32) -> [LinkId; 4] {
        [
            self.l_gpu_to_nic(node, local),
            self.l_nic_to_gpu(node, local),
            self.l_nic_up(node, local),
            self.l_nic_down(node, local),
        ]
    }

    /// Both directions of one leaf↔spine uplink of a node (leaf/spine
    /// fabric only) — the shared-fate set a cable fault disables there.
    pub fn leaf_uplinks(&self, node: u32, spine: u32) -> [LinkId; 2] {
        [self.l_leaf_up(node, spine), self.l_leaf_down(node, spine)]
    }
}

// ---------------------------------------------------------------------
// Per-hop serialization-delay model (paper §5), formerly network/qbb.rs
// — folded in here because Table 5's link delays *are* this formula
// evaluated at each link's unidirectional bandwidth:
//
// > we compute the PCIe and NVLink delays using the formula
// > `delay = jumbo_frame_size_bytes * 8 / unidirectional_bw`,
// > considering a jumbo frame size of 9200 bytes.
//
// This is the SimAI ns-3 `QbbChannel` modification reproduced as a
// plain function.

/// RoCE jumbo frame size used by the paper (§5).
pub const JUMBO_FRAME_BYTES: u64 = 9200;

/// Serialization delay of one frame at `unidirectional_bw` — the
/// QbbChannel per-hop delay formula behind every Table-5 delay column.
pub fn frame_delay(frame_bytes: u64, unidirectional_bw: Bandwidth) -> Time {
    unidirectional_bw.transfer_time(frame_bytes)
}

/// The paper's Table-5 delays divide the quoted (bidirectional
/// aggregate) NVLink bandwidth by two before applying the formula.
pub fn nvlink_delay_from_aggregate(aggregate_bw: Bandwidth) -> Time {
    frame_delay(JUMBO_FRAME_BYTES, aggregate_bw / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn link_counts_scale_with_cluster() {
        let c = presets::cluster("ampere", 2).unwrap();
        let t = Topology::build(&c).unwrap();
        // per GPU: 2 nvlink + 2 pcie + 2 nic = 6 directed links
        assert_eq!(t.num_links(), 2 * 8 * 6);
        assert_eq!(t.total_gpus(), 16);
    }

    #[test]
    fn nvlink_bandwidth_is_unidirectional_half() {
        let c = presets::cluster("ampere", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let l = t.link(t.l_gpu_to_nvsw(0, 0));
        assert!((l.bw.gbps() - 2400.0).abs() < 1e-6);
        assert_eq!(l.kind, LinkKind::NvLink);
    }

    #[test]
    fn pcie_delay_is_two_trips() {
        let c = presets::cluster("hopper", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let l = t.link(t.l_gpu_to_nic(0, 3));
        assert!((l.delay.as_ns() - 2.0 * 143.75).abs() < 0.01);
    }

    #[test]
    fn hetero_nodes_carry_their_own_interconnect() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let ampere_nv = t.link(t.l_gpu_to_nvsw(0, 0));
        let hopper_nv = t.link(t.l_gpu_to_nvsw(1, 0));
        assert!((ampere_nv.delay.as_ns() - 30.66).abs() < 0.01);
        assert!((hopper_nv.delay.as_ns() - 20.44).abs() < 0.01);
        assert!(hopper_nv.bw > ampere_nv.bw);
    }

    #[test]
    fn rank_locate_roundtrip() {
        let c = presets::cluster("ampere", 4).unwrap();
        let t = Topology::build(&c).unwrap();
        for rank in 0..t.total_gpus() {
            let (n, l) = t.locate(rank);
            assert_eq!(t.rank_of(n, l), rank);
        }
    }

    #[test]
    fn mixed_node_sizes_locate_agrees_with_cluster() {
        let mut c = presets::cluster_hetero(1, 1).unwrap();
        c.nodes[0].gpus_per_node = 4; // 4×A100 beside 8×H100
        let t = Topology::build(&c).unwrap();
        assert_eq!(t.total_gpus(), 12);
        assert_eq!(t.node_gpus(0), 4);
        assert_eq!(t.node_gpus(1), 8);
        for rank in 0..t.total_gpus() {
            let (n, l) = t.locate(rank);
            assert_eq!(Some((n, l)), c.locate(rank));
            assert_eq!(Some(n), c.node_of_rank(rank));
            assert_eq!(t.rank_of(n, l), rank);
        }
        // per-slot links exist for every slot of every node
        let l = t.link(t.l_gpu_to_nic(1, 7));
        assert_eq!(l.kind, LinkKind::Pcie);
    }

    #[test]
    fn nic_down_includes_switch_delay() {
        let c = presets::cluster("ampere", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let up = t.link(t.l_nic_up(0, 0));
        let down = t.link(t.l_nic_down(0, 0));
        assert!(down.delay > up.delay);
        assert!((down.delay.as_ns() - (300.0 + 368.0)).abs() < 0.01);
    }

    #[test]
    fn single_switch_fabric_shares_one_switch() {
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = FabricSpec::SingleSwitch;
        let t = Topology::build(&c).unwrap();
        // same link count as rail-only: only the switch endpoint differs
        assert_eq!(t.num_links(), 2 * 8 * 6);
        for n in 0..2 {
            for g in 0..8 {
                assert_eq!(t.link(t.l_nic_up(n, g)).to, NodeRef::Spine { idx: 0 });
                assert_eq!(t.link(t.l_nic_down(n, g)).from, NodeRef::Spine { idx: 0 });
            }
        }
    }

    #[test]
    fn leaf_spine_fabric_builds_tapered_uplinks() {
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 4.0 };
        let t = Topology::build(&c).unwrap();
        // rail links + 2 nodes × 2 spines × 2 directions
        assert_eq!(t.num_links(), 2 * 8 * 6 + 2 * 2 * 2);
        assert_eq!(t.link(t.l_nic_up(0, 3)).to, NodeRef::Leaf { node: 0 });
        let up = t.link(t.l_leaf_up(0, 1));
        assert_eq!((up.from, up.to), (NodeRef::Leaf { node: 0 }, NodeRef::Spine { idx: 1 }));
        // 8 NICs × 200 Gbps / (2 spines × 4 OS) = 200 Gbps per uplink
        assert!((up.bw.gbps() - 200.0).abs() < 1e-6, "{}", up.bw.gbps());
        // OS = 1 quadruples the uplink
        c.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 1.0 };
        let t1 = Topology::build(&c).unwrap();
        assert!((t1.link(t1.l_leaf_up(0, 1)).bw.gbps() - 800.0).abs() < 1e-6);
    }

    #[test]
    fn spine_selection_is_deterministic_and_spreads_ring_patterns() {
        // a linear (a·src + b·dst) mod S rule aliases ring patterns
        // whenever the induced stride shares a factor with S — check
        // the multiplicative mix spreads them for several spine counts
        for spines in [2u32, 3, 4] {
            let mut c = presets::cluster("ampere", 2).unwrap();
            c.fabric = FabricSpec::LeafSpine { spines, oversubscription: 1.0 };
            let t = Topology::build(&c).unwrap();
            // pure function of the rank pair
            assert_eq!(t.spine_for(3, 12), t.spine_for(3, 12));
            // the slot-wise DP pattern (i -> i + 8) must not alias
            // onto a single spine, in either direction
            let fwd: std::collections::HashSet<u32> =
                (0..8).map(|i| t.spine_for(i, i + 8)).collect();
            let rev: std::collections::HashSet<u32> =
                (0..8).map(|i| t.spine_for(i + 8, i)).collect();
            assert!(fwd.len() > 1, "S={spines}: forward ring aliased onto one spine");
            assert!(rev.len() > 1, "S={spines}: reverse ring aliased onto one spine");
            for s in 0..8 {
                assert!(t.spine_for(s, s + 8) < spines);
            }
        }
    }

    // -- serialization-delay formula (formerly qbb.rs) -------------------

    #[test]
    fn ampere_nvlink_delay_matches_table5() {
        // 9200*8 / 2400 Gbps = 30.66 ns
        let d = nvlink_delay_from_aggregate(Bandwidth::from_gbps(4800.0));
        assert!((d.as_ns() - 30.66).abs() < 0.01, "{}", d.as_ns());
    }

    #[test]
    fn hopper_nvlink_delay_matches_table5() {
        // 9200*8 / 3600 Gbps = 20.44 ns
        let d = nvlink_delay_from_aggregate(Bandwidth::from_gbps(7200.0));
        assert!((d.as_ns() - 20.44).abs() < 0.01, "{}", d.as_ns());
    }

    #[test]
    fn pcie_trip_delays_match_table5() {
        // Gen4: 9200*8/256 Gbps = 287.5 ns (unidirectional 512/2)
        let g4 = frame_delay(JUMBO_FRAME_BYTES, Bandwidth::from_gbps(512.0) / 2.0);
        assert!((g4.as_ns() - 287.5).abs() < 0.01, "{}", g4.as_ns());
        // Gen5: 9200*8/512 Gbps = 143.75 ns
        let g5 = frame_delay(JUMBO_FRAME_BYTES, Bandwidth::from_gbps(1024.0) / 2.0);
        assert!((g5.as_ns() - 143.75).abs() < 0.01, "{}", g5.as_ns());
    }

    #[test]
    fn delay_scales_inverse_with_bandwidth() {
        let fast = frame_delay(9200, Bandwidth::from_gbps(400.0));
        let slow = frame_delay(9200, Bandwidth::from_gbps(200.0));
        assert!((slow.as_ns() / fast.as_ns() - 2.0).abs() < 1e-9);
    }
}
