//! Rail-only topology graph (paper Fig 2 + abstraction A2).
//!
//! Devices: per node — `gpus_per_node` GPUs, one NVSwitch, one NIC per
//! GPU (rail-optimized); per cluster — one rail switch per local rank.
//! Links are **directed** with a bandwidth (shared by flows) and a
//! fixed per-hop delay (paid once per flow, the QbbChannel model):
//!
//! * GPU ↔ NVSwitch: NVLink bandwidth / delay.
//! * GPU ↔ its NIC: PCIe bandwidth, delay = 2 PCIe trips (GPU→PCIe
//!   switch→NIC, paper §5) — the dedicated PCI channel of the rail
//!   design, so it is not shared between GPUs.
//! * NIC ↔ rail switch `r`: NIC bandwidth; NIC processing delay on the
//!   egress hop, switch + NIC processing delay on the ingress hop.

use crate::config::cluster::ClusterSpec;
use crate::util::units::{Bandwidth, Time};

/// A device in the topology graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NodeRef {
    /// A GPU slot.
    Gpu {
        /// Hosting node index.
        node: u32,
        /// Local rank within the node.
        local: u32,
    },
    /// The node's NVSwitch.
    NvSwitch {
        /// Hosting node index.
        node: u32,
    },
    /// One rail NIC (one per GPU slot).
    Nic {
        /// Hosting node index.
        node: u32,
        /// Local rank the NIC is railed to.
        local: u32,
    },
    /// The cluster-level rail switch for one local rank.
    RailSwitch {
        /// The local rank (rail index) this switch serves.
        local: u32,
    },
}

/// Physical link class (selects the Table-5 bandwidth/delay pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// GPU ↔ NVSwitch.
    NvLink,
    /// GPU ↔ its rail NIC (dedicated PCIe channel).
    Pcie,
    /// NIC → rail switch (egress).
    NicUp,
    /// Rail switch → NIC (ingress).
    NicDown,
}

/// Dense link index into [`Topology::links`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub u32);

/// One directed link of the graph.
#[derive(Debug, Clone)]
pub struct Link {
    /// Tail device.
    pub from: NodeRef,
    /// Head device.
    pub to: NodeRef,
    /// Physical link class.
    pub kind: LinkKind,
    /// Bandwidth shared (max-min fairly) by the flows crossing it.
    pub bw: Bandwidth,
    /// Fixed per-hop delay, paid once per flow (QbbChannel model).
    pub delay: Time,
}

/// The built graph plus index structures for O(1) route assembly.
#[derive(Debug)]
pub struct Topology {
    /// All directed links, indexed by [`LinkId`].
    pub links: Vec<Link>,
    /// Node count of the cluster.
    pub num_nodes: u32,
    /// GPU slots (and rail NICs) per node.
    pub gpus_per_node: u32,
    // index: [node][local] -> link ids
    gpu_to_nvsw: Vec<LinkId>,
    nvsw_to_gpu: Vec<LinkId>,
    gpu_to_nic: Vec<LinkId>,
    nic_to_gpu: Vec<LinkId>,
    nic_up: Vec<LinkId>,
    nic_down: Vec<LinkId>,
}

impl Topology {
    /// Build the rail-only graph for a (validated) cluster spec.
    pub fn build(cluster: &ClusterSpec) -> anyhow::Result<Topology> {
        cluster.validate()?;
        let num_nodes = cluster.nodes.len() as u32;
        let gpn = cluster.gpus_per_node();
        let mut t = Topology {
            links: Vec::new(),
            num_nodes,
            gpus_per_node: gpn,
            gpu_to_nvsw: Vec::new(),
            nvsw_to_gpu: Vec::new(),
            gpu_to_nic: Vec::new(),
            nic_to_gpu: Vec::new(),
            nic_up: Vec::new(),
            nic_down: Vec::new(),
        };
        for (n, spec) in cluster.nodes.iter().enumerate() {
            let n = n as u32;
            let ic = &spec.interconnect;
            for g in 0..gpn {
                let gpu = NodeRef::Gpu { node: n, local: g };
                let nvsw = NodeRef::NvSwitch { node: n };
                let nic = NodeRef::Nic { node: n, local: g };
                let rail = NodeRef::RailSwitch { local: g };
                // NVLink both directions (unidirectional share of the
                // aggregate bandwidth each way).
                let nv_bw = ic.nvlink_bw / 2.0;
                let id = t.add(gpu, nvsw, LinkKind::NvLink, nv_bw, ic.nvlink_delay);
                t.gpu_to_nvsw.push(id);
                let id = t.add(nvsw, gpu, LinkKind::NvLink, nv_bw, ic.nvlink_delay);
                t.nvsw_to_gpu.push(id);
                // Dedicated PCIe channel to the NIC: 2 trips of latency.
                let pcie_bw = ic.pcie_bw / 2.0;
                let pcie_delay = Time(ic.pcie_latency.as_ps() * 2);
                let id = t.add(gpu, nic, LinkKind::Pcie, pcie_bw, pcie_delay);
                t.gpu_to_nic.push(id);
                let id = t.add(nic, gpu, LinkKind::Pcie, pcie_bw, pcie_delay);
                t.nic_to_gpu.push(id);
                // NIC <-> rail switch.
                let id = t.add(nic, rail, LinkKind::NicUp, ic.nic_bw, ic.nic_processing_delay);
                t.nic_up.push(id);
                let down_delay = cluster.switch_delay + ic.nic_processing_delay;
                let id = t.add(rail, nic, LinkKind::NicDown, ic.nic_bw, down_delay);
                t.nic_down.push(id);
            }
        }
        Ok(t)
    }

    fn add(&mut self, from: NodeRef, to: NodeRef, kind: LinkKind, bw: Bandwidth, delay: Time) -> LinkId {
        let id = LinkId(self.links.len() as u32);
        self.links.push(Link { from, to, kind, bw, delay });
        id
    }

    fn idx(&self, node: u32, local: u32) -> usize {
        (node * self.gpus_per_node + local) as usize
    }

    /// The link behind an id.
    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0 as usize]
    }

    /// Total directed link count.
    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    /// World size of the underlying cluster.
    pub fn total_gpus(&self) -> u32 {
        self.num_nodes * self.gpus_per_node
    }

    /// Decompose a global rank.
    pub fn locate(&self, rank: u32) -> (u32, u32) {
        (rank / self.gpus_per_node, rank % self.gpus_per_node)
    }

    /// Compose a global rank from (node, local).
    pub fn rank_of(&self, node: u32, local: u32) -> u32 {
        node * self.gpus_per_node + local
    }

    // -- link lookups used by routing -------------------------------------

    /// GPU → NVSwitch link of a slot.
    pub fn l_gpu_to_nvsw(&self, node: u32, local: u32) -> LinkId {
        self.gpu_to_nvsw[self.idx(node, local)]
    }
    /// NVSwitch → GPU link of a slot.
    pub fn l_nvsw_to_gpu(&self, node: u32, local: u32) -> LinkId {
        self.nvsw_to_gpu[self.idx(node, local)]
    }
    /// GPU → rail-NIC link of a slot.
    pub fn l_gpu_to_nic(&self, node: u32, local: u32) -> LinkId {
        self.gpu_to_nic[self.idx(node, local)]
    }
    /// Rail-NIC → GPU link of a slot.
    pub fn l_nic_to_gpu(&self, node: u32, local: u32) -> LinkId {
        self.nic_to_gpu[self.idx(node, local)]
    }
    /// NIC → rail-switch (egress) link of a slot.
    pub fn l_nic_up(&self, node: u32, local: u32) -> LinkId {
        self.nic_up[self.idx(node, local)]
    }
    /// Rail-switch → NIC (ingress) link of a slot.
    pub fn l_nic_down(&self, node: u32, local: u32) -> LinkId {
        self.nic_down[self.idx(node, local)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn link_counts_scale_with_cluster() {
        let c = presets::cluster("ampere", 2).unwrap();
        let t = Topology::build(&c).unwrap();
        // per GPU: 2 nvlink + 2 pcie + 2 nic = 6 directed links
        assert_eq!(t.num_links(), 2 * 8 * 6);
        assert_eq!(t.total_gpus(), 16);
    }

    #[test]
    fn nvlink_bandwidth_is_unidirectional_half() {
        let c = presets::cluster("ampere", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let l = t.link(t.l_gpu_to_nvsw(0, 0));
        assert!((l.bw.gbps() - 2400.0).abs() < 1e-6);
        assert_eq!(l.kind, LinkKind::NvLink);
    }

    #[test]
    fn pcie_delay_is_two_trips() {
        let c = presets::cluster("hopper", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let l = t.link(t.l_gpu_to_nic(0, 3));
        assert!((l.delay.as_ns() - 2.0 * 143.75).abs() < 0.01);
    }

    #[test]
    fn hetero_nodes_carry_their_own_interconnect() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let ampere_nv = t.link(t.l_gpu_to_nvsw(0, 0));
        let hopper_nv = t.link(t.l_gpu_to_nvsw(1, 0));
        assert!((ampere_nv.delay.as_ns() - 30.66).abs() < 0.01);
        assert!((hopper_nv.delay.as_ns() - 20.44).abs() < 0.01);
        assert!(hopper_nv.bw > ampere_nv.bw);
    }

    #[test]
    fn rank_locate_roundtrip() {
        let c = presets::cluster("ampere", 4).unwrap();
        let t = Topology::build(&c).unwrap();
        for rank in 0..t.total_gpus() {
            let (n, l) = t.locate(rank);
            assert_eq!(t.rank_of(n, l), rank);
        }
    }

    #[test]
    fn nic_down_includes_switch_delay() {
        let c = presets::cluster("ampere", 1).unwrap();
        let t = Topology::build(&c).unwrap();
        let up = t.link(t.l_nic_up(0, 0));
        let down = t.link(t.l_nic_down(0, 0));
        assert!(down.delay > up.delay);
        assert!((down.delay.as_ns() - (300.0 + 368.0)).abs() < 0.01);
    }
}
