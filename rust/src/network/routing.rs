//! Rail-only route computation (paper Fig 2).
//!
//! Three cases:
//! * (a) intra-node: GPU → NVSwitch → GPU.
//! * (b) inter-node, same local rank `r`: GPU → NIC (PCIe, 2 trips) →
//!   rail switch `r` → NIC → GPU.
//! * (c) inter-node, different local rank: first an NVLink hop to the
//!   source-node GPU that sits on the destination's rail, then case (b)
//!   along that rail. (Rail-only design: no traffic crosses aggregation
//!   switches, paper §2.)

use super::topology::{LinkId, Topology};

/// A route is the ordered list of directed links a flow traverses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// The links, in traversal order (empty = self-communication).
    pub links: Vec<LinkId>,
}

impl Route {
    /// Number of link traversals.
    pub fn hops(&self) -> usize {
        self.links.len()
    }
}

/// Compute the rail-only route between two global ranks.
/// Returns an empty route for self-communication (zero-copy).
pub fn route(topo: &Topology, src_rank: u32, dst_rank: u32) -> Route {
    if src_rank == dst_rank {
        return Route { links: vec![] };
    }
    let (sn, sl) = topo.locate(src_rank);
    let (dn, dl) = topo.locate(dst_rank);

    if sn == dn {
        // (a) intra-node via NVSwitch
        return Route {
            links: vec![topo.l_gpu_to_nvsw(sn, sl), topo.l_nvsw_to_gpu(sn, dl)],
        };
    }

    let mut links = Vec::with_capacity(6);
    let rail = dl; // flows ride the destination's rail
    if sl != dl {
        // (c) NVLink hop to the GPU on the destination rail first
        links.push(topo.l_gpu_to_nvsw(sn, sl));
        links.push(topo.l_nvsw_to_gpu(sn, rail));
    }
    // (b) up the rail
    links.push(topo.l_gpu_to_nic(sn, rail));
    links.push(topo.l_nic_up(sn, rail));
    links.push(topo.l_nic_down(dn, rail));
    links.push(topo.l_nic_to_gpu(dn, dl));
    Route { links }
}

/// Sum of fixed per-hop delays along a route (the QbbChannel part of a
/// flow's completion time).
pub fn fixed_delay(topo: &Topology, r: &Route) -> crate::util::units::Time {
    crate::util::units::Time(r.links.iter().map(|l| topo.link(*l).delay.as_ps()).sum())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::network::topology::{LinkKind, NodeRef};

    fn topo(nodes: u32) -> Topology {
        Topology::build(&presets::cluster("ampere", nodes).unwrap()).unwrap()
    }

    #[test]
    fn self_route_is_empty() {
        let t = topo(1);
        assert_eq!(route(&t, 3, 3).hops(), 0);
    }

    #[test]
    fn intra_node_uses_nvlink_only() {
        let t = topo(2);
        let r = route(&t, 0, 7); // fig 2 case (a)
        assert_eq!(r.hops(), 2);
        for l in &r.links {
            assert_eq!(t.link(*l).kind, LinkKind::NvLink);
        }
    }

    #[test]
    fn inter_node_same_rail_skips_nvlink() {
        let t = topo(2);
        let r = route(&t, 7, 15); // fig 2 case (b): local rank 7 both sides
        assert_eq!(r.hops(), 4);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(kinds, vec![LinkKind::Pcie, LinkKind::NicUp, LinkKind::NicDown, LinkKind::Pcie]);
    }

    #[test]
    fn inter_node_cross_rail_adds_nvlink_hop() {
        let t = topo(2);
        let r = route(&t, 7, 8); // fig 2 case (c): local 7 -> local 0
        assert_eq!(r.hops(), 6);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(
            kinds,
            vec![
                LinkKind::NvLink,
                LinkKind::NvLink,
                LinkKind::Pcie,
                LinkKind::NicUp,
                LinkKind::NicDown,
                LinkKind::Pcie
            ]
        );
        // the rail used is the destination's local rank (0)
        match t.link(r.links[4]).to {
            NodeRef::Nic { node, local } => {
                assert_eq!((node, local), (1, 0));
            }
            other => panic!("unexpected endpoint {other:?}"),
        }
    }

    #[test]
    fn fixed_delay_counts_every_hop() {
        let t = topo(2);
        let r = route(&t, 7, 15);
        // pcie(2x287.5) + nic(368) + switch(300)+nic(368) + pcie(2x287.5)
        let expect = 2.0 * 287.5 + 368.0 + (300.0 + 368.0) + 2.0 * 287.5;
        assert!((fixed_delay(&t, &r).as_ns() - expect).abs() < 0.01);
    }

    #[test]
    fn routes_stay_on_destination_rail() {
        let t = topo(4);
        for dst_local in 0..8u32 {
            let r = route(&t, 0, t.rank_of(3, dst_local));
            // every NicUp link must sit on the destination rail
            for l in &r.links {
                if t.link(*l).kind == LinkKind::NicUp {
                    match t.link(*l).from {
                        NodeRef::Nic { local, .. } => assert_eq!(local, dst_local),
                        _ => panic!(),
                    }
                }
            }
        }
    }
}
