//! Fabric-dispatched route assembly (paper Fig 2, generalized per
//! DESIGN.md §24).
//!
//! Intra-node traffic rides the NVSwitch on every fabric:
//! GPU → NVSwitch → GPU. Inter-node assembly depends on the built
//! fabric ([`Topology::fabric`]):
//!
//! * **RailOnly** (paper Fig 2 cases a–c): flows ride the destination's
//!   rail; a source-side NVLink hop reaches the GPU sitting on that
//!   rail when the source local rank differs. On mixed-node-size
//!   clusters the rail index is `dst_local mod src_node_gpus` (every
//!   node owns rails `0..gpus_per_node`, so both endpoints must share
//!   one), and a destination-side NVLink hop finishes the path when the
//!   shared rail is not the destination's own. On uniform clusters the
//!   shared rail *is* `dst_local` — routes are byte-identical to the
//!   pre-fabric implementation.
//! * **SingleSwitch**: GPU → NIC → switch → NIC → GPU; each endpoint
//!   uses its own NIC (no rail alignment, no NVLink detours).
//! * **LeafSpine**: GPU → NIC → leaf → spine → leaf → NIC → GPU, with
//!   the spine chosen by the deterministic index rule
//!   [`Topology::spine_for`].

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::cluster::FabricSpec;
use crate::util::units::Time;

use super::topology::{LinkId, Topology};

/// A route is the ordered list of directed links a flow traverses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// The links, in traversal order (empty = self-communication).
    pub links: Vec<LinkId>,
}

impl Route {
    /// Number of link traversals.
    pub fn hops(&self) -> usize {
        self.links.len()
    }
}

/// Compute the route between two global ranks under the topology's
/// fabric. Returns an empty route for self-communication (zero-copy).
pub fn route(topo: &Topology, src_rank: u32, dst_rank: u32) -> Route {
    if src_rank == dst_rank {
        return Route { links: vec![] };
    }
    let (sn, sl) = topo.locate(src_rank);
    let (dn, dl) = topo.locate(dst_rank);

    if sn == dn {
        // intra-node via NVSwitch (every fabric)
        return Route {
            links: vec![topo.l_gpu_to_nvsw(sn, sl), topo.l_nvsw_to_gpu(sn, dl)],
        };
    }

    let mut links = Vec::with_capacity(8);
    match topo.fabric {
        FabricSpec::RailOnly => {
            // flows ride the destination's rail; on mixed node sizes the
            // rail must exist on the source node too, so fold it into
            // the source's rail range (identity on uniform clusters)
            let rail = dl % topo.node_gpus(sn);
            if sl != rail {
                // NVLink hop to the source GPU on the shared rail first
                links.push(topo.l_gpu_to_nvsw(sn, sl));
                links.push(topo.l_nvsw_to_gpu(sn, rail));
            }
            links.push(topo.l_gpu_to_nic(sn, rail));
            links.push(topo.l_nic_up(sn, rail));
            links.push(topo.l_nic_down(dn, rail));
            links.push(topo.l_nic_to_gpu(dn, rail));
            if rail != dl {
                // destination sits off the shared rail (only possible
                // with non-uniform node sizes): final NVLink hop
                links.push(topo.l_gpu_to_nvsw(dn, rail));
                links.push(topo.l_nvsw_to_gpu(dn, dl));
            }
        }
        FabricSpec::SingleSwitch => {
            links.push(topo.l_gpu_to_nic(sn, sl));
            links.push(topo.l_nic_up(sn, sl));
            links.push(topo.l_nic_down(dn, dl));
            links.push(topo.l_nic_to_gpu(dn, dl));
        }
        FabricSpec::LeafSpine { .. } => {
            let s = topo.spine_for(src_rank, dst_rank);
            links.push(topo.l_gpu_to_nic(sn, sl));
            links.push(topo.l_nic_up(sn, sl));
            links.push(topo.l_leaf_up(sn, s));
            links.push(topo.l_leaf_down(dn, s));
            links.push(topo.l_nic_down(dn, dl));
            links.push(topo.l_nic_to_gpu(dn, dl));
        }
    }
    Route { links }
}

/// Compute a route that avoids every link in `dead`, or `None` when no
/// such route exists — the degraded-mode companion of [`route`]
/// (DESIGN.md §28).
///
/// The primary route is returned untouched when it already avoids the
/// dead set (so degraded simulations perturb only the flows that
/// actually crossed the failed hardware). Otherwise detour candidates
/// are enumerated in deterministic ascending index order, mirroring the
/// primary assembly per fabric:
///
/// * **RailOnly** — alternate shared rails, reached by NVLink hops on
///   both endpoints when the rail is not the endpoint's own.
/// * **SingleSwitch** — alternate (src NIC, dst NIC) pairs, NVLink
///   detours to the GPUs owning them.
/// * **LeafSpine** — alternate (src NIC, spine, dst NIC) triples.
///
/// Intra-node NVLink paths are never detoured: NVLink islands are not
/// fault candidates ([`crate::system::failure::faulted_links`] only
/// names NIC/fabric links), so a dead intra-node route means the caller
/// passed a dead set this module does not model — `None` says so.
pub fn route_avoiding(
    topo: &Topology,
    src_rank: u32,
    dst_rank: u32,
    dead: &[LinkId],
) -> Option<Route> {
    let primary = route(topo, src_rank, dst_rank);
    if dead.is_empty() || primary.links.iter().all(|l| !dead.contains(l)) {
        return Some(primary);
    }
    let (sn, sl) = topo.locate(src_rank);
    let (dn, dl) = topo.locate(dst_rank);
    if sn == dn {
        return None;
    }
    let ok = |links: &[LinkId]| links.iter().all(|l| !dead.contains(l));
    match topo.fabric {
        FabricSpec::RailOnly => {
            // alternate rails exist on both endpoints below the smaller
            // node's rail count (the primary rail always qualifies too)
            let rails = topo.node_gpus(sn).min(topo.node_gpus(dn));
            for rail in 0..rails {
                let mut links = Vec::with_capacity(8);
                if sl != rail {
                    links.push(topo.l_gpu_to_nvsw(sn, sl));
                    links.push(topo.l_nvsw_to_gpu(sn, rail));
                }
                links.extend([
                    topo.l_gpu_to_nic(sn, rail),
                    topo.l_nic_up(sn, rail),
                    topo.l_nic_down(dn, rail),
                    topo.l_nic_to_gpu(dn, rail),
                ]);
                if rail != dl {
                    links.push(topo.l_gpu_to_nvsw(dn, rail));
                    links.push(topo.l_nvsw_to_gpu(dn, dl));
                }
                if ok(&links) {
                    return Some(Route { links });
                }
            }
            None
        }
        FabricSpec::SingleSwitch => {
            for s_nic in 0..topo.node_gpus(sn) {
                for d_nic in 0..topo.node_gpus(dn) {
                    let mut links = Vec::with_capacity(8);
                    if s_nic != sl {
                        links.push(topo.l_gpu_to_nvsw(sn, sl));
                        links.push(topo.l_nvsw_to_gpu(sn, s_nic));
                    }
                    links.extend([
                        topo.l_gpu_to_nic(sn, s_nic),
                        topo.l_nic_up(sn, s_nic),
                        topo.l_nic_down(dn, d_nic),
                        topo.l_nic_to_gpu(dn, d_nic),
                    ]);
                    if d_nic != dl {
                        links.push(topo.l_gpu_to_nvsw(dn, d_nic));
                        links.push(topo.l_nvsw_to_gpu(dn, dl));
                    }
                    if ok(&links) {
                        return Some(Route { links });
                    }
                }
            }
            None
        }
        FabricSpec::LeafSpine { spines, .. } => {
            for s_nic in 0..topo.node_gpus(sn) {
                for spine in 0..spines {
                    for d_nic in 0..topo.node_gpus(dn) {
                        let mut links = Vec::with_capacity(10);
                        if s_nic != sl {
                            links.push(topo.l_gpu_to_nvsw(sn, sl));
                            links.push(topo.l_nvsw_to_gpu(sn, s_nic));
                        }
                        links.extend([
                            topo.l_gpu_to_nic(sn, s_nic),
                            topo.l_nic_up(sn, s_nic),
                            topo.l_leaf_up(sn, spine),
                            topo.l_leaf_down(dn, spine),
                            topo.l_nic_down(dn, d_nic),
                            topo.l_nic_to_gpu(dn, d_nic),
                        ]);
                        if d_nic != dl {
                            links.push(topo.l_gpu_to_nvsw(dn, d_nic));
                            links.push(topo.l_nvsw_to_gpu(dn, dl));
                        }
                        if ok(&links) {
                            return Some(Route { links });
                        }
                    }
                }
            }
            None
        }
    }
}

/// Sum of fixed per-hop delays along a route (the QbbChannel part of a
/// flow's completion time).
pub fn fixed_delay(topo: &Topology, r: &Route) -> crate::util::units::Time {
    crate::util::units::Time(r.links.iter().map(|l| topo.link(*l).delay.as_ps()).sum())
}

/// Lazily-materialized route store. A route is a pure function of
/// (topology, src, dst), so each endpoint pair is assembled — and its
/// fixed-delay sum computed — exactly once, then shared behind an
/// `Arc` (a clone is a pointer bump). Collectives re-post the same
/// pairs every ring step and every iteration, so a simulation's cache
/// converges to the set of *distinct* pairs while the per-flow cost
/// drops to one hash lookup; at 100k ranks this also avoids holding a
/// dense all-pairs route table that would dwarf the topology itself.
#[derive(Debug, Default)]
pub struct RouteCache {
    entries: HashMap<(u32, u32), (Arc<Route>, Time)>,
}

impl RouteCache {
    /// An empty cache; routes materialize on first use.
    pub fn new() -> Self {
        RouteCache::default()
    }

    /// The route and fixed-delay sum between two ranks, materializing
    /// them on the first request for this pair.
    pub fn get(&mut self, topo: &Topology, src: u32, dst: u32) -> (Arc<Route>, Time) {
        match self.entries.entry((src, dst)) {
            std::collections::hash_map::Entry::Occupied(e) => {
                let (r, d) = e.get();
                (r.clone(), *d)
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                let r = Arc::new(route(topo, src, dst));
                let d = fixed_delay(topo, &r);
                let (r, d) = v.insert((r, d));
                (r.clone(), *d)
            }
        }
    }

    /// Degraded-mode variant of [`RouteCache::get`]: routes are
    /// materialized through [`route_avoiding`] against `dead`, so pairs
    /// untouched by the dead set keep their primary route and affected
    /// pairs cache their detour. Returns `None` when no route survives.
    ///
    /// A cache instance must be used with one consistent dead set —
    /// entries do not record which set they were computed under
    /// ([`crate::network::flow::FlowSim::set_dead_links`] resets the
    /// cache when the set changes).
    pub fn get_avoiding(
        &mut self,
        topo: &Topology,
        src: u32,
        dst: u32,
        dead: &[LinkId],
    ) -> Option<(Arc<Route>, Time)> {
        if let Some((r, d)) = self.entries.get(&(src, dst)) {
            return Some((r.clone(), *d));
        }
        let r = Arc::new(route_avoiding(topo, src, dst, dead)?);
        let d = fixed_delay(topo, &r);
        self.entries.insert((src, dst), (r.clone(), d));
        Some((r, d))
    }

    /// Distinct (src, dst) pairs materialized so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no route has been materialized yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::network::topology::{LinkKind, NodeRef};

    fn topo(nodes: u32) -> Topology {
        Topology::build(&presets::cluster("ampere", nodes).unwrap()).unwrap()
    }

    #[test]
    fn self_route_is_empty() {
        let t = topo(1);
        assert_eq!(route(&t, 3, 3).hops(), 0);
    }

    #[test]
    fn intra_node_uses_nvlink_only() {
        let t = topo(2);
        let r = route(&t, 0, 7); // fig 2 case (a)
        assert_eq!(r.hops(), 2);
        for l in &r.links {
            assert_eq!(t.link(*l).kind, LinkKind::NvLink);
        }
    }

    #[test]
    fn inter_node_same_rail_skips_nvlink() {
        let t = topo(2);
        let r = route(&t, 7, 15); // fig 2 case (b): local rank 7 both sides
        assert_eq!(r.hops(), 4);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(kinds, vec![LinkKind::Pcie, LinkKind::NicUp, LinkKind::NicDown, LinkKind::Pcie]);
    }

    #[test]
    fn inter_node_cross_rail_adds_nvlink_hop() {
        let t = topo(2);
        let r = route(&t, 7, 8); // fig 2 case (c): local 7 -> local 0
        assert_eq!(r.hops(), 6);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(
            kinds,
            vec![
                LinkKind::NvLink,
                LinkKind::NvLink,
                LinkKind::Pcie,
                LinkKind::NicUp,
                LinkKind::NicDown,
                LinkKind::Pcie
            ]
        );
        // the rail used is the destination's local rank (0)
        match t.link(r.links[4]).to {
            NodeRef::Nic { node, local } => {
                assert_eq!((node, local), (1, 0));
            }
            other => panic!("unexpected endpoint {other:?}"),
        }
    }

    #[test]
    fn fixed_delay_counts_every_hop() {
        let t = topo(2);
        let r = route(&t, 7, 15);
        // pcie(2x287.5) + nic(368) + switch(300)+nic(368) + pcie(2x287.5)
        let expect = 2.0 * 287.5 + 368.0 + (300.0 + 368.0) + 2.0 * 287.5;
        assert!((fixed_delay(&t, &r).as_ns() - expect).abs() < 0.01);
    }

    #[test]
    fn routes_stay_on_destination_rail() {
        let t = topo(4);
        for dst_local in 0..8u32 {
            let r = route(&t, 0, t.rank_of(3, dst_local));
            // every NicUp link must sit on the destination rail
            for l in &r.links {
                if t.link(*l).kind == LinkKind::NicUp {
                    match t.link(*l).from {
                        NodeRef::Nic { local, .. } => assert_eq!(local, dst_local),
                        _ => panic!(),
                    }
                }
            }
        }
    }

    #[test]
    fn rail_routes_on_mixed_node_sizes_fold_to_shared_rails() {
        // 4-GPU node 0 beside 8-GPU node 1: a flow from node 0 to local
        // rank 6 of node 1 must ride a rail < 4 and finish with an
        // NVLink hop on the destination node
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.nodes[0].gpus_per_node = 4;
        let t = Topology::build(&c).unwrap();
        let r = route(&t, 0, t.rank_of(1, 6)); // rail = 6 % 4 = 2
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(
            kinds,
            vec![
                LinkKind::NvLink, // 0 -> rail-2 GPU on node 0
                LinkKind::NvLink,
                LinkKind::Pcie,
                LinkKind::NicUp,
                LinkKind::NicDown,
                LinkKind::Pcie,
                LinkKind::NvLink, // rail-2 GPU on node 1 -> local 6
                LinkKind::NvLink,
            ]
        );
        // link-contiguity across the whole path
        for w in r.links.windows(2) {
            assert_eq!(t.link(w[0]).to, t.link(w[1]).from);
        }
        // and the reverse direction works too
        let back = route(&t, t.rank_of(1, 6), 0);
        assert!(back.hops() >= 4);
    }

    #[test]
    fn single_switch_routes_use_own_nics() {
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = crate::config::cluster::FabricSpec::SingleSwitch;
        let t = Topology::build(&c).unwrap();
        // cross-rail inter-node: no NVLink detour on the one-switch fabric
        let r = route(&t, 7, 8);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(kinds, vec![LinkKind::Pcie, LinkKind::NicUp, LinkKind::NicDown, LinkKind::Pcie]);
        match t.link(r.links[1]).from {
            NodeRef::Nic { node, local } => assert_eq!((node, local), (0, 7)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn leaf_spine_routes_traverse_both_tiers() {
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = crate::config::cluster::FabricSpec::LeafSpine {
            spines: 2,
            oversubscription: 2.0,
        };
        let t = Topology::build(&c).unwrap();
        let r = route(&t, 3, 12);
        let kinds: Vec<LinkKind> = r.links.iter().map(|l| t.link(*l).kind).collect();
        assert_eq!(
            kinds,
            vec![
                LinkKind::Pcie,
                LinkKind::NicUp,
                LinkKind::LeafUp,
                LinkKind::LeafDown,
                LinkKind::NicDown,
                LinkKind::Pcie
            ]
        );
        for w in r.links.windows(2) {
            assert_eq!(t.link(w[0]).to, t.link(w[1]).from);
        }
        // both directions of one pair may use different spines — but
        // each is deterministic
        assert_eq!(route(&t, 3, 12), route(&t, 3, 12));
    }

    #[test]
    fn route_avoiding_detours_around_dead_rails() {
        let t = topo(2);
        let primary = route(&t, 7, 15); // rail 7 both sides
        // no dead set: the primary route comes back untouched
        assert_eq!(route_avoiding(&t, 7, 15, &[]), Some(primary.clone()));
        // kill rail 7's uplink pair on node 0: the detour must use
        // another rail via NVLink hops on both endpoints
        let dead = vec![t.l_nic_up(0, 7), t.l_nic_down(0, 7)];
        let r = route_avoiding(&t, 7, 15, &dead).unwrap();
        assert_ne!(r, primary);
        assert!(r.links.iter().all(|l| !dead.contains(l)));
        // the detour is a contiguous path ending at the destination GPU
        for w in r.links.windows(2) {
            assert_eq!(t.link(w[0]).to, t.link(w[1]).from);
        }
        assert_eq!(t.link(*r.links.last().unwrap()).to, NodeRef::Gpu { node: 1, local: 7 });
        // unaffected pairs keep their primary routes exactly
        assert_eq!(route_avoiding(&t, 3, 11, &dead), Some(route(&t, 3, 11)));
        // intra-node traffic never detours (NVLink is not a fault
        // candidate) and survives any NIC-side dead set
        assert_eq!(route_avoiding(&t, 0, 7, &dead), Some(route(&t, 0, 7)));

        // a single-rail pair has no detour: killing the only rail
        // severs the route entirely
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.nodes[0].gpus_per_node = 1;
        c.nodes[1].gpus_per_node = 1;
        let t1 = Topology::build(&c).unwrap();
        let dead = vec![t1.l_nic_up(0, 0), t1.l_nic_down(0, 0)];
        assert_eq!(route_avoiding(&t1, 0, 1, &dead), None);
    }

    #[test]
    fn route_avoiding_uses_alternate_spines() {
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.fabric = crate::config::cluster::FabricSpec::LeafSpine {
            spines: 2,
            oversubscription: 2.0,
        };
        let t = Topology::build(&c).unwrap();
        let primary = route(&t, 3, 12);
        let spine = t.spine_for(3, 12);
        // kill the primary spine's uplinks on the source node
        let dead = vec![t.l_leaf_up(0, spine), t.l_leaf_down(0, spine)];
        let r = route_avoiding(&t, 3, 12, &dead).unwrap();
        assert_ne!(r, primary);
        assert!(r.links.iter().all(|l| !dead.contains(l)));
        assert!(r.links.contains(&t.l_leaf_up(0, 1 - spine)));
        for w in r.links.windows(2) {
            assert_eq!(t.link(w[0]).to, t.link(w[1]).from);
        }
        // a single-spine fabric has no alternate: the route is severed
        let mut c1 = presets::cluster("ampere", 2).unwrap();
        c1.fabric = crate::config::cluster::FabricSpec::LeafSpine {
            spines: 1,
            oversubscription: 2.0,
        };
        let t1 = Topology::build(&c1).unwrap();
        let dead = vec![t1.l_leaf_up(0, 0), t1.l_leaf_down(0, 0)];
        assert_eq!(route_avoiding(&t1, 3, 12, &dead), None);
    }

    #[test]
    fn route_cache_get_avoiding_caches_detours() {
        let t = topo(2);
        let dead = vec![t.l_nic_up(0, 7), t.l_nic_down(0, 7)];
        let mut cache = RouteCache::new();
        let (r1, d1) = cache.get_avoiding(&t, 7, 15, &dead).unwrap();
        assert_eq!(*r1, route_avoiding(&t, 7, 15, &dead).unwrap());
        assert_eq!(d1, fixed_delay(&t, &r1));
        let (r2, _) = cache.get_avoiding(&t, 7, 15, &dead).unwrap();
        assert!(Arc::ptr_eq(&r1, &r2));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn route_cache_materializes_each_pair_once() {
        let t = topo(2);
        let mut cache = RouteCache::new();
        assert!(cache.is_empty());
        let (r1, d1) = cache.get(&t, 7, 8);
        assert_eq!(*r1, route(&t, 7, 8));
        assert_eq!(d1, fixed_delay(&t, &r1));
        let (r2, d2) = cache.get(&t, 7, 8);
        // second request shares the same materialized route
        assert!(Arc::ptr_eq(&r1, &r2));
        assert_eq!(d1, d2);
        assert_eq!(cache.len(), 1);
        // a different pair is a new entry, not a collision
        let (r3, _) = cache.get(&t, 8, 7);
        assert_ne!(*r3, *r1);
        assert_eq!(cache.len(), 2);
    }
}
