//! Compute-cost layer (system S10, paper component **C4**: "simulate
//! the compute performance based on the bottleneck device").
//!
//! The paper profiles per-layer times on real A100/H100 GPUs via AICB;
//! we replace profiling with the calibrated roofline model described in
//! DESIGN.md §4. Two interchangeable evaluators:
//!
//! * [`cost::NativeCostModel`] — pure-Rust mirror of the Layer-2 JAX
//!   formulas (`python/compile/model.py`), used as the in-process
//!   fallback and as the cross-check oracle.
//! * [`crate::runtime::PjrtCostModel`] — executes the AOT-lowered
//!   `artifacts/cost_model.hlo.txt` through PJRT: the production path
//!   proving the three-layer architecture composes. The integration
//!   test asserts both agree to f32 tolerance.
//!
//! [`table::CostTable`] batches all distinct (layer, GPU) descriptor
//! pairs of a simulation, evaluates them in one shot and serves cached
//! lookups to the event simulator.

pub mod cost;
pub mod table;

pub use cost::{LayerWork, NativeCostModel, GPU_FIELDS, LAYER_FIELDS};
pub use table::{CostEvaluator, CostTable};
