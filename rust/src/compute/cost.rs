//! Native mirror of the Layer-1/Layer-2 cost model.
//!
//! These formulas MUST stay in lockstep with `python/compile/model.py`
//! (`layer_flops_bytes`) and `python/compile/kernels/roofline.py`
//! (`_roofline_block`); `rust/tests/integration_runtime.rs` cross-checks
//! this module against the PJRT-executed artifact row by row.

use crate::config::cluster::GpuSpec;
use crate::config::model::LayerKind;

/// Fields per layer-descriptor row of the AOT artifact.
pub const LAYER_FIELDS: usize = 10;
/// Fields per GPU-descriptor row of the AOT artifact.
pub const GPU_FIELDS: usize = 8;

/// Dtype bytes constant (mirrors model.py).
pub const DTYPE_BYTES: f64 = 2.0;
/// Backward-pass FLOPs multiplier vs forward (mirrors model.py).
pub const BWD_FLOPS_FACTOR: f64 = 2.0;
/// Backward-pass HBM-bytes multiplier vs forward (mirrors model.py).
pub const BWD_BYTES_FACTOR: f64 = 2.0;

/// One layer-descriptor row: the work one GPU performs for one
/// microbatch of one layer (per TP shard).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerWork {
    /// Layer class (selects the FLOPs/bytes formula).
    pub kind: LayerKind,
    /// Model (embedding) dimension.
    pub hidden: f64,
    /// MLP inner dimension.
    pub ffn: f64,
    /// Attention head count.
    pub heads: f64,
    /// Sequence length.
    pub seq: f64,
    /// Microbatch size.
    pub mbs: f64,
    /// MoE expert count (0 for dense layers).
    pub n_experts: f64,
    /// MoE routed experts per token (0 for dense layers).
    pub top_k: f64,
    /// TP degree the layer is sharded across.
    pub tp: f64,
    /// Backward (true) or forward (false) pass.
    pub is_bwd: bool,
}

impl LayerWork {
    /// Pack into the 10-field f32 row the AOT artifact expects.
    pub fn descriptor_row(&self) -> [f32; LAYER_FIELDS] {
        [
            self.kind.code(),
            self.hidden as f32,
            self.ffn as f32,
            self.heads as f32,
            self.seq as f32,
            self.mbs as f32,
            self.n_experts as f32,
            self.top_k as f32,
            self.tp as f32,
            if self.is_bwd { 1.0 } else { 0.0 },
        ]
    }

    /// FLOPs and bytes for this work item (mirror of
    /// `model.layer_flops_bytes`). Computed in f32 to match the
    /// artifact's arithmetic exactly.
    pub fn flops_bytes(&self) -> (f64, f64) {
        let hidden = self.hidden;
        let ffn = self.ffn;
        let heads = self.heads;
        let seq = self.seq;
        let mbs = self.mbs;
        let tokens = mbs * seq;
        let d = DTYPE_BYTES;
        let tp = self.tp.max(1.0);

        let (flops, bytes) = match self.kind {
            LayerKind::Embedding => {
                (2.0 * tokens * hidden, tokens * (2.0 * hidden * d + 4.0))
            }
            LayerKind::Attention => (
                mbs * (8.0 * seq * hidden * hidden + 4.0 * seq * seq * hidden),
                mbs * (12.0 * seq * hidden * d + heads * seq * seq * d) + 4.0 * hidden * hidden * d,
            ),
            LayerKind::Mlp => (
                4.0 * tokens * hidden * ffn,
                tokens * (hidden + ffn) * 2.0 * d + 2.0 * hidden * ffn * d,
            ),
            LayerKind::Moe => {
                let mlp_flops = 4.0 * tokens * hidden * ffn;
                (
                    2.0 * tokens * hidden * self.n_experts + self.top_k * mlp_flops,
                    tokens * (hidden + self.top_k * ffn) * 2.0 * d
                        + self.n_experts * 2.0 * hidden * ffn * d,
                )
            }
            LayerKind::Other => (10.0 * tokens * hidden, 6.0 * tokens * hidden * d),
        };
        let (mut flops, mut bytes) = (flops / tp, bytes / tp);
        if self.is_bwd {
            flops *= BWD_FLOPS_FACTOR;
            bytes *= BWD_BYTES_FACTOR;
        }
        (flops, bytes)
    }
}

/// Pure-Rust roofline evaluator (mirror of `_roofline_block`).
#[derive(Debug, Default, Clone)]
pub struct NativeCostModel;

impl NativeCostModel {
    /// Execution-time estimate in seconds.
    pub fn time_seconds(&self, work: &LayerWork, gpu: &GpuSpec) -> f64 {
        let (flops, bytes) = work.flops_bytes();
        let eff_f = match work.kind {
            LayerKind::Attention | LayerKind::Other => gpu.eff_attn,
            _ => gpu.eff_mlp,
        };
        let eff_m = match work.kind {
            LayerKind::Embedding => gpu.eff_embed,
            _ => gpu.eff_mem,
        };
        let t_compute = flops / (gpu.peak_flops * eff_f);
        let t_memory = bytes / (gpu.mem_bw * eff_m);
        t_compute.max(t_memory) + gpu.launch_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn work(kind: LayerKind) -> LayerWork {
        LayerWork {
            kind,
            hidden: 4096.0,
            ffn: 16384.0,
            heads: 32.0,
            seq: 2048.0,
            mbs: 8.0,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    #[test]
    fn mlp_ratio_matches_paper_fig5() {
        let m = NativeCostModel;
        let a = presets::gpu("A100").unwrap();
        let h = presets::gpu("H100").unwrap();
        let w = work(LayerKind::Mlp);
        let ratio = m.time_seconds(&w, &a) / m.time_seconds(&w, &h);
        assert!((3.0..4.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn attention_ratio_matches_paper_fig5() {
        let m = NativeCostModel;
        let a = presets::gpu("A100").unwrap();
        let h = presets::gpu("H100").unwrap();
        let w = work(LayerKind::Attention);
        let ratio = m.time_seconds(&w, &a) / m.time_seconds(&w, &h);
        assert!((1.5..1.95).contains(&ratio), "{ratio}");
    }

    #[test]
    fn embedding_ratio_matches_paper_fig5() {
        let m = NativeCostModel;
        let a = presets::gpu("A100").unwrap();
        let h = presets::gpu("H100").unwrap();
        let w = work(LayerKind::Embedding);
        let ratio = m.time_seconds(&w, &a) / m.time_seconds(&w, &h);
        assert!((30.0..40.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn backward_doubles_flops() {
        let mut w = work(LayerKind::Mlp);
        let (f1, b1) = w.flops_bytes();
        w.is_bwd = true;
        let (f2, b2) = w.flops_bytes();
        assert_eq!(f2, 2.0 * f1);
        assert_eq!(b2, 2.0 * b1);
    }

    #[test]
    fn tp_divides_work() {
        let mut w = work(LayerKind::Attention);
        let (f1, _) = w.flops_bytes();
        w.tp = 8.0;
        let (f8, _) = w.flops_bytes();
        assert!((f1 / f8 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn moe_uses_topk_experts() {
        let mut w = work(LayerKind::Moe);
        w.ffn = 14336.0;
        w.n_experts = 8.0;
        w.top_k = 2.0;
        let (f_moe, _) = w.flops_bytes();
        let mut dense = w;
        dense.kind = LayerKind::Mlp;
        let (f_dense, _) = dense.flops_bytes();
        // top-2 experts ~= 2x dense FLOPs (+ router)
        assert!(f_moe > 2.0 * f_dense && f_moe < 2.2 * f_dense, "{f_moe} vs {f_dense}");
    }

    #[test]
    fn descriptor_row_layout() {
        let w = work(LayerKind::Attention);
        let r = w.descriptor_row();
        assert_eq!(r[0], 1.0); // attention code
        assert_eq!(r[1], 4096.0);
        assert_eq!(r[9], 0.0);
    }

    #[test]
    fn launch_overhead_is_floor() {
        let m = NativeCostModel;
        let h = presets::gpu("H100").unwrap();
        let mut w = work(LayerKind::Mlp);
        w.hidden = 1.0;
        w.ffn = 1.0;
        w.seq = 1.0;
        w.mbs = 1.0;
        let t = m.time_seconds(&w, &h);
        assert!(t >= h.launch_overhead);
        assert!(t < h.launch_overhead * 1.01);
    }
}
