//! Batched cost-table evaluation and caching.
//!
//! The workload generator registers every distinct (LayerWork, GpuSpec)
//! pair it needs; `CostTable::evaluate` runs them through a
//! [`CostEvaluator`] in artifact-sized batches (256 rows) and caches the
//! results for O(1) lookup from the event loop. This keeps PJRT strictly
//! on the *setup* path — zero artifact executions per simulated event.

use std::collections::HashMap;

use super::cost::{LayerWork, NativeCostModel};
use crate::config::cluster::GpuSpec;
use crate::util::units::Time;

/// Batch size of the AOT artifact (ROWS in python/compile/model.py).
pub const BATCH_ROWS: usize = 256;

/// Anything that can evaluate a batch of descriptor rows.
///
/// `Send + Sync` is a supertrait so a [`CostTable`] (and therefore a
/// prepared [`crate::simulator::Simulation`]) can be shared across
/// worker threads.
pub trait CostEvaluator: Send + Sync {
    /// layers: `n x LAYER_FIELDS`, gpus: `n x GPU_FIELDS` (row-aligned),
    /// `n <= BATCH_ROWS`. Returns `n` seconds values.
    fn evaluate_batch(&mut self, layers: &[[f32; 10]], gpus: &[[f32; 8]]) -> anyhow::Result<Vec<f32>>;

    /// Human label for reports ("native" / "pjrt").
    fn name(&self) -> &'static str;
}

impl CostEvaluator for NativeCostModel {
    fn evaluate_batch(&mut self, layers: &[[f32; 10]], gpus: &[[f32; 8]]) -> anyhow::Result<Vec<f32>> {
        // Reconstruct specs from rows so the native path goes through
        // the exact same interface as the artifact.
        use crate::config::model::LayerKind;
        let mut out = Vec::with_capacity(layers.len());
        for (l, g) in layers.iter().zip(gpus) {
            let kind = match l[0] as u32 {
                0 => LayerKind::Embedding,
                1 => LayerKind::Attention,
                2 => LayerKind::Mlp,
                3 => LayerKind::Moe,
                _ => LayerKind::Other,
            };
            let work = LayerWork {
                kind,
                hidden: l[1] as f64,
                ffn: l[2] as f64,
                heads: l[3] as f64,
                seq: l[4] as f64,
                mbs: l[5] as f64,
                n_experts: l[6] as f64,
                top_k: l[7] as f64,
                tp: l[8] as f64,
                is_bwd: l[9] > 0.5,
            };
            let gpu = GpuSpec {
                name: String::new(),
                peak_flops: g[0] as f64,
                mem_bw: g[1] as f64,
                mem_capacity: 0,
                eff_mlp: g[2] as f64,
                eff_attn: g[3] as f64,
                eff_embed: g[4] as f64,
                eff_mem: g[5] as f64,
                launch_overhead: g[6] as f64,
            };
            out.push(self.time_seconds(&work, &gpu) as f32);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Key for the lookup cache: descriptor rows bit-cast to ints so they
/// hash exactly.
fn key(l: &[f32; 10], g: &[f32; 8]) -> ([u32; 10], [u32; 8]) {
    let mut lk = [0u32; 10];
    let mut gk = [0u32; 8];
    for (i, v) in l.iter().enumerate() {
        lk[i] = v.to_bits();
    }
    for (i, v) in g.iter().enumerate() {
        gk[i] = v.to_bits();
    }
    (lk, gk)
}

/// Registered-then-evaluated cost cache.
pub struct CostTable {
    evaluator: Box<dyn CostEvaluator>,
    pending: Vec<([f32; 10], [f32; 8])>,
    cache: HashMap<([u32; 10], [u32; 8]), f32>,
    /// Number of artifact executions performed (perf accounting).
    pub batches_run: u64,
}

impl std::fmt::Debug for CostTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostTable")
            .field("evaluator", &self.evaluator.name())
            .field("cached", &self.cache.len())
            .field("pending", &self.pending.len())
            .finish()
    }
}

impl CostTable {
    /// A table over any evaluator (native or PJRT).
    pub fn new(evaluator: Box<dyn CostEvaluator>) -> Self {
        CostTable { evaluator, pending: Vec::new(), cache: HashMap::new(), batches_run: 0 }
    }

    /// A table over the pure-Rust roofline mirror.
    pub fn native() -> Self {
        Self::new(Box::new(NativeCostModel))
    }

    /// The backing evaluator's report label ("native" / "pjrt").
    pub fn evaluator_name(&self) -> &'static str {
        self.evaluator.name()
    }

    /// Register a pair for batched evaluation (dedup-aware).
    pub fn register(&mut self, work: &LayerWork, gpu: &GpuSpec) {
        let l = work.descriptor_row();
        let g = gpu.descriptor_row();
        if !self.cache.contains_key(&key(&l, &g)) {
            self.pending.push((l, g));
        }
    }

    /// Evaluate all registered pairs (in BATCH_ROWS chunks).
    pub fn evaluate(&mut self) -> anyhow::Result<()> {
        // dedup pending
        self.pending.sort_by_key(|(l, g)| key(l, g));
        self.pending.dedup_by_key(|(l, g)| key(l, g));
        let pending = std::mem::take(&mut self.pending);
        for chunk in pending.chunks(BATCH_ROWS) {
            let layers: Vec<[f32; 10]> = chunk.iter().map(|(l, _)| *l).collect();
            let gpus: Vec<[f32; 8]> = chunk.iter().map(|(_, g)| *g).collect();
            let times = self.evaluator.evaluate_batch(&layers, &gpus)?;
            anyhow::ensure!(times.len() == chunk.len(), "evaluator row-count mismatch");
            self.batches_run += 1;
            for ((l, g), t) in chunk.iter().zip(times) {
                anyhow::ensure!(
                    t.is_finite() && t >= 0.0,
                    "evaluator produced invalid time {t} for row {l:?}"
                );
                self.cache.insert(key(l, g), t);
            }
        }
        Ok(())
    }

    /// Cached lookup; errors if the pair was never registered+evaluated.
    pub fn time(&self, work: &LayerWork, gpu: &GpuSpec) -> anyhow::Result<Time> {
        let l = work.descriptor_row();
        let g = gpu.descriptor_row();
        match self.cache.get(&key(&l, &g)) {
            Some(t) => Ok(Time::from_secs(*t as f64)),
            None => anyhow::bail!(
                "cost table miss for kind={:?} gpu={} — workload registration incomplete",
                work.kind,
                gpu.name
            ),
        }
    }

    /// Distinct (layer, GPU) pairs currently cached.
    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    /// Snapshot the evaluated cache into a fresh **native-backed**
    /// table. Used by [`crate::simulator::EvalContext`] to hand each
    /// candidate build a warm cache without holding a lock across
    /// registration: entries are pure functions of their descriptor
    /// rows, so a shared snapshot can never disagree with a fresh
    /// evaluation. (Context sharing is native-only; the PJRT evaluator
    /// is not cloneable.)
    pub fn share(&self) -> CostTable {
        CostTable {
            evaluator: Box::new(NativeCostModel),
            pending: Vec::new(),
            cache: self.cache.clone(),
            batches_run: 0,
        }
    }

    /// Merge `other`'s evaluated entries into this table's cache
    /// (existing entries win; values are identical by purity). The
    /// write-back half of the [`CostTable::share`] pattern.
    pub fn absorb(&mut self, other: &CostTable) {
        for (k, v) in &other.cache {
            self.cache.entry(*k).or_insert(*v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::LayerKind;
    use crate::config::presets;

    fn work(kind: LayerKind, mbs: f64) -> LayerWork {
        LayerWork {
            kind,
            hidden: 4096.0,
            ffn: 16384.0,
            heads: 32.0,
            seq: 2048.0,
            mbs,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    #[test]
    fn register_evaluate_lookup() {
        let mut t = CostTable::native();
        let gpu = presets::gpu("H100").unwrap();
        let w = work(LayerKind::Mlp, 8.0);
        t.register(&w, &gpu);
        t.evaluate().unwrap();
        let time = t.time(&w, &gpu).unwrap();
        assert!(time > Time::ZERO);
    }

    #[test]
    fn miss_errors_clearly() {
        let t = CostTable::native();
        let gpu = presets::gpu("H100").unwrap();
        let err = t.time(&work(LayerKind::Mlp, 8.0), &gpu).unwrap_err();
        assert!(err.to_string().contains("cost table miss"));
    }

    #[test]
    fn dedup_avoids_rework() {
        let mut t = CostTable::native();
        let gpu = presets::gpu("A100").unwrap();
        for _ in 0..100 {
            t.register(&work(LayerKind::Attention, 4.0), &gpu);
        }
        t.evaluate().unwrap();
        assert_eq!(t.cached_len(), 1);
        assert_eq!(t.batches_run, 1);
    }

    #[test]
    fn chunking_handles_many_rows() {
        let mut t = CostTable::native();
        let gpu = presets::gpu("A100").unwrap();
        for i in 0..600 {
            t.register(&work(LayerKind::Mlp, 1.0 + i as f64), &gpu);
        }
        t.evaluate().unwrap();
        assert_eq!(t.cached_len(), 600);
        assert!(t.batches_run >= 3);
    }

    #[test]
    fn matches_direct_native_model() {
        let mut t = CostTable::native();
        let gpu = presets::gpu("H100").unwrap();
        let w = work(LayerKind::Attention, 8.0);
        t.register(&w, &gpu);
        t.evaluate().unwrap();
        let direct = NativeCostModel.time_seconds(&w, &gpu);
        let cached = t.time(&w, &gpu).unwrap().as_secs();
        assert!((direct - cached).abs() / direct < 1e-5);
    }
}
