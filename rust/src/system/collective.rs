//! Heterogeneity-aware collective communication library (component
//! **C3**). Imitates NCCL's algorithm structure the way SimAI does, but
//! over *heterogeneous* device groups:
//!
//! * logical-ring **graph generation** orders ranks (node-major) so ring
//!   edges stay intra-node where possible, and — the heterogeneity-aware
//!   part — groups nodes of the same architecture together so a ring
//!   crosses the slow↔fast boundary the minimum number of times;
//! * ring allreduce / allgather / reduce-scatter, pairwise all-to-all,
//!   binomial-tree broadcast, p2p;
//! * hierarchical allreduce for rail topologies (intra-node
//!   reduce-scatter → per-rail inter-node rings → intra-node allgather);
//! * a step machine ([`CollectiveExec`]) that expands each algorithm
//!   step into a batch of [`FlowSpec`]s for the fluid network simulator
//!   (collectives are *blocking*: step k+1 starts only when every flow
//!   of step k delivered — exactly the property the paper uses to read
//!   bottleneck flows off the FCT distribution).

use crate::config::cluster::ClusterSpec;
use crate::network::flow::FlowSpec;

/// Collective algorithms (codes mirror `python/compile/kernels/collective.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveAlgo {
    /// Ring allreduce (reduce-scatter + allgather phases).
    AllReduceRing,
    /// Ring allgather.
    AllGather,
    /// Ring reduce-scatter.
    ReduceScatter,
    /// Pairwise-exchange all-to-all.
    AllToAll,
    /// Binomial-tree broadcast from the first rank.
    Broadcast,
    /// Hierarchical allreduce: intra-node RS, per-rail inter-node
    /// allreduce, intra-node AG (NCCL-style for rail topologies).
    AllReduceHierarchical,
}

impl CollectiveAlgo {
    /// Numeric code used in the AOT cost-model feature rows.
    pub fn code(self) -> f32 {
        match self {
            CollectiveAlgo::AllReduceRing | CollectiveAlgo::AllReduceHierarchical => 0.0,
            CollectiveAlgo::AllGather => 1.0,
            CollectiveAlgo::ReduceScatter => 2.0,
            CollectiveAlgo::AllToAll => 3.0,
            CollectiveAlgo::Broadcast => 4.0,
        }
    }

    /// Lower-case display name.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveAlgo::AllReduceRing => "allreduce",
            CollectiveAlgo::AllGather => "allgather",
            CollectiveAlgo::ReduceScatter => "reducescatter",
            CollectiveAlgo::AllToAll => "alltoall",
            CollectiveAlgo::Broadcast => "broadcast",
            CollectiveAlgo::AllReduceHierarchical => "allreduce-hier",
        }
    }
}

/// Which parallelism dimension a collective belongs to (Fig-6 labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommKind {
    /// Tensor-parallel activation allreduces.
    Tp,
    /// Data-parallel gradient synchronization.
    Dp,
    /// Pipeline stage-boundary transfers.
    Pp,
    /// Expert-parallel (MoE) all-to-alls.
    Ep,
    /// Resharding traffic (component C2).
    Reshard,
}

impl CommKind {
    /// Upper-case label used in FCT report keys.
    pub fn name(self) -> &'static str {
        match self {
            CommKind::Tp => "TP",
            CommKind::Dp => "DP",
            CommKind::Pp => "PP",
            CommKind::Ep => "EP",
            CommKind::Reshard => "RESHARD",
        }
    }
}

/// A collective operation over a device group.
#[derive(Debug, Clone)]
pub struct CollectiveDef {
    /// Workload-unique collective id (doubles as the flow tag).
    pub id: u64,
    /// Algorithm to expand into flow steps.
    pub algo: CollectiveAlgo,
    /// Participating global ranks (logical order as given; ring order is
    /// recomputed by graph generation).
    pub ranks: Vec<u32>,
    /// Payload bytes contributed per rank.
    pub bytes_per_rank: u64,
    /// Parallelism dimension this collective belongs to.
    pub kind: CommKind,
    /// Human-readable label (`tp-ar-g0s1mb2-attn-f` style).
    pub label: String,
}

/// Ring-order policy (the C3 "graph generation" knob; `Naive` is the
/// ablation baseline that ignores topology and architecture).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RingPolicy {
    /// Use the ranks in the order given.
    Naive,
    /// Node-major + architecture-major ordering (heterogeneity-aware).
    HeteroAware,
}

/// Topology-aware allreduce algorithm selection: choose between the
/// flat ring and the hierarchical (intra-node → inter-node → intra-node)
/// plan from the *fabric shape* and the group's node footprint.
///
/// * Single-node groups, and groups contributing at most one rank per
///   node, always use the flat ring — the hierarchy has nothing to
///   collapse.
/// * Irregular multi-node groups (per-node populations that differ)
///   also use the flat ring: the hierarchical plan's per-slot
///   inter-node rings would leave single-owner slots without
///   cross-node flows, under-counting traffic — the flat ring models
///   every byte.
/// * On the rail-only fabric the flat ring stays the default even for
///   regular groups: rail paths are non-blocking along each rail, and
///   keeping the seed choice preserves the byte-identical RailOnly
///   golden timelines.
/// * On switch and leaf/spine fabrics, regular multi-node groups with
///   ≥ 2 ranks per node select the hierarchical plan: it shrinks the
///   bytes crossing the (potentially oversubscribed) inter-node tier
///   by the intra-node group size, exactly where those fabrics
///   bottleneck.
///
/// Ring ordering inside either algorithm is node-major via
/// [`ClusterSpec::locate`], which is prefix-sum based and therefore
/// correct on clusters with non-uniform per-node GPU counts.
pub fn select_allreduce_algo(cluster: &ClusterSpec, ranks: &[u32]) -> CollectiveAlgo {
    use crate::config::cluster::FabricSpec;
    let mut per_node: std::collections::BTreeMap<u32, u32> = std::collections::BTreeMap::new();
    for r in ranks {
        let n = cluster.node_of_rank(*r).unwrap_or(u32::MAX);
        *per_node.entry(n).or_insert(0) += 1;
    }
    let multi_node = per_node.len() > 1;
    let mut counts = per_node.values();
    let first = counts.next().copied().unwrap_or(0);
    let regular = first >= 2 && counts.all(|c| *c == first);
    if !multi_node || !regular {
        return CollectiveAlgo::AllReduceRing;
    }
    match cluster.fabric {
        FabricSpec::RailOnly => CollectiveAlgo::AllReduceRing,
        FabricSpec::SingleSwitch | FabricSpec::LeafSpine { .. } => {
            CollectiveAlgo::AllReduceHierarchical
        }
    }
}

/// Order ranks for a logical ring.
pub fn ring_order(cluster: &ClusterSpec, ranks: &[u32], policy: RingPolicy) -> Vec<u32> {
    match policy {
        RingPolicy::Naive => ranks.to_vec(),
        RingPolicy::HeteroAware => {
            // architecture-major, then node, then local rank: rings walk
            // all nodes of one architecture before crossing to the next,
            // minimizing slow<->fast boundary edges (2 per ring).
            // Decorate-sort-undecorate with one prefix-sum location per
            // rank — re-running `ClusterSpec::locate` (an O(nodes)
            // scan) plus an arch-name clone per sort-key evaluation is
            // quadratic on the 100k-rank DP rings of the fold ladder.
            let starts = cluster.node_starts();
            let world = *starts.last().unwrap_or(&0);
            let mut v: Vec<(&str, u32, u32, u32)> = ranks
                .iter()
                .map(|&r| {
                    if r >= world {
                        return ("", u32::MAX, u32::MAX, r);
                    }
                    let node = starts.partition_point(|&s| s <= r) - 1;
                    let local = r - starts[node];
                    (cluster.nodes[node].gpu.name.as_str(), node as u32, local, r)
                })
                .collect();
            // stable sort on the (arch, node, local) key alone — the
            // same ordering the previous per-key sort produced
            v.sort_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
            v.into_iter().map(|(_, _, _, r)| r).collect()
        }
    }
}

/// The expanded execution plan: a sequence of steps, each a batch of
/// flows that must all complete before the next step starts.
#[derive(Debug, Clone)]
pub struct CollectiveExec {
    /// Id of the [`CollectiveDef`] this plan expands.
    pub def_id: u64,
    /// The flow batches, one per blocking step.
    pub steps: Vec<Vec<FlowSpec>>,
    /// Index of the step currently executing.
    pub current: usize,
    /// Flows outstanding in the current step.
    pub outstanding: usize,
}

impl CollectiveExec {
    /// Expand a collective into its step plan.
    pub fn plan(cluster: &ClusterSpec, def: &CollectiveDef, policy: RingPolicy) -> CollectiveExec {
        let order = ring_order(cluster, &def.ranks, policy);
        let n = order.len();
        let bytes = def.bytes_per_rank;
        let tag = def.id;
        let mut steps: Vec<Vec<FlowSpec>> = Vec::new();

        if n <= 1 || bytes == 0 {
            return CollectiveExec { def_id: def.id, steps, current: 0, outstanding: 0 };
        }

        let ring_steps = |steps: &mut Vec<Vec<FlowSpec>>, count: usize, chunk: u64| {
            for _ in 0..count {
                let mut batch = Vec::with_capacity(n);
                for i in 0..n {
                    let src = order[i];
                    let dst = order[(i + 1) % n];
                    batch.push(FlowSpec { src, dst, bytes: chunk, tag });
                }
                steps.push(batch);
            }
        };

        match def.algo {
            CollectiveAlgo::AllReduceRing => {
                // reduce-scatter + allgather: 2(n-1) steps of size/n chunks
                ring_steps(&mut steps, 2 * (n - 1), (bytes / n as u64).max(1));
            }
            CollectiveAlgo::AllGather | CollectiveAlgo::ReduceScatter => {
                ring_steps(&mut steps, n - 1, (bytes / n as u64).max(1));
            }
            CollectiveAlgo::AllToAll => {
                // pairwise exchange: step s, rank i sends to (i+s) mod n
                let chunk = (bytes / n as u64).max(1);
                for s in 1..n {
                    let mut batch = Vec::with_capacity(n);
                    for i in 0..n {
                        batch.push(FlowSpec {
                            src: order[i],
                            dst: order[(i + s) % n],
                            bytes: chunk,
                            tag,
                        });
                    }
                    steps.push(batch);
                }
            }
            CollectiveAlgo::Broadcast => {
                // binomial tree from order[0]
                let mut have = 1usize;
                while have < n {
                    let senders = have.min(n - have);
                    let mut batch = Vec::with_capacity(senders);
                    for i in 0..senders {
                        batch.push(FlowSpec {
                            src: order[i],
                            dst: order[have + i],
                            bytes,
                            tag,
                        });
                    }
                    steps.push(batch);
                    have += senders;
                }
            }
            CollectiveAlgo::AllReduceHierarchical => {
                plan_hierarchical(cluster, &order, bytes, tag, &mut steps);
            }
        }
        CollectiveExec { def_id: def.id, steps, current: 0, outstanding: 0 }
    }

    /// Total bytes the plan moves (traffic-conservation invariant).
    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().flatten().map(|f| f.bytes).sum()
    }

    /// True once every step has executed.
    pub fn is_done(&self) -> bool {
        self.current >= self.steps.len()
    }

    /// Take the next step's flow batch (marks them outstanding).
    pub fn next_step(&mut self) -> Option<&[FlowSpec]> {
        if self.is_done() {
            return None;
        }
        let step = &self.steps[self.current];
        self.outstanding = step.len();
        Some(step)
    }

    /// Report one completed flow; returns true when the step finished
    /// (advance with `next_step`).
    pub fn flow_done(&mut self) -> bool {
        debug_assert!(self.outstanding > 0, "flow_done without outstanding flows");
        self.outstanding -= 1;
        if self.outstanding == 0 {
            self.current += 1;
            true
        } else {
            false
        }
    }
}

/// Hierarchical allreduce for rail topologies.
fn plan_hierarchical(
    cluster: &ClusterSpec,
    order: &[u32],
    bytes: u64,
    tag: u64,
    steps: &mut Vec<Vec<FlowSpec>>,
) {
    use std::collections::BTreeMap;
    // bucket ranks per node (preserving order)
    let mut per_node: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for r in order {
        let (n, _) = cluster.locate(*r).unwrap_or((u32::MAX, 0));
        per_node.entry(n).or_default().push(*r);
    }
    let nodes: Vec<&Vec<u32>> = per_node.values().collect();
    let local = nodes.iter().map(|v| v.len()).max().unwrap_or(1);

    // Phase 1: intra-node reduce-scatter (rings inside each node, run
    // concurrently: merged into shared step batches).
    let intra_steps = local.saturating_sub(1);
    let chunk1 = (bytes / local.max(1) as u64).max(1);
    for s in 0..intra_steps {
        let mut batch = Vec::new();
        for node_ranks in &nodes {
            let ln = node_ranks.len();
            if ln > 1 && s < ln - 1 {
                for i in 0..ln {
                    batch.push(FlowSpec {
                        src: node_ranks[i],
                        dst: node_ranks[(i + 1) % ln],
                        bytes: chunk1,
                        tag,
                    });
                }
            }
        }
        if !batch.is_empty() {
            steps.push(batch);
        }
    }

    // Phase 2: per-slot inter-node allreduce rings. Each slot rings
    // over exactly the nodes that own it, so node populations may
    // differ without breaking ring connectivity (a slot shared by a
    // subset of nodes used to drop the hop to a node lacking it,
    // silently skipping part of the reduction). Slots owned by a
    // single node generate no inter-node flows — their chunks are
    // approximated as reduced by the owning node's intra-node phases;
    // on ragged groups this under-counts cross-node bytes, which is
    // why [`select_allreduce_algo`] only routes *regular* groups
    // (equal per-node populations) here automatically.
    let nn = nodes.len();
    if nn > 1 {
        let chunk2 = (bytes / (local.max(1) as u64 * nn as u64)).max(1);
        let slot_nodes: Vec<Vec<usize>> = (0..local)
            .map(|slot| (0..nn).filter(|ni| slot < nodes[*ni].len()).collect())
            .collect();
        fn ring_len(owners: &[usize]) -> usize {
            if owners.len() > 1 {
                2 * (owners.len() - 1)
            } else {
                0
            }
        }
        let max_ring_steps =
            slot_nodes.iter().map(|o| ring_len(o)).max().unwrap_or(0);
        for s in 0..max_ring_steps {
            let mut batch = Vec::new();
            for (slot, owners) in slot_nodes.iter().enumerate() {
                if s >= ring_len(owners) {
                    continue;
                }
                for (pos, ni) in owners.iter().enumerate() {
                    let next = owners[(pos + 1) % owners.len()];
                    batch.push(FlowSpec {
                        src: nodes[*ni][slot],
                        dst: nodes[next][slot],
                        bytes: chunk2,
                        tag,
                    });
                }
            }
            if !batch.is_empty() {
                steps.push(batch);
            }
        }
    }

    // Phase 3: intra-node allgather.
    for s in 0..intra_steps {
        let mut batch = Vec::new();
        for node_ranks in &nodes {
            let ln = node_ranks.len();
            if ln > 1 && s < ln - 1 {
                for i in 0..ln {
                    batch.push(FlowSpec {
                        src: node_ranks[i],
                        dst: node_ranks[(i + 1) % ln],
                        bytes: chunk1,
                        tag,
                    });
                }
            }
        }
        if !batch.is_empty() {
            steps.push(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn def(algo: CollectiveAlgo, ranks: Vec<u32>, bytes: u64) -> CollectiveDef {
        CollectiveDef { id: 1, algo, ranks, bytes_per_rank: bytes, kind: CommKind::Tp, label: "t".into() }
    }

    #[test]
    fn ring_allreduce_step_structure() {
        let c = presets::cluster("ampere", 1).unwrap();
        let e = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllReduceRing, (0..8).collect(), 8000), RingPolicy::Naive);
        assert_eq!(e.steps.len(), 14); // 2*(8-1)
        assert!(e.steps.iter().all(|s| s.len() == 8));
        assert!(e.steps[0].iter().all(|f| f.bytes == 1000));
    }

    #[test]
    fn allreduce_moves_2x_data_of_allgather() {
        let c = presets::cluster("ampere", 1).unwrap();
        let ar = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllReduceRing, (0..8).collect(), 8000), RingPolicy::Naive);
        let ag = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllGather, (0..8).collect(), 8000), RingPolicy::Naive);
        assert_eq!(ar.total_bytes(), 2 * ag.total_bytes());
    }

    #[test]
    fn single_rank_collective_is_noop() {
        let c = presets::cluster("ampere", 1).unwrap();
        let e = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllReduceRing, vec![3], 1 << 20), RingPolicy::Naive);
        assert!(e.is_done());
        assert_eq!(e.total_bytes(), 0);
    }

    #[test]
    fn broadcast_binomial_tree_counts() {
        let c = presets::cluster("ampere", 1).unwrap();
        let e = CollectiveExec::plan(&c, &def(CollectiveAlgo::Broadcast, (0..8).collect(), 100), RingPolicy::Naive);
        assert_eq!(e.steps.len(), 3); // log2(8)
        assert_eq!(e.steps[0].len(), 1);
        assert_eq!(e.steps[1].len(), 2);
        assert_eq!(e.steps[2].len(), 4);
    }

    #[test]
    fn alltoall_pairwise_exchange() {
        let c = presets::cluster("ampere", 1).unwrap();
        let e = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllToAll, (0..4).collect(), 4000), RingPolicy::Naive);
        assert_eq!(e.steps.len(), 3);
        // every step: 4 flows of size/4
        for s in &e.steps {
            assert_eq!(s.len(), 4);
            assert!(s.iter().all(|f| f.bytes == 1000));
        }
    }

    #[test]
    fn step_machine_advances_on_flow_completion() {
        let c = presets::cluster("ampere", 1).unwrap();
        let mut e = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllGather, (0..4).collect(), 4000), RingPolicy::Naive);
        let mut total_flows = 0;
        while let Some(step) = e.next_step() {
            let n = step.len();
            total_flows += n;
            for i in 0..n {
                let finished = e.flow_done();
                assert_eq!(finished, i == n - 1);
            }
        }
        assert!(e.is_done());
        assert_eq!(total_flows, 3 * 4);
    }

    #[test]
    fn hetero_aware_ring_minimizes_arch_crossings() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        // interleaved rank order: worst case for a naive ring
        let ranks: Vec<u32> = (0..32).map(|i| (i % 4) * 8 + i / 4).collect();
        let order = ring_order(&c, &ranks, RingPolicy::HeteroAware);
        // count architecture boundary crossings around the ring
        let arch = |r: u32| c.gpu_of_rank(r).unwrap().name.clone();
        let crossings = (0..order.len())
            .filter(|&i| arch(order[i]) != arch(order[(i + 1) % order.len()]))
            .count();
        assert_eq!(crossings, 2, "{order:?}");
        // naive order crosses much more often
        let naive = ring_order(&c, &ranks, RingPolicy::Naive);
        let naive_crossings = (0..naive.len())
            .filter(|&i| arch(naive[i]) != arch(naive[(i + 1) % naive.len()]))
            .count();
        assert!(naive_crossings > 2);
    }

    #[test]
    fn hierarchical_conserves_traffic_phases() {
        let c = presets::cluster("ampere", 2).unwrap();
        let ranks: Vec<u32> = (0..16).collect();
        let e = CollectiveExec::plan(
            &c,
            &def(CollectiveAlgo::AllReduceHierarchical, ranks, 16000),
            RingPolicy::HeteroAware,
        );
        // phases: 7 intra + 2 inter + 7 intra = 16 steps
        assert_eq!(e.steps.len(), 7 + 2 + 7);
        // inter-node steps only contain cross-node flows
        let inter = &e.steps[7];
        for f in inter {
            assert_ne!(f.src / 8, f.dst / 8);
        }
    }

    #[test]
    fn algo_selection_follows_fabric_shape() {
        use crate::config::cluster::FabricSpec;
        let mut c = presets::cluster("ampere", 2).unwrap();
        let spanning: Vec<u32> = (0..16).collect(); // ≥2 ranks on both nodes
        let one_per_node = vec![0u32, 8];
        let intra: Vec<u32> = (0..8).collect();
        // rail-only keeps the seed's flat-ring default everywhere
        assert_eq!(select_allreduce_algo(&c, &spanning), CollectiveAlgo::AllReduceRing);
        // switch / leaf-spine fabrics go hierarchical on regular
        // multi-node groups
        for fabric in [
            FabricSpec::SingleSwitch,
            FabricSpec::LeafSpine { spines: 2, oversubscription: 2.0 },
        ] {
            c.fabric = fabric;
            assert_eq!(
                select_allreduce_algo(&c, &spanning),
                CollectiveAlgo::AllReduceHierarchical
            );
            // nothing to collapse: single node or one rank per node
            assert_eq!(select_allreduce_algo(&c, &intra), CollectiveAlgo::AllReduceRing);
            assert_eq!(
                select_allreduce_algo(&c, &one_per_node),
                CollectiveAlgo::AllReduceRing
            );
        }
        // irregular groups (unequal per-node populations) stay on the
        // flat ring even on switch fabrics: the hierarchical plan
        // would under-count their cross-node traffic
        let mut mixed = presets::cluster("ampere", 2).unwrap();
        mixed.nodes[0].gpus_per_node = 4;
        mixed.fabric = FabricSpec::SingleSwitch;
        let ragged: Vec<u32> = (0..12).collect(); // 4 on node 0, 8 on node 1
        assert_eq!(select_allreduce_algo(&mixed, &ragged), CollectiveAlgo::AllReduceRing);
        // a regular group on the same mixed-size cluster still
        // upgrades (2 ranks from each node)
        let regular = vec![0u32, 1, 4, 5];
        assert_eq!(
            select_allreduce_algo(&mixed, &regular),
            CollectiveAlgo::AllReduceHierarchical
        );
    }

    #[test]
    fn hierarchical_plan_handles_non_uniform_node_sizes() {
        // 4-GPU node beside 8-GPU node: every slot shared by both
        // nodes must ring over both (a subset-owned slot used to drop
        // its hops silently); single-owner slots emit no inter-node
        // flows by design (documented approximation — the automatic
        // selection never routes such ragged groups here)
        let mut c = presets::cluster("ampere", 2).unwrap();
        c.nodes[0].gpus_per_node = 4;
        let ranks: Vec<u32> = (0..12).collect();
        let e = CollectiveExec::plan(
            &c,
            &def(CollectiveAlgo::AllReduceHierarchical, ranks, 24_000),
            RingPolicy::HeteroAware,
        );
        assert!(!e.steps.is_empty());
        // phase 2 starts after the max(4,8)-1 = 7 intra steps and
        // contains only cross-node flows
        let inter = &e.steps[7];
        for f in inter {
            assert_ne!(c.node_of_rank(f.src), c.node_of_rank(f.dst), "{f:?}");
        }
        // each shared slot (0..4) rings both directions: node0 slot s
        // is rank s, node1 slot s is rank 4 + s
        for s in 0..4u32 {
            assert!(inter.iter().any(|f| f.src == s && f.dst == 4 + s), "slot {s} fwd");
            assert!(inter.iter().any(|f| f.src == 4 + s && f.dst == s), "slot {s} rev");
        }
        // every flow stays inside the group
        for f in e.steps.iter().flatten() {
            assert!(f.src < 12 && f.dst < 12);
        }
    }

    #[test]
    fn zero_bytes_collective_is_noop() {
        let c = presets::cluster("ampere", 1).unwrap();
        let e = CollectiveExec::plan(&c, &def(CollectiveAlgo::AllReduceRing, (0..8).collect(), 0), RingPolicy::Naive);
        assert!(e.is_done());
    }
}
