//! System layer (paper §4.2): logical resource management and
//! scheduling — components **C1** (non-uniform hybrid parallelism over
//! custom device groups), **C2** (resharding) and **C3**
//! (heterogeneity-aware collective communication).
//!
//! * [`device_group`] — runtime device-group views: TP groups, DP sync
//!   groups, PP edges, locality classification.
//! * [`collective`] — the CCL: ring / tree / hierarchical algorithms,
//!   heterogeneity-aware logical ring ordering, and the step-machine
//!   that expands a collective into batches of network flows.
//! * [`resharding`] — shape-mismatch detection between communicating
//!   device groups and the extra traffic a reshard injects.
//! * [`fold`] — symmetry folding: equivalence classes of
//!   interchangeable device groups, so the engine simulates one
//!   representative per class and multiplies (DESIGN.md §25).
//! * [`failure`] — deterministic fault injection: scheduled node / NIC
//!   / link failures and stragglers, MTBF-driven schedules, and the
//!   checkpoint cost model behind goodput reporting (DESIGN.md §26).
//! * [`compiled`] — the dense, immutable simulation core: a workload
//!   lowered once (durations resolved, collectives pre-planned, ids
//!   remapped to `Vec` indices) so runs share it without re-deriving.
//! * [`scheduler`] — the per-rank program executor: runs compute ops,
//!   blocks on collectives/receives, coordinates the compute and
//!   network simulators over one training iteration.
//! * [`serve_scheduler`] — the request-level serving scheduler:
//!   continuous batching with KV-budget admission control and
//!   pluggable policies (fifo/srpt/wsrpt) over per-node device groups
//!   (DESIGN.md §27).

pub mod collective;
pub mod compiled;
pub mod device_group;
pub mod failure;
pub mod fold;
pub mod resharding;
pub mod scheduler;
pub mod serve_scheduler;

pub use collective::{CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind};
pub use compiled::{CompiledWorkload, DenseOp};
pub use device_group::DeviceGroups;
pub use failure::{FaultKind, FaultReport, FaultSpec};
pub use fold::{FoldMode, FoldPlan};
pub use resharding::{needs_resharding, ReshardPlan};
pub use scheduler::{Scheduler, SchedulerReport};
pub use serve_scheduler::ServeSim;
