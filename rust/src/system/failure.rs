//! Deterministic fault injection (DESIGN.md §26): scheduled node / NIC /
//! link failures, straggler slow-downs, and MTBF-driven schedules.
//!
//! A [`FaultSpec`] is a *plan input*, not a random process at run time:
//! every fault is an explicit `(time, kind)` pair, either written out in
//! scenario JSON (`"faults"` key) or materialized up front from a
//! per-architecture MTBF table by [`mtbf_schedule`] using the in-tree
//! seeded PRNG. Once the spec exists, the simulation is exactly as
//! deterministic as the fault-free path: the scheduler only ever reads
//! the resolved [`IterationFaults`], which is a pure function of the
//! spec and the cluster.
//!
//! Fail-stop kinds ([`FaultKind::NodeFail`], [`FaultKind::NicFail`],
//! [`FaultKind::LinkFail`]) abort the in-flight iteration at the fault
//! time and charge the whole partial iteration as lost work (gradient
//! state is gone — the job restarts from the last checkpoint).
//! [`FaultKind::Straggler`] keeps the node running but multiplies its
//! compute durations. The checkpoint/restore cost model and the
//! goodput walk that consumes these events live in
//! [`crate::report::goodput`].

use crate::config::cluster::ClusterSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::units::Time;

/// What fails (or slows down). All kinds name a *node*: the paper's
/// failure domains are node-granular (a GPU, its NIC, and its NVLink
/// island share fate for scheduling purposes — any of them going away
/// stalls every rank on the node).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The node is lost permanently (kernel panic, hardware retirement).
    /// Fail-stop for the in-flight iteration; on top of the restart
    /// cost, the surviving cluster is re-planned
    /// ([`crate::report::goodput`] splices the new plan's per-iteration
    /// cost).
    NodeFail {
        /// Cluster node index of the failed node.
        node: u32,
    },
    /// The node's NIC dies. Fail-stop (collectives through the node
    /// wedge), but the node rejoins after repair — same plan resumes.
    NicFail {
        /// Cluster node index owning the failed NIC.
        node: u32,
    },
    /// An inter-node link attached to the node flaps hard enough to
    /// kill in-flight collectives. Fail-stop; same plan resumes.
    LinkFail {
        /// Cluster node index at the failing link's endpoint.
        node: u32,
    },
    /// The node keeps running, `mult`× slower (thermal throttling, a
    /// sick HBM stack). Applies to every compute op on the node's ranks
    /// from the fault time onward.
    Straggler {
        /// Cluster node index of the slow node.
        node: u32,
        /// Compute-duration multiplier, ≥ 1.0.
        mult: f64,
    },
}

impl FaultKind {
    /// The node index this fault applies to.
    pub fn node(&self) -> u32 {
        match *self {
            FaultKind::NodeFail { node }
            | FaultKind::NicFail { node }
            | FaultKind::LinkFail { node }
            | FaultKind::Straggler { node, .. } => node,
        }
    }

    /// Short stable name (JSON `kind` value / report label).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeFail { .. } => "node_fail",
            FaultKind::NicFail { .. } => "nic_fail",
            FaultKind::LinkFail { .. } => "link_fail",
            FaultKind::Straggler { .. } => "straggler",
        }
    }

    /// True for the kinds that abort the in-flight iteration.
    pub fn is_fail_stop(&self) -> bool {
        !matches!(self, FaultKind::Straggler { .. })
    }

    fn canon(&self) -> String {
        match *self {
            FaultKind::Straggler { node, mult } => format!("straggler:{node}:{mult}"),
            k => format!("{}:{}", k.name(), k.node()),
        }
    }
}

/// One scheduled fault: `kind` strikes `at_s` seconds into training.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Wall-clock offset from training start, in seconds.
    pub at_s: f64,
    /// What happens.
    pub kind: FaultKind,
}

/// Checkpoint/restore cost model. Checkpoint bytes are
/// `param_count × (dtype_bytes + 12)` — weights plus fp32 Adam moments
/// and master copy — sharded across the plan's DP writers, so write
/// time is `bytes / (write_gbps · 1e9 · dp)`. Restore reads the same
/// bytes at the same bandwidth; `restart_warmup_s` adds the fixed
/// rendezvous / JIT / pipeline-refill cost after every restart.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointSpec {
    /// Iterations between checkpoints (amortized write cost, and the
    /// expected half-interval of work lost per fail-stop).
    pub interval_iters: u64,
    /// Per-DP-writer storage bandwidth in GB/s (decimal).
    pub write_gbps: f64,
    /// Fixed restart overhead in seconds (rendezvous, load, warmup).
    pub restart_warmup_s: f64,
}

impl Default for CheckpointSpec {
    fn default() -> Self {
        CheckpointSpec { interval_iters: 32, write_gbps: 10.0, restart_warmup_s: 60.0 }
    }
}

/// A complete, deterministic fault plan: explicit events plus the
/// checkpoint cost model and the seed any MTBF materialization used.
/// An empty spec (no events) is defined to be byte-identical to not
/// configuring faults at all — the builder normalizes it away.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Scheduled faults, sorted by `at_s` ([`FaultSpec::normalize`]).
    pub events: Vec<FaultEvent>,
    /// Checkpoint/restore cost model for goodput accounting.
    pub checkpoint: CheckpointSpec,
    /// Seed recorded for provenance (MTBF schedules derive from it).
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { events: Vec::new(), checkpoint: CheckpointSpec::default(), seed: 42 }
    }
}

fn strict_f64(v: &Json, key: &str, default: f64) -> anyhow::Result<f64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("faults: `{key}` must be a number")),
    }
}

fn strict_u64(v: &Json, key: &str, default: u64) -> anyhow::Result<u64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => {
            x.as_u64().ok_or_else(|| anyhow::anyhow!("faults: `{key}` must be an unsigned int"))
        }
    }
}

impl FaultSpec {
    /// True when the spec injects nothing (and is therefore
    /// indistinguishable from no spec at all).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Sort events by time (stable — equal-time events keep their
    /// declaration order).
    pub fn normalize(&mut self) {
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    }

    /// Check the spec against a cluster: node indices in range, finite
    /// non-negative times, straggler multipliers ≥ 1.
    pub fn validate(&self, cluster: &ClusterSpec) -> anyhow::Result<()> {
        let nodes = cluster.nodes.len() as u32;
        for ev in &self.events {
            anyhow::ensure!(
                ev.at_s.is_finite() && ev.at_s >= 0.0,
                "fault time {} is not a finite non-negative number of seconds",
                ev.at_s
            );
            anyhow::ensure!(
                ev.kind.node() < nodes,
                "fault names node {} but cluster {} has {} nodes",
                ev.kind.node(),
                cluster.name,
                nodes
            );
            if let FaultKind::Straggler { mult, .. } = ev.kind {
                anyhow::ensure!(
                    mult.is_finite() && mult >= 1.0,
                    "straggler multiplier {mult} must be a finite number >= 1"
                );
            }
        }
        anyhow::ensure!(
            self.checkpoint.interval_iters > 0,
            "checkpoint interval_iters must be >= 1"
        );
        anyhow::ensure!(
            self.checkpoint.write_gbps.is_finite() && self.checkpoint.write_gbps > 0.0,
            "checkpoint write_gbps must be a positive number"
        );
        anyhow::ensure!(
            self.checkpoint.restart_warmup_s.is_finite() && self.checkpoint.restart_warmup_s >= 0.0,
            "checkpoint restart_warmup_s must be a non-negative number"
        );
        Ok(())
    }

    /// Stable cache-key marker for this spec: the empty string when the
    /// spec is empty (the fault layer is invisible when off), otherwise
    /// a `|faults:<hash>` suffix appended to the simulator's eval keys
    /// so faulted and fault-free scores never alias.
    pub fn fingerprint(&self) -> String {
        if self.is_empty() {
            return String::new();
        }
        let mut s = format!(
            "s{};i{};w{};r{}",
            self.seed,
            self.checkpoint.interval_iters,
            self.checkpoint.write_gbps,
            self.checkpoint.restart_warmup_s
        );
        for ev in &self.events {
            s.push(';');
            s.push_str(&ev.kind.canon());
            s.push('@');
            s.push_str(&ev.at_s.to_string());
        }
        // FNV-1a over the canonical serialization
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in s.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("|faults:{h:016x}")
    }

    /// Parse a `"faults"` JSON object (scenario key or `--faults` file).
    ///
    /// Recognized keys — all optional, but present-and-malformed is an
    /// error, never a silent default:
    ///
    /// * `"events"`: array of `{"at_s": …, "kind": "node_fail" |
    ///   "nic_fail" | "link_fail" | "straggler", "node": …,
    ///   "mult": …}` (`mult` required for stragglers only),
    /// * `"checkpoint"`: `{"interval_iters", "write_gbps",
    ///   "restart_warmup_s"}` overriding [`CheckpointSpec::default`],
    /// * `"mtbf"`: `{"horizon_s", "scale"}` — materialize an MTBF
    ///   schedule over the cluster via [`mtbf_schedule`] and append it
    ///   to the explicit events,
    /// * `"seed"`: PRNG seed for the MTBF draw (defaults to
    ///   `default_seed`, which scenario files wire to their own
    ///   `"seed"` key).
    pub fn from_json(
        v: &Json,
        cluster: &ClusterSpec,
        default_seed: u64,
    ) -> anyhow::Result<FaultSpec> {
        anyhow::ensure!(
            v.get("events").is_some() || v.get("mtbf").is_some() || v.get("checkpoint").is_some(),
            "faults: expected at least one of `events`, `mtbf`, `checkpoint`"
        );
        let seed = strict_u64(v, "seed", default_seed)?;
        let mut checkpoint = CheckpointSpec::default();
        if let Some(c) = v.get("checkpoint") {
            checkpoint.interval_iters = strict_u64(c, "interval_iters", checkpoint.interval_iters)?;
            checkpoint.write_gbps = strict_f64(c, "write_gbps", checkpoint.write_gbps)?;
            checkpoint.restart_warmup_s =
                strict_f64(c, "restart_warmup_s", checkpoint.restart_warmup_s)?;
        }
        let mut events = Vec::new();
        if let Some(arr) = v.get("events") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("faults: `events` must be an array"))?;
            for (i, e) in arr.iter().enumerate() {
                let at_s = e
                    .req_f64("at_s")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?;
                let kind_name = e
                    .req_str("kind")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?;
                let node = e
                    .req_u64("node")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?
                    as u32;
                let kind = match kind_name {
                    "node_fail" => FaultKind::NodeFail { node },
                    "nic_fail" => FaultKind::NicFail { node },
                    "link_fail" => FaultKind::LinkFail { node },
                    "straggler" => {
                        let mult = e.req_f64("mult").map_err(|err| {
                            anyhow::anyhow!("faults: events[{i}] (straggler): {err}")
                        })?;
                        FaultKind::Straggler { node, mult }
                    }
                    other => anyhow::bail!(
                        "faults: events[{i}]: unknown kind {other:?} (want node_fail, \
                         nic_fail, link_fail or straggler)"
                    ),
                };
                events.push(FaultEvent { at_s, kind });
            }
        }
        if let Some(m) = v.get("mtbf") {
            let horizon_s = m
                .req_f64("horizon_s")
                .map_err(|err| anyhow::anyhow!("faults: mtbf: {err}"))?;
            anyhow::ensure!(
                horizon_s.is_finite() && horizon_s > 0.0,
                "faults: mtbf horizon_s must be a positive number of seconds"
            );
            let scale = strict_f64(m, "scale", 1.0)?;
            anyhow::ensure!(
                scale.is_finite() && scale >= 0.0,
                "faults: mtbf scale must be a finite non-negative number"
            );
            events.extend(mtbf_schedule(cluster, horizon_s, scale, seed));
        }
        let mut spec = FaultSpec { events, checkpoint, seed };
        spec.normalize();
        spec.validate(cluster)?;
        Ok(spec)
    }

    /// Resolve the spec against one iteration window starting
    /// `window_start_s` seconds into training (the scheduler simulates
    /// a single iteration; 0.0 for stand-alone runs).
    ///
    /// * Stragglers that struck **at or before** the window start slow
    ///   their node's ranks for the whole iteration.
    /// * The earliest fail-stop **at or after** the window start aborts
    ///   the iteration at its offset into the window — unless the
    ///   iteration finishes first, in which case nothing happens.
    pub fn resolve_iteration(
        &self,
        cluster: &ClusterSpec,
        window_start_s: f64,
    ) -> IterationFaults {
        let mut slow = vec![1.0f64; cluster.total_gpus() as usize];
        let starts = cluster.node_starts();
        let mut abort: Option<(Time, u32)> = None;
        for ev in &self.events {
            match ev.kind {
                FaultKind::Straggler { node, mult } => {
                    if ev.at_s <= window_start_s {
                        let lo = starts[node as usize] as usize;
                        let hi = lo + cluster.node(node).gpus_per_node as usize;
                        for m in &mut slow[lo..hi] {
                            *m = m.max(mult);
                        }
                    }
                }
                kind => {
                    if ev.at_s >= window_start_s {
                        let off = Time::from_secs(ev.at_s - window_start_s);
                        let earlier = match abort {
                            None => true,
                            Some((t, _)) => off < t,
                        };
                        if earlier {
                            abort = Some((off, kind.node()));
                        }
                    }
                }
            }
        }
        IterationFaults { abort, slow }
    }
}

/// A [`FaultSpec`] resolved against one iteration window: what the
/// scheduler actually consumes.
#[derive(Debug, Clone)]
pub struct IterationFaults {
    /// Earliest fail-stop in the window: abort the iteration at this
    /// offset (simulated time), attributing the fault to this node.
    pub abort: Option<(Time, u32)>,
    /// Per-rank compute-duration multiplier (1.0 = healthy).
    pub slow: Vec<f64>,
}

impl IterationFaults {
    /// True when this resolution changes nothing (no abort, all
    /// multipliers 1.0) — callers may skip the fault path entirely.
    pub fn is_noop(&self) -> bool {
        self.abort.is_none() && self.slow.iter().all(|m| *m == 1.0)
    }
}

/// What a fault did to one simulated iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultReport {
    /// Simulated time at which the iteration aborted.
    pub at: Time,
    /// The node the fault was attributed to.
    pub node: u32,
    /// Work charged as lost: the whole partial iteration (gradient
    /// state does not survive a fail-stop; recovery resumes from the
    /// last checkpoint, which the goodput walk accounts separately).
    pub lost_work: Time,
}

/// Synthetic per-node MTBF in hours by GPU architecture. The source
/// paper does not publish MTBF numbers; these are order-of-magnitude
/// values consistent with published large-cluster studies (per-node
/// interruption every few weeks at the ~1000-node scale), trending
/// better for newer platforms. They parameterize *relative* resilience
/// comparisons — absolute goodput should be read with the table's
/// synthetic nature in mind.
pub fn mtbf_hours(arch: &str) -> f64 {
    match arch {
        "V100" => 600.0,
        "A100" => 800.0,
        "H100" => 1000.0,
        "B200" => 1200.0,
        _ => 800.0,
    }
}

/// Failure-rate scales above this are clamped: the thinning construction
/// draws candidate events at `SCALE_CAP / MTBF` and keeps each with
/// probability `scale / SCALE_CAP`, which makes any lower-scale schedule
/// an exact subset of any higher-scale one (same seed) — the property
/// that makes goodput provably monotone in the failure rate.
pub const SCALE_CAP: f64 = 16.0;

/// Materialize a deterministic fault schedule from the per-arch MTBF
/// table: for each node, a Poisson process at `scale / MTBF(arch)`
/// events per second over `[0, horizon_s]`, with kind mix 25%
/// straggler (×1.2–2.0), 25% node loss, 25% NIC, 25% link.
///
/// Determinism and monotonicity: each node forks its own PRNG stream
/// from `seed`, candidate events are drawn at the [`SCALE_CAP`] rate
/// with *all* attributes (time, kind, multiplier, keep-coin) drawn
/// before thinning, and an event survives iff
/// `keep · SCALE_CAP < scale`. Raising `scale` therefore only ever
/// *adds* events; it never moves or removes one.
pub fn mtbf_schedule(
    cluster: &ClusterSpec,
    horizon_s: f64,
    scale: f64,
    seed: u64,
) -> Vec<FaultEvent> {
    let mut root = Rng::new(seed);
    let scale = scale.clamp(0.0, SCALE_CAP);
    let mut events = Vec::new();
    for (i, node) in cluster.nodes.iter().enumerate() {
        let mut rng = root.fork(i as u64);
        let cap_rate = SCALE_CAP / (mtbf_hours(&node.gpu.name) * 3600.0);
        let mut t = 0.0f64;
        loop {
            let u = 1.0 - rng.f64(); // (0, 1]: ln is finite
            t += -u.ln() / cap_rate;
            if t > horizon_s {
                break;
            }
            // draw every attribute before thinning (see monotonicity note)
            let u_kind = rng.f64();
            let u_mult = rng.f64();
            let keep = rng.f64() * SCALE_CAP < scale;
            if !keep {
                continue;
            }
            let node = i as u32;
            let kind = if u_kind < 0.25 {
                FaultKind::Straggler { node, mult: 1.2 + 0.8 * u_mult }
            } else if u_kind < 0.50 {
                FaultKind::NodeFail { node }
            } else if u_kind < 0.75 {
                FaultKind::NicFail { node }
            } else {
                FaultKind::LinkFail { node }
            };
            events.push(FaultEvent { at_s: t, kind });
        }
    }
    events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn mtbf_schedule_is_deterministic() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        let a = mtbf_schedule(&c, 1e6, 4.0, 7);
        let b = mtbf_schedule(&c, 1e6, 4.0, 7);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "1e6s over 4 nodes at 4x should produce events");
        // sorted by time
        for w in a.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
        // a different seed moves the schedule
        assert_ne!(a, mtbf_schedule(&c, 1e6, 4.0, 8));
    }

    #[test]
    fn mtbf_schedules_nest_across_scales() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        let lo = mtbf_schedule(&c, 2e6, 1.0, 11);
        let hi = mtbf_schedule(&c, 2e6, 8.0, 11);
        assert!(hi.len() >= lo.len());
        for ev in &lo {
            assert!(hi.contains(ev), "low-scale event {ev:?} missing at high scale");
        }
        // zero scale keeps nothing
        assert!(mtbf_schedule(&c, 2e6, 0.0, 11).is_empty());
    }

    #[test]
    fn resolve_iteration_picks_earliest_fail_stop_and_active_stragglers() {
        let c = presets::cluster_hetero(1, 1).unwrap(); // 2 nodes x 8
        let spec = FaultSpec {
            events: vec![
                FaultEvent { at_s: 0.0, kind: FaultKind::Straggler { node: 1, mult: 1.5 } },
                FaultEvent { at_s: 9.0, kind: FaultKind::NicFail { node: 0 } },
                FaultEvent { at_s: 3.0, kind: FaultKind::NodeFail { node: 1 } },
                // already in the past relative to any window >= 0
                FaultEvent { at_s: 5.0, kind: FaultKind::Straggler { node: 0, mult: 2.0 } },
            ],
            ..Default::default()
        };
        spec.validate(&c).unwrap();
        let r = spec.resolve_iteration(&c, 0.0);
        let (at, node) = r.abort.unwrap();
        assert_eq!((at, node), (Time::from_secs(3.0), 1));
        assert!(r.slow[..8].iter().all(|m| *m == 1.0)); // node-0 straggler is in the future
        assert!(r.slow[8..].iter().all(|m| *m == 1.5));
        assert!(!r.is_noop());
        // later window: node-0 straggler now active, NIC fault is next
        let r = spec.resolve_iteration(&c, 6.0);
        assert_eq!(r.abort.unwrap(), (Time::from_secs(3.0), 0));
        assert!(r.slow[..8].iter().all(|m| *m == 2.0));
        // empty spec is a no-op
        assert!(FaultSpec::default().resolve_iteration(&c, 0.0).is_noop());
    }

    #[test]
    fn validate_rejects_hostile_specs() {
        let c = presets::cluster("hopper", 1).unwrap();
        let bad_node = FaultSpec {
            events: vec![FaultEvent { at_s: 0.0, kind: FaultKind::NodeFail { node: 5 } }],
            ..Default::default()
        };
        assert!(bad_node.validate(&c).unwrap_err().to_string().contains("node 5"));
        let bad_mult = FaultSpec {
            events: vec![FaultEvent {
                at_s: 0.0,
                kind: FaultKind::Straggler { node: 0, mult: 0.5 },
            }],
            ..Default::default()
        };
        assert!(bad_mult.validate(&c).unwrap_err().to_string().contains("multiplier"));
        let bad_time = FaultSpec {
            events: vec![FaultEvent { at_s: f64::NAN, kind: FaultKind::NicFail { node: 0 } }],
            ..Default::default()
        };
        assert!(bad_time.validate(&c).is_err());
    }

    #[test]
    fn from_json_parses_and_rejects() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let v = Json::parse(
            r#"{"events": [{"at_s": 2.5, "kind": "straggler", "node": 1, "mult": 1.4},
                           {"at_s": 1.0, "kind": "node_fail", "node": 0}],
                "checkpoint": {"interval_iters": 8, "write_gbps": 4.0}}"#,
        )
        .unwrap();
        let spec = FaultSpec::from_json(&v, &c, 42).unwrap();
        assert_eq!(spec.events.len(), 2);
        assert_eq!(spec.events[0].at_s, 1.0); // normalized order
        assert_eq!(spec.checkpoint.interval_iters, 8);
        assert_eq!(spec.checkpoint.restart_warmup_s, 60.0); // default kept
        assert_eq!(spec.seed, 42);
        assert!(!spec.fingerprint().is_empty());

        for (text, needle) in [
            (r#"{}"#, "at least one"),
            (r#"{"events": 3}"#, "array"),
            (r#"{"events": [{"at_s": 1.0, "kind": "fire", "node": 0}]}"#, "unknown kind"),
            (r#"{"events": [{"kind": "node_fail", "node": 0}]}"#, "at_s"),
            (r#"{"events": [{"at_s": 1.0, "kind": "straggler", "node": 0}]}"#, "mult"),
            (r#"{"events": [], "mtbf": {"scale": 2.0}}"#, "horizon_s"),
            (r#"{"events": [], "checkpoint": {"interval_iters": "x"}}"#, "unsigned int"),
        ] {
            let v = Json::parse(text).unwrap();
            let err = FaultSpec::from_json(&v, &c, 42).unwrap_err().to_string();
            assert!(err.contains(needle), "{text} -> {err}");
        }
    }

    #[test]
    fn fingerprint_distinguishes_specs_and_vanishes_when_empty() {
        assert_eq!(FaultSpec::default().fingerprint(), "");
        let a = FaultSpec {
            events: vec![FaultEvent { at_s: 1.0, kind: FaultKind::NodeFail { node: 0 } }],
            ..Default::default()
        };
        let mut b = a.clone();
        b.events[0].at_s = 2.0;
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert!(a.fingerprint().starts_with("|faults:"));
    }
}
