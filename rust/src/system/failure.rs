//! Deterministic fault injection (DESIGN.md §26, §28): scheduled node /
//! NIC / link failures, straggler slow-downs, MTBF-driven schedules,
//! correlated failure domains, and the degraded-bandwidth model behind
//! link rerouting.
//!
//! A [`FaultSpec`] is a *plan input*, not a random process at run time:
//! every fault is an explicit `(time, kind)` pair, either written out in
//! scenario JSON (`"faults"` key) or materialized up front from a
//! per-architecture MTBF table by [`mtbf_schedule`] — or from a
//! correlated per-rack domain process by [`domain_schedule`] — using the
//! in-tree seeded PRNG. Once the spec exists, the simulation is exactly
//! as deterministic as the fault-free path: the scheduler only ever
//! reads the resolved [`IterationFaults`], which is a pure function of
//! the spec and the cluster.
//!
//! Fault severity is graded (§28):
//!
//! * [`FaultKind::NodeFail`] is permanent — fail-stop for the in-flight
//!   iteration, then the surviving cluster is re-planned.
//! * [`FaultKind::NicFail`] / [`FaultKind::LinkFail`] are *repairable*:
//!   the strike still wedges the in-flight iteration (in-flight
//!   collectives die), but the job resumes from device memory — no
//!   checkpoint restore — and runs **degraded** until the repair
//!   completes, rerouting flows around the dead links
//!   ([`crate::network::routing::route_avoiding`]). Only when no route
//!   survives (single-rail nodes, single-spine fabrics) does the fault
//!   escalate to a fail-stop.
//! * [`FaultKind::Straggler`] keeps the node running but multiplies its
//!   compute durations.
//!
//! The checkpoint/restore cost model and the goodput walk that consumes
//! these events live in [`crate::report::goodput`].

use crate::config::cluster::{ClusterSpec, FabricSpec};
use crate::network::routing::route_avoiding;
use crate::network::topology::{LinkId, Topology};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::units::Time;

/// What fails (or slows down). All kinds name a *node*: the paper's
/// failure domains are node-granular (a GPU, its NIC, and its NVLink
/// island share fate for scheduling purposes), and correlated rack /
/// leaf domains expand to per-node events ([`domain_schedule`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The node is lost permanently (kernel panic, hardware retirement).
    /// Fail-stop for the in-flight iteration; on top of the restart
    /// cost, the surviving cluster is re-planned
    /// ([`crate::report::goodput`] splices the new plan's per-iteration
    /// cost).
    NodeFail {
        /// Cluster node index of the failed node.
        node: u32,
    },
    /// The node's NIC dies. The in-flight iteration wedges, then the
    /// node runs degraded through its surviving NICs (NVLink detours to
    /// sibling rails) until the NIC is swapped
    /// ([`RepairSpec::nic_s`]).
    NicFail {
        /// Cluster node index owning the failed NIC.
        node: u32,
    },
    /// An inter-node cable attached to the node dies (rail uplink, or
    /// one leaf→spine uplink on leaf/spine fabrics). The in-flight
    /// iteration wedges, then traffic reroutes around the cable until
    /// it is re-seated ([`RepairSpec::link_s`]).
    LinkFail {
        /// Cluster node index at the failing link's endpoint.
        node: u32,
    },
    /// The node keeps running, `mult`× slower (thermal throttling, a
    /// sick HBM stack). Applies to every compute op on the node's ranks
    /// from the fault time onward.
    Straggler {
        /// Cluster node index of the slow node.
        node: u32,
        /// Compute-duration multiplier, ≥ 1.0.
        mult: f64,
    },
}

/// Severity class of a fail-stop-capable fault: what hardware is gone
/// and therefore which recovery path applies (replan vs. reroute).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Permanent node loss — checkpoint restore plus survivor replan.
    Node,
    /// Repairable NIC loss — degraded rerouting through sibling NICs.
    Nic,
    /// Repairable cable loss — degraded rerouting around the cable.
    Link,
}

impl FaultClass {
    /// Short stable label (report output, JSON-adjacent surfaces).
    pub fn name(&self) -> &'static str {
        match self {
            FaultClass::Node => "node_fail",
            FaultClass::Nic => "nic_fail",
            FaultClass::Link => "link_fail",
        }
    }
}

impl FaultKind {
    /// The node index this fault applies to.
    pub fn node(&self) -> u32 {
        match *self {
            FaultKind::NodeFail { node }
            | FaultKind::NicFail { node }
            | FaultKind::LinkFail { node }
            | FaultKind::Straggler { node, .. } => node,
        }
    }

    /// Short stable name (JSON `kind` value / report label).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::NodeFail { .. } => "node_fail",
            FaultKind::NicFail { .. } => "nic_fail",
            FaultKind::LinkFail { .. } => "link_fail",
            FaultKind::Straggler { .. } => "straggler",
        }
    }

    /// True for the kinds that can abort the in-flight iteration.
    pub fn is_fail_stop(&self) -> bool {
        !matches!(self, FaultKind::Straggler { .. })
    }

    /// The severity class, `None` for stragglers (which never stop
    /// anything).
    pub fn class(&self) -> Option<FaultClass> {
        match self {
            FaultKind::NodeFail { .. } => Some(FaultClass::Node),
            FaultKind::NicFail { .. } => Some(FaultClass::Nic),
            FaultKind::LinkFail { .. } => Some(FaultClass::Link),
            FaultKind::Straggler { .. } => None,
        }
    }

    fn canon(&self) -> String {
        match *self {
            FaultKind::Straggler { node, mult } => format!("straggler:{node}:{mult}"),
            k => format!("{}:{}", k.name(), k.node()),
        }
    }
}

/// One scheduled fault: `kind` strikes `at_s` seconds into training.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Wall-clock offset from training start, in seconds.
    pub at_s: f64,
    /// What happens.
    pub kind: FaultKind,
}

/// Checkpoint/restore cost model. Checkpoint bytes are
/// `param_count × (dtype_bytes + 12)` — weights plus fp32 Adam moments
/// and master copy — sharded across the plan's DP writers, so write
/// time is `bytes / (write_gbps · 1e9 · dp)`. Restore reads the same
/// bytes at the same bandwidth; `restart_warmup_s` adds the fixed
/// rendezvous / JIT / pipeline-refill cost after every restart.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointSpec {
    /// Iterations between checkpoints (amortized write cost, and the
    /// expected half-interval of work lost per fail-stop).
    pub interval_iters: u64,
    /// Per-DP-writer storage bandwidth in GB/s (decimal).
    pub write_gbps: f64,
    /// Fixed restart overhead in seconds (rendezvous, load, warmup).
    pub restart_warmup_s: f64,
}

impl Default for CheckpointSpec {
    fn default() -> Self {
        CheckpointSpec { interval_iters: 32, write_gbps: 10.0, restart_warmup_s: 60.0 }
    }
}

/// Mean repair times for the repairable fault classes. A NIC swap is a
/// technician visit; a cable re-seat is faster. [`FaultClass::Node`]
/// has no repair window — node losses are permanent within a run's
/// horizon (the survivor replan owns that path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RepairSpec {
    /// Seconds to replace a failed NIC.
    pub nic_s: f64,
    /// Seconds to re-seat / replace a failed cable.
    pub link_s: f64,
}

impl Default for RepairSpec {
    fn default() -> Self {
        RepairSpec { nic_s: 600.0, link_s: 300.0 }
    }
}

impl RepairSpec {
    /// Repair window in seconds for a fault class (infinite for
    /// permanent node losses).
    pub fn for_class(&self, class: FaultClass) -> f64 {
        match class {
            FaultClass::Node => f64::INFINITY,
            FaultClass::Nic => self.nic_s,
            FaultClass::Link => self.link_s,
        }
    }
}

/// A correlated failure-domain process: racks of `rack_size` consecutive
/// nodes share a blast domain (PDU, top-of-rack/leaf switch), and one
/// domain event takes the whole rack down at once
/// ([`domain_schedule`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DomainSpec {
    /// Consecutive nodes per failure domain (≥ 1; the last rack may be
    /// smaller when the node count is not a multiple).
    pub rack_size: u32,
    /// Per-domain MTBF in hours (PDU / top-of-rack switch class
    /// hardware, not the per-node GPU table).
    pub mtbf_hours: f64,
    /// Seconds of training over which domain events are drawn.
    pub horizon_s: f64,
    /// Failure-rate multiplier with the same [`SCALE_CAP`]-thinning
    /// nesting guarantee as [`mtbf_schedule`].
    pub scale: f64,
}

/// Node → failure-domain membership, derived from the cluster layout:
/// consecutive `rack_size`-node chunks in deployment order. On
/// leaf/spine fabrics each node owns its leaf, so a rack is the natural
/// shared-PDU / shared-pod blast domain above it; the degraded-routing
/// side of correlated analysis (which fabric paths survive) lives in
/// [`DegradedModel`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailureDomains {
    /// Member node indices per domain, ascending within each domain.
    pub members: Vec<Vec<u32>>,
}

impl FailureDomains {
    /// Chunk the cluster's nodes into consecutive `rack_size` domains
    /// (`rack_size` is clamped to ≥ 1).
    pub fn derive(cluster: &ClusterSpec, rack_size: u32) -> FailureDomains {
        let rack = rack_size.max(1) as usize;
        let nodes: Vec<u32> = (0..cluster.nodes.len() as u32).collect();
        FailureDomains { members: nodes.chunks(rack).map(|c| c.to_vec()).collect() }
    }
}

/// A complete, deterministic fault plan: explicit events plus the
/// checkpoint and repair cost models, the correlated-domain process (if
/// any, already materialized into `events`), the Monte-Carlo trajectory
/// count, and the seed any schedule materialization used. An empty spec
/// (no events) is defined to be byte-identical to not configuring
/// faults at all — the builder normalizes it away.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Scheduled faults, sorted by `at_s` ([`FaultSpec::normalize`]).
    pub events: Vec<FaultEvent>,
    /// Checkpoint/restore cost model for goodput accounting.
    pub checkpoint: CheckpointSpec,
    /// Repair-time model for the repairable fault classes.
    pub repair: RepairSpec,
    /// The correlated-domain process these events were drawn from
    /// (provenance; `from_json` materializes it into `events`).
    pub domains: Option<DomainSpec>,
    /// Monte-Carlo goodput trajectories requested by the scenario
    /// (`faults.monte_carlo`); 0 or 1 = single-trajectory analysis.
    pub monte_carlo: u32,
    /// Seed recorded for provenance (MTBF/domain schedules derive from
    /// it, and Monte-Carlo trajectory seeds fan out from it).
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            events: Vec::new(),
            checkpoint: CheckpointSpec::default(),
            repair: RepairSpec::default(),
            domains: None,
            monte_carlo: 0,
            seed: 42,
        }
    }
}

fn strict_f64(v: &Json, key: &str, default: f64) -> anyhow::Result<f64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("faults: `{key}` must be a number")),
    }
}

fn strict_u64(v: &Json, key: &str, default: u64) -> anyhow::Result<u64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => {
            x.as_u64().ok_or_else(|| anyhow::anyhow!("faults: `{key}` must be an unsigned int"))
        }
    }
}

impl FaultSpec {
    /// True when the spec injects nothing (and is therefore
    /// indistinguishable from no spec at all).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Sort events by time (stable — equal-time events keep their
    /// declaration order).
    pub fn normalize(&mut self) {
        self.events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    }

    /// Check the spec against a cluster: node indices in range, finite
    /// non-negative times, straggler multipliers ≥ 1, no duplicate
    /// `(at_s, node)` events, and no overlapping repair windows on one
    /// node (either would silently double-charge lost work).
    pub fn validate(&self, cluster: &ClusterSpec) -> anyhow::Result<()> {
        let nodes = cluster.nodes.len() as u32;
        for ev in &self.events {
            anyhow::ensure!(
                ev.at_s.is_finite() && ev.at_s >= 0.0,
                "fault time {} is not a finite non-negative number of seconds",
                ev.at_s
            );
            anyhow::ensure!(
                ev.kind.node() < nodes,
                "fault names node {} but cluster {} has {} nodes",
                ev.kind.node(),
                cluster.name,
                nodes
            );
            if let FaultKind::Straggler { mult, .. } = ev.kind {
                anyhow::ensure!(
                    mult.is_finite() && mult >= 1.0,
                    "straggler multiplier {mult} must be a finite number >= 1"
                );
            }
        }
        // duplicate (at_s, node) pairs double-charge lost work
        let mut seen: Vec<(u64, u32)> =
            self.events.iter().map(|ev| (ev.at_s.to_bits(), ev.kind.node())).collect();
        seen.sort_unstable();
        for w in seen.windows(2) {
            anyhow::ensure!(
                w[0] != w[1],
                "duplicate fault events on node {} at t={}s",
                w[0].1,
                f64::from_bits(w[0].0)
            );
        }
        // overlapping repair windows on one node double-charge degraded
        // time (a rack-correlated schedule never trips this: its
        // simultaneous events hit *distinct* nodes)
        let mut windows: Vec<(u32, f64, f64)> = self
            .events
            .iter()
            .filter_map(|ev| match ev.kind.class() {
                Some(c @ (FaultClass::Nic | FaultClass::Link)) => {
                    Some((ev.kind.node(), ev.at_s, ev.at_s + self.repair.for_class(c)))
                }
                _ => None,
            })
            .collect();
        windows.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for w in windows.windows(2) {
            let ((n0, _, end0), (n1, start1, _)) = (w[0], w[1]);
            anyhow::ensure!(
                n0 != n1 || *start1 >= *end0,
                "overlapping repair windows on node {n0}: a fault at t={start1}s strikes \
                 before the previous repair finishes at t={end0}s"
            );
        }
        anyhow::ensure!(
            self.checkpoint.interval_iters > 0,
            "checkpoint interval_iters must be >= 1"
        );
        anyhow::ensure!(
            self.checkpoint.write_gbps.is_finite() && self.checkpoint.write_gbps > 0.0,
            "checkpoint write_gbps must be a positive number"
        );
        anyhow::ensure!(
            self.checkpoint.restart_warmup_s.is_finite() && self.checkpoint.restart_warmup_s >= 0.0,
            "checkpoint restart_warmup_s must be a non-negative number"
        );
        for (label, v) in [("nic_s", self.repair.nic_s), ("link_s", self.repair.link_s)] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "repair {label} must be a finite non-negative number of seconds"
            );
        }
        if let Some(d) = &self.domains {
            anyhow::ensure!(d.rack_size >= 1, "faults: domains rack_size must be >= 1");
            anyhow::ensure!(
                d.mtbf_hours.is_finite() && d.mtbf_hours > 0.0,
                "faults: domains mtbf_hours must be a positive number"
            );
            anyhow::ensure!(
                d.horizon_s.is_finite() && d.horizon_s > 0.0,
                "faults: domains horizon_s must be a positive number of seconds"
            );
            anyhow::ensure!(
                d.scale.is_finite() && d.scale >= 0.0,
                "faults: domains scale must be a finite non-negative number"
            );
        }
        anyhow::ensure!(
            self.monte_carlo <= 4096,
            "faults: monte_carlo trajectories must be <= 4096"
        );
        Ok(())
    }

    /// Stable cache-key marker for this spec: the empty string when the
    /// spec is empty (the fault layer is invisible when off), otherwise
    /// a `|faults:<hash>` suffix appended to the simulator's eval keys
    /// so faulted and fault-free scores never alias.
    pub fn fingerprint(&self) -> String {
        if self.is_empty() {
            return String::new();
        }
        let mut s = format!(
            "s{};i{};w{};r{};rn{};rl{};mc{}",
            self.seed,
            self.checkpoint.interval_iters,
            self.checkpoint.write_gbps,
            self.checkpoint.restart_warmup_s,
            self.repair.nic_s,
            self.repair.link_s,
            self.monte_carlo
        );
        if let Some(d) = &self.domains {
            s.push_str(&format!(
                ";dom{}:{}:{}:{}",
                d.rack_size, d.mtbf_hours, d.horizon_s, d.scale
            ));
        }
        for ev in &self.events {
            s.push(';');
            s.push_str(&ev.kind.canon());
            s.push('@');
            s.push_str(&ev.at_s.to_string());
        }
        // FNV-1a over the canonical serialization
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in s.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("|faults:{h:016x}")
    }

    /// Parse a `"faults"` JSON object (scenario key or `--faults` file).
    ///
    /// Recognized keys — all optional, but present-and-malformed is an
    /// error, never a silent default:
    ///
    /// * `"events"`: array of `{"at_s": …, "kind": "node_fail" |
    ///   "nic_fail" | "link_fail" | "straggler", "node": …,
    ///   "mult": …}` (`mult` required for stragglers only),
    /// * `"checkpoint"`: `{"interval_iters", "write_gbps",
    ///   "restart_warmup_s"}` overriding [`CheckpointSpec::default`],
    /// * `"repair"`: `{"nic_s", "link_s"}` overriding
    ///   [`RepairSpec::default`] — the degraded windows NIC/link faults
    ///   run under before full bandwidth returns,
    /// * `"mtbf"`: `{"horizon_s", "scale"}` — materialize a per-node
    ///   MTBF schedule over the cluster via [`mtbf_schedule`] and
    ///   append it to the explicit events,
    /// * `"domains"`: `{"rack_size", "horizon_s", "mtbf_hours",
    ///   "scale"}` — materialize a *correlated* rack-level schedule via
    ///   [`domain_schedule`]: one domain event fails every node of the
    ///   rack at the same instant,
    /// * `"monte_carlo"`: `{"trajectories"}` — how many seeded fault
    ///   trajectories goodput analysis should average over
    ///   ([`crate::report::goodput::monte_carlo`]),
    /// * `"seed"`: PRNG seed for the schedule draws (defaults to
    ///   `default_seed`, which scenario files wire to their own
    ///   `"seed"` key).
    pub fn from_json(
        v: &Json,
        cluster: &ClusterSpec,
        default_seed: u64,
    ) -> anyhow::Result<FaultSpec> {
        anyhow::ensure!(
            ["events", "mtbf", "checkpoint", "repair", "domains", "monte_carlo"]
                .iter()
                .any(|k| v.get(k).is_some()),
            "faults: expected at least one of `events`, `mtbf`, `checkpoint`, `repair`, \
             `domains`, `monte_carlo`"
        );
        let seed = strict_u64(v, "seed", default_seed)?;
        let mut checkpoint = CheckpointSpec::default();
        if let Some(c) = v.get("checkpoint") {
            checkpoint.interval_iters = strict_u64(c, "interval_iters", checkpoint.interval_iters)?;
            checkpoint.write_gbps = strict_f64(c, "write_gbps", checkpoint.write_gbps)?;
            checkpoint.restart_warmup_s =
                strict_f64(c, "restart_warmup_s", checkpoint.restart_warmup_s)?;
        }
        let mut repair = RepairSpec::default();
        if let Some(r) = v.get("repair") {
            repair.nic_s = strict_f64(r, "nic_s", repair.nic_s)?;
            repair.link_s = strict_f64(r, "link_s", repair.link_s)?;
        }
        let monte_carlo = match v.get("monte_carlo") {
            None => 0,
            Some(m) => m
                .req_u64("trajectories")
                .map_err(|err| anyhow::anyhow!("faults: monte_carlo: {err}"))?
                as u32,
        };
        let mut events = Vec::new();
        if let Some(arr) = v.get("events") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("faults: `events` must be an array"))?;
            for (i, e) in arr.iter().enumerate() {
                let at_s = e
                    .req_f64("at_s")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?;
                let kind_name = e
                    .req_str("kind")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?;
                let node = e
                    .req_u64("node")
                    .map_err(|err| anyhow::anyhow!("faults: events[{i}]: {err}"))?
                    as u32;
                let kind = match kind_name {
                    "node_fail" => FaultKind::NodeFail { node },
                    "nic_fail" => FaultKind::NicFail { node },
                    "link_fail" => FaultKind::LinkFail { node },
                    "straggler" => {
                        let mult = e.req_f64("mult").map_err(|err| {
                            anyhow::anyhow!("faults: events[{i}] (straggler): {err}")
                        })?;
                        FaultKind::Straggler { node, mult }
                    }
                    other => anyhow::bail!(
                        "faults: events[{i}]: unknown kind {other:?} (want node_fail, \
                         nic_fail, link_fail or straggler)"
                    ),
                };
                events.push(FaultEvent { at_s, kind });
            }
        }
        if let Some(m) = v.get("mtbf") {
            let horizon_s = m
                .req_f64("horizon_s")
                .map_err(|err| anyhow::anyhow!("faults: mtbf: {err}"))?;
            anyhow::ensure!(
                horizon_s.is_finite() && horizon_s > 0.0,
                "faults: mtbf horizon_s must be a positive number of seconds"
            );
            let scale = strict_f64(m, "scale", 1.0)?;
            anyhow::ensure!(
                scale.is_finite() && scale >= 0.0,
                "faults: mtbf scale must be a finite non-negative number"
            );
            events.extend(mtbf_schedule(cluster, horizon_s, scale, seed));
        }
        let mut domains = None;
        if let Some(d) = v.get("domains") {
            let spec = DomainSpec {
                rack_size: d
                    .req_u64("rack_size")
                    .map_err(|err| anyhow::anyhow!("faults: domains: {err}"))?
                    as u32,
                mtbf_hours: strict_f64(d, "mtbf_hours", 4380.0)?,
                horizon_s: d
                    .req_f64("horizon_s")
                    .map_err(|err| anyhow::anyhow!("faults: domains: {err}"))?,
                scale: strict_f64(d, "scale", 1.0)?,
            };
            let racks = FailureDomains::derive(cluster, spec.rack_size);
            events.extend(domain_schedule(
                cluster,
                &racks,
                spec.horizon_s,
                spec.mtbf_hours,
                spec.scale,
                seed,
            ));
            domains = Some(spec);
        }
        let mut spec = FaultSpec { events, checkpoint, repair, domains, monte_carlo, seed };
        spec.normalize();
        spec.validate(cluster)?;
        Ok(spec)
    }

    /// Resolve the spec against one iteration window starting
    /// `window_start_s` seconds into training (the scheduler simulates
    /// a single iteration; 0.0 for stand-alone runs).
    ///
    /// * Stragglers that struck **at or before** the window start slow
    ///   their node's ranks for the whole iteration.
    /// * NIC/link faults whose repair window covers the window start
    ///   mark their node *degraded*: the scheduler kills the faulted
    ///   links and reroutes around them ([`faulted_links`]).
    /// * The earliest fail-stop striking **inside** the window (node
    ///   losses at or after the start; NIC/link strikes strictly after
    ///   — at exactly the boundary they are already-down, i.e.
    ///   degraded) aborts the iteration at its offset — unless the
    ///   iteration finishes first, in which case nothing happens.
    pub fn resolve_iteration(
        &self,
        cluster: &ClusterSpec,
        window_start_s: f64,
    ) -> IterationFaults {
        let mut slow = vec![1.0f64; cluster.total_gpus() as usize];
        let starts = cluster.node_starts();
        let mut abort: Option<(Time, u32, FaultClass)> = None;
        let mut degraded: Vec<(u32, FaultClass)> = Vec::new();
        let mut propose = |abort: &mut Option<(Time, u32, FaultClass)>,
                           at_s: f64,
                           node: u32,
                           class: FaultClass| {
            let off = Time::from_secs(at_s - window_start_s);
            if abort.map(|(t, _, _)| off < t).unwrap_or(true) {
                *abort = Some((off, node, class));
            }
        };
        for ev in &self.events {
            let node = ev.kind.node();
            match ev.kind {
                FaultKind::Straggler { node, mult } => {
                    if ev.at_s <= window_start_s {
                        let lo = starts[node as usize] as usize;
                        let hi = lo + cluster.node(node).gpus_per_node as usize;
                        for m in &mut slow[lo..hi] {
                            *m = m.max(mult);
                        }
                    }
                }
                FaultKind::NodeFail { .. } => {
                    if ev.at_s >= window_start_s {
                        propose(&mut abort, ev.at_s, node, FaultClass::Node);
                    }
                }
                FaultKind::NicFail { .. } | FaultKind::LinkFail { .. } => {
                    let class = ev.kind.class().expect("nic/link faults have a class");
                    if ev.at_s > window_start_s {
                        propose(&mut abort, ev.at_s, node, class);
                    } else if ev.at_s + self.repair.for_class(class) > window_start_s
                        && !degraded.contains(&(node, class))
                    {
                        degraded.push((node, class));
                    }
                }
            }
        }
        IterationFaults { abort, slow, degraded }
    }
}

/// A [`FaultSpec`] resolved against one iteration window: what the
/// scheduler actually consumes.
#[derive(Debug, Clone)]
pub struct IterationFaults {
    /// Earliest fail-stop in the window: abort the iteration at this
    /// offset (simulated time), attributing the fault to this node and
    /// class.
    pub abort: Option<(Time, u32, FaultClass)>,
    /// Per-rank compute-duration multiplier (1.0 = healthy).
    pub slow: Vec<f64>,
    /// Nodes inside an unexpired NIC/link repair window at the window
    /// start: the scheduler removes their faulted links
    /// ([`faulted_links`]) and runs the iteration over rerouted,
    /// degraded paths — or escalates to an immediate abort when no
    /// route survives.
    pub degraded: Vec<(u32, FaultClass)>,
}

impl IterationFaults {
    /// True when this resolution changes nothing (no abort, all
    /// multipliers 1.0, nothing degraded) — callers may skip the fault
    /// path entirely.
    pub fn is_noop(&self) -> bool {
        self.abort.is_none() && self.degraded.is_empty() && self.slow.iter().all(|m| *m == 1.0)
    }
}

/// What a fault did to one simulated iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultReport {
    /// Simulated time at which the iteration aborted.
    pub at: Time,
    /// The node the fault was attributed to.
    pub node: u32,
    /// Severity class of the triggering fault (node losses restore from
    /// checkpoint; NIC/link wedges resume from device memory).
    pub kind: FaultClass,
    /// Work charged as lost: the whole partial iteration (gradient
    /// state is gone whichever class struck; what recovery costs *next*
    /// differs by class and is the goodput walk's concern).
    pub lost_work: Time,
}

/// The directed topology links a node-scoped fault of `class` disables,
/// fabric-dispatched (DESIGN.md §28):
///
/// * `Nic` — the node's NIC 0 in its entirety: host link both ways plus
///   its fabric uplink/downlink. Survivors are the sibling NICs
///   (NVLink-detour rails).
/// * `Link` — the cable only: NIC 0's fabric uplink/downlink on
///   rail-only and single-switch fabrics; the node's leaf↔spine-0
///   uplink pair on leaf/spine (the NIC itself survives, the alternate
///   spines carry the detour).
/// * `Node` — nothing: a lost node is removed by replan, not rerouted
///   around.
pub fn faulted_links(topo: &Topology, node: u32, class: FaultClass) -> Vec<LinkId> {
    match class {
        FaultClass::Node => Vec::new(),
        FaultClass::Nic => topo.nic_links(node, 0).to_vec(),
        FaultClass::Link => match topo.fabric {
            FabricSpec::LeafSpine { .. } => topo.leaf_uplinks(node, 0).to_vec(),
            FabricSpec::RailOnly | FabricSpec::SingleSwitch => {
                let l = topo.nic_links(node, 0);
                vec![l[2], l[3]]
            }
        },
    }
}

/// Per-node degraded-bandwidth model: for each node and repairable
/// fault class, the fraction of the node's fabric bandwidth that
/// survives rerouting around the dead links — or `None` when no route
/// survives at all (single-rail nodes, single-spine fabrics) and the
/// fault escalates to a fail-stop.
///
/// Derived once per cluster from the built topology: the survivability
/// oracle is [`route_avoiding`] over [`faulted_links`], the surviving
/// fraction is `(G−1)/G` of the node's `G` NICs (NIC and cable faults
/// on NIC-per-rail fabrics) or `(S−1)/S` of the `S` spines (cable
/// faults on leaf/spine).
#[derive(Debug, Clone, PartialEq)]
pub struct DegradedModel {
    nic: Vec<Option<f64>>,
    link: Vec<Option<f64>>,
}

impl DegradedModel {
    /// Build the model for a cluster by probing degraded routes on its
    /// fabric.
    pub fn derive(cluster: &ClusterSpec) -> anyhow::Result<DegradedModel> {
        let topo = Topology::build(cluster)?;
        let nodes = cluster.nodes.len() as u32;
        let mut nic = Vec::with_capacity(nodes as usize);
        let mut link = Vec::with_capacity(nodes as usize);
        for node in 0..nodes {
            for class in [FaultClass::Nic, FaultClass::Link] {
                let frac = match (0..nodes).find(|&m| m != node) {
                    // single-node clusters have no inter-node traffic
                    None => Some(1.0),
                    Some(other) => {
                        let dead = faulted_links(&topo, node, class);
                        let a = topo.rank_of(node, 0);
                        let b = topo.rank_of(other, 0);
                        let survives = route_avoiding(&topo, a, b, &dead).is_some()
                            && route_avoiding(&topo, b, a, &dead).is_some();
                        survives.then(|| surviving_fraction(cluster, node, class))
                    }
                };
                match class {
                    FaultClass::Nic => nic.push(frac),
                    _ => link.push(frac),
                }
            }
        }
        Ok(DegradedModel { nic, link })
    }

    /// Surviving fabric-bandwidth fraction for a node under a fault
    /// class; `None` when no route survives (or for `Node`, which is
    /// never rerouted).
    pub fn bw_fraction(&self, node: u32, class: FaultClass) -> Option<f64> {
        match class {
            FaultClass::Node => None,
            FaultClass::Nic => self.nic.get(node as usize).copied().flatten(),
            FaultClass::Link => self.link.get(node as usize).copied().flatten(),
        }
    }

    /// Iteration-time multiplier while degraded: the communication
    /// share of the iteration (`comm_fraction`, 0..1) stretches by the
    /// inverse surviving-bandwidth fraction, the compute share is
    /// untouched. `None` when no route survives.
    pub fn slowdown(&self, node: u32, class: FaultClass, comm_fraction: f64) -> Option<f64> {
        let phi = self.bw_fraction(node, class)?;
        let c = comm_fraction.clamp(0.0, 1.0);
        Some(1.0 - c + c / phi.max(f64::MIN_POSITIVE))
    }
}

fn surviving_fraction(cluster: &ClusterSpec, node: u32, class: FaultClass) -> f64 {
    match (class, &cluster.fabric) {
        (FaultClass::Link, FabricSpec::LeafSpine { spines, .. }) => {
            (*spines as f64 - 1.0) / *spines as f64
        }
        _ => {
            let g = cluster.node(node).gpus_per_node as f64;
            (g - 1.0) / g
        }
    }
}

/// Synthetic per-node MTBF in hours by GPU architecture. The source
/// paper does not publish MTBF numbers; these are order-of-magnitude
/// values consistent with published large-cluster studies (per-node
/// interruption every few weeks at the ~1000-node scale), trending
/// better for newer platforms. They parameterize *relative* resilience
/// comparisons — absolute goodput should be read with the table's
/// synthetic nature in mind.
pub fn mtbf_hours(arch: &str) -> f64 {
    match arch {
        "V100" => 600.0,
        "A100" => 800.0,
        "H100" => 1000.0,
        "B200" => 1200.0,
        _ => 800.0,
    }
}

/// Failure-rate scales above this are clamped: the thinning construction
/// draws candidate events at `SCALE_CAP / MTBF` and keeps each with
/// probability `scale / SCALE_CAP`, which makes any lower-scale schedule
/// an exact subset of any higher-scale one (same seed) — the property
/// that makes goodput provably monotone in the failure rate.
pub const SCALE_CAP: f64 = 16.0;

/// Materialize a deterministic fault schedule from the per-arch MTBF
/// table: for each node, a Poisson process at `scale / MTBF(arch)`
/// events per second over `[0, horizon_s]`, with kind mix 25%
/// straggler (×1.2–2.0), 25% node loss, 25% NIC, 25% link (the NIC and
/// link quarter being repairable, degraded-mode faults).
///
/// Determinism and monotonicity: each node forks its own PRNG stream
/// from `seed`, candidate events are drawn at the [`SCALE_CAP`] rate
/// with *all* attributes (time, kind, multiplier, keep-coin) drawn
/// before thinning, and an event survives iff
/// `keep · SCALE_CAP < scale`. Raising `scale` therefore only ever
/// *adds* events; it never moves or removes one.
pub fn mtbf_schedule(
    cluster: &ClusterSpec,
    horizon_s: f64,
    scale: f64,
    seed: u64,
) -> Vec<FaultEvent> {
    let mut root = Rng::new(seed);
    let scale = scale.clamp(0.0, SCALE_CAP);
    let mut events = Vec::new();
    for (i, node) in cluster.nodes.iter().enumerate() {
        let mut rng = root.fork(i as u64);
        let cap_rate = SCALE_CAP / (mtbf_hours(&node.gpu.name) * 3600.0);
        let mut t = 0.0f64;
        loop {
            let u = 1.0 - rng.f64(); // (0, 1]: ln is finite
            t += -u.ln() / cap_rate;
            if t > horizon_s {
                break;
            }
            // draw every attribute before thinning (see monotonicity note)
            let u_kind = rng.f64();
            let u_mult = rng.f64();
            let keep = rng.f64() * SCALE_CAP < scale;
            if !keep {
                continue;
            }
            let node = i as u32;
            let kind = if u_kind < 0.25 {
                FaultKind::Straggler { node, mult: 1.2 + 0.8 * u_mult }
            } else if u_kind < 0.50 {
                FaultKind::NodeFail { node }
            } else if u_kind < 0.75 {
                FaultKind::NicFail { node }
            } else {
                FaultKind::LinkFail { node }
            };
            events.push(FaultEvent { at_s: t, kind });
        }
    }
    events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    events
}

/// Stream salt separating the correlated-domain PRNG from the per-node
/// MTBF streams drawn from the same scenario seed.
const DOMAIN_STREAM: u64 = 0x646f_6d61_696e_7321; // "domains!"

/// Materialize a deterministic *correlated* fault schedule: each
/// failure domain (rack) runs its own Poisson process at
/// `scale / mtbf_hours`, and every kept domain event expands to a
/// [`FaultKind::NodeFail`] for **every member node at the same
/// instant** — the blast radius the goodput walk coalesces into one
/// incident.
///
/// The same [`SCALE_CAP`]-thinning construction as [`mtbf_schedule`]
/// applies per domain, and expansion is all-or-nothing, so a scale-`k`
/// schedule is an exact subset of a scale-`2k` schedule at the
/// expanded-event level.
pub fn domain_schedule(
    cluster: &ClusterSpec,
    domains: &FailureDomains,
    horizon_s: f64,
    mtbf_hours: f64,
    scale: f64,
    seed: u64,
) -> Vec<FaultEvent> {
    debug_assert!(
        domains.members.iter().flatten().all(|n| (*n as usize) < cluster.nodes.len()),
        "domain membership out of cluster range"
    );
    let mut root = Rng::new(seed ^ DOMAIN_STREAM);
    let scale = scale.clamp(0.0, SCALE_CAP);
    let cap_rate = SCALE_CAP / (mtbf_hours.max(f64::MIN_POSITIVE) * 3600.0);
    let mut events = Vec::new();
    for (d, members) in domains.members.iter().enumerate() {
        let mut rng = root.fork(d as u64);
        let mut t = 0.0f64;
        loop {
            let u = 1.0 - rng.f64();
            t += -u.ln() / cap_rate;
            if t > horizon_s {
                break;
            }
            let keep = rng.f64() * SCALE_CAP < scale;
            if !keep {
                continue;
            }
            for &node in members {
                events.push(FaultEvent { at_s: t, kind: FaultKind::NodeFail { node } });
            }
        }
    }
    // sort by time, members of one domain event staying adjacent in
    // ascending node order (ties across domains are measure-zero)
    events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.kind.node().cmp(&b.kind.node())));
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn mtbf_schedule_is_deterministic() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        let a = mtbf_schedule(&c, 1e6, 4.0, 7);
        let b = mtbf_schedule(&c, 1e6, 4.0, 7);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "1e6s over 4 nodes at 4x should produce events");
        // sorted by time
        for w in a.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
        // a different seed moves the schedule
        assert_ne!(a, mtbf_schedule(&c, 1e6, 4.0, 8));
    }

    #[test]
    fn mtbf_schedules_nest_across_scales() {
        let c = presets::cluster_hetero(2, 2).unwrap();
        let lo = mtbf_schedule(&c, 2e6, 1.0, 11);
        let hi = mtbf_schedule(&c, 2e6, 8.0, 11);
        assert!(hi.len() >= lo.len());
        for ev in &lo {
            assert!(hi.contains(ev), "low-scale event {ev:?} missing at high scale");
        }
        // zero scale keeps nothing
        assert!(mtbf_schedule(&c, 2e6, 0.0, 11).is_empty());
    }

    #[test]
    fn domain_schedule_is_correlated_and_nests() {
        let c = presets::cluster_hetero(2, 2).unwrap(); // 4 nodes
        let racks = FailureDomains::derive(&c, 2);
        assert_eq!(racks.members, vec![vec![0, 1], vec![2, 3]]);
        let lo = domain_schedule(&c, &racks, 5e7, 400.0, 2.0, 13);
        let hi = domain_schedule(&c, &racks, 5e7, 400.0, 8.0, 13);
        assert_eq!(lo, domain_schedule(&c, &racks, 5e7, 400.0, 2.0, 13));
        assert!(!lo.is_empty(), "5e7s at 400h MTBF x2 should produce events");
        assert!(hi.len() > lo.len(), "want the nesting check to be non-vacuous");
        for ev in &lo {
            assert!(hi.contains(ev), "low-scale event {ev:?} missing at high scale");
        }
        // every domain event expands to the whole rack at one instant
        for sched in [&lo, &hi] {
            let mut i = 0;
            while i < sched.len() {
                let rack = racks
                    .members
                    .iter()
                    .find(|m| m.contains(&sched[i].kind.node()))
                    .expect("event node belongs to a rack");
                for (k, &member) in rack.iter().enumerate() {
                    let ev = sched[i + k];
                    assert_eq!(ev.at_s, sched[i].at_s, "blast members share the instant");
                    assert_eq!(ev.kind, FaultKind::NodeFail { node: member });
                }
                i += rack.len();
            }
        }
        // the last rack absorbs the remainder on non-multiple clusters
        let odd = FailureDomains::derive(&c, 3);
        assert_eq!(odd.members, vec![vec![0, 1, 2], vec![3]]);
    }

    #[test]
    fn resolve_iteration_picks_earliest_fail_stop_and_active_stragglers() {
        let c = presets::cluster_hetero(1, 1).unwrap(); // 2 nodes x 8
        let spec = FaultSpec {
            events: vec![
                FaultEvent { at_s: 0.0, kind: FaultKind::Straggler { node: 1, mult: 1.5 } },
                FaultEvent { at_s: 9.0, kind: FaultKind::NicFail { node: 0 } },
                FaultEvent { at_s: 3.0, kind: FaultKind::NodeFail { node: 1 } },
                // already in the past relative to any window >= 0
                FaultEvent { at_s: 5.0, kind: FaultKind::Straggler { node: 0, mult: 2.0 } },
            ],
            ..Default::default()
        };
        spec.validate(&c).unwrap();
        let r = spec.resolve_iteration(&c, 0.0);
        let (at, node, class) = r.abort.unwrap();
        assert_eq!((at, node, class), (Time::from_secs(3.0), 1, FaultClass::Node));
        assert!(r.slow[..8].iter().all(|m| *m == 1.0)); // node-0 straggler is in the future
        assert!(r.slow[8..].iter().all(|m| *m == 1.5));
        assert!(r.degraded.is_empty());
        assert!(!r.is_noop());
        // later window: node-0 straggler now active, NIC fault is next
        let r = spec.resolve_iteration(&c, 6.0);
        assert_eq!(r.abort.unwrap(), (Time::from_secs(3.0), 0, FaultClass::Nic));
        assert!(r.slow[..8].iter().all(|m| *m == 2.0));
        // window after the NIC strike but inside its repair: degraded
        let r = spec.resolve_iteration(&c, 10.0);
        assert!(r.abort.is_none());
        assert_eq!(r.degraded, vec![(0, FaultClass::Nic)]);
        assert!(!r.is_noop());
        // window past the repair: healthy again
        let r = spec.resolve_iteration(&c, 9.0 + spec.repair.nic_s + 1.0);
        assert!(r.abort.is_none() && r.degraded.is_empty());
        // empty spec is a no-op
        assert!(FaultSpec::default().resolve_iteration(&c, 0.0).is_noop());
    }

    #[test]
    fn validate_rejects_hostile_specs() {
        let c = presets::cluster("hopper", 1).unwrap();
        let bad_node = FaultSpec {
            events: vec![FaultEvent { at_s: 0.0, kind: FaultKind::NodeFail { node: 5 } }],
            ..Default::default()
        };
        assert!(bad_node.validate(&c).unwrap_err().to_string().contains("node 5"));
        let bad_mult = FaultSpec {
            events: vec![FaultEvent {
                at_s: 0.0,
                kind: FaultKind::Straggler { node: 0, mult: 0.5 },
            }],
            ..Default::default()
        };
        assert!(bad_mult.validate(&c).unwrap_err().to_string().contains("multiplier"));
        let bad_time = FaultSpec {
            events: vec![FaultEvent { at_s: f64::NAN, kind: FaultKind::NicFail { node: 0 } }],
            ..Default::default()
        };
        assert!(bad_time.validate(&c).is_err());
    }

    #[test]
    fn validate_rejects_duplicates_and_overlapping_repairs() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let dup = FaultSpec {
            events: vec![
                FaultEvent { at_s: 4.0, kind: FaultKind::NodeFail { node: 1 } },
                FaultEvent { at_s: 4.0, kind: FaultKind::NicFail { node: 1 } },
            ],
            ..Default::default()
        };
        assert!(dup.validate(&c).unwrap_err().to_string().contains("duplicate"));
        // two NIC faults on one node inside one repair window
        let overlap = FaultSpec {
            events: vec![
                FaultEvent { at_s: 0.0, kind: FaultKind::NicFail { node: 0 } },
                FaultEvent { at_s: 100.0, kind: FaultKind::LinkFail { node: 0 } },
            ],
            ..Default::default() // nic repair 600s covers t=100
        };
        assert!(overlap.validate(&c).unwrap_err().to_string().contains("overlapping"));
        // same times on distinct nodes (a rack blast) are fine
        let blast = FaultSpec {
            events: vec![
                FaultEvent { at_s: 4.0, kind: FaultKind::NodeFail { node: 0 } },
                FaultEvent { at_s: 4.0, kind: FaultKind::NodeFail { node: 1 } },
            ],
            ..Default::default()
        };
        blast.validate(&c).unwrap();
        // and sequential repairs on one node are fine
        let sequential = FaultSpec {
            events: vec![
                FaultEvent { at_s: 0.0, kind: FaultKind::LinkFail { node: 0 } },
                FaultEvent { at_s: 400.0, kind: FaultKind::LinkFail { node: 0 } },
            ],
            ..Default::default() // link repair 300s ends before t=400
        };
        sequential.validate(&c).unwrap();
        let bad_mc = FaultSpec { monte_carlo: 100_000, ..Default::default() };
        // monte_carlo bound applies even to otherwise-empty specs
        assert!(bad_mc.validate(&c).unwrap_err().to_string().contains("monte_carlo"));
    }

    #[test]
    fn from_json_parses_and_rejects() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let v = Json::parse(
            r#"{"events": [{"at_s": 2.5, "kind": "straggler", "node": 1, "mult": 1.4},
                           {"at_s": 1.0, "kind": "node_fail", "node": 0}],
                "checkpoint": {"interval_iters": 8, "write_gbps": 4.0},
                "repair": {"nic_s": 120.0},
                "monte_carlo": {"trajectories": 8}}"#,
        )
        .unwrap();
        let spec = FaultSpec::from_json(&v, &c, 42).unwrap();
        assert_eq!(spec.events.len(), 2);
        assert_eq!(spec.events[0].at_s, 1.0); // normalized order
        assert_eq!(spec.checkpoint.interval_iters, 8);
        assert_eq!(spec.checkpoint.restart_warmup_s, 60.0); // default kept
        assert_eq!(spec.repair.nic_s, 120.0);
        assert_eq!(spec.repair.link_s, 300.0); // default kept
        assert_eq!(spec.monte_carlo, 8);
        assert_eq!(spec.seed, 42);
        assert!(!spec.fingerprint().is_empty());

        // a correlated-domain draw materializes whole-rack events
        let v = Json::parse(
            r#"{"domains": {"rack_size": 1, "horizon_s": 5e7, "mtbf_hours": 400.0,
                            "scale": 2.0}}"#,
        )
        .unwrap();
        let spec = FaultSpec::from_json(&v, &c, 13).unwrap();
        assert!(!spec.events.is_empty());
        assert!(spec.events.iter().all(|ev| matches!(ev.kind, FaultKind::NodeFail { .. })));
        assert_eq!(spec.domains.unwrap().rack_size, 1);

        for (text, needle) in [
            (r#"{}"#, "at least one"),
            (r#"{"events": 3}"#, "array"),
            (r#"{"events": [{"at_s": 1.0, "kind": "fire", "node": 0}]}"#, "unknown kind"),
            (r#"{"events": [{"kind": "node_fail", "node": 0}]}"#, "at_s"),
            (r#"{"events": [{"at_s": 1.0, "kind": "straggler", "node": 0}]}"#, "mult"),
            (r#"{"events": [], "mtbf": {"scale": 2.0}}"#, "horizon_s"),
            (r#"{"events": [], "checkpoint": {"interval_iters": "x"}}"#, "unsigned int"),
            (r#"{"repair": {"nic_s": -1.0}}"#, "nic_s"),
            (r#"{"domains": {"horizon_s": 1e6}}"#, "rack_size"),
            (r#"{"monte_carlo": {"trajectories": 100000}}"#, "monte_carlo"),
        ] {
            let v = Json::parse(text).unwrap();
            let err = FaultSpec::from_json(&v, &c, 42).unwrap_err().to_string();
            assert!(err.contains(needle), "{text} -> {err}");
        }
    }

    #[test]
    fn fingerprint_distinguishes_specs_and_vanishes_when_empty() {
        assert_eq!(FaultSpec::default().fingerprint(), "");
        let a = FaultSpec {
            events: vec![FaultEvent { at_s: 1.0, kind: FaultKind::NodeFail { node: 0 } }],
            ..Default::default()
        };
        let mut b = a.clone();
        b.events[0].at_s = 2.0;
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert!(a.fingerprint().starts_with("|faults:"));
        // the repair and MC knobs are part of the key
        let mut c = a.clone();
        c.repair.link_s = 7.0;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = a.clone();
        d.monte_carlo = 4;
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn degraded_model_reflects_fabric_redundancy() {
        // 8 NICs per node on the rail fabric: NIC loss keeps 7/8 of the
        // fabric bandwidth, cable loss likewise detours over 7 rails
        let c = presets::cluster_hetero(1, 1).unwrap();
        let m = DegradedModel::derive(&c).unwrap();
        assert_eq!(m.bw_fraction(0, FaultClass::Nic), Some(7.0 / 8.0));
        assert_eq!(m.bw_fraction(1, FaultClass::Link), Some(7.0 / 8.0));
        assert_eq!(m.bw_fraction(0, FaultClass::Node), None);
        // comm-bound iterations stretch by 1/phi on the comm share
        let s = m.slowdown(0, FaultClass::Nic, 0.5).unwrap();
        assert!((s - (0.5 + 0.5 * 8.0 / 7.0)).abs() < 1e-12);
        assert_eq!(m.slowdown(0, FaultClass::Nic, 0.0), Some(1.0));

        // single-rail nodes have no detour: NIC loss is fatal
        let mut c1 = presets::cluster("ampere", 2).unwrap();
        c1.nodes[0].gpus_per_node = 1;
        c1.nodes[1].gpus_per_node = 1;
        let m1 = DegradedModel::derive(&c1).unwrap();
        assert_eq!(m1.bw_fraction(0, FaultClass::Nic), None);
        assert_eq!(m1.slowdown(0, FaultClass::Nic, 0.5), None);

        // leaf/spine: a cable fault detours via the alternate spine
        let mut c2 = presets::cluster("ampere", 2).unwrap();
        c2.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 2.0 };
        let m2 = DegradedModel::derive(&c2).unwrap();
        assert_eq!(m2.bw_fraction(0, FaultClass::Link), Some(0.5));
        // ... but a single-spine fabric has nowhere to detour to
        let mut c3 = presets::cluster("ampere", 2).unwrap();
        c3.fabric = FabricSpec::LeafSpine { spines: 1, oversubscription: 2.0 };
        let m3 = DegradedModel::derive(&c3).unwrap();
        assert_eq!(m3.bw_fraction(0, FaultClass::Link), None);
        // the NIC itself is redundant either way
        assert_eq!(m3.bw_fraction(0, FaultClass::Nic), Some(7.0 / 8.0));

        // single-node clusters have no inter-node traffic to degrade
        let c4 = presets::cluster("ampere", 1).unwrap();
        let m4 = DegradedModel::derive(&c4).unwrap();
        assert_eq!(m4.bw_fraction(0, FaultClass::Nic), Some(1.0));
    }

    #[test]
    fn faulted_links_dispatch_on_fabric() {
        let c = presets::cluster("ampere", 2).unwrap();
        let topo = Topology::build(&c).unwrap();
        assert!(faulted_links(&topo, 0, FaultClass::Node).is_empty());
        assert_eq!(faulted_links(&topo, 0, FaultClass::Nic).len(), 4);
        assert_eq!(faulted_links(&topo, 0, FaultClass::Link).len(), 2);
        let mut c2 = presets::cluster("ampere", 2).unwrap();
        c2.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 2.0 };
        let t2 = Topology::build(&c2).unwrap();
        // leaf/spine cable faults name the spine-0 uplink pair
        assert_eq!(faulted_links(&t2, 1, FaultClass::Link), t2.leaf_uplinks(1, 0).to_vec());
    }
}
