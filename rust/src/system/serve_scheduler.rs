//! Request-level serving scheduler (DESIGN.md §27): continuous
//! batching with KV-budget admission control over the per-node device
//! groups of a (possibly heterogeneous) cluster.
//!
//! # Request lifecycle
//!
//! Requests arrive on a shared queue ([`ServeSpec::materialize`] fixes
//! their **arrival index**, the global tie-breaker). Each per-node
//! group runs its own engine clock. When a group acts it first admits:
//! arrived, unadmitted requests are ordered by the policy key (`fifo`:
//! arrival index; `srpt`: total tokens then index; `wsrpt`:
//! tokens/weight then index) and admitted while the batch has a slot
//! and the request's full KV footprint (prompt + all output tokens,
//! [`Request::kv_tokens`]) fits the group's remaining budget. Reserving
//! the footprint up front means an admitted request can never be
//! evicted mid-flight — admission is the only control point, which
//! keeps the conservation invariant (`tests/properties.rs`) trivial to
//! state: every admitted request completes exactly once.
//!
//! The engine step is the vLLM-style continuous-batching cycle: if any
//! resident request still needs prefill, the step runs those prefills
//! back-to-back (each emits its first token at step end — prefill
//! stalls decode, the classic TTFT/TBT trade this simulator makes
//! visible); otherwise the step decodes one token for the entire
//! resident batch at the batched-roofline cost
//! ([`crate::workload::serve::decode_works`]).
//!
//! # Determinism argument
//!
//! The only parallelism is the per-group cost-table build through
//! [`parallel_map`], which is pure per index; the event loop itself is
//! sequential with a total order on (act time, group index) and
//! (policy key, arrival index). Reports are therefore byte-identical
//! across `--threads` values — enforced by
//! `tests/integration_serve.rs` and the serve-sim golden.

use std::collections::{BTreeSet, HashMap};

use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::report::serve::{LatencyStats, ServeGroupReport, ServeReport};
use crate::util::par::parallel_map;
use crate::util::stats::Samples;
use crate::workload::serve::{
    decode_works, prefill_works, serve_groups, Request, ServeGroup, ServePolicy, ServeSpec,
};

/// A serving simulation: a materialized request trace bound to the
/// per-node device groups of a cluster.
#[derive(Debug, Clone)]
pub struct ServeSim {
    model: ModelSpec,
    cluster: ClusterSpec,
    spec: ServeSpec,
    requests: Vec<Request>,
    groups: Vec<ServeGroup>,
}

/// Per-group pricing: step costs in seconds, precomputed from the cost
/// tables so the event loop is pure arithmetic.
struct GroupCost {
    /// prompt length (tokens) → full prefill pass, seconds.
    prefill_s: HashMap<u64, f64>,
    /// batch size → one decode step, seconds (index 0 unused).
    decode_s: Vec<f64>,
    evaluator: &'static str,
}

struct InFlight {
    id: usize,
    prefilled: bool,
    generated: u64,
    first_token_s: f64,
}

#[derive(Clone, Copy)]
struct Completion {
    group: usize,
    first_token_s: f64,
    completed_s: f64,
}

impl ServeSim {
    /// Bind a serving spec to a model and cluster: validates both,
    /// materializes the request trace, derives the per-node device
    /// groups and KV budgets, and rejects traces containing a request
    /// whose KV footprint fits no group (it could never be admitted).
    pub fn new(model: ModelSpec, cluster: ClusterSpec, spec: ServeSpec) -> anyhow::Result<ServeSim> {
        model.validate()?;
        cluster.validate()?;
        spec.validate()?;
        let groups = serve_groups(&model, &cluster, spec.kv_frac)?;
        let requests = spec.materialize();
        let max_budget = groups.iter().map(|g| g.kv_budget_tokens).max().unwrap_or(0);
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(
                r.kv_tokens() <= max_budget,
                "serving: request {i} needs {} KV tokens but the largest group budget is {}",
                r.kv_tokens(),
                max_budget
            );
        }
        Ok(ServeSim { model, cluster, spec, requests, groups })
    }

    /// The materialized trace, in arrival-index order.
    pub fn requests(&self) -> &[Request] {
        &self.requests
    }

    /// The device groups (and their KV budgets) the trace runs on.
    pub fn groups(&self) -> &[ServeGroup] {
        &self.groups
    }

    /// The model being served.
    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    /// The cluster the trace runs on.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    /// The request-level scheduling policy in effect.
    pub fn policy(&self) -> ServePolicy {
        self.spec.policy
    }

    /// Price every (group × prompt length × batch size) the loop can
    /// touch. Pure per group index, so `parallel_map` keeps the result
    /// byte-identical for any thread count.
    fn price(&self, threads: usize) -> anyhow::Result<Vec<GroupCost>> {
        let prompts: BTreeSet<u64> = self.requests.iter().map(|r| r.prompt_tokens).collect();
        let max_batch = self.spec.max_batch.min(self.requests.len().max(1) as u32);
        let costs = parallel_map(self.groups.len(), threads, |gi| {
            let g = &self.groups[gi];
            let gpu = &self.cluster.nodes[g.node as usize].gpu;
            let mut table = CostTable::native();
            for &p in &prompts {
                for (w, _) in prefill_works(&self.model, p, g.tp) {
                    table.register(&w, gpu);
                }
            }
            for b in 1..=max_batch {
                for (w, _) in decode_works(&self.model, b, g.tp) {
                    table.register(&w, gpu);
                }
            }
            table.evaluate()?;
            let mut prefill_s = HashMap::new();
            for &p in &prompts {
                let mut t = 0.0;
                for (w, n) in prefill_works(&self.model, p, g.tp) {
                    t += table.time(&w, gpu)?.as_secs() * n as f64;
                }
                prefill_s.insert(p, t);
            }
            let mut decode_s = vec![0.0];
            for b in 1..=max_batch {
                let mut t = 0.0;
                for (w, n) in decode_works(&self.model, b, g.tp) {
                    t += table.time(&w, gpu)?.as_secs() * n as f64;
                }
                decode_s.push(t);
            }
            Ok(GroupCost { prefill_s, decode_s, evaluator: table.evaluator_name() })
        });
        costs.into_iter().collect()
    }

    /// Run the trace to completion and report. `threads` parallelizes
    /// the cost-table build only; the result is byte-identical for any
    /// value (0 = all cores).
    pub fn run(&self, threads: usize) -> anyhow::Result<ServeReport> {
        let n = self.requests.len();
        let costs = self.price(threads)?;
        let evaluator = costs.first().map(|c| c.evaluator).unwrap_or("native");

        struct GroupState {
            t: f64,
            running: Vec<InFlight>,
            kv_used: u64,
            kv_peak: u64,
            busy_s: f64,
            steps: u64,
        }
        let mut gs: Vec<GroupState> = self
            .groups
            .iter()
            .map(|_| GroupState {
                t: 0.0,
                running: Vec::new(),
                kv_used: 0,
                kv_peak: 0,
                busy_s: 0.0,
                steps: 0,
            })
            .collect();
        let mut admitted = vec![false; n];
        let mut done: Vec<Option<Completion>> = vec![None; n];
        let mut completed = 0usize;

        while completed < n {
            // Acting group: the smallest (act time, group index). A busy
            // group acts at its clock; an idle group acts when the
            // earliest unadmitted request that fits its budget arrives.
            let mut acting: Option<(f64, usize)> = None;
            for (gi, st) in gs.iter().enumerate() {
                let act = if st.running.is_empty() {
                    let next = self
                        .requests
                        .iter()
                        .enumerate()
                        .filter(|(id, r)| {
                            !admitted[*id] && r.kv_tokens() <= self.groups[gi].kv_budget_tokens
                        })
                        .map(|(_, r)| r.arrival_s)
                        .fold(f64::INFINITY, f64::min);
                    if next.is_infinite() {
                        continue; // nothing this group could ever serve
                    }
                    st.t.max(next)
                } else {
                    st.t
                };
                let better = match acting {
                    None => true,
                    Some((best, _)) => act < best,
                };
                if better {
                    acting = Some((act, gi));
                }
            }
            let (now, gi) = acting.expect("requests pending but no group can act");
            let budget = self.groups[gi].kv_budget_tokens;
            let st = &mut gs[gi];
            st.t = now;

            // Admission: policy-ordered over arrived, unadmitted requests.
            let mut candidates: Vec<usize> = (0..n)
                .filter(|&id| !admitted[id] && self.requests[id].arrival_s <= st.t)
                .collect();
            match self.spec.policy {
                ServePolicy::Fifo => {} // already in arrival-index order
                ServePolicy::Srpt => candidates.sort_by_key(|&id| (self.requests[id].kv_tokens(), id)),
                ServePolicy::Wsrpt => candidates.sort_by(|&a, &b| {
                    let ka = self.requests[a].kv_tokens() as f64 / self.requests[a].weight;
                    let kb = self.requests[b].kv_tokens() as f64 / self.requests[b].weight;
                    ka.total_cmp(&kb).then(a.cmp(&b))
                }),
            }
            for id in candidates {
                if st.running.len() >= self.spec.max_batch as usize {
                    break;
                }
                let need = self.requests[id].kv_tokens();
                if st.kv_used + need > budget {
                    continue; // reserve-in-full admission control
                }
                admitted[id] = true;
                st.kv_used += need;
                st.kv_peak = st.kv_peak.max(st.kv_used);
                st.running.push(InFlight { id, prefilled: false, generated: 0, first_token_s: 0.0 });
            }
            if st.running.is_empty() {
                // Arrived candidates exist but none fit this group right
                // now; jump past this instant so another group (or a
                // later arrival) gets picked next turn.
                let next = self
                    .requests
                    .iter()
                    .enumerate()
                    .filter(|(id, r)| !admitted[*id] && r.arrival_s > st.t)
                    .map(|(_, r)| r.arrival_s)
                    .fold(f64::INFINITY, f64::min);
                anyhow::ensure!(
                    next.is_finite(),
                    "serving: deadlock — pending requests fit no group's free KV budget"
                );
                st.t = next;
                continue;
            }

            // Engine step: pending prefills first, else one batched
            // decode token for every resident request.
            let cost = &costs[gi];
            let step_s = if st.running.iter().any(|f| !f.prefilled) {
                st.running
                    .iter()
                    .filter(|f| !f.prefilled)
                    .map(|f| cost.prefill_s[&self.requests[f.id].prompt_tokens])
                    .sum()
            } else {
                cost.decode_s[st.running.len()]
            };
            let end = st.t + step_s;
            let mut retired = Vec::new();
            for (slot, f) in st.running.iter_mut().enumerate() {
                if !f.prefilled {
                    f.prefilled = true;
                    f.generated = 1;
                    f.first_token_s = end;
                } else {
                    f.generated += 1;
                }
                if f.generated >= self.requests[f.id].output_tokens {
                    retired.push(slot);
                }
            }
            for &slot in retired.iter().rev() {
                let f = st.running.remove(slot);
                st.kv_used -= self.requests[f.id].kv_tokens();
                done[f.id] =
                    Some(Completion { group: gi, first_token_s: f.first_token_s, completed_s: end });
                completed += 1;
            }
            st.t = end;
            st.busy_s += step_s;
            st.steps += 1;
        }

        // Assemble the report (all-zero when the trace is empty).
        let mut ttft_all = Samples::new();
        let mut tbt_all = Samples::new();
        let mut lat_all = Samples::new();
        let mut groups_out = Vec::with_capacity(self.groups.len());
        let mut tokens_total = 0u64;
        let mut makespan = 0.0f64;
        for (gi, g) in self.groups.iter().enumerate() {
            let mut ttft = Samples::new();
            let mut tbt = Samples::new();
            let mut lat = Samples::new();
            let mut requests = 0u64;
            let mut tokens = 0u64;
            let mut last = 0.0f64;
            for (id, c) in done.iter().enumerate() {
                let c = match c {
                    Some(c) if c.group == gi => c,
                    _ => continue,
                };
                let r = &self.requests[id];
                requests += 1;
                tokens += r.output_tokens;
                last = last.max(c.completed_s);
                ttft.push(c.first_token_s - r.arrival_s);
                lat.push(c.completed_s - r.arrival_s);
                if r.output_tokens > 1 {
                    tbt.push((c.completed_s - c.first_token_s) / (r.output_tokens - 1) as f64);
                }
            }
            ttft_all.extend(ttft.values().iter().copied());
            tbt_all.extend(tbt.values().iter().copied());
            lat_all.extend(lat.values().iter().copied());
            tokens_total += tokens;
            makespan = makespan.max(last);
            groups_out.push(ServeGroupReport {
                node: g.node,
                gpu: g.gpu.clone(),
                tp: g.tp,
                requests,
                tokens_out: tokens,
                busy_s: gs[gi].busy_s,
                kv_peak_tokens: gs[gi].kv_peak,
                kv_budget_tokens: g.kv_budget_tokens,
                goodput_tok_s: if last > 0.0 { tokens as f64 / last } else { 0.0 },
                ttft: LatencyStats::of(&mut ttft),
                tbt: LatencyStats::of(&mut tbt),
                latency: LatencyStats::of(&mut lat),
            });
        }
        Ok(ServeReport {
            model: self.model.name.clone(),
            cluster: self.cluster.name.clone(),
            policy: self.spec.policy,
            groups: groups_out,
            requests_total: completed as u64,
            tokens_out_total: tokens_total,
            makespan_s: makespan,
            goodput_tok_s: if makespan > 0.0 { tokens_total as f64 / makespan } else { 0.0 },
            ttft: LatencyStats::of(&mut ttft_all),
            tbt: LatencyStats::of(&mut tbt_all),
            latency: LatencyStats::of(&mut lat_all),
            events: gs.iter().map(|s| s.steps).sum(),
            evaluator,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::workload::serve::PoissonSpec;

    fn sim(spec: ServeSpec) -> ServeSim {
        ServeSim::new(
            presets::model("gpt-6.7b").unwrap(),
            presets::cluster_hetero(1, 1).unwrap(),
            spec,
        )
        .unwrap()
    }

    fn req(arrival_s: f64, prompt: u64, output: u64, weight: f64) -> Request {
        Request { arrival_s, prompt_tokens: prompt, output_tokens: output, weight }
    }

    #[test]
    fn conservation_and_thread_invariance() {
        let spec = ServeSpec {
            poisson: Some(PoissonSpec {
                rate_per_s: 8.0,
                horizon_s: 4.0,
                ..Default::default()
            }),
            ..Default::default()
        };
        let s = sim(spec);
        let rep = s.run(1).unwrap();
        assert_eq!(rep.requests_total as usize, s.requests().len());
        assert_eq!(
            rep.groups.iter().map(|g| g.requests).sum::<u64>(),
            rep.requests_total
        );
        assert!(rep.goodput_tok_s > 0.0);
        assert!(rep.ttft.p50_s > 0.0);
        for g in &rep.groups {
            assert!(g.kv_peak_tokens <= g.kv_budget_tokens);
        }
        let one = rep.render();
        for threads in [4, 8] {
            assert_eq!(one, s.run(threads).unwrap().render(), "threads={threads}");
        }
    }

    #[test]
    fn empty_trace_reports_empty() {
        let s = sim(ServeSpec {
            poisson: Some(PoissonSpec { rate_per_s: 1.0, horizon_s: 2.0, scale: 0.0, ..Default::default() }),
            ..Default::default()
        });
        assert!(s.requests().is_empty());
        let rep = s.run(1).unwrap();
        assert_eq!(rep.requests_total, 0);
        assert_eq!(rep.events, 0);
        assert_eq!(rep.goodput_tok_s, 0.0);
        rep.render(); // must not panic
    }

    #[test]
    fn srpt_overtakes_fifo() {
        // One long request ahead of several short ones, all at t=0 so
        // both policies see the same candidate set at first admission;
        // max_batch=1 serializes each engine so ordering is visible.
        let mut requests = vec![req(0.0, 512, 64, 1.0)];
        for _ in 0..4 {
            requests.push(req(0.0, 32, 4, 1.0));
        }
        let run = |policy| {
            let s = sim(ServeSpec { requests: requests.clone(), policy, max_batch: 1, ..Default::default() });
            s.run(1).unwrap()
        };
        let fifo = run(ServePolicy::Fifo);
        let srpt = run(ServePolicy::Srpt);
        assert_eq!(fifo.requests_total, srpt.requests_total);
        // SRPT lets the short requests jump the long one => lower p50
        // latency; FIFO keeps arrival order.
        assert!(
            srpt.latency.p50_s < fifo.latency.p50_s,
            "srpt p50 {} !< fifo p50 {}",
            srpt.latency.p50_s,
            fifo.latency.p50_s
        );
        assert_ne!(fifo.render(), srpt.render());
    }

    #[test]
    fn wsrpt_respects_weight() {
        // Two identical-size requests at t=0, one heavily weighted; a
        // third long request occupies slot 1 first.
        let requests = vec![
            req(0.0, 256, 32, 1.0),
            req(0.001, 64, 8, 1.0),
            req(0.002, 64, 8, 100.0), // urgent: tokens/weight tiny
        ];
        let s = sim(ServeSpec {
            requests,
            policy: ServePolicy::Wsrpt,
            max_batch: 1,
            ..Default::default()
        });
        let rep = s.run(1).unwrap();
        assert_eq!(rep.requests_total, 3);
        // Both nodes are idle at t=0, so requests spread across groups;
        // the invariant we can assert without pinning the layout is
        // completion conservation + a rendered report.
        assert!(rep.render().contains("policy wsrpt"));
    }

    #[test]
    fn admission_respects_kv_budget_and_batch_cap() {
        let requests: Vec<Request> = (0..6).map(|i| req(i as f64 * 1e-4, 128, 8, 1.0)).collect();
        let s = sim(ServeSpec { requests, max_batch: 2, ..Default::default() });
        let rep = s.run(1).unwrap();
        assert_eq!(rep.requests_total, 6);
        for g in &rep.groups {
            // max_batch=2 with 136-token footprints: peak residency can
            // never exceed 2 footprints.
            assert!(g.kv_peak_tokens <= 2 * 136, "{}", g.kv_peak_tokens);
        }
    }

    #[test]
    fn oversized_request_is_rejected_up_front() {
        let err = ServeSim::new(
            presets::model("gpt-6.7b").unwrap(),
            presets::cluster_hetero(1, 1).unwrap(),
            ServeSpec {
                requests: vec![req(0.0, 10_000_000, 10_000_000, 1.0)],
                ..Default::default()
            },
        );
        assert!(err.is_err());
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("largest group budget"), "{msg}");
    }

    #[test]
    fn heterogeneous_groups_pace_independently() {
        // Saturating load: the H100 group should finish more tokens
        // than the A100 group over the same horizon.
        let spec = ServeSpec {
            poisson: Some(PoissonSpec { rate_per_s: 100.0, horizon_s: 2.0, ..Default::default() }),
            ..Default::default()
        };
        let s = sim(spec);
        let rep = s.run(0).unwrap();
        let a100 = rep.groups.iter().find(|g| g.gpu == "A100").unwrap();
        let h100 = rep.groups.iter().find(|g| g.gpu == "H100").unwrap();
        assert!(
            h100.tokens_out > a100.tokens_out,
            "H100 {} !> A100 {}",
            h100.tokens_out,
            a100.tokens_out
        );
    }
}
