//! Compiled (dense) workload representation — the reusable simulation
//! core behind the scheduler refactor.
//!
//! [`CompiledWorkload::compile`] lowers a [`Workload`] once, on the cold
//! path, into flat `Vec`-indexed tables so the event loop never touches
//! a `HashMap`:
//!
//! * per-rank op streams with **pre-resolved compute durations** (the
//!   cost table is consulted exactly once per distinct op, at compile
//!   time, never per event);
//! * collective definitions remapped to **dense ids** (`cid`), which
//!   double as the network flow tag, plus pre-planned per-collective
//!   flow-step templates (ring-order graph generation runs once, not on
//!   every launch);
//! * p2p message tags remapped to dense indices with **uniqueness
//!   validation** — a reused tag is rejected here instead of silently
//!   completing a later `Recv` against a stale delivery. This is what
//!   lets interleaved pipeline schedules
//!   ([`crate::workload::schedule`]) emit one transfer per *virtual*
//!   stage boundary: every chunk crossing carries its own tag, and a
//!   generator bug that collided tags across virtual stages would fail
//!   compilation rather than corrupt the timeline.
//!
//! A `CompiledWorkload` is immutable plain data (`Send + Sync`), so one
//! compiled scenario can back many concurrent scheduler runs.

use std::collections::{HashMap, HashSet};

use crate::compute::table::CostTable;
use crate::config::cluster::{ClusterSpec, RankIdx};
use crate::network::flow::FlowSpec;
use crate::system::collective::{CollectiveDef, CollectiveExec, CommKind, RingPolicy};
use crate::util::units::Time;
use crate::workload::op::{Op, Workload};

/// One lowered operation. Compute durations are resolved; collective and
/// message references are dense indices into the compiled tables.
#[derive(Debug, Clone, Copy)]
pub enum DenseOp {
    /// Local kernel execution with its pre-resolved duration.
    Compute { dur: Time, label: &'static str },
    /// Participate in compiled collective `cid` (blocking).
    Collective { cid: u32 },
    /// Asynchronous p2p send to global rank `peer`.
    Send { peer: RankIdx, bytes: u64, msg: u32 },
    /// Block until dense message `msg` is delivered (one-shot).
    Recv { msg: u32 },
}

/// The dense, immutable simulation core for one scenario.
#[derive(Debug)]
pub struct CompiledWorkload {
    /// Cluster world size; every dense rank table has this length.
    pub world: u32,
    /// Lowered op stream per global rank (empty for vacant ranks).
    pub ops: Vec<Vec<DenseOp>>,
    /// Whether a rank has a program (vacant ranks are skipped by the
    /// scheduler's seeding and deadlock scan).
    pub has_program: Vec<bool>,
    /// Collective definitions in dense order; `defs[cid].id == cid`, and
    /// `cid` is also the tag carried by the collective's network flows.
    pub defs: Vec<CollectiveDef>,
    /// Communication kind per dense collective (FCT report labels).
    pub kinds: Vec<CommKind>,
    /// Pre-planned flow-step templates per dense collective: the ring /
    /// tree / pairwise expansion under `ring_policy`, computed once.
    pub steps: Vec<Vec<Vec<FlowSpec>>>,
    /// Participant count per dense collective.
    pub expected: Vec<u32>,
    /// Number of distinct p2p messages (dense message-table length).
    pub num_msgs: u32,
    /// Original user-authored p2p tag per dense message id (diagnostics
    /// report these, not the remapped indices).
    pub msg_tags: Vec<u64>,
    /// The ring policy the step templates were planned with.
    pub ring_policy: RingPolicy,
}

impl CompiledWorkload {
    /// Lower `workload` for `cluster`, resolving every compute duration
    /// through `cost` and planning every collective under `ring_policy`.
    ///
    /// Errors on: ranks or peers outside the cluster, unknown or
    /// duplicate collective ids, cost-table misses, and reused p2p
    /// message tags (each tag must name exactly one send and at most one
    /// recv per iteration — delivery is one-shot).
    pub fn compile(
        workload: &Workload,
        cluster: &ClusterSpec,
        cost: &CostTable,
        ring_policy: RingPolicy,
    ) -> anyhow::Result<CompiledWorkload> {
        let world = cluster.total_gpus();

        // dense collective table (original ids remapped to 0..n)
        let mut cid_of: HashMap<u64, u32> = HashMap::with_capacity(workload.collectives.len());
        let mut defs: Vec<CollectiveDef> = Vec::with_capacity(workload.collectives.len());
        let mut kinds: Vec<CommKind> = Vec::with_capacity(workload.collectives.len());
        for (i, def) in workload.collectives.iter().enumerate() {
            anyhow::ensure!(
                cid_of.insert(def.id, i as u32).is_none(),
                "duplicate collective id {}",
                def.id
            );
            for r in &def.ranks {
                anyhow::ensure!(
                    *r < world,
                    "collective {} rank {r} outside cluster of {world} GPUs",
                    def.id
                );
            }
            let mut d = def.clone();
            d.id = i as u64; // dense id doubles as the flow tag
            kinds.push(d.kind);
            defs.push(d);
        }

        // per-rank dense op streams
        let node_of = cluster.rank_nodes();
        let mut ops: Vec<Vec<DenseOp>> = vec![Vec::new(); world as usize];
        let mut has_program = vec![false; world as usize];
        let mut msg_of: HashMap<u64, u32> = HashMap::new();
        let mut send_seen: HashSet<u64> = HashSet::new();
        let mut recv_seen: HashSet<u64> = HashSet::new();
        for p in &workload.programs {
            anyhow::ensure!(
                p.rank < world,
                "rank {} outside cluster of {world} GPUs",
                p.rank
            );
            let slot = p.rank as usize;
            anyhow::ensure!(!has_program[slot], "two programs for rank {}", p.rank);
            has_program[slot] = true;
            let gpu = &cluster.nodes[node_of[slot] as usize].gpu;
            let mut stream = Vec::with_capacity(p.ops.len());
            for op in &p.ops {
                match op {
                    Op::Compute { work, label } => {
                        stream.push(DenseOp::Compute { dur: cost.time(work, gpu)?, label: *label });
                    }
                    Op::Collective { def_id } => {
                        let cid = *cid_of.get(def_id).ok_or_else(|| {
                            anyhow::anyhow!(
                                "rank {} references unknown collective {def_id}",
                                p.rank
                            )
                        })?;
                        stream.push(DenseOp::Collective { cid });
                    }
                    Op::Send { peer, bytes, msg } => {
                        anyhow::ensure!(
                            *peer < world,
                            "send peer {peer} outside cluster of {world} GPUs"
                        );
                        anyhow::ensure!(
                            send_seen.insert(*msg),
                            "p2p message tag {msg} reused by a second Send — \
                             tags must be unique within an iteration"
                        );
                        let next = msg_of.len() as u32;
                        let m = *msg_of.entry(*msg).or_insert(next);
                        stream.push(DenseOp::Send { peer: RankIdx(*peer), bytes: *bytes, msg: m });
                    }
                    Op::Recv { msg } => {
                        anyhow::ensure!(
                            recv_seen.insert(*msg),
                            "p2p message tag {msg} reused by a second Recv — \
                             tags must be unique within an iteration"
                        );
                        let next = msg_of.len() as u32;
                        let m = *msg_of.entry(*msg).or_insert(next);
                        stream.push(DenseOp::Recv { msg: m });
                    }
                }
            }
            ops[slot] = stream;
        }

        // pre-plan every collective's flow steps (graph generation is a
        // pure function of cluster + def + policy, so this is hoisted
        // out of the event loop entirely)
        let mut steps = Vec::with_capacity(defs.len());
        let mut expected = Vec::with_capacity(defs.len());
        for d in &defs {
            expected.push(d.ranks.len() as u32);
            steps.push(CollectiveExec::plan(cluster, d, ring_policy).steps);
        }

        let mut msg_tags = vec![0u64; msg_of.len()];
        for (tag, idx) in &msg_of {
            msg_tags[*idx as usize] = *tag;
        }

        Ok(CompiledWorkload {
            world,
            ops,
            has_program,
            defs,
            kinds,
            steps,
            expected,
            num_msgs: msg_of.len() as u32,
            msg_tags,
            ring_policy,
        })
    }

    /// Total lowered ops across all ranks.
    pub fn op_count(&self) -> usize {
        self.ops.iter().map(Vec::len).sum()
    }

    /// Total flows across all pre-planned collective steps.
    pub fn planned_flow_count(&self) -> usize {
        self.steps.iter().flatten().map(Vec::len).sum()
    }

    /// Engine event-queue capacity hint for one run: sized to the
    /// *peak concurrency*, not the run's total event count — each rank
    /// has at most one pending compute event and each in-flight flow
    /// one completion event, and pops/cancels recycle heap and slab
    /// space. A generous multiple of (world + largest planned step)
    /// covers overlapping collectives without reserving the
    /// total-event-count's worth of memory per scored candidate.
    pub fn event_capacity_hint(&self) -> usize {
        self.world as usize * 4 + self.max_step_flows() * 4
    }

    /// Largest single pre-planned flow step (a lower bound on peak
    /// concurrent flows; the scheduler uses it to pre-size the flow
    /// slab and the posted-time scratch buffer).
    pub fn max_step_flows(&self) -> usize {
        self.steps.iter().flatten().map(Vec::len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::cost::LayerWork;
    use crate::config::model::LayerKind;
    use crate::config::presets;
    use crate::system::collective::CollectiveAlgo;
    use crate::workload::op::RankProgram;

    fn lw() -> LayerWork {
        LayerWork {
            kind: LayerKind::Mlp,
            hidden: 512.0,
            ffn: 2048.0,
            heads: 8.0,
            seq: 128.0,
            mbs: 1.0,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    fn cost_for(c: &ClusterSpec) -> CostTable {
        let mut t = CostTable::native();
        let w = lw();
        for n in &c.nodes {
            t.register(&w, &n.gpu);
        }
        t.evaluate().unwrap();
        t
    }

    fn coll(id: u64, ranks: Vec<u32>) -> CollectiveDef {
        CollectiveDef {
            id,
            algo: CollectiveAlgo::AllReduceRing,
            ranks,
            bytes_per_rank: 1 << 16,
            kind: CommKind::Tp,
            label: "t".into(),
        }
    }

    #[test]
    fn collectives_remapped_to_dense_ids() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 77 }] },
                RankProgram { rank: 1, ops: vec![Op::Collective { def_id: 77 }] },
            ],
            collectives: vec![coll(77, vec![0, 1])],
        };
        let cw =
            CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::HeteroAware)
                .unwrap();
        assert_eq!(cw.defs.len(), 1);
        assert_eq!(cw.defs[0].id, 0); // dense id, not 77
        assert_eq!(cw.expected, vec![2]);
        // flow tags in the step template carry the dense id
        assert!(cw.steps[0].iter().flatten().all(|f| f.tag == 0));
        assert!(matches!(cw.ops[0][0], DenseOp::Collective { cid: 0 }));
    }

    #[test]
    fn compute_durations_preresolved() {
        let c = presets::cluster("hopper", 1).unwrap();
        let t = cost_for(&c);
        let w = Workload {
            programs: vec![RankProgram {
                rank: 0,
                ops: vec![Op::Compute { work: lw(), label: "mlp" }],
            }],
            collectives: vec![],
        };
        let cw = CompiledWorkload::compile(&w, &c, &t, RingPolicy::HeteroAware).unwrap();
        match cw.ops[0][0] {
            DenseOp::Compute { dur, .. } => {
                let expect = t.time(&lw(), &c.nodes[0].gpu).unwrap();
                assert_eq!(dur, expect);
            }
            ref other => panic!("unexpected op {other:?}"),
        }
    }

    #[test]
    fn reused_send_tag_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Send { peer: 1, bytes: 8, msg: 5 },
                        Op::Send { peer: 1, bytes: 8, msg: 5 },
                    ],
                },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 5 }] },
            ],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("reused"), "{err}");
    }

    #[test]
    fn reused_recv_tag_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Send { peer: 1, bytes: 8, msg: 5 }] },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 5 }, Op::Recv { msg: 5 }] },
            ],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("reused"), "{err}");
    }

    #[test]
    fn rank_outside_cluster_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram { rank: 500, ops: vec![] }],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("outside cluster"), "{err}");
    }

    #[test]
    fn msg_ids_densely_numbered() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Send { peer: 1, bytes: 8, msg: 1_000_000 },
                        Op::Send { peer: 1, bytes: 8, msg: 42 },
                    ],
                },
                RankProgram {
                    rank: 1,
                    ops: vec![Op::Recv { msg: 1_000_000 }, Op::Recv { msg: 42 }],
                },
            ],
            collectives: vec![],
        };
        let cw = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap();
        assert_eq!(cw.num_msgs, 2);
        match (cw.ops[0][0], cw.ops[0][1]) {
            (DenseOp::Send { msg: a, .. }, DenseOp::Send { msg: b, .. }) => {
                assert_eq!((a, b), (0, 1));
            }
            other => panic!("unexpected ops {other:?}"),
        }
    }
}
