//! Compiled (dense) workload representation — the reusable simulation
//! core behind the scheduler refactor.
//!
//! [`CompiledWorkload::compile`] lowers a [`Workload`] once, on the cold
//! path, into flat `Vec`-indexed tables so the event loop never touches
//! a `HashMap`:
//!
//! * per-rank op streams with **pre-resolved compute durations** (the
//!   cost table is consulted exactly once per distinct op, at compile
//!   time, never per event);
//! * collective definitions remapped to **dense ids** (`cid`), which
//!   double as the network flow tag, plus pre-planned per-collective
//!   flow-step templates (ring-order graph generation runs once, not on
//!   every launch);
//! * p2p message tags remapped to dense indices with **uniqueness
//!   validation** — a reused tag is rejected here instead of silently
//!   completing a later `Recv` against a stale delivery. This is what
//!   lets interleaved pipeline schedules
//!   ([`crate::workload::schedule`]) emit one transfer per *virtual*
//!   stage boundary: every chunk crossing carries its own tag, and a
//!   generator bug that collided tags across virtual stages would fail
//!   compilation rather than corrupt the timeline.
//!
//! A `CompiledWorkload` is immutable plain data (`Send + Sync`), so one
//! compiled scenario can back many concurrent scheduler runs.

use std::collections::{HashMap, HashSet};

use crate::compute::table::CostTable;
use crate::config::cluster::{ClusterSpec, RankIdx};
use crate::network::flow::FlowSpec;
use crate::network::routing;
use crate::network::topology::Topology;
use crate::system::collective::{
    ring_order, CollectiveAlgo, CollectiveDef, CollectiveExec, CommKind, RingPolicy,
};
use crate::system::fold::FoldPlan;
use crate::util::units::Time;
use crate::workload::op::{Op, Workload};

/// One lowered operation. Compute durations are resolved; collective and
/// message references are dense indices into the compiled tables.
#[derive(Debug, Clone, Copy)]
pub enum DenseOp {
    /// Local kernel execution with its pre-resolved duration.
    Compute { dur: Time, label: &'static str },
    /// Participate in compiled collective `cid` (blocking).
    Collective { cid: u32 },
    /// Asynchronous p2p send to global rank `peer`.
    Send { peer: RankIdx, bytes: u64, msg: u32 },
    /// Block until dense message `msg` is delivered (one-shot).
    Recv { msg: u32 },
}

/// The dense, immutable simulation core for one scenario.
#[derive(Debug)]
pub struct CompiledWorkload {
    /// Cluster world size; every dense rank table has this length.
    pub world: u32,
    /// Lowered op stream per global rank (empty for vacant ranks).
    pub ops: Vec<Vec<DenseOp>>,
    /// Whether a rank has a program (vacant ranks are skipped by the
    /// scheduler's seeding and deadlock scan).
    pub has_program: Vec<bool>,
    /// Collective definitions in dense order; `defs[cid].id == cid`, and
    /// `cid` is also the tag carried by the collective's network flows.
    pub defs: Vec<CollectiveDef>,
    /// Communication kind per dense collective (FCT report labels).
    pub kinds: Vec<CommKind>,
    /// Pre-planned flow-step templates per dense collective: the ring /
    /// tree / pairwise expansion under `ring_policy`, computed once.
    pub steps: Vec<Vec<Vec<FlowSpec>>>,
    /// Participant count per dense collective.
    pub expected: Vec<u32>,
    /// Number of distinct p2p messages (dense message-table length).
    pub num_msgs: u32,
    /// Original user-authored p2p tag per dense message id (diagnostics
    /// report these, not the remapped indices).
    pub msg_tags: Vec<u64>,
    /// The ring policy the step templates were planned with.
    pub ring_policy: RingPolicy,
    /// Symmetry-fold metadata when this core was compiled folded
    /// ([`CompiledWorkload::compile_folded`]); `None` for the classic
    /// path — the scheduler's accounting is byte-identical to the
    /// pre-folding code when this is `None`.
    pub fold: Option<FoldedMeta>,
}

/// Per-run weights the scheduler needs to make a folded timeline report
/// the *unfolded* totals (see [`crate::system::fold`]).
#[derive(Debug)]
pub struct FoldedMeta {
    /// Per rank: the class-representative counterpart whose DP arrival
    /// time stands in for this rank's (identity when unfolded).
    pub twin: Vec<u32>,
    /// Per rank: class multiplicity weighting its compute-busy time.
    pub rank_mult: Vec<u64>,
    /// Per dense collective: how many unfolded collectives it stands
    /// for (class multiplicity for group-local collectives of a
    /// representative group, 1 for DP-sync collectives, which are
    /// shared across the whole class and already unique).
    pub coll_mult: Vec<u64>,
    /// Flows removed from DP step templates by component folding
    /// (diagnostics).
    pub folded_flows: u64,
}

impl CompiledWorkload {
    /// Lower `workload` for `cluster`, resolving every compute duration
    /// through `cost` and planning every collective under `ring_policy`.
    ///
    /// Errors on: ranks or peers outside the cluster, unknown or
    /// duplicate collective ids, cost-table misses, and reused p2p
    /// message tags (each tag must name exactly one send and at most one
    /// recv per iteration — delivery is one-shot).
    pub fn compile(
        workload: &Workload,
        cluster: &ClusterSpec,
        cost: &CostTable,
        ring_policy: RingPolicy,
    ) -> anyhow::Result<CompiledWorkload> {
        Self::compile_inner(workload, cluster, cost, ring_policy, None)
    }

    /// [`CompiledWorkload::compile`] under a symmetry-fold plan
    /// ([`crate::system::fold`]): the workload must come from
    /// [`crate::workload::aicb::generate_folded`] with the same plan.
    /// Group-local collectives are planned as usual (only
    /// representatives have them); DP-sync collectives get *folded*
    /// step templates — one flow per symmetry orbit of the unfolded
    /// flow set, chosen so every kept flow's max-min rate and every
    /// def's per-step completion time are bit-identical to the
    /// unfolded plan (the dropped flows form connected components that
    /// share no link with any kept flow and duplicate a kept
    /// component's canonical profile).
    pub fn compile_folded(
        workload: &Workload,
        cluster: &ClusterSpec,
        cost: &CostTable,
        ring_policy: RingPolicy,
        topo: &Topology,
        fold: &FoldPlan,
    ) -> anyhow::Result<CompiledWorkload> {
        Self::compile_inner(workload, cluster, cost, ring_policy, Some((topo, fold)))
    }

    fn compile_inner(
        workload: &Workload,
        cluster: &ClusterSpec,
        cost: &CostTable,
        ring_policy: RingPolicy,
        folded: Option<(&Topology, &FoldPlan)>,
    ) -> anyhow::Result<CompiledWorkload> {
        let world = cluster.total_gpus();

        // dense collective table (original ids remapped to 0..n)
        let mut cid_of: HashMap<u64, u32> = HashMap::with_capacity(workload.collectives.len());
        let mut defs: Vec<CollectiveDef> = Vec::with_capacity(workload.collectives.len());
        let mut kinds: Vec<CommKind> = Vec::with_capacity(workload.collectives.len());
        for (i, def) in workload.collectives.iter().enumerate() {
            anyhow::ensure!(
                cid_of.insert(def.id, i as u32).is_none(),
                "duplicate collective id {}",
                def.id
            );
            for r in &def.ranks {
                anyhow::ensure!(
                    *r < world,
                    "collective {} rank {r} outside cluster of {world} GPUs",
                    def.id
                );
            }
            let mut d = def.clone();
            d.id = i as u64; // dense id doubles as the flow tag
            kinds.push(d.kind);
            defs.push(d);
        }

        // per-rank dense op streams
        let node_of = cluster.rank_nodes();
        let mut ops: Vec<Vec<DenseOp>> = vec![Vec::new(); world as usize];
        let mut has_program = vec![false; world as usize];
        let mut msg_of: HashMap<u64, u32> = HashMap::new();
        let mut send_seen: HashSet<u64> = HashSet::new();
        let mut recv_seen: HashSet<u64> = HashSet::new();
        for p in &workload.programs {
            anyhow::ensure!(
                p.rank < world,
                "rank {} outside cluster of {world} GPUs",
                p.rank
            );
            let slot = p.rank as usize;
            anyhow::ensure!(!has_program[slot], "two programs for rank {}", p.rank);
            has_program[slot] = true;
            let gpu = &cluster.nodes[node_of[slot] as usize].gpu;
            let mut stream = Vec::with_capacity(p.ops.len());
            for op in &p.ops {
                match op {
                    Op::Compute { work, label } => {
                        stream.push(DenseOp::Compute { dur: cost.time(work, gpu)?, label: *label });
                    }
                    Op::Collective { def_id } => {
                        let cid = *cid_of.get(def_id).ok_or_else(|| {
                            anyhow::anyhow!(
                                "rank {} references unknown collective {def_id}",
                                p.rank
                            )
                        })?;
                        stream.push(DenseOp::Collective { cid });
                    }
                    Op::Send { peer, bytes, msg } => {
                        anyhow::ensure!(
                            *peer < world,
                            "send peer {peer} outside cluster of {world} GPUs"
                        );
                        anyhow::ensure!(
                            send_seen.insert(*msg),
                            "p2p message tag {msg} reused by a second Send — \
                             tags must be unique within an iteration"
                        );
                        let next = msg_of.len() as u32;
                        let m = *msg_of.entry(*msg).or_insert(next);
                        stream.push(DenseOp::Send { peer: RankIdx(*peer), bytes: *bytes, msg: m });
                    }
                    Op::Recv { msg } => {
                        anyhow::ensure!(
                            recv_seen.insert(*msg),
                            "p2p message tag {msg} reused by a second Recv — \
                             tags must be unique within an iteration"
                        );
                        let next = msg_of.len() as u32;
                        let m = *msg_of.entry(*msg).or_insert(next);
                        stream.push(DenseOp::Recv { msg: m });
                    }
                }
            }
            ops[slot] = stream;
        }

        // pre-plan every collective's flow steps (graph generation is a
        // pure function of cluster + def + policy, so this is hoisted
        // out of the event loop entirely)
        let mut steps = Vec::with_capacity(defs.len());
        let mut expected = Vec::with_capacity(defs.len());
        let fold_meta = match folded {
            None => {
                for d in &defs {
                    expected.push(d.ranks.len() as u32);
                    steps.push(CollectiveExec::plan(cluster, d, ring_policy).steps);
                }
                None
            }
            Some((topo, fold)) => {
                // a collective launches when every *program-bearing*
                // participant arrives; folded ranks never will
                for d in &defs {
                    let n = d.ranks.iter().filter(|&&r| has_program[r as usize]).count();
                    anyhow::ensure!(
                        n > 0,
                        "folded collective {} has no represented participant",
                        d.label
                    );
                    expected.push(n as u32);
                }
                let (folded_steps, folded_flows) =
                    plan_folded_steps(cluster, topo, &defs, ring_policy, fold);
                steps = folded_steps;
                let coll_mult: Vec<u64> = defs
                    .iter()
                    .map(|d| match d.kind {
                        // DP-sync defs span the whole class already
                        CommKind::Dp => 1,
                        // group-local defs: all ranks are in one
                        // (representative) group → its class multiplicity
                        _ => d.ranks.first().map_or(1, |&r| fold.rank_mult[r as usize]),
                    })
                    .collect();
                Some(FoldedMeta {
                    twin: fold.twin.clone(),
                    rank_mult: fold.rank_mult.clone(),
                    coll_mult,
                    folded_flows,
                })
            }
        };

        let mut msg_tags = vec![0u64; msg_of.len()];
        for (tag, idx) in &msg_of {
            msg_tags[*idx as usize] = *tag;
        }

        Ok(CompiledWorkload {
            world,
            ops,
            has_program,
            defs,
            kinds,
            steps,
            expected,
            num_msgs: msg_of.len() as u32,
            msg_tags,
            ring_policy,
            fold: fold_meta,
        })
    }

    /// Total lowered ops across all ranks.
    pub fn op_count(&self) -> usize {
        self.ops.iter().map(Vec::len).sum()
    }

    /// Total flows across all pre-planned collective steps.
    pub fn planned_flow_count(&self) -> usize {
        self.steps.iter().flatten().map(Vec::len).sum()
    }

    /// Engine event-queue capacity hint for one run: sized to the
    /// *peak concurrency*, not the run's total event count — each rank
    /// has at most one pending compute event and each in-flight flow
    /// one completion event, and pops/cancels recycle heap and slab
    /// space. A generous multiple of (world + largest planned step)
    /// covers overlapping collectives without reserving the
    /// total-event-count's worth of memory per scored candidate.
    pub fn event_capacity_hint(&self) -> usize {
        self.world as usize * 4 + self.max_step_flows() * 4
    }

    /// Largest single pre-planned flow step (a lower bound on peak
    /// concurrent flows; the scheduler uses it to pre-size the flow
    /// slab and the posted-time scratch buffer).
    pub fn max_step_flows(&self) -> usize {
        self.steps.iter().flatten().map(Vec::len).max().unwrap_or(0)
    }
}

/// One candidate DP flow in the folded planner: a ring edge (every step
/// of a ring collective repeats the same batch, so one edge stands for
/// the flow at that ring position in *every* step) or, for non-ring DP
/// algorithms, one distinct (src, dst) pair whose component must be
/// force-kept.
struct DpEdge {
    /// Dense collective index.
    def: usize,
    src: u32,
    dst: u32,
    /// Links the flow traverses (routing is deterministic per pair).
    route: Vec<crate::network::topology::LinkId>,
    /// Component containing this edge may never be dropped.
    forced: bool,
}

/// Fold the DP-sync flow sets: simulate one connected component per
/// symmetry orbit instead of all of them.
///
/// Exactness argument (DESIGN.md §25): flows are grouped into
/// connected components by shared links across **all** DP collectives.
/// Max-min fair sharing decomposes over components (a flow's rate
/// depends only on flows it transitively shares links with), so
/// dropping a whole component never changes a kept flow's rate. A
/// component may be dropped only when another kept component has the
/// same canonical profile — same per-edge (collective shape, endpoint
/// equivalence classes, chunk bytes) and an isomorphic link pattern
/// with identical (kind, bandwidth, delay) — *and* touches the same
/// set of collectives, so each collective's per-step completion time
/// (the max over its components) is preserved exactly. Every
/// collective keeps at least one component.
///
/// Returns per-def step templates plus the number of flows folded away
/// (summed over steps).
fn plan_folded_steps(
    cluster: &ClusterSpec,
    topo: &Topology,
    defs: &[CollectiveDef],
    ring_policy: RingPolicy,
    fold: &FoldPlan,
) -> (Vec<Vec<Vec<FlowSpec>>>, u64) {
    let mut steps: Vec<Vec<Vec<FlowSpec>>> = Vec::with_capacity(defs.len());
    // per-def ring template: Some((order, nsteps, chunk)) for ring
    // algorithms, None for everything else (planned normally below)
    let mut rings: Vec<Option<(usize, u64)>> = Vec::with_capacity(defs.len());
    let mut edges: Vec<DpEdge> = Vec::new();
    for (di, d) in defs.iter().enumerate() {
        if d.kind != CommKind::Dp {
            // group-local collective of a representative group: planned
            // in full; pp == 1 means it never overlaps DP traffic, so
            // it stays out of the component analysis
            steps.push(CollectiveExec::plan(cluster, d, ring_policy).steps);
            rings.push(None);
            continue;
        }
        let n = d.ranks.len();
        if n <= 1 || d.bytes_per_rank == 0 {
            steps.push(Vec::new());
            rings.push(None);
            continue;
        }
        let ring = match d.algo {
            CollectiveAlgo::AllReduceRing => Some(2 * (n - 1)),
            CollectiveAlgo::AllGather | CollectiveAlgo::ReduceScatter => Some(n - 1),
            _ => None,
        };
        match ring {
            Some(nsteps) => {
                let order = ring_order(cluster, &d.ranks, ring_policy);
                let chunk = (d.bytes_per_rank / n as u64).max(1);
                for i in 0..n {
                    let (src, dst) = (order[i], order[(i + 1) % n]);
                    edges.push(DpEdge {
                        def: di,
                        src,
                        dst,
                        route: routing::route(topo, src, dst).links,
                        forced: false,
                    });
                }
                steps.push(Vec::new()); // assembled after the keep pass
                rings.push(Some((nsteps, chunk)));
            }
            None => {
                // non-ring DP algorithm (not emitted by the generator
                // today): keep it fully expanded, and force-keep any
                // component its flows touch so their contention stays
                // simulated
                let plan = CollectiveExec::plan(cluster, d, ring_policy).steps;
                let mut seen: HashSet<(u32, u32)> = HashSet::new();
                for f in plan.iter().flatten() {
                    if f.src != f.dst && seen.insert((f.src, f.dst)) {
                        edges.push(DpEdge {
                            def: di,
                            src: f.src,
                            dst: f.dst,
                            route: routing::route(topo, f.src, f.dst).links,
                            forced: true,
                        });
                    }
                }
                steps.push(plan);
                rings.push(None);
            }
        }
    }

    // union-find over edges sharing any link
    let mut parent: Vec<usize> = (0..edges.len()).collect();
    fn find(parent: &mut [usize], mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let mut link_owner: Vec<usize> = vec![usize::MAX; topo.num_links()];
    for ei in 0..edges.len() {
        for l in &edges[ei].route {
            let slot = l.0 as usize;
            if link_owner[slot] == usize::MAX {
                link_owner[slot] = ei;
            } else {
                let (a, b) = (find(&mut parent, ei), find(&mut parent, link_owner[slot]));
                if a != b {
                    parent[a.max(b)] = a.min(b);
                }
            }
        }
    }

    // canonical profile per component, iterating edges in emission
    // order so component discovery and link canonicalization are
    // deterministic
    let mut comp_edges: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut comp_order: Vec<usize> = Vec::new(); // roots, by first edge
    for ei in 0..edges.len() {
        let root = find(&mut parent, ei);
        let slot = comp_edges.entry(root).or_default();
        if slot.is_empty() {
            comp_order.push(root);
        }
        slot.push(ei);
    }
    let mut kept: HashSet<usize> = HashSet::new();
    let mut by_profile: HashMap<String, usize> = HashMap::new();
    for &root in &comp_order {
        let members = &comp_edges[&root];
        if members.iter().any(|&ei| edges[ei].forced) {
            kept.insert(root);
            continue;
        }
        let mut profile = String::new();
        let mut local: HashMap<u32, usize> = HashMap::new();
        for &ei in members {
            let e = &edges[ei];
            let (nsteps, chunk) = rings[e.def].as_ref().expect("ring edge");
            profile.push_str(&format!(
                "d{:?}.{}.{}.{}|c{}>{}|",
                defs[e.def].algo,
                nsteps,
                chunk,
                e.def, // exact def identity: per-def step maxima must survive
                fold.rank_class[e.src as usize],
                fold.rank_class[e.dst as usize],
            ));
            for l in &e.route {
                let next = local.len();
                let li = *local.entry(l.0).or_insert(next);
                let link = topo.link(*l);
                profile.push_str(&format!(
                    "{li}:{:?}:{}:{};",
                    link.kind,
                    link.bw.0,
                    link.delay.0
                ));
            }
            profile.push('|');
        }
        if let std::collections::hash_map::Entry::Vacant(v) = by_profile.entry(profile) {
            v.insert(root);
            kept.insert(root);
        }
    }
    // every ring def keeps at least one component (a collective with an
    // all-dropped step could never finish)
    let mut def_covered: Vec<bool> = vec![false; defs.len()];
    for &root in &kept {
        for &ei in &comp_edges[&root] {
            def_covered[edges[ei].def] = true;
        }
    }
    for ei in 0..edges.len() {
        let di = edges[ei].def;
        if rings[di].is_some() && !def_covered[di] {
            let root = find(&mut parent, ei);
            kept.insert(root);
            for &mi in &comp_edges[&root] {
                def_covered[edges[mi].def] = true;
            }
        }
    }

    // assemble ring-def step templates from the kept edges
    let mut kept_flows: Vec<Vec<FlowSpec>> = vec![Vec::new(); defs.len()];
    let mut folded_flows: u64 = 0;
    for ei in 0..edges.len() {
        let root = find(&mut parent, ei);
        let e = &edges[ei];
        let Some((nsteps, chunk)) = rings[e.def].as_ref() else { continue };
        if kept.contains(&root) {
            kept_flows[e.def].push(FlowSpec {
                src: e.src,
                dst: e.dst,
                bytes: *chunk,
                tag: e.def as u64,
            });
        } else {
            folded_flows += *nsteps as u64;
        }
    }
    for (di, flows) in kept_flows.into_iter().enumerate() {
        if let Some((nsteps, _)) = &rings[di] {
            steps[di] = vec![flows; *nsteps];
        }
    }
    (steps, folded_flows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::cost::LayerWork;
    use crate::config::model::LayerKind;
    use crate::config::presets;
    use crate::system::collective::CollectiveAlgo;
    use crate::workload::op::RankProgram;

    fn lw() -> LayerWork {
        LayerWork {
            kind: LayerKind::Mlp,
            hidden: 512.0,
            ffn: 2048.0,
            heads: 8.0,
            seq: 128.0,
            mbs: 1.0,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    fn cost_for(c: &ClusterSpec) -> CostTable {
        let mut t = CostTable::native();
        let w = lw();
        for n in &c.nodes {
            t.register(&w, &n.gpu);
        }
        t.evaluate().unwrap();
        t
    }

    fn coll(id: u64, ranks: Vec<u32>) -> CollectiveDef {
        CollectiveDef {
            id,
            algo: CollectiveAlgo::AllReduceRing,
            ranks,
            bytes_per_rank: 1 << 16,
            kind: CommKind::Tp,
            label: "t".into(),
        }
    }

    #[test]
    fn collectives_remapped_to_dense_ids() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 77 }] },
                RankProgram { rank: 1, ops: vec![Op::Collective { def_id: 77 }] },
            ],
            collectives: vec![coll(77, vec![0, 1])],
        };
        let cw =
            CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::HeteroAware)
                .unwrap();
        assert_eq!(cw.defs.len(), 1);
        assert_eq!(cw.defs[0].id, 0); // dense id, not 77
        assert_eq!(cw.expected, vec![2]);
        // flow tags in the step template carry the dense id
        assert!(cw.steps[0].iter().flatten().all(|f| f.tag == 0));
        assert!(matches!(cw.ops[0][0], DenseOp::Collective { cid: 0 }));
    }

    #[test]
    fn compute_durations_preresolved() {
        let c = presets::cluster("hopper", 1).unwrap();
        let t = cost_for(&c);
        let w = Workload {
            programs: vec![RankProgram {
                rank: 0,
                ops: vec![Op::Compute { work: lw(), label: "mlp" }],
            }],
            collectives: vec![],
        };
        let cw = CompiledWorkload::compile(&w, &c, &t, RingPolicy::HeteroAware).unwrap();
        match cw.ops[0][0] {
            DenseOp::Compute { dur, .. } => {
                let expect = t.time(&lw(), &c.nodes[0].gpu).unwrap();
                assert_eq!(dur, expect);
            }
            ref other => panic!("unexpected op {other:?}"),
        }
    }

    #[test]
    fn reused_send_tag_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Send { peer: 1, bytes: 8, msg: 5 },
                        Op::Send { peer: 1, bytes: 8, msg: 5 },
                    ],
                },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 5 }] },
            ],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("reused"), "{err}");
    }

    #[test]
    fn reused_recv_tag_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Send { peer: 1, bytes: 8, msg: 5 }] },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 5 }, Op::Recv { msg: 5 }] },
            ],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("reused"), "{err}");
    }

    #[test]
    fn rank_outside_cluster_rejected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram { rank: 500, ops: vec![] }],
            collectives: vec![],
        };
        let err = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap_err();
        assert!(err.to_string().contains("outside cluster"), "{err}");
    }

    #[test]
    fn msg_ids_densely_numbered() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Send { peer: 1, bytes: 8, msg: 1_000_000 },
                        Op::Send { peer: 1, bytes: 8, msg: 42 },
                    ],
                },
                RankProgram {
                    rank: 1,
                    ops: vec![Op::Recv { msg: 1_000_000 }, Op::Recv { msg: 42 }],
                },
            ],
            collectives: vec![],
        };
        let cw = CompiledWorkload::compile(&w, &c, &CostTable::native(), RingPolicy::Naive)
            .unwrap();
        assert_eq!(cw.num_msgs, 2);
        match (cw.ops[0][0], cw.ops[0][1]) {
            (DenseOp::Send { msg: a, .. }, DenseOp::Send { msg: b, .. }) => {
                assert_eq!((a, b), (0, 1));
            }
            other => panic!("unexpected ops {other:?}"),
        }
    }
}
