//! Resharding (component **C2**): shape matching before synchronization
//! across non-uniform device groups.
//!
//! Paper §3: resharding is needed when (1) communicating DP groups
//! process different microbatch sizes, or (2) their TP degrees differ.
//! PP layer-count differences alone do NOT need resharding (sequential
//! communication).
//!
//! The plan we emit models the standard reshard dance: each TP group
//! all-gathers its shards to full-tensor form on its ranks, the group
//! leaders run the synchronizing allreduce, and each group re-scatters
//! to its own shard shape. The extra all-gather/scatter traffic and the
//! leader-ring allreduce over the *full* tensor (rather than per-shard
//! rings) is exactly the overhead Table 3 attributes to resharding-
//! dependent strategies.

use super::collective::{CollectiveAlgo, CollectiveDef, CommKind};
use super::device_group::DpParticipant;

/// Paper §3 conditions for resharding between two DP participants.
pub fn needs_resharding(a: &DpParticipant, b: &DpParticipant) -> bool {
    a.tp != b.tp || a.micro_batch != b.micro_batch
}

/// Does any pair in a DP sync group require resharding?
pub fn group_needs_resharding(parts: &[DpParticipant]) -> bool {
    parts.windows(2).any(|w| needs_resharding(&w[0], &w[1]))
}

/// The reshard + synchronization plan for one DP sync group.
#[derive(Debug, Clone)]
pub struct ReshardPlan {
    /// Pre-sync collectives (intra-group all-gathers).
    pub pre: Vec<CollectiveDef>,
    /// The synchronizing allreduce (leaders, full tensor).
    pub sync: CollectiveDef,
    /// Post-sync collectives (intra-group broadcasts of the result).
    pub post: Vec<CollectiveDef>,
}

impl ReshardPlan {
    /// All collectives of the plan in execution order (pre → sync →
    /// post).
    pub fn all_defs(&self) -> Vec<&CollectiveDef> {
        self.pre.iter().chain(std::iter::once(&self.sync)).chain(self.post.iter()).collect()
    }
}

/// Build the plan. `full_bytes` is the unsharded gradient tensor size of
/// the stage; `next_id` allocates collective ids.
pub fn plan(
    participants: &[DpParticipant],
    full_bytes: u64,
    stage: u32,
    next_id: &mut u64,
) -> ReshardPlan {
    let mut alloc = || {
        let id = *next_id;
        *next_id += 1;
        id
    };
    let mut pre = Vec::new();
    let mut post = Vec::new();
    for p in participants {
        if p.tp > 1 {
            // each rank holds full_bytes / tp; all-gather to full tensor
            pre.push(CollectiveDef {
                id: alloc(),
                algo: CollectiveAlgo::AllGather,
                ranks: p.ranks.clone(),
                bytes_per_rank: full_bytes,
                kind: CommKind::Reshard,
                label: format!("reshard-ag-s{stage}-g{}", p.group),
            });
            post.push(CollectiveDef {
                id: alloc(),
                algo: CollectiveAlgo::Broadcast,
                ranks: p.ranks.clone(),
                bytes_per_rank: full_bytes / p.tp as u64,
                kind: CommKind::Reshard,
                label: format!("reshard-bc-s{stage}-g{}", p.group),
            });
        }
    }
    let leaders: Vec<u32> = participants.iter().map(|p| p.ranks[0]).collect();
    let sync = CollectiveDef {
        id: alloc(),
        algo: CollectiveAlgo::AllReduceRing,
        ranks: leaders,
        bytes_per_rank: full_bytes,
        kind: CommKind::Dp,
        label: format!("dp-sync-resharded-s{stage}"),
    };
    ReshardPlan { pre, sync, post }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(group: u32, tp: u32, mbs: u64, base_rank: u32) -> DpParticipant {
        DpParticipant {
            group,
            ranks: (base_rank..base_rank + tp).collect(),
            tp,
            batch_share: 16,
            micro_batch: mbs,
        }
    }

    #[test]
    fn tp_mismatch_triggers_resharding() {
        // paper §3 condition (2)
        assert!(needs_resharding(&part(0, 3, 1, 0), &part(1, 1, 1, 3)));
        assert!(!needs_resharding(&part(0, 4, 1, 0), &part(1, 4, 1, 4)));
    }

    #[test]
    fn microbatch_mismatch_triggers_resharding() {
        // paper §3 condition (1)
        assert!(needs_resharding(&part(0, 2, 4, 0), &part(1, 2, 8, 2)));
    }

    #[test]
    fn uniform_group_skips_resharding() {
        let parts = vec![part(0, 4, 8, 0), part(1, 4, 8, 4), part(2, 4, 8, 8)];
        assert!(!group_needs_resharding(&parts));
    }

    #[test]
    fn plan_emits_pre_sync_post() {
        let parts = vec![part(0, 3, 1, 0), part(1, 1, 1, 3)];
        let mut id = 100;
        let p = plan(&parts, 1 << 30, 0, &mut id);
        // only the tp=3 group needs gather/scatter
        assert_eq!(p.pre.len(), 1);
        assert_eq!(p.post.len(), 1);
        assert_eq!(p.sync.ranks, vec![0, 3]); // leaders
        assert_eq!(p.sync.bytes_per_rank, 1 << 30);
        assert_eq!(id, 103);
    }

    #[test]
    fn plan_ids_unique_and_labeled() {
        let parts = vec![part(0, 2, 1, 0), part(1, 4, 1, 2)];
        let mut id = 0;
        let p = plan(&parts, 4096, 7, &mut id);
        let defs = p.all_defs();
        let mut ids: Vec<u64> = defs.iter().map(|d| d.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), defs.len());
        assert!(defs.iter().any(|d| d.label.contains("s7")));
    }

    #[test]
    fn reshard_traffic_exceeds_uniform_sync() {
        use crate::config::presets;
        use crate::system::collective::{CollectiveExec, RingPolicy};
        let c = presets::cluster("ampere", 1).unwrap();
        let parts = vec![part(0, 3, 1, 0), part(1, 1, 1, 3)];
        let mut id = 0;
        let full = 3 << 20;
        let p = plan(&parts, full, 0, &mut id);
        let planned: u64 = p
            .all_defs()
            .iter()
            .map(|d| CollectiveExec::plan(&c, d, RingPolicy::Naive).total_bytes())
            .sum();
        // a uniform per-shard sync would move ~2*(n-1)/n * full
        let uniform = CollectiveExec::plan(
            &c,
            &CollectiveDef {
                id: 99,
                algo: CollectiveAlgo::AllReduceRing,
                ranks: vec![0, 3],
                bytes_per_rank: full,
                kind: CommKind::Dp,
                label: "u".into(),
            },
            RingPolicy::Naive,
        )
        .total_bytes();
        assert!(planned > uniform, "reshard {planned} <= uniform {uniform}");
    }
}
