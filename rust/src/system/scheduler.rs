//! The event-driven training-iteration scheduler (paper §4.2 System
//! layer: "coordinates the event stream between the compute and network
//! simulators, and ensures accurate modeling of event dependencies,
//! resharding delays, and bandwidth contention").
//!
//! Each rank executes its [`RankProgram`] in order. Compute ops run on
//! the rank's GPU (duration from the cost table — the bottleneck-device
//! rule of component C4 emerges naturally: a TP group's collective
//! cannot start until its slowest member arrives). `Collective` and
//! `Recv` ops block; `Send` is asynchronous. Collectives expand into
//! step-synchronized flow batches on the fluid network simulator.

use std::collections::HashMap;

use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::engine::trace::{TraceCategory, TraceRecorder};
use crate::engine::Engine;
use crate::network::flow::{FlowId, FlowSim, FlowSpec};
use crate::network::topology::Topology;
use crate::util::stats::Samples;
use crate::util::units::Time;
use crate::workload::op::{Op, Workload};

use super::collective::{CollectiveExec, CommKind, RingPolicy};

/// Tag space split: collective defs use their id; p2p messages are
/// offset so the two never collide.
pub const MSG_TAG_BASE: u64 = 1 << 62;

/// Engine event payload.
#[derive(Debug, Clone, Copy)]
pub enum SimEvent {
    ComputeDone { rank: u32 },
    FlowDone(FlowId),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RankState {
    Ready,
    Computing,
    BlockedCollective(u64),
    BlockedRecv(u64),
    Finished,
}

#[derive(Debug)]
struct CollState {
    arrived: usize,
    expected: usize,
    exec: Option<CollectiveExec>,
    start: Time,
    /// Per-rank arrival time at the collective: the moment the rank
    /// *posted* its sends (SimAI semantics). Early posters' flows carry
    /// the straggler wait in their recorded FCT.
    arrivals: HashMap<u32, Time>,
}

#[derive(Debug, Default)]
struct MsgState {
    delivered: bool,
    waiting: Option<u32>,
}

/// Result of one simulated iteration.
#[derive(Debug)]
pub struct SchedulerReport {
    pub iteration_time: Time,
    /// FCT samples (seconds) per communication kind — the Fig-6 data.
    pub fct_by_kind: HashMap<&'static str, Samples>,
    /// All FCTs pooled.
    pub fct_all: Samples,
    pub flows_completed: usize,
    pub events_processed: u64,
    pub compute_busy: Time,
    pub comm_busy: Time,
    pub trace: TraceRecorder,
}

/// The scheduler. Borrows the immutable inputs; owns the mutable
/// simulation state for one run.
pub struct Scheduler<'a> {
    workload: &'a Workload,
    cluster: &'a ClusterSpec,
    cost: &'a CostTable,
    pub ring_policy: RingPolicy,
    pub record_trace: bool,

    flows: FlowSim,
    /// rank -> index into workload.programs (O(1) advance dispatch)
    prog_idx: HashMap<u32, usize>,
    pc: HashMap<u32, usize>,
    state: HashMap<u32, RankState>,
    colls: HashMap<u64, CollState>,
    msgs: HashMap<u64, MsgState>,
    tag_kind: HashMap<u64, CommKind>,
    trace: TraceRecorder,
}

impl<'a> Scheduler<'a> {
    pub fn new(
        workload: &'a Workload,
        cluster: &'a ClusterSpec,
        cost: &'a CostTable,
    ) -> anyhow::Result<Self> {
        let topo = Topology::build(cluster)?;
        let mut tag_kind = HashMap::new();
        let mut colls = HashMap::new();
        for def in &workload.collectives {
            tag_kind.insert(def.id, def.kind);
            colls.insert(
                def.id,
                CollState {
                    arrived: 0,
                    expected: def.ranks.len(),
                    exec: None,
                    start: Time::ZERO,
                    arrivals: HashMap::new(),
                },
            );
        }
        Ok(Scheduler {
            workload,
            cluster,
            cost,
            ring_policy: RingPolicy::HeteroAware,
            record_trace: false,
            flows: FlowSim::new(topo),
            prog_idx: workload
                .programs
                .iter()
                .enumerate()
                .map(|(i, p)| (p.rank, i))
                .collect(),
            pc: workload.programs.iter().map(|p| (p.rank, 0)).collect(),
            state: workload.programs.iter().map(|p| (p.rank, RankState::Ready)).collect(),
            colls,
            msgs: HashMap::new(),
            tag_kind,
            trace: TraceRecorder::new(false),
        })
    }

    /// Run one iteration to completion.
    pub fn run(mut self) -> anyhow::Result<SchedulerReport> {
        self.trace = TraceRecorder::new(self.record_trace);
        let mut eng: Engine<SimEvent> = Engine::new();
        eng.max_events = 500_000_000;

        let ranks: Vec<u32> = self.workload.programs.iter().map(|p| p.rank).collect();
        for r in &ranks {
            self.advance(&mut eng, *r)?;
        }
        while let Some(ev) = eng.step() {
            match ev.payload {
                SimEvent::ComputeDone { rank } => {
                    *self.pc.get_mut(&rank).unwrap() += 1;
                    self.state.insert(rank, RankState::Ready);
                    self.advance(&mut eng, rank)?;
                }
                SimEvent::FlowDone(fid) => {
                    let rec = self.flows.on_complete(&mut eng, fid, ev.id, &SimEvent::FlowDone);
                    if let Some(rec) = rec {
                        self.on_flow_done(&mut eng, rec.tag)?;
                    }
                }
            }
        }

        // deadlock / starvation check
        let stuck: Vec<(u32, RankState)> = self
            .state
            .iter()
            .filter(|(_, s)| **s != RankState::Finished)
            .map(|(r, s)| (*r, *s))
            .collect();
        anyhow::ensure!(
            stuck.is_empty(),
            "iteration deadlocked: {} ranks unfinished, e.g. {:?}",
            stuck.len(),
            &stuck[..stuck.len().min(4)]
        );

        // assemble report
        let mut fct_by_kind: HashMap<&'static str, Samples> = HashMap::new();
        let mut fct_all = Samples::with_capacity(self.flows.records.len());
        for rec in &self.flows.records {
            let kind = self
                .tag_kind
                .get(&rec.tag)
                .map(|k| k.name())
                .unwrap_or(if rec.tag >= MSG_TAG_BASE { "PP" } else { "?" });
            let secs = rec.fct().as_secs();
            fct_by_kind.entry(kind).or_default().push(secs);
            fct_all.push(secs);
        }
        let flows_completed = self.flows.records.len();
        Ok(SchedulerReport {
            iteration_time: eng.now(),
            fct_by_kind,
            fct_all,
            flows_completed,
            events_processed: eng.processed(),
            compute_busy: self.trace.busy_by_category(TraceCategory::Compute),
            comm_busy: self.trace.busy_by_category(TraceCategory::Communication),
            trace: self.trace,
        })
    }

    /// Execute ops for `rank` until it blocks or finishes.
    fn advance(&mut self, eng: &mut Engine<SimEvent>, rank: u32) -> anyhow::Result<()> {
        let prog = &self.workload.programs[*self
            .prog_idx
            .get(&rank)
            .ok_or_else(|| anyhow::anyhow!("no program for rank {rank}"))?];
        loop {
            let pc = self.pc[&rank];
            if pc >= prog.ops.len() {
                self.state.insert(rank, RankState::Finished);
                return Ok(());
            }
            match &prog.ops[pc] {
                Op::Compute { work, label } => {
                    let gpu = self
                        .cluster
                        .gpu_of_rank(rank)
                        .ok_or_else(|| anyhow::anyhow!("rank {rank} outside cluster"))?;
                    let dur = self.cost.time(work, gpu)?;
                    let now = eng.now();
                    self.trace.record(rank, TraceCategory::Compute, *label, now, now + dur);
                    eng.schedule_in(dur, SimEvent::ComputeDone { rank });
                    self.state.insert(rank, RankState::Computing);
                    return Ok(());
                }
                Op::Collective { def_id } => {
                    let def_id = *def_id;
                    self.state.insert(rank, RankState::BlockedCollective(def_id));
                    let ready = {
                        let now = eng.now();
                        let st = self
                            .colls
                            .get_mut(&def_id)
                            .ok_or_else(|| anyhow::anyhow!("unknown collective {def_id}"))?;
                        st.arrived += 1;
                        st.arrivals.insert(rank, now);
                        anyhow::ensure!(
                            st.arrived <= st.expected,
                            "collective {def_id} over-subscribed"
                        );
                        st.arrived == st.expected
                    };
                    if ready {
                        self.launch_collective(eng, def_id)?;
                    }
                    return Ok(());
                }
                Op::Send { peer, bytes, msg } => {
                    let tag = MSG_TAG_BASE + msg;
                    self.msgs.entry(*msg).or_default();
                    self.flows.start(
                        eng,
                        FlowSpec { src: rank, dst: *peer, bytes: *bytes, tag },
                        &SimEvent::FlowDone,
                    );
                    *self.pc.get_mut(&rank).unwrap() += 1;
                }
                Op::Recv { msg } => {
                    let st = self.msgs.entry(*msg).or_default();
                    if st.delivered {
                        *self.pc.get_mut(&rank).unwrap() += 1;
                    } else {
                        anyhow::ensure!(
                            st.waiting.is_none(),
                            "two ranks waiting on message {msg}"
                        );
                        st.waiting = Some(rank);
                        self.state.insert(rank, RankState::BlockedRecv(*msg));
                        return Ok(());
                    }
                }
            }
        }
    }

    fn launch_collective(&mut self, eng: &mut Engine<SimEvent>, def_id: u64) -> anyhow::Result<()> {
        let def = self
            .workload
            .collective(def_id)
            .ok_or_else(|| anyhow::anyhow!("unknown collective {def_id}"))?;
        let mut exec = CollectiveExec::plan(self.cluster, def, self.ring_policy);
        let start = eng.now();
        if exec.is_done() {
            // degenerate (single rank / zero bytes): completes instantly
            self.finish_collective(eng, def_id, start)?;
            return Ok(());
        }
        let step: Vec<FlowSpec> = exec.next_step().unwrap().to_vec();
        // First-step flows are posted at each sender's arrival time
        // (SimAI/ns-3 semantics): early posters' FCT absorbs the
        // straggler wait — the source of the paper's Fig-6 hetero tails.
        let posted: Vec<Time> = {
            let st = &self.colls[&def_id];
            step.iter().map(|f| st.arrivals.get(&f.src).copied().unwrap_or(start)).collect()
        };
        self.flows.start_many_posted(eng, &step, Some(&posted), &SimEvent::FlowDone);
        let st = self.colls.get_mut(&def_id).unwrap();
        st.exec = Some(exec);
        st.start = start;
        Ok(())
    }

    fn on_flow_done(&mut self, eng: &mut Engine<SimEvent>, tag: u64) -> anyhow::Result<()> {
        if tag >= MSG_TAG_BASE {
            // p2p message delivered
            let msg = tag - MSG_TAG_BASE;
            let st = self.msgs.entry(msg).or_default();
            st.delivered = true;
            if let Some(rank) = st.waiting.take() {
                *self.pc.get_mut(&rank).unwrap() += 1;
                self.state.insert(rank, RankState::Ready);
                self.advance(eng, rank)?;
            }
            return Ok(());
        }
        // collective flow
        let (step_finished, next): (bool, Option<Vec<FlowSpec>>) = {
            let st = self
                .colls
                .get_mut(&tag)
                .ok_or_else(|| anyhow::anyhow!("flow for unknown collective {tag}"))?;
            let exec = st.exec.as_mut().ok_or_else(|| anyhow::anyhow!("collective {tag} not launched"))?;
            if exec.flow_done() {
                let next = exec.next_step().map(|s| s.to_vec());
                (true, next)
            } else {
                (false, None)
            }
        };
        if step_finished {
            match next {
                Some(step) => {
                    // All chunks of a collective are posted when the
                    // sender arrives (NCCL enqueues the full send
                    // schedule), so later steps' FCTs also measure from
                    // arrival — ns-3 flow semantics.
                    let posted: Vec<Time> = {
                        let st = &self.colls[&tag];
                        step.iter()
                            .map(|f| st.arrivals.get(&f.src).copied().unwrap_or(st.start))
                            .collect()
                    };
                    self.flows.start_many_posted(eng, &step, Some(&posted), &SimEvent::FlowDone);
                }
                None => {
                    let start = self.colls[&tag].start;
                    self.finish_collective(eng, tag, start)?;
                }
            }
        }
        Ok(())
    }

    fn finish_collective(
        &mut self,
        eng: &mut Engine<SimEvent>,
        def_id: u64,
        start: Time,
    ) -> anyhow::Result<()> {
        let def = self.workload.collective(def_id).unwrap();
        let now = eng.now();
        if self.record_trace {
            let r0 = def.ranks.first().copied().unwrap_or(0);
            self.trace.record(r0, TraceCategory::Communication, def.label.clone(), start, now);
        }
        // unblock all participants
        for r in def.ranks.clone() {
            if self.state.get(&r) == Some(&RankState::BlockedCollective(def_id)) {
                *self.pc.get_mut(&r).unwrap() += 1;
                self.state.insert(r, RankState::Ready);
                self.advance(eng, r)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::cost::LayerWork;
    use crate::config::model::LayerKind;
    use crate::config::presets;
    use crate::system::collective::{CollectiveAlgo, CollectiveDef};
    use crate::workload::op::RankProgram;

    fn lw(mbs: f64) -> LayerWork {
        LayerWork {
            kind: LayerKind::Mlp,
            hidden: 1024.0,
            ffn: 4096.0,
            heads: 8.0,
            seq: 512.0,
            mbs,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    fn cost_for(works: &[LayerWork], cluster: &ClusterSpec) -> CostTable {
        let mut t = CostTable::native();
        for w in works {
            for n in &cluster.nodes {
                t.register(w, &n.gpu);
            }
        }
        t.evaluate().unwrap();
        t
    }

    #[test]
    fn pure_compute_program_runs() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram {
                rank: 0,
                ops: vec![
                    Op::Compute { work: lw(1.0), label: "mlp" },
                    Op::Compute { work: lw(1.0), label: "mlp" },
                ],
            }],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(1.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        let expect = 2.0 * crate::compute::cost::NativeCostModel
            .time_seconds(&lw(1.0), &c.nodes[0].gpu);
        assert!((rep.iteration_time.as_secs() - expect).abs() / expect < 1e-4);
    }

    #[test]
    fn collective_blocks_until_all_arrive() {
        // rank 1 computes first; the collective must not finish before
        // rank 1 arrives, so iteration > compute time.
        let c = presets::cluster("hopper", 1).unwrap();
        let coll = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 1],
            bytes_per_rank: 1 << 20,
            kind: CommKind::Tp,
            label: "tp".into(),
        };
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 0 }] },
                RankProgram {
                    rank: 1,
                    ops: vec![
                        Op::Compute { work: lw(8.0), label: "mlp" },
                        Op::Collective { def_id: 0 },
                    ],
                },
            ],
            collectives: vec![coll],
        };
        let cost = cost_for(&[lw(8.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        let compute =
            crate::compute::cost::NativeCostModel.time_seconds(&lw(8.0), &c.nodes[0].gpu);
        assert!(rep.iteration_time.as_secs() > compute);
        assert!(rep.flows_completed > 0);
        assert!(rep.fct_by_kind.contains_key("TP"));
    }

    #[test]
    fn send_recv_pairs_deliver() {
        let c = presets::cluster("hopper", 2).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Send { peer: 8, bytes: 1 << 20, msg: 1 }] },
                RankProgram {
                    rank: 8,
                    ops: vec![Op::Recv { msg: 1 }, Op::Compute { work: lw(1.0), label: "mlp" }],
                },
            ],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(1.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        assert_eq!(rep.flows_completed, 1);
        assert!(rep.fct_by_kind.contains_key("PP"));
        assert!(rep.iteration_time > Time::ZERO);
    }

    #[test]
    fn recv_before_send_blocks_not_deadlocks() {
        let c = presets::cluster("hopper", 1).unwrap();
        // rank 1 recvs immediately; rank 0 computes, then sends
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Compute { work: lw(4.0), label: "mlp" },
                        Op::Send { peer: 1, bytes: 4096, msg: 9 },
                    ],
                },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 9 }] },
            ],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(4.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        assert_eq!(rep.flows_completed, 1);
    }

    #[test]
    fn true_deadlock_detected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram { rank: 0, ops: vec![Op::Recv { msg: 42 }] }],
            collectives: vec![],
        };
        let cost = CostTable::native();
        let err = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn hetero_collective_bottlenecked_by_slow_member() {
        // same collective on a homogeneous-hopper vs hetero cluster: the
        // hetero one is slower because the A100 member computes longer
        // before arriving (bottleneck-device rule, component C4).
        let coll = |_ranks: Vec<u32>| CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 22,
            kind: CommKind::Dp,
            label: "dp".into(),
        };
        let mk = |cluster: &ClusterSpec| {
            let w = Workload {
                programs: vec![
                    RankProgram {
                        rank: 0,
                        ops: vec![
                            Op::Compute { work: lw(8.0), label: "mlp" },
                            Op::Collective { def_id: 0 },
                        ],
                    },
                    RankProgram {
                        rank: 8,
                        ops: vec![
                            Op::Compute { work: lw(8.0), label: "mlp" },
                            Op::Collective { def_id: 0 },
                        ],
                    },
                ],
                collectives: vec![coll(vec![0, 8])],
            };
            let cost = cost_for(&[lw(8.0)], cluster);
            Scheduler::new(&w, cluster, &cost).unwrap().run().unwrap().iteration_time
        };
        let homo = mk(&presets::cluster("hopper", 2).unwrap());
        let hetero = mk(&presets::cluster_hetero(1, 1).unwrap());
        assert!(hetero > homo, "hetero {hetero} <= homo {homo}");
    }
}
