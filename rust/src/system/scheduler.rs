//! The event-driven training-iteration scheduler (paper §4.2 System
//! layer: "coordinates the event stream between the compute and network
//! simulators, and ensures accurate modeling of event dependencies,
//! resharding delays, and bandwidth contention").
//!
//! Each rank executes its program in order. Compute ops run on the
//! rank's GPU (duration pre-resolved by [`CompiledWorkload`] — the
//! bottleneck-device rule of component C4 emerges naturally: a TP
//! group's collective cannot start until its slowest member arrives).
//! `Collective` and `Recv` ops block; `Send` is asynchronous.
//! Collectives expand into step-synchronized flow batches on the fluid
//! network simulator.
//!
//! **Dense-state hot path**: all per-rank (`pc`, `state`, arrival),
//! per-collective and per-message state lives in `Vec`s indexed by the
//! compact ids assigned at compile time ([`crate::system::compiled`]);
//! the event loop performs no hash lookups and no per-launch collective
//! planning. `benches/perf_engine.rs` compares this against the seed's
//! `HashMap`-keyed scheduler.

use std::collections::HashMap;
use std::sync::Arc;

use crate::compute::table::CostTable;
use crate::config::cluster::{ClusterSpec, RankIdx};
use crate::engine::trace::{TraceCategory, TraceRecorder};
use crate::engine::Engine;
use crate::network::flow::{FlowId, FlowSim, FlowSpec};
use crate::network::topology::Topology;
use crate::util::stats::Samples;
use crate::util::units::Time;
use crate::workload::op::Workload;

use super::collective::RingPolicy;
use super::compiled::{CompiledWorkload, DenseOp, FoldedMeta};
use super::failure::{faulted_links, FaultReport, IterationFaults};

/// Tag space split: collective flows use their dense id; p2p messages
/// are offset so the two never collide.
pub const MSG_TAG_BASE: u64 = 1 << 62;

/// Engine event payload.
#[derive(Debug, Clone, Copy)]
pub enum SimEvent {
    /// A rank finished its current compute op.
    ComputeDone {
        /// The finishing global rank.
        rank: u32,
    },
    /// A network flow delivered its last byte.
    FlowDone(FlowId),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RankState {
    Ready,
    Computing,
    BlockedCollective(u32),
    BlockedRecv(u32),
    Finished,
}

/// Per-collective run state (dense, indexed by `cid`).
#[derive(Debug, Clone, Copy, Default)]
struct CollRun {
    arrived: u32,
    step: u32,
    outstanding: u32,
    start: Time,
}

/// Per-message delivery slot (dense, indexed by the compiled msg id).
/// Delivery is one-shot: a `Recv` consumes the flag.
#[derive(Debug, Clone, Copy)]
struct MsgSlot {
    delivered: bool,
    waiting: RankIdx,
}

impl Default for MsgSlot {
    fn default() -> Self {
        MsgSlot { delivered: false, waiting: RankIdx::NONE }
    }
}

/// Result of one simulated iteration.
#[derive(Debug)]
pub struct SchedulerReport {
    /// Simulated wall-clock time of the iteration.
    pub iteration_time: Time,
    /// FCT samples (seconds) per communication kind — the Fig-6 data.
    pub fct_by_kind: HashMap<&'static str, Samples>,
    /// All FCTs pooled.
    pub fct_all: Samples,
    /// Network flows completed during the iteration.
    pub flows_completed: usize,
    /// Discrete events the engine processed.
    pub events_processed: u64,
    /// Summed per-rank compute busy time. Accumulated directly by the
    /// run (two integer adds per op) — available even with trace
    /// recording off, so planner scoring never pays per-move timeline
    /// allocations for its compute/comm breakdown.
    pub compute_busy: Time,
    /// Summed collective busy time (same always-on accumulator).
    pub comm_busy: Time,
    /// Per-rank busy-interval trace (empty unless `record_trace`).
    pub trace: TraceRecorder,
    /// What an injected fail-stop did to the run (`None` for clean
    /// completions — including runs that finished *before* a scheduled
    /// fault would have struck).
    pub fault: Option<FaultReport>,
    /// True when the run was abandoned because the clock passed
    /// [`Scheduler::cutoff`] — the branch-and-bound incumbent-cutoff
    /// path (DESIGN.md §29). A cutoff-hit report's timing fields are
    /// partial and must not be ranked; a run that *completes* under a
    /// finite cutoff is bit-identical to the cutoff-free run.
    pub cutoff_hit: bool,
}

enum Source<'a> {
    /// Raw inputs; compiled lazily inside [`Scheduler::run`] so input
    /// errors (cost-table misses, bad ranks) surface at run time, after
    /// construction knobs like `ring_policy` are set.
    Raw { workload: &'a Workload, cost: &'a CostTable },
    /// A pre-compiled core borrowed from a [`crate::simulator::Simulation`]:
    /// zero per-run compilation, safe to share across threads.
    Prepared(&'a CompiledWorkload),
}

/// The scheduler. Borrows the immutable inputs; owns the mutable
/// simulation state for one run.
pub struct Scheduler<'a> {
    source: Source<'a>,
    cluster: &'a ClusterSpec,
    topology: Arc<Topology>,
    ring_policy: RingPolicy,
    /// Record the per-rank busy-interval trace during the run.
    pub record_trace: bool,
    /// Injected faults resolved against this iteration's window
    /// ([`crate::system::failure::FaultSpec::resolve_iteration`]);
    /// `None` runs the pristine fault-free path.
    pub faults: Option<IterationFaults>,
    /// Incumbent cutoff: abandon the run the moment the *next* event
    /// would land strictly past this time (the candidate can no longer
    /// beat the incumbent, so stop paying for its events). Checked with
    /// the same peek-before-dispatch pattern as fault aborts, so
    /// `None` — and any run that finishes at or under the cutoff — is
    /// bit-identical to the plain path. Strict `>` means a run whose
    /// final event lands exactly at the cutoff still completes, which
    /// is what keeps branch-and-bound exact under ties (DESIGN.md §29).
    pub cutoff: Option<Time>,
}

impl<'a> Scheduler<'a> {
    /// Build a lazily-compiling scheduler over raw workload inputs
    /// (compilation happens inside [`Scheduler::run`]).
    pub fn new(
        workload: &'a Workload,
        cluster: &'a ClusterSpec,
        cost: &'a CostTable,
    ) -> anyhow::Result<Self> {
        let topology = Arc::new(Topology::build(cluster)?);
        Ok(Scheduler {
            source: Source::Raw { workload, cost },
            cluster,
            topology,
            ring_policy: RingPolicy::HeteroAware,
            record_trace: false,
            faults: None,
            cutoff: None,
        })
    }

    /// Select the collective ring policy. Only meaningful for lazily
    /// compiled schedulers ([`Scheduler::new`]); a prepared workload's
    /// policy was fixed at compile time, and [`Scheduler::run`] errors
    /// on a mismatch instead of silently ignoring the request.
    pub fn with_ring_policy(mut self, policy: RingPolicy) -> Self {
        self.ring_policy = policy;
        self
    }

    /// Borrow a pre-compiled workload and shared topology. The ring
    /// policy is the one the workload was compiled with.
    pub fn prepared(
        compiled: &'a CompiledWorkload,
        cluster: &'a ClusterSpec,
        topology: Arc<Topology>,
    ) -> Self {
        let ring_policy = compiled.ring_policy;
        Scheduler {
            source: Source::Prepared(compiled),
            cluster,
            topology,
            ring_policy,
            record_trace: false,
            faults: None,
            cutoff: None,
        }
    }

    /// Run one iteration to completion.
    pub fn run(self) -> anyhow::Result<SchedulerReport> {
        let owned;
        let cw: &CompiledWorkload = match self.source {
            Source::Raw { workload, cost } => {
                owned = CompiledWorkload::compile(workload, self.cluster, cost, self.ring_policy)?;
                &owned
            }
            Source::Prepared(c) => {
                anyhow::ensure!(
                    self.ring_policy == c.ring_policy,
                    "prepared workload was compiled with {:?} rings; \
                     rebuild the simulation to run with {:?}",
                    c.ring_policy,
                    self.ring_policy
                );
                c
            }
        };
        let mut flows = FlowSim::new(self.topology.clone());
        let mut faults = self.faults;
        // Degraded mode (DESIGN.md §28): nodes inside an unexpired
        // NIC/link repair window lose their faulted links; the flow
        // model reroutes every affected pair around them for the whole
        // iteration. When some degraded node has *no* surviving route
        // the fault escalates to an immediate fail-stop instead.
        if let Some(f) = faults.as_mut() {
            if !f.degraded.is_empty() {
                let topo = &self.topology;
                let mut dead = Vec::new();
                for &(node, class) in &f.degraded {
                    dead.extend(faulted_links(topo, node, class));
                }
                let nodes = self.cluster.nodes.len() as u32;
                let severed = f.degraded.iter().copied().find(|&(node, _)| {
                    // one representative peer suffices: the per-node
                    // dead set affects every inter-node pair of the
                    // degraded node identically
                    (0..nodes).find(|&m| m != node).is_some_and(|other| {
                        let a = topo.rank_of(node, 0);
                        let b = topo.rank_of(other, 0);
                        crate::network::routing::route_avoiding(topo, a, b, &dead).is_none()
                            || crate::network::routing::route_avoiding(topo, b, a, &dead)
                                .is_none()
                    })
                });
                match severed {
                    Some((node, class)) => f.abort = Some((Time::ZERO, node, class)),
                    None => flows.set_dead_links(dead),
                }
            }
        }
        Exec::new(cw, flows, self.record_trace, faults, self.cutoff).run()
    }
}

/// Mutable state of one run over a borrowed compiled core.
struct Exec<'w> {
    cw: &'w CompiledWorkload,
    record_trace: bool,
    flows: FlowSim,
    /// Program counter per global rank.
    pc: Vec<u32>,
    state: Vec<RankState>,
    colls: Vec<CollRun>,
    /// Time each rank posted its current collective. A rank blocks on at
    /// most one collective at a time, so one slot per rank suffices;
    /// early posters' flows carry the straggler wait in their recorded
    /// FCT (SimAI semantics — the source of the paper's Fig-6 tails).
    arrival: Vec<Time>,
    msgs: Vec<MsgSlot>,
    trace: TraceRecorder,
    /// Always-on busy accumulators (see [`SchedulerReport`]).
    compute_busy: Time,
    comm_busy: Time,
    /// Reusable posted-time buffer for collective step launches.
    posted_scratch: Vec<Time>,
    /// Resolved fault injection for this window (`None` = pristine
    /// fault-free path: no per-event checks beyond one `Option` read).
    faults: Option<IterationFaults>,
    /// Incumbent cutoff (see [`Scheduler::cutoff`]); `None` costs one
    /// `Option` read per dispatched event, like `faults`.
    cutoff: Option<Time>,
}

/// Post time for a flow from `r`: the sender's own collective arrival,
/// or — when `r` is a folded rank with no program — the arrival of its
/// class twin, which by symmetry equals the time the folded rank would
/// have arrived. Free function (not a method) so the closure capturing
/// it stays disjoint from the `posted_scratch` borrow.
fn posted_of(arrival: &[Time], fold: Option<&FoldedMeta>, r: u32) -> Time {
    match fold {
        Some(f) => arrival[f.twin[r as usize] as usize],
        None => arrival[r as usize],
    }
}

impl<'w> Exec<'w> {
    fn new(
        cw: &'w CompiledWorkload,
        mut flows: FlowSim,
        record_trace: bool,
        faults: Option<IterationFaults>,
        cutoff: Option<Time>,
    ) -> Self {
        let world = cw.world as usize;
        // pre-size the flow slab and record store from compiled counts
        flows.reserve(
            cw.max_step_flows() + world,
            cw.planned_flow_count() + cw.num_msgs as usize,
        );
        Exec {
            cw,
            record_trace,
            flows,
            pc: vec![0; world],
            // vacant ranks start Finished so the deadlock scan skips them
            state: vec![RankState::Finished; world],
            colls: vec![CollRun::default(); cw.defs.len()],
            arrival: vec![Time::ZERO; world],
            msgs: vec![MsgSlot::default(); cw.num_msgs as usize],
            trace: TraceRecorder::new(record_trace),
            compute_busy: Time::ZERO,
            comm_busy: Time::ZERO,
            posted_scratch: Vec::with_capacity(cw.max_step_flows()),
            faults,
            cutoff,
        }
    }

    fn run(mut self) -> anyhow::Result<SchedulerReport> {
        let cw = self.cw;
        let mut eng: Engine<SimEvent> = Engine::with_capacity(cw.event_capacity_hint());
        eng.max_events = 500_000_000;

        for r in 0..cw.world {
            if cw.has_program[r as usize] {
                self.state[r as usize] = RankState::Ready;
                self.advance(&mut eng, r)?;
            }
        }
        // A scheduled fail-stop aborts the run the moment the *next*
        // event would land at or past the fault time — checked by
        // peeking before each dispatch, so a run that drains first is
        // byte-identical to the fault-free path (same clock, same
        // event count), and an aborted run never pops the event it
        // would have processed.
        let abort = self.faults.as_ref().and_then(|f| f.abort);
        let mut fault: Option<FaultReport> = None;
        // The incumbent cutoff reuses the same peek pattern, but with a
        // *strict* comparison: an event landing exactly at the cutoff
        // still runs, so a candidate tied with the incumbent completes
        // and stays rankable (the bnb grid-identity argument, §29).
        let cutoff = self.cutoff;
        let mut cutoff_hit = false;
        loop {
            if let Some((at, node, kind)) = abort {
                match eng.peek_time() {
                    None => break, // iteration completed before the fault
                    Some(t) if t >= at => {
                        // the whole partial iteration is lost work:
                        // gradient state dies with the fail-stop
                        fault = Some(FaultReport { at, node, kind, lost_work: at });
                        break;
                    }
                    Some(_) => {}
                }
            }
            if let Some(limit) = cutoff {
                match eng.peek_time() {
                    None => break, // completed at or under the cutoff
                    Some(t) if t > limit => {
                        cutoff_hit = true;
                        break;
                    }
                    Some(_) => {}
                }
            }
            let Some(ev) = eng.step() else { break };
            match ev.payload {
                SimEvent::ComputeDone { rank } => {
                    self.pc[rank as usize] += 1;
                    self.state[rank as usize] = RankState::Ready;
                    self.advance(&mut eng, rank)?;
                }
                SimEvent::FlowDone(fid) => {
                    let rec = self.flows.on_complete(&mut eng, fid, ev.id, &SimEvent::FlowDone);
                    if let Some(rec) = rec {
                        self.on_flow_done(&mut eng, rec.tag)?;
                    }
                }
            }
        }

        // deadlock / starvation check — not meaningful after an abort
        // (blocked ranks are exactly what a fail-stop or cutoff leaves
        // behind)
        if fault.is_none() && !cutoff_hit {
            let stuck: Vec<(u32, RankState)> = (0..cw.world)
                .filter(|&r| {
                    cw.has_program[r as usize] && self.state[r as usize] != RankState::Finished
                })
                .map(|r| (r, self.state[r as usize]))
                .collect();
            anyhow::ensure!(
                stuck.is_empty(),
                "iteration deadlocked: {} ranks unfinished, e.g. {:?}",
                stuck.len(),
                &stuck[..stuck.len().min(4)]
            );
        }

        // assemble report
        let mut fct_by_kind: HashMap<&'static str, Samples> = HashMap::new();
        let mut fct_all = Samples::with_capacity(self.flows.records.len());
        for rec in &self.flows.records {
            let kind = if rec.tag >= MSG_TAG_BASE {
                "PP"
            } else {
                cw.kinds[rec.tag as usize].name()
            };
            let secs = rec.fct().as_secs();
            fct_by_kind.entry(kind).or_default().push(secs);
            fct_all.push(secs);
        }
        let flows_completed = self.flows.records.len();
        debug_assert!(
            !self.record_trace
                || self.compute_busy == self.trace.busy_by_category(TraceCategory::Compute),
            "compute-busy accumulator diverged from the recorded trace"
        );
        Ok(SchedulerReport {
            // an aborted iteration ends at the fault, not at the last
            // event that happened to complete before it
            iteration_time: fault.map(|f| f.at).unwrap_or_else(|| eng.now()),
            fct_by_kind,
            fct_all,
            flows_completed,
            events_processed: eng.processed(),
            compute_busy: self.compute_busy,
            comm_busy: self.comm_busy,
            trace: self.trace,
            fault,
            cutoff_hit,
        })
    }

    /// Execute ops for `rank` until it blocks or finishes.
    fn advance(&mut self, eng: &mut Engine<SimEvent>, rank: u32) -> anyhow::Result<()> {
        let cw = self.cw;
        let r = rank as usize;
        let ops = &cw.ops[r];
        loop {
            let pc = self.pc[r] as usize;
            if pc >= ops.len() {
                self.state[r] = RankState::Finished;
                return Ok(());
            }
            match ops[pc] {
                DenseOp::Compute { dur, label } => {
                    let now = eng.now();
                    // Straggler injection: scale this rank's compute.
                    // Guarded on != 1.0 so the healthy path never
                    // round-trips a picosecond count through f64.
                    let dur = match &self.faults {
                        Some(f) if f.slow[r] != 1.0 => {
                            Time((dur.as_ps() as f64 * f.slow[r]).round() as u64)
                        }
                        _ => dur,
                    };
                    // Under symmetry folding a representative rank's
                    // compute stands for its whole class; weight the
                    // accumulator so the report shows unfolded totals.
                    self.compute_busy += match &cw.fold {
                        Some(f) => dur * f.rank_mult[r],
                        None => dur,
                    };
                    self.trace.record(rank, TraceCategory::Compute, label, now, now + dur);
                    eng.schedule_in(dur, SimEvent::ComputeDone { rank });
                    self.state[r] = RankState::Computing;
                    return Ok(());
                }
                DenseOp::Collective { cid } => {
                    self.state[r] = RankState::BlockedCollective(cid);
                    self.arrival[r] = eng.now();
                    let expected = cw.expected[cid as usize];
                    let c = &mut self.colls[cid as usize];
                    c.arrived += 1;
                    anyhow::ensure!(
                        c.arrived <= expected,
                        "collective '{}' over-subscribed",
                        cw.defs[cid as usize].label
                    );
                    if c.arrived == expected {
                        self.launch(eng, cid)?;
                    }
                    return Ok(());
                }
                DenseOp::Send { peer, bytes, msg } => {
                    let tag = MSG_TAG_BASE + msg as u64;
                    self.flows.start(
                        eng,
                        FlowSpec { src: rank, dst: peer.0, bytes, tag },
                        &SimEvent::FlowDone,
                    );
                    self.pc[r] += 1;
                }
                DenseOp::Recv { msg } => {
                    let slot = &mut self.msgs[msg as usize];
                    if slot.delivered {
                        slot.delivered = false; // one-shot consumption
                        self.pc[r] += 1;
                    } else {
                        anyhow::ensure!(
                            slot.waiting.is_none(),
                            "two ranks waiting on p2p message tag {}",
                            cw.msg_tags[msg as usize]
                        );
                        slot.waiting = RankIdx(rank);
                        self.state[r] = RankState::BlockedRecv(msg);
                        return Ok(());
                    }
                }
            }
        }
    }

    /// All participants arrived: post the first pre-planned flow step.
    fn launch(&mut self, eng: &mut Engine<SimEvent>, cid: u32) -> anyhow::Result<()> {
        let cw = self.cw;
        let steps = &cw.steps[cid as usize];
        let start = eng.now();
        if steps.is_empty() {
            // degenerate (single rank / zero bytes): completes instantly
            return self.finish(eng, cid, start);
        }
        let step = &steps[0];
        {
            let c = &mut self.colls[cid as usize];
            c.step = 0;
            c.outstanding = step.len() as u32;
            c.start = start;
        }
        // Flows are posted at each sender's arrival time (SimAI/ns-3
        // semantics): early posters' FCT absorbs the straggler wait.
        self.posted_scratch.clear();
        let fold = cw.fold.as_ref();
        self.posted_scratch
            .extend(step.iter().map(|f| posted_of(&self.arrival, fold, f.src)));
        self.flows.start_many_posted(eng, step, Some(&self.posted_scratch), &SimEvent::FlowDone);
        Ok(())
    }

    fn on_flow_done(&mut self, eng: &mut Engine<SimEvent>, tag: u64) -> anyhow::Result<()> {
        let cw = self.cw;
        if tag >= MSG_TAG_BASE {
            // p2p message delivered (one-shot)
            let msg = (tag - MSG_TAG_BASE) as usize;
            let waiting = self.msgs[msg].waiting;
            if waiting.is_none() {
                self.msgs[msg].delivered = true;
            } else {
                self.msgs[msg].waiting = RankIdx::NONE;
                self.pc[waiting.idx()] += 1;
                self.state[waiting.idx()] = RankState::Ready;
                self.advance(eng, waiting.0)?;
            }
            return Ok(());
        }
        // collective flow
        let cid = tag as usize;
        {
            let c = &mut self.colls[cid];
            debug_assert!(c.outstanding > 0, "flow for idle collective {cid}");
            c.outstanding -= 1;
            if c.outstanding > 0 {
                return Ok(());
            }
            c.step += 1;
        }
        let next = self.colls[cid].step as usize;
        if next < cw.steps[cid].len() {
            // All chunks of a collective are posted when the sender
            // arrives (NCCL enqueues the full send schedule), so later
            // steps' FCTs also measure from arrival — ns-3 semantics.
            let step = &cw.steps[cid][next];
            self.colls[cid].outstanding = step.len() as u32;
            self.posted_scratch.clear();
            let fold = cw.fold.as_ref();
            self.posted_scratch
                .extend(step.iter().map(|f| posted_of(&self.arrival, fold, f.src)));
            self.flows.start_many_posted(eng, step, Some(&self.posted_scratch), &SimEvent::FlowDone);
            Ok(())
        } else {
            let start = self.colls[cid].start;
            self.finish(eng, cid as u32, start)
        }
    }

    fn finish(&mut self, eng: &mut Engine<SimEvent>, cid: u32, start: Time) -> anyhow::Result<()> {
        let cw = self.cw;
        let def = &cw.defs[cid as usize];
        let now = eng.now();
        // Weighted like compute: a representative group's collective
        // stands for every replica in its class (DP-syncs weigh 1).
        self.comm_busy += match &cw.fold {
            Some(f) => (now - start) * f.coll_mult[cid as usize],
            None => now - start,
        };
        if self.record_trace {
            let r0 = def.ranks.first().copied().unwrap_or(0);
            self.trace.record(r0, TraceCategory::Communication, def.label.clone(), start, now);
        }
        // unblock all participants
        for &r in &def.ranks {
            if self.state[r as usize] == RankState::BlockedCollective(cid) {
                self.pc[r as usize] += 1;
                self.state[r as usize] = RankState::Ready;
                self.advance(eng, r)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::cost::LayerWork;
    use crate::config::model::LayerKind;
    use crate::config::presets;
    use crate::system::collective::{CollectiveAlgo, CollectiveDef, CommKind};
    use crate::workload::op::{Op, RankProgram};

    fn lw(mbs: f64) -> LayerWork {
        LayerWork {
            kind: LayerKind::Mlp,
            hidden: 1024.0,
            ffn: 4096.0,
            heads: 8.0,
            seq: 512.0,
            mbs,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    fn cost_for(works: &[LayerWork], cluster: &ClusterSpec) -> CostTable {
        let mut t = CostTable::native();
        for w in works {
            for n in &cluster.nodes {
                t.register(w, &n.gpu);
            }
        }
        t.evaluate().unwrap();
        t
    }

    #[test]
    fn pure_compute_program_runs() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram {
                rank: 0,
                ops: vec![
                    Op::Compute { work: lw(1.0), label: "mlp" },
                    Op::Compute { work: lw(1.0), label: "mlp" },
                ],
            }],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(1.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        let expect = 2.0 * crate::compute::cost::NativeCostModel
            .time_seconds(&lw(1.0), &c.nodes[0].gpu);
        assert!((rep.iteration_time.as_secs() - expect).abs() / expect < 1e-4);
    }

    #[test]
    fn collective_blocks_until_all_arrive() {
        // rank 1 computes first; the collective must not finish before
        // rank 1 arrives, so iteration > compute time.
        let c = presets::cluster("hopper", 1).unwrap();
        let coll = CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 1],
            bytes_per_rank: 1 << 20,
            kind: CommKind::Tp,
            label: "tp".into(),
        };
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 0 }] },
                RankProgram {
                    rank: 1,
                    ops: vec![
                        Op::Compute { work: lw(8.0), label: "mlp" },
                        Op::Collective { def_id: 0 },
                    ],
                },
            ],
            collectives: vec![coll],
        };
        let cost = cost_for(&[lw(8.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        let compute =
            crate::compute::cost::NativeCostModel.time_seconds(&lw(8.0), &c.nodes[0].gpu);
        assert!(rep.iteration_time.as_secs() > compute);
        assert!(rep.flows_completed > 0);
        assert!(rep.fct_by_kind.contains_key("TP"));
    }

    #[test]
    fn send_recv_pairs_deliver() {
        let c = presets::cluster("hopper", 2).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Send { peer: 8, bytes: 1 << 20, msg: 1 }] },
                RankProgram {
                    rank: 8,
                    ops: vec![Op::Recv { msg: 1 }, Op::Compute { work: lw(1.0), label: "mlp" }],
                },
            ],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(1.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        assert_eq!(rep.flows_completed, 1);
        assert!(rep.fct_by_kind.contains_key("PP"));
        assert!(rep.iteration_time > Time::ZERO);
    }

    #[test]
    fn recv_before_send_blocks_not_deadlocks() {
        let c = presets::cluster("hopper", 1).unwrap();
        // rank 1 recvs immediately; rank 0 computes, then sends
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Compute { work: lw(4.0), label: "mlp" },
                        Op::Send { peer: 1, bytes: 4096, msg: 9 },
                    ],
                },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 9 }] },
            ],
            collectives: vec![],
        };
        let cost = cost_for(&[lw(4.0)], &c);
        let rep = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        assert_eq!(rep.flows_completed, 1);
    }

    #[test]
    fn true_deadlock_detected() {
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![RankProgram { rank: 0, ops: vec![Op::Recv { msg: 42 }] }],
            collectives: vec![],
        };
        let cost = CostTable::native();
        let err = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn reused_message_tag_rejected_at_run() {
        // regression: the seed scheduler never consumed `delivered`, so
        // a reused tag let a second Recv complete instantly against the
        // stale delivery. Tags are now validated unique at compile time
        // and delivery is one-shot.
        let c = presets::cluster("hopper", 1).unwrap();
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Send { peer: 1, bytes: 4096, msg: 7 },
                        Op::Send { peer: 1, bytes: 4096, msg: 7 },
                    ],
                },
                RankProgram { rank: 1, ops: vec![Op::Recv { msg: 7 }, Op::Recv { msg: 7 }] },
            ],
            collectives: vec![],
        };
        let cost = CostTable::native();
        let err = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("reused"), "{err}");
        // the workload validator rejects it up front as well
        assert!(w.validate().is_err());
    }

    #[test]
    fn prepared_run_matches_lazy_run() {
        use std::sync::Arc;
        let c = presets::cluster_hetero(1, 1).unwrap();
        let coll = CollectiveDef {
            id: 9,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 22,
            kind: CommKind::Dp,
            label: "dp".into(),
        };
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Compute { work: lw(8.0), label: "mlp" },
                        Op::Collective { def_id: 9 },
                    ],
                },
                RankProgram {
                    rank: 8,
                    ops: vec![
                        Op::Compute { work: lw(8.0), label: "mlp" },
                        Op::Collective { def_id: 9 },
                    ],
                },
            ],
            collectives: vec![coll],
        };
        let cost = cost_for(&[lw(8.0)], &c);
        let lazy = Scheduler::new(&w, &c, &cost).unwrap().run().unwrap();
        let compiled =
            CompiledWorkload::compile(&w, &c, &cost, RingPolicy::HeteroAware).unwrap();
        let topo = Arc::new(Topology::build(&c).unwrap());
        let prepared = Scheduler::prepared(&compiled, &c, topo).run().unwrap();
        assert_eq!(lazy.iteration_time, prepared.iteration_time);
        assert_eq!(lazy.flows_completed, prepared.flows_completed);
        assert_eq!(lazy.events_processed, prepared.events_processed);
    }

    #[test]
    fn hetero_collective_bottlenecked_by_slow_member() {
        // same collective on a homogeneous-hopper vs hetero cluster: the
        // hetero one is slower because the A100 member computes longer
        // before arriving (bottleneck-device rule, component C4).
        let coll = |_ranks: Vec<u32>| CollectiveDef {
            id: 0,
            algo: CollectiveAlgo::AllReduceRing,
            ranks: vec![0, 8],
            bytes_per_rank: 1 << 22,
            kind: CommKind::Dp,
            label: "dp".into(),
        };
        let mk = |cluster: &ClusterSpec| {
            let w = Workload {
                programs: vec![
                    RankProgram {
                        rank: 0,
                        ops: vec![
                            Op::Compute { work: lw(8.0), label: "mlp" },
                            Op::Collective { def_id: 0 },
                        ],
                    },
                    RankProgram {
                        rank: 8,
                        ops: vec![
                            Op::Compute { work: lw(8.0), label: "mlp" },
                            Op::Collective { def_id: 0 },
                        ],
                    },
                ],
                collectives: vec![coll(vec![0, 8])],
            };
            let cost = cost_for(&[lw(8.0)], cluster);
            Scheduler::new(&w, cluster, &cost).unwrap().run().unwrap().iteration_time
        };
        let homo = mk(&presets::cluster("hopper", 2).unwrap());
        let hetero = mk(&presets::cluster_hetero(1, 1).unwrap());
        assert!(hetero > homo, "hetero {hetero} <= homo {homo}");
    }
}
