//! Runtime device-group views derived from a [`FrameworkSpec`]
//! (component **C1**: custom homogeneous/heterogeneous device groups and
//! their mapping to parallelism dimensions).

use crate::config::cluster::ClusterSpec;
use crate::config::framework::FrameworkSpec;

/// One DP synchronization group: the ranks holding the *same* model
/// shard across device groups (same stage, same TP slot) — or, when TP
/// degrees differ, the per-group participants that must reshard first.
#[derive(Debug, Clone, PartialEq)]
pub struct DpSyncGroup {
    /// Pipeline-stage index the group synchronizes.
    pub stage: u32,
    /// (device-group id, ranks of that group participating, tp degree,
    /// batch share) per participant.
    pub participants: Vec<DpParticipant>,
}

/// One device group's contribution to a DP sync group.
#[derive(Debug, Clone, PartialEq)]
pub struct DpParticipant {
    /// Device-group id.
    pub group: u32,
    /// The group's ranks at this stage (its TP group).
    pub ranks: Vec<u32>,
    /// TP degree of that stage.
    pub tp: u32,
    /// Samples of the global batch the group trains per iteration.
    pub batch_share: u64,
    /// Microbatch size the group runs.
    pub micro_batch: u64,
}

/// A pipeline edge between consecutive stages of one device group.
#[derive(Debug, Clone, PartialEq)]
pub struct PpEdge {
    /// Device-group id.
    pub group: u32,
    /// Producing stage index (`from_stage + 1` consumes).
    pub from_stage: u32,
    /// Ranks of the producing stage.
    pub from_ranks: Vec<u32>,
    /// Ranks of the consuming stage.
    pub to_ranks: Vec<u32>,
}

/// All derived group structure for a framework spec.
#[derive(Debug, Clone)]
pub struct DeviceGroups {
    /// TP groups: (device-group id, stage index, ranks).
    pub tp_groups: Vec<(u32, u32, Vec<u32>)>,
    /// DP sync groups, one per stage index with > 1 participant.
    pub dp_sync: Vec<DpSyncGroup>,
    /// Stage-boundary edges of every group's pipeline.
    pub pp_edges: Vec<PpEdge>,
}

impl DeviceGroups {
    /// Derive the runtime views from a validated framework spec.
    pub fn derive(fw: &FrameworkSpec) -> DeviceGroups {
        let mut tp_groups = Vec::new();
        let mut pp_edges = Vec::new();
        let max_stages = fw.groups.iter().map(|g| g.stages.len()).max().unwrap_or(0);

        for g in &fw.groups {
            for (s, stage) in g.stages.iter().enumerate() {
                tp_groups.push((g.id, s as u32, stage.ranks.clone()));
                if s + 1 < g.stages.len() {
                    pp_edges.push(PpEdge {
                        group: g.id,
                        from_stage: s as u32,
                        from_ranks: stage.ranks.clone(),
                        to_ranks: g.stages[s + 1].ranks.clone(),
                    });
                }
            }
        }

        // DP sync groups: align stages by index across device groups.
        // Groups with fewer stages simply do not participate at deeper
        // stage indices (non-uniform PP).
        let mut dp_sync = Vec::new();
        for s in 0..max_stages {
            let mut participants = Vec::new();
            for g in &fw.groups {
                if let Some(stage) = g.stages.get(s) {
                    participants.push(DpParticipant {
                        group: g.id,
                        ranks: stage.ranks.clone(),
                        tp: stage.tp(),
                        batch_share: g.batch_share,
                        micro_batch: g.micro_batch,
                    });
                }
            }
            if participants.len() > 1 {
                dp_sync.push(DpSyncGroup { stage: s as u32, participants });
            }
        }
        DeviceGroups { tp_groups, dp_sync, pp_edges }
    }

    /// Locality of a rank set: true if all ranks share one node.
    pub fn is_intra_node(cluster: &ClusterSpec, ranks: &[u32]) -> bool {
        let mut nodes = ranks.iter().map(|r| cluster.locate(*r).map(|(n, _)| n));
        let first = match nodes.next() {
            Some(Some(n)) => n,
            _ => return false,
        };
        nodes.all(|n| n == Some(first))
    }

    /// GPU architectures present in a rank set (for C3 graph generation).
    pub fn architectures<'c>(cluster: &'c ClusterSpec, ranks: &[u32]) -> Vec<&'c str> {
        let mut archs: Vec<&str> = Vec::new();
        for r in ranks {
            if let Some(g) = cluster.gpu_of_rank(*r) {
                if !archs.contains(&g.name.as_str()) {
                    archs.push(g.name.as_str());
                }
            }
        }
        archs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::{FrameworkSpec, ParallelismSpec};
    use crate::config::presets;

    fn uniform() -> (crate::config::model::ModelSpec, crate::config::cluster::ClusterSpec, FrameworkSpec) {
        let mut m = presets::model("llama2-70b").unwrap();
        m.global_batch = 64;
        let c = presets::cluster("ampere", 8).unwrap(); // 64 GPUs
        let f =
            FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 4, dp: 4 }).unwrap();
        (m, c, f)
    }

    #[test]
    fn derives_tp_groups_per_stage() {
        let (_, _, f) = uniform();
        let dg = DeviceGroups::derive(&f);
        assert_eq!(dg.tp_groups.len(), 16); // 4 dp x 4 pp
        assert!(dg.tp_groups.iter().all(|(_, _, r)| r.len() == 4));
    }

    #[test]
    fn derives_dp_sync_per_stage() {
        let (_, _, f) = uniform();
        let dg = DeviceGroups::derive(&f);
        assert_eq!(dg.dp_sync.len(), 4); // one per stage
        for s in &dg.dp_sync {
            assert_eq!(s.participants.len(), 4); // dp=4
            assert!(s.participants.iter().all(|p| p.tp == 4));
        }
    }

    #[test]
    fn derives_pp_edges() {
        let (_, _, f) = uniform();
        let dg = DeviceGroups::derive(&f);
        assert_eq!(dg.pp_edges.len(), 4 * 3); // dp x (pp-1)
        let e = &dg.pp_edges[0];
        assert_eq!(e.from_stage, 0);
        assert_ne!(e.from_ranks, e.to_ranks);
    }

    #[test]
    fn locality_classification() {
        let c = presets::cluster("ampere", 2).unwrap();
        assert!(DeviceGroups::is_intra_node(&c, &[0, 3, 7]));
        assert!(!DeviceGroups::is_intra_node(&c, &[0, 8]));
        assert!(!DeviceGroups::is_intra_node(&c, &[99]));
    }

    #[test]
    fn architectures_of_hetero_group() {
        let c = presets::cluster_hetero(1, 1).unwrap();
        let archs = DeviceGroups::architectures(&c, &[0, 8]);
        assert_eq!(archs, vec!["A100", "H100"]);
    }

    #[test]
    fn non_uniform_pp_depth_tolerated() {
        let (m, c, mut f) = uniform();
        let _ = (m, c);
        // chop one group to 2 stages (layers conservation not checked here)
        f.groups[0].stages.truncate(2);
        let dg = DeviceGroups::derive(&f);
        // stage 2 and 3 sync groups only have 3 participants
        let deep: Vec<_> = dg.dp_sync.iter().filter(|s| s.stage >= 2).collect();
        assert!(deep.iter().all(|s| s.participants.len() == 3));
    }
}
