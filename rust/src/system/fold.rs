//! Symmetry folding: equivalence classes of device groups (DESIGN.md
//! §25).
//!
//! DP replicas in large training jobs execute identical op streams
//! against identical subtopologies. When two device groups are provably
//! interchangeable — same batch/microbatch split, same stage shape,
//! same relative rank layout over the same node classes, and a fabric
//! view where every link their group-local collectives touch is owned
//! exclusively by the group — simulating both is redundant: one
//! representative timeline, multiplied, reproduces the pair exactly.
//!
//! [`classify`] computes those classes. The result feeds three folding
//! consumers:
//!
//! * workload generation ([`crate::workload::aicb::generate_folded`])
//!   emits programs only for class representatives;
//! * compilation ([`crate::system::compiled::CompiledWorkload::compile_folded`])
//!   folds the DP-sync flow sets down to one connected component per
//!   symmetry orbit (the max-min fixpoint on the kept components is
//!   identical to the unfolded one — dropped components share no link
//!   with kept ones, so removing them perturbs no rate);
//! * the scheduler weighs busy accumulators by class multiplicity so
//!   reported utilization matches the unfolded run bit-for-bit.
//!
//! # When folding is refused (expansion is forced)
//!
//! `classify` returns `None` — the caller falls back to the unfolded
//! path — whenever any of the global gates fail:
//!
//! * `mode` is [`FoldMode::Off`];
//! * any device group has more than one pipeline stage (`pp > 1`
//!   interleaves p2p traffic with group-local collectives in time, so
//!   group timelines are no longer independent);
//! * any DP sync group needs gradient resharding (reshard traffic
//!   crosses group boundaries outside the folded DP planner);
//! * no equivalence class ends up with multiplicity ≥ 2 (nothing to
//!   fold);
//! * a non-empty fault spec is injected ([`classify_with_faults`]): a
//!   straggler slows exactly one member of a class, and a fail-stop
//!   abort must observe every rank's partial progress — both break the
//!   interchangeability proof, so faults force the expanded path.
//!
//! Individual groups that fail the *per-group* symmetry conditions
//! (mixed node classes where the layout differs, partial node
//! occupancy on a shared-leaf fabric, multi-spine hash asymmetry) are
//! placed in singleton classes: they are simulated unfolded while the
//! symmetric remainder still folds.

use std::collections::BTreeMap;
use std::collections::HashMap;

use crate::config::cluster::{ClusterSpec, FabricSpec};
use crate::config::framework::FrameworkSpec;
use crate::system::device_group::DeviceGroups;
use crate::system::resharding;

/// Whether the build pipeline may fold symmetric device groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FoldMode {
    /// Never fold; byte-identical to the pre-folding simulator.
    #[default]
    Off,
    /// Fold whenever [`classify`] proves it exact; silently fall back
    /// to the unfolded path otherwise.
    Auto,
}

impl FoldMode {
    /// Parse a CLI/scenario value: `"off"` or `"auto"`.
    pub fn parse(s: &str) -> anyhow::Result<FoldMode> {
        match s {
            "off" => Ok(FoldMode::Off),
            "auto" => Ok(FoldMode::Auto),
            other => anyhow::bail!("unknown fold mode '{other}' (auto | off)"),
        }
    }

    /// Canonical name (`"off"` / `"auto"`).
    pub fn name(self) -> &'static str {
        match self {
            FoldMode::Off => "off",
            FoldMode::Auto => "auto",
        }
    }
}

/// The proven equivalence-class structure for one (cluster, framework)
/// pair. Indices into `represented`/`group_class` follow
/// `fw.groups` order; per-rank tables are dense over the cluster's
/// global rank space.
#[derive(Debug, Clone)]
pub struct FoldPlan {
    /// Global rank count of the cluster.
    pub world: u32,
    /// Per device group (by position in `fw.groups`): is this group its
    /// class representative (and therefore simulated)?
    pub represented: Vec<bool>,
    /// Per device group: its equivalence-class id.
    pub group_class: Vec<u32>,
    /// Per class: number of member groups (≥ 1).
    pub class_mult: Vec<u64>,
    /// Per rank: the corresponding rank of the class representative
    /// (identity for representative and singleton ranks). Maps a folded
    /// rank's DP-arrival lookup onto the representative's timeline.
    pub twin: Vec<u32>,
    /// Per rank: its group's class multiplicity (1 for vacant ranks).
    pub rank_mult: Vec<u64>,
    /// Per rank: its group's class id (`u32::MAX` for ranks outside
    /// every group). Used by the folded DP planner to match flow
    /// endpoints across symmetric components.
    pub rank_class: Vec<u32>,
    /// Ranks whose programs are folded away (diagnostics).
    pub folded_ranks: u64,
}

impl FoldPlan {
    /// Number of equivalence classes.
    pub fn num_classes(&self) -> usize {
        self.class_mult.len()
    }
}

/// Compute device-group equivalence classes, or `None` when folding is
/// off, unsound for this deployment, or pointless (see module docs for
/// the exact gates).
pub fn classify(cluster: &ClusterSpec, fw: &FrameworkSpec, mode: FoldMode) -> Option<FoldPlan> {
    if mode == FoldMode::Off {
        return None;
    }
    // pp must be 1 everywhere: with a single stage, every group-local
    // collective completes before the group's DP arrival, so group
    // timelines are mutually independent and DP-sync traffic never
    // overlaps group-local traffic in time.
    if fw.groups.iter().any(|g| g.stages.len() != 1) {
        return None;
    }
    let groups = DeviceGroups::derive(fw);
    if groups.dp_sync.iter().any(|s| resharding::group_needs_resharding(&s.participants)) {
        return None;
    }
    // Node classes: full NodeSpec equality (GPU name alone is not
    // enough — same-GPU nodes can differ in interconnect).
    let mut node_class: Vec<u32> = Vec::with_capacity(cluster.nodes.len());
    let mut distinct: Vec<usize> = Vec::new();
    for spec in &cluster.nodes {
        let id = match distinct.iter().position(|&d| cluster.nodes[d] == *spec) {
            Some(i) => i as u32,
            None => {
                distinct.push(node_class.len());
                (distinct.len() - 1) as u32
            }
        };
        node_class.push(id);
    }
    let world = cluster.total_gpus();
    // Dense rank → (node, local) table: `group_key` needs a location
    // per rank and `ClusterSpec::locate` is an O(nodes) scan — one
    // O(world) prefix-sum pass here keeps classification linear on
    // 100k-rank clusters.
    let starts = cluster.node_starts();
    let mut locs: Vec<(u32, u32)> = Vec::with_capacity(world as usize);
    for n in 0..cluster.nodes.len() {
        for l in 0..(starts[n + 1] - starts[n]) {
            locs.push((n as u32, l));
        }
    }
    // Per-group class key (None → singleton class).
    let keys: Vec<Option<String>> =
        fw.groups.iter().map(|g| group_key(cluster, &node_class, &locs, g)).collect();
    let mut class_of: Vec<u32> = Vec::with_capacity(fw.groups.len());
    let mut rep_of: Vec<usize> = Vec::new();
    let mut mult: Vec<u64> = Vec::new();
    let mut by_key: HashMap<&str, u32> = HashMap::new();
    for (gi, key) in keys.iter().enumerate() {
        let cls = match key {
            Some(k) => match by_key.get(k.as_str()) {
                Some(&c) => {
                    mult[c as usize] += 1;
                    c
                }
                None => {
                    let c = rep_of.len() as u32;
                    by_key.insert(k.as_str(), c);
                    rep_of.push(gi);
                    mult.push(1);
                    c
                }
            },
            None => {
                let c = rep_of.len() as u32;
                rep_of.push(gi);
                mult.push(1);
                c
            }
        };
        class_of.push(cls);
    }
    if !mult.iter().any(|&m| m >= 2) {
        return None;
    }
    let mut twin: Vec<u32> = (0..world).collect();
    let mut rank_mult: Vec<u64> = vec![1; world as usize];
    let mut rank_class: Vec<u32> = vec![u32::MAX; world as usize];
    let mut represented = vec![false; fw.groups.len()];
    let mut folded_ranks = 0u64;
    for (gi, g) in fw.groups.iter().enumerate() {
        let cls = class_of[gi] as usize;
        let rep = rep_of[cls];
        represented[gi] = gi == rep;
        let rep_ranks = fw.groups[rep].stages[0].ranks.clone();
        for (pos, &r) in g.stages[0].ranks.iter().enumerate() {
            rank_class[r as usize] = cls as u32;
            rank_mult[r as usize] = mult[cls];
            // positional twin: the class key pins the stage-order rank
            // layout, so position i of any member corresponds to
            // position i of the representative
            twin[r as usize] = rep_ranks[pos];
            if gi != rep {
                folded_ranks += 1;
            }
        }
    }
    Some(FoldPlan {
        world,
        represented,
        group_class: class_of,
        class_mult: mult,
        twin,
        rank_mult,
        rank_class,
        folded_ranks,
    })
}

/// [`classify`] guarded by the fault-injection gate (DESIGN.md §26):
/// any non-empty [`crate::system::failure::FaultSpec`] refuses folding
/// outright, so fault trajectories are always simulated against the
/// full expanded rank space. With no spec (or an empty one) this is
/// exactly `classify`.
pub fn classify_with_faults(
    cluster: &ClusterSpec,
    fw: &FrameworkSpec,
    mode: FoldMode,
    faults: Option<&crate::system::failure::FaultSpec>,
) -> Option<FoldPlan> {
    match faults {
        Some(spec) if !spec.is_empty() => None,
        _ => classify(cluster, fw, mode),
    }
}

/// The canonical symmetry key of one (single-stage) device group, or
/// `None` when the group cannot be folded on this cluster/fabric.
///
/// Two groups with equal keys have isomorphic op streams AND
/// link-disjoint, characteristic-identical intra-group fabric views, so
/// their timelines are bit-identical — the folding precondition.
fn group_key(
    cluster: &ClusterSpec,
    node_class: &[u32],
    locs: &[(u32, u32)],
    g: &crate::config::framework::DeviceGroupPlan,
) -> Option<String> {
    let stage = &g.stages[0];
    // node → locals, in ascending node order (BTreeMap keeps it sorted)
    let mut by_node: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for &r in &stage.ranks {
        let (n, l) = *locs.get(r as usize)?;
        by_node.entry(n).or_default().push(l);
    }
    let nodes: Vec<u32> = by_node.keys().copied().collect();
    if nodes.len() > 1 {
        // Multi-node groups: inter-node routes must stay inside links
        // owned by the group's own (node, local) slots.
        let mut sets: Vec<Vec<u32>> = by_node.values().cloned().collect();
        for s in &mut sets {
            s.sort_unstable();
        }
        // identical local occupancy and node size everywhere (rail
        // selection is `dst_local % node_gpus`, so equal sizes keep the
        // rail inside the occupied set)
        let first = &sets[0];
        if sets.iter().any(|s| s != first) {
            return None;
        }
        let size = cluster.node(nodes[0]).gpus_per_node;
        if nodes.iter().any(|&n| cluster.node(n).gpus_per_node != size) {
            return None;
        }
        match cluster.fabric {
            FabricSpec::RailOnly | FabricSpec::SingleSwitch => {}
            FabricSpec::LeafSpine { spines, .. } => {
                // leaf uplinks are shared per (node, spine) across all
                // of a node's locals: the group must own its nodes
                // outright, and multi-spine hashing of absolute ranks
                // breaks cross-group route isomorphism
                if spines != 1 || first.len() != size as usize {
                    return None;
                }
            }
        }
    }
    // Canonical layout: rank positions as (node index in ascending
    // order, local's position in that node's sorted local set) — the
    // heterogeneity-aware ring order sorts by (arch, node, local), and
    // both coordinates are order-isomorphic to it within a class.
    let mut sorted_locals: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for (&n, ls) in &by_node {
        let mut s = ls.clone();
        s.sort_unstable();
        sorted_locals.insert(n, s);
    }
    let mut key = format!(
        "b{} m{} L{} e{} |",
        g.batch_share, g.micro_batch, stage.num_layers, stage.has_embedding
    );
    for &n in &nodes {
        key.push_str(&format!(" n{}", node_class[n as usize]));
    }
    key.push('|');
    for &r in &stage.ranks {
        let (n, l) = *locs.get(r as usize)?;
        let npos = nodes.iter().position(|&x| x == n)?;
        let lpos = sorted_locals[&n].iter().position(|&x| x == l)?;
        key.push_str(&format!(" {npos}.{lpos}"));
    }
    Some(key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::ParallelismSpec;
    use crate::config::presets;

    fn uniform(
        cluster: &ClusterSpec,
        tp: u32,
        pp: u32,
        dp: u32,
    ) -> FrameworkSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = dp as u64 * 2;
        m.micro_batch = 1;
        FrameworkSpec::uniform(&m, cluster, ParallelismSpec { tp, pp, dp }).unwrap()
    }

    #[test]
    fn off_mode_never_folds() {
        let c = presets::cluster("hopper", 2).unwrap();
        let fw = uniform(&c, 8, 1, 2);
        assert!(classify(&c, &fw, FoldMode::Off).is_none());
    }

    #[test]
    fn homogeneous_single_node_groups_fold() {
        let c = presets::cluster("hopper", 2).unwrap();
        let fw = uniform(&c, 8, 1, 2);
        let plan = classify(&c, &fw, FoldMode::Auto).expect("symmetric dp=2 must fold");
        assert_eq!(plan.num_classes(), 1);
        assert_eq!(plan.class_mult, vec![2]);
        assert_eq!(plan.represented, vec![true, false]);
        assert_eq!(plan.folded_ranks, 8);
        // twin maps group 1's ranks onto group 0's, position-wise
        assert_eq!(plan.twin[8], 0);
        assert_eq!(plan.twin[15], 7);
        assert_eq!(plan.rank_mult[0], 2);
    }

    #[test]
    fn pipeline_parallelism_forces_expansion() {
        let c = presets::cluster("hopper", 2).unwrap();
        let fw = uniform(&c, 4, 2, 2);
        assert!(classify(&c, &fw, FoldMode::Auto).is_none());
    }

    #[test]
    fn hetero_pairs_fold_within_arch() {
        // 2 ampere + 2 hopper nodes, tp=8 → 4 single-node groups in 2
        // classes of multiplicity 2
        let c = presets::cluster_hetero(2, 2).unwrap();
        let fw = uniform(&c, 8, 1, 4);
        let plan = classify(&c, &fw, FoldMode::Auto).unwrap();
        assert_eq!(plan.num_classes(), 2);
        assert_eq!(plan.class_mult, vec![2, 2]);
        assert_eq!(plan.folded_ranks, 16);
    }

    #[test]
    fn singleton_classes_disable_folding() {
        // 1 ampere + 1 hopper node: the two groups are in different
        // classes, nothing to fold
        let c = presets::cluster_hetero(1, 1).unwrap();
        let fw = uniform(&c, 8, 1, 2);
        assert!(classify(&c, &fw, FoldMode::Auto).is_none());
    }

    #[test]
    fn multi_spine_multi_node_groups_stay_unfolded() {
        let mut c = presets::cluster("hopper", 4).unwrap();
        c.fabric = FabricSpec::LeafSpine { spines: 2, oversubscription: 1.0 };
        // each group spans 2 nodes → spine-hash asymmetry forces
        // singleton classes → no folding
        let fw = uniform(&c, 16, 1, 2);
        assert!(classify(&c, &fw, FoldMode::Auto).is_none());
        // single-spine spanning groups fold
        c.fabric = FabricSpec::LeafSpine { spines: 1, oversubscription: 1.0 };
        let plan = classify(&c, &fw, FoldMode::Auto).unwrap();
        assert_eq!(plan.class_mult, vec![2]);
    }

    #[test]
    fn non_empty_fault_spec_forces_expansion() {
        use crate::system::failure::{FaultEvent, FaultKind, FaultSpec};
        let c = presets::cluster("hopper", 2).unwrap();
        let fw = uniform(&c, 8, 1, 2);
        // this deployment folds without faults...
        assert!(classify_with_faults(&c, &fw, FoldMode::Auto, None).is_some());
        let empty = FaultSpec::default();
        assert!(classify_with_faults(&c, &fw, FoldMode::Auto, Some(&empty)).is_some());
        // ...but any scheduled fault refuses folding
        let mut spec = FaultSpec::default();
        spec.events.push(FaultEvent { at_s: 1.0, kind: FaultKind::NodeFail { node: 0 } });
        assert!(classify_with_faults(&c, &fw, FoldMode::Auto, Some(&spec)).is_none());
    }

    #[test]
    fn rank_scale_100k_classification_is_linear() {
        // the ladder shape: 12.5k nodes, dp == world, single-rank groups
        let c = presets::cluster("ampere", 12_500).unwrap();
        let fw = uniform(&c, 1, 1, 100_000);
        let plan = classify(&c, &fw, FoldMode::Auto).unwrap();
        assert_eq!(plan.num_classes(), 1);
        assert_eq!(plan.class_mult, vec![100_000]);
        assert_eq!(plan.folded_ranks, 99_999);
    }
}
