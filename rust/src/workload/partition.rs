//! Non-uniform workload partitioning (component **C1**, paper §3):
//! assign more layers to faster pipeline stages, more batch to faster
//! device groups, and variable TP degrees to heterogeneous device
//! groups (Fig 3).
//!
//! Three entry points build non-uniform [`FrameworkSpec`]s:
//! [`plan_hetero`] (proportional splits on the uniform rank grid),
//! [`plan_variable_tp`] (explicit per-node TP splits, the Fig-3 shape
//! the planner enumerates and [`crate::planner::refine`] polishes), and
//! the hand-written [`fig3_plan`] reference.

use crate::config::cluster::ClusterSpec;
use crate::config::framework::{
    split_evenly, DeviceGroupPlan, FrameworkSpec, ParallelismSpec, StagePlan,
};
use crate::config::model::ModelSpec;

/// Why a proportional split cannot be produced. Returned (not panicked)
/// so the planner can *prune* infeasible candidates — a deep pipeline
/// on a shallow model, or more device groups than batch samples — with
/// a typed reason instead of aborting the whole search.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum SplitError {
    /// An empty weight vector was passed (zero parts requested).
    #[error("cannot split {total}: no parts requested")]
    NoParts {
        /// The total that was to be split.
        total: u64,
    },
    /// `total < minimum * parts`: the floor cannot be honoured.
    #[error("total {total} cannot give {parts} parts of at least {minimum}")]
    TotalTooSmall {
        /// The total that was to be split.
        total: u64,
        /// Requested part count.
        parts: u64,
        /// Per-part floor that made the split infeasible.
        minimum: u64,
    },
}

/// Split `total` into parts proportional to `weights`, each at least
/// `minimum`, conserving the sum exactly (largest-remainder method).
/// Fails with a typed [`SplitError`] when the floor cannot be honoured,
/// so callers can prune rather than abort.
pub fn split_proportional(
    total: u64,
    weights: &[f64],
    minimum: u64,
) -> Result<Vec<u64>, SplitError> {
    let n = weights.len();
    if n == 0 {
        return Err(SplitError::NoParts { total });
    }
    if total < minimum * n as u64 {
        return Err(SplitError::TotalTooSmall { total, parts: n as u64, minimum });
    }
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        // degenerate: equal split
        return Ok(crate::config::framework::split_evenly(total, n as u64));
    }
    let spendable = total - minimum * n as u64;
    let ideal: Vec<f64> = weights.iter().map(|w| spendable as f64 * w / wsum).collect();
    let mut parts: Vec<u64> = ideal.iter().map(|x| x.floor() as u64).collect();
    let assigned: u64 = parts.iter().sum();
    let mut rem: Vec<(usize, f64)> =
        ideal.iter().enumerate().map(|(i, x)| (i, x - x.floor())).collect();
    rem.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    for k in 0..(spendable - assigned) as usize {
        parts[rem[k % n].0] += 1;
    }
    for p in &mut parts {
        *p += minimum;
    }
    Ok(parts)
}

/// Heterogeneity-aware plan: same rank layout as the uniform mapping
/// (TP fastest, then PP, then DP), but with
/// * layers per stage ∝ the stage's aggregate compute power, and
/// * batch share per device group ∝ the group's aggregate power.
///
/// The bottleneck-device rule (component C4) applies inside a stage:
/// a heterogeneous TP group advances at its slowest member, so stage
/// power = tp × min(member power).
pub fn plan_hetero(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    par: ParallelismSpec,
) -> anyhow::Result<FrameworkSpec> {
    let uniform = FrameworkSpec::uniform(model, cluster, par)?;
    let mut groups = Vec::with_capacity(uniform.groups.len());
    let mut group_powers = Vec::with_capacity(uniform.groups.len());

    for g in &uniform.groups {
        let stage_powers: Vec<f64> =
            g.stages.iter().map(|s| stage_power(cluster, &s.ranks)).collect();
        let layers = split_proportional(model.num_layers as u64, &stage_powers, 1)?;
        let mut stages: Vec<StagePlan> = Vec::with_capacity(g.stages.len());
        for (s, plan) in g.stages.iter().enumerate() {
            stages.push(StagePlan {
                ranks: plan.ranks.clone(),
                num_layers: layers[s] as u32,
                has_embedding: plan.has_embedding,
            });
        }
        group_powers.push(stage_powers.iter().sum::<f64>());
        groups.push(DeviceGroupPlan {
            id: g.id,
            stages,
            batch_share: 0, // filled below
            micro_batch: g.micro_batch,
        });
    }

    let shares = split_proportional(model.global_batch, &group_powers, 1)?;
    for (g, share) in groups.iter_mut().zip(shares) {
        g.batch_share = share;
    }
    let spec = FrameworkSpec { groups, base: par, schedule: uniform.schedule };
    spec.validate(model, cluster)?;
    Ok(spec)
}

/// Aggregate compute power of one TP group: the bottleneck-device rule
/// (component C4) says a heterogeneous TP group advances at its slowest
/// member, so power = member count × min(member power).
pub fn stage_power(cluster: &ClusterSpec, ranks: &[u32]) -> f64 {
    let min_power = ranks
        .iter()
        .filter_map(|r| cluster.gpu_of_rank(*r))
        .map(|gpu| gpu.compute_power())
        .fold(f64::INFINITY, f64::min);
    if min_power.is_finite() {
        min_power * ranks.len() as f64
    } else {
        0.0
    }
}

/// Build a [`FrameworkSpec`] from **explicit per-node TP splits** — the
/// paper's Fig-3 shape generalized: each node is one device group whose
/// pipeline stages are the node's GPUs split into the given TP degrees
/// (`splits[node] = [3, 1]` puts a TP=3 stage and a TP=1 stage on that
/// node). TP degrees need not match across groups; mismatches are what
/// triggers resharding (component C2) at DP-sync time.
///
/// With `hetero = true`, layers per stage and batch share per group are
/// proportional to compute power (the [`plan_hetero`] rule); with
/// `hetero = false` they are split evenly — the uniform-partitioning
/// ablation on the same layout.
///
/// Fails with a typed [`SplitError`]-carrying error when the model has
/// fewer layers than a group has stages or fewer batch samples than
/// there are groups; the planner prunes such layouts instead of
/// aborting.
pub fn plan_variable_tp(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    splits: &[Vec<u32>],
    hetero: bool,
) -> anyhow::Result<FrameworkSpec> {
    anyhow::ensure!(
        splits.len() == cluster.nodes.len(),
        "per-node TP splits cover {} nodes, cluster has {}",
        splits.len(),
        cluster.nodes.len()
    );
    let mut groups = Vec::with_capacity(splits.len());
    let mut group_powers = Vec::with_capacity(splits.len());
    let mut base_rank: u32 = 0;
    let mut max_tp = 1;
    let mut max_pp = 1;
    for (node_idx, (split, node)) in splits.iter().zip(&cluster.nodes).enumerate() {
        anyhow::ensure!(!split.is_empty(), "node {node_idx}: empty TP split");
        anyhow::ensure!(
            split.iter().all(|t| *t >= 1),
            "node {node_idx}: TP degrees must be >= 1 ({split:?})"
        );
        let used: u32 = split.iter().sum();
        anyhow::ensure!(
            used == node.gpus_per_node,
            "node {node_idx}: TP split {split:?} uses {used} GPUs, node has {}",
            node.gpus_per_node
        );
        // contiguous ranks, stage-major within the node
        let mut stage_ranks = Vec::with_capacity(split.len());
        let mut r = base_rank;
        for tp in split {
            stage_ranks.push((r..r + tp).collect::<Vec<u32>>());
            r += tp;
        }
        base_rank += node.gpus_per_node;
        let stage_powers: Vec<f64> =
            stage_ranks.iter().map(|ranks| stage_power(cluster, ranks)).collect();
        let layers = if hetero {
            split_proportional(model.num_layers as u64, &stage_powers, 1)?
        } else {
            let even = split_evenly(model.num_layers as u64, split.len() as u64);
            if even.iter().any(|l| *l == 0) {
                // typed like the proportional path, so the planner can
                // prune uniform-partitioning layouts the same way
                return Err(SplitError::TotalTooSmall {
                    total: u64::from(model.num_layers),
                    parts: split.len() as u64,
                    minimum: 1,
                }
                .into());
            }
            even
        };
        max_tp = max_tp.max(*split.iter().max().unwrap());
        max_pp = max_pp.max(split.len() as u32);
        group_powers.push(stage_powers.iter().sum::<f64>());
        groups.push(DeviceGroupPlan {
            id: node_idx as u32,
            stages: stage_ranks
                .into_iter()
                .enumerate()
                .map(|(s, ranks)| StagePlan {
                    ranks,
                    num_layers: layers[s] as u32,
                    has_embedding: s == 0,
                })
                .collect(),
            batch_share: 0, // filled below
            micro_batch: model.micro_batch,
        });
    }
    let shares = if hetero {
        split_proportional(model.global_batch, &group_powers, 1)?
    } else {
        let even = split_evenly(model.global_batch, groups.len() as u64);
        if even.iter().any(|s| *s == 0) {
            return Err(SplitError::TotalTooSmall {
                total: model.global_batch,
                parts: groups.len() as u64,
                minimum: 1,
            }
            .into());
        }
        even
    };
    for (g, share) in groups.iter_mut().zip(shares) {
        g.batch_share = share;
    }
    let spec = FrameworkSpec {
        groups,
        base: ParallelismSpec { tp: max_tp, pp: max_pp, dp: splits.len() as u32 },
        schedule: crate::workload::schedule::ScheduleKind::GPipe,
    };
    spec.validate(model, cluster)?;
    Ok(spec)
}

/// The paper's Fig-3-style scenario: Llama-2 70B on one 4×H100 node +
/// one 4×A100 node, two device groups with variable TP degree,
/// non-uniform layer split and non-uniform batch shares — the
/// configuration that exercises resharding (TP 3 vs TP 4).
pub fn fig3_cluster() -> anyhow::Result<ClusterSpec> {
    use crate::config::presets;
    let mut hopper = presets::cluster("hopper", 1)?;
    let mut ampere = presets::cluster("ampere", 1)?;
    hopper.nodes[0].gpus_per_node = 4;
    ampere.nodes[0].gpus_per_node = 4;
    Ok(ClusterSpec {
        name: "fig3-4h100-4a100".into(),
        nodes: vec![hopper.nodes.remove(0), ampere.nodes.remove(0)],
        fabric: hopper.fabric,
        switch_bw: hopper.switch_bw,
        switch_delay: hopper.switch_delay,
    })
}

/// The Fig-3 model: Llama-2 70B with the figure's batch configuration
/// (delegates to the `"fig3"` preset so the CLI and this helper cannot
/// drift apart).
pub fn fig3_model() -> anyhow::Result<ModelSpec> {
    crate::config::presets::model("fig3")
}

/// The Fig-3 framework plan:
/// * DG0 (H100 node): stage0 = 3 GPUs TP=3 with 75 layers, stage1 =
///   1 GPU TP=1 with 5 layers; batch share 16.
/// * DG1 (A100 node): single stage, 4 GPUs TP=4, all 80 layers;
///   batch share 8.
/// DP sync between TP=3/TP=1 and TP=4 participants requires resharding.
pub fn fig3_plan(model: &ModelSpec, cluster: &ClusterSpec) -> anyhow::Result<FrameworkSpec> {
    anyhow::ensure!(cluster.total_gpus() == 8, "fig3 cluster has 8 GPUs");
    let spec = FrameworkSpec {
        groups: vec![
            DeviceGroupPlan {
                id: 0,
                stages: vec![
                    StagePlan { ranks: vec![0, 1, 2], num_layers: 75, has_embedding: true },
                    StagePlan { ranks: vec![3], num_layers: 5, has_embedding: false },
                ],
                batch_share: 16,
                micro_batch: model.micro_batch,
            },
            DeviceGroupPlan {
                id: 1,
                stages: vec![StagePlan {
                    ranks: vec![4, 5, 6, 7],
                    num_layers: 80,
                    has_embedding: true,
                }],
                batch_share: 8,
                micro_batch: model.micro_batch,
            },
        ],
        base: ParallelismSpec { tp: 4, pp: 1, dp: 2 },
        schedule: crate::workload::schedule::ScheduleKind::GPipe,
    };
    spec.validate(model, cluster)?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::system::device_group::DeviceGroups;
    use crate::system::resharding;

    #[test]
    fn split_proportional_conserves() {
        let parts = split_proportional(80, &[3.0, 1.0], 1).unwrap();
        assert_eq!(parts.iter().sum::<u64>(), 80);
        assert!(parts[0] > parts[1]);
        // ~3:1 split
        assert!((55..=62).contains(&parts[0]), "{parts:?}");
    }

    #[test]
    fn split_proportional_respects_minimum() {
        let parts = split_proportional(10, &[1000.0, 1.0, 1.0], 1).unwrap();
        assert_eq!(parts.iter().sum::<u64>(), 10);
        assert!(parts.iter().all(|p| *p >= 1), "{parts:?}");
    }

    #[test]
    fn split_proportional_zero_weights_falls_back() {
        let parts = split_proportional(9, &[0.0, 0.0, 0.0], 1).unwrap();
        assert_eq!(parts.iter().sum::<u64>(), 9);
    }

    #[test]
    fn split_proportional_infeasible_is_typed_not_a_panic() {
        // the former assert!-panic path: 3 parts with floor 2 from 5
        assert_eq!(
            split_proportional(5, &[1.0, 1.0, 1.0], 2),
            Err(SplitError::TotalTooSmall { total: 5, parts: 3, minimum: 2 })
        );
        assert_eq!(split_proportional(7, &[], 1), Err(SplitError::NoParts { total: 7 }));
    }

    #[test]
    fn variable_tp_plan_reproduces_fig3_shape() {
        let m = fig3_model().unwrap();
        let c = fig3_cluster().unwrap();
        let f = plan_variable_tp(&m, &c, &[vec![3, 1], vec![4]], true).unwrap();
        assert_eq!(f.groups.len(), 2);
        assert_eq!(f.groups[0].stages[0].ranks, vec![0, 1, 2]);
        assert_eq!(f.groups[0].stages[1].ranks, vec![3]);
        assert_eq!(f.groups[1].stages[0].ranks, vec![4, 5, 6, 7]);
        // layer and batch conservation under the proportional split
        assert_eq!(f.groups[0].stages.iter().map(|s| s.num_layers).sum::<u32>(), 80);
        assert_eq!(f.groups[1].stages[0].num_layers, 80);
        assert_eq!(f.groups.iter().map(|g| g.batch_share).sum::<u64>(), 24);
        // the H100 group gets the larger share
        assert!(f.groups[0].batch_share > f.groups[1].batch_share);
        // TP mismatch across DP participants → resharding required
        let dg = DeviceGroups::derive(&f);
        assert!(resharding::group_needs_resharding(&dg.dp_sync[0].participants));
    }

    #[test]
    fn variable_tp_plan_uniform_splits_evenly() {
        let m = fig3_model().unwrap();
        let c = fig3_cluster().unwrap();
        let f = plan_variable_tp(&m, &c, &[vec![2, 2], vec![2, 2]], false).unwrap();
        assert_eq!(f.groups[0].stages[0].num_layers, 40);
        assert_eq!(f.groups[0].stages[1].num_layers, 40);
        assert_eq!(f.groups[0].batch_share, 12);
        assert_eq!(f.groups[1].batch_share, 12);
    }

    #[test]
    fn variable_tp_plan_rejects_bad_splits() {
        let m = fig3_model().unwrap();
        let c = fig3_cluster().unwrap();
        // wrong GPU count on node 0
        assert!(plan_variable_tp(&m, &c, &[vec![3, 2], vec![4]], true).is_err());
        // wrong number of nodes
        assert!(plan_variable_tp(&m, &c, &[vec![4]], true).is_err());
        // more stages than layers
        let mut shallow = m.clone();
        shallow.num_layers = 1;
        assert!(plan_variable_tp(&shallow, &c, &[vec![1, 1, 1, 1], vec![4]], true).is_err());
    }

    #[test]
    fn hetero_plan_gives_fast_groups_more_batch() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.global_batch = 128;
        m.micro_batch = 4;
        let c = presets::cluster_hetero(2, 2).unwrap(); // 32 GPUs
        let f = plan_hetero(&m, &c, ParallelismSpec { tp: 8, pp: 1, dp: 4 }).unwrap();
        // groups 0,1 are on A100 nodes; 2,3 on H100 (contiguous layout)
        assert!(f.groups[2].batch_share > f.groups[0].batch_share);
        let total: u64 = f.groups.iter().map(|g| g.batch_share).sum();
        assert_eq!(total, 128);
    }

    #[test]
    fn hetero_plan_gives_fast_stages_more_layers() {
        let mut m = presets::model("llama2-70b").unwrap();
        m.global_batch = 32;
        m.micro_batch = 1;
        // one pipeline spanning an A100 node then an H100 node
        let c = presets::cluster_hetero(1, 1).unwrap();
        let f = plan_hetero(&m, &c, ParallelismSpec { tp: 8, pp: 2, dp: 1 }).unwrap();
        let g = &f.groups[0];
        // stage 0 on the A100 node gets fewer layers than stage 1 (H100)
        assert!(g.stages[0].num_layers < g.stages[1].num_layers, "{:?}",
            g.stages.iter().map(|s| s.num_layers).collect::<Vec<_>>());
        assert_eq!(g.stages.iter().map(|s| s.num_layers).sum::<u32>(), 80);
    }

    #[test]
    fn uniform_cluster_hetero_plan_reduces_to_uniform() {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.global_batch = 64;
        m.micro_batch = 4;
        let c = presets::cluster("hopper", 2).unwrap();
        let f = plan_hetero(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 4 }).unwrap();
        let shares: Vec<u64> = f.groups.iter().map(|g| g.batch_share).collect();
        assert_eq!(shares, vec![16, 16, 16, 16]);
    }

    #[test]
    fn fig3_plan_requires_resharding() {
        let m = fig3_model().unwrap();
        let c = fig3_cluster().unwrap();
        let f = fig3_plan(&m, &c).unwrap();
        let dg = DeviceGroups::derive(&f);
        assert_eq!(dg.dp_sync.len(), 1);
        assert!(resharding::group_needs_resharding(&dg.dp_sync[0].participants));
        // the paper's non-uniform properties
        assert_ne!(f.groups[0].batch_share, f.groups[1].batch_share);
        assert_ne!(f.groups[0].stages[0].tp(), f.groups[1].stages[0].tp());
    }
}
