//! Workload-trace file format (the paper's "workload files" interface):
//! JSON-lines, one header line, one line per collective definition, one
//! line per rank op. Deterministic writer + validating parser.

use crate::compute::cost::LayerWork;
use crate::config::model::LayerKind;
use crate::system::collective::{CollectiveAlgo, CollectiveDef, CommKind};
use crate::util::json::Json;

use super::op::{Op, RankProgram, Workload};

fn kind_code(k: LayerKind) -> f64 {
    k.code() as f64
}

fn kind_from(code: u64) -> LayerKind {
    match code {
        0 => LayerKind::Embedding,
        1 => LayerKind::Attention,
        2 => LayerKind::Mlp,
        3 => LayerKind::Moe,
        _ => LayerKind::Other,
    }
}

fn algo_name(a: CollectiveAlgo) -> &'static str {
    a.name()
}

fn algo_from(s: &str) -> anyhow::Result<CollectiveAlgo> {
    Ok(match s {
        "allreduce" => CollectiveAlgo::AllReduceRing,
        "allgather" => CollectiveAlgo::AllGather,
        "reducescatter" => CollectiveAlgo::ReduceScatter,
        "alltoall" => CollectiveAlgo::AllToAll,
        "broadcast" => CollectiveAlgo::Broadcast,
        "allreduce-hier" => CollectiveAlgo::AllReduceHierarchical,
        _ => anyhow::bail!("unknown algo '{s}'"),
    })
}

fn comm_from(s: &str) -> anyhow::Result<CommKind> {
    Ok(match s {
        "TP" => CommKind::Tp,
        "DP" => CommKind::Dp,
        "PP" => CommKind::Pp,
        "EP" => CommKind::Ep,
        "RESHARD" => CommKind::Reshard,
        _ => anyhow::bail!("unknown comm kind '{s}'"),
    })
}

/// Intern op labels back to statics when parsing.
fn label_from(s: &str) -> &'static str {
    match s {
        "embedding-fwd" => "embedding-fwd",
        "embedding-bwd" => "embedding-bwd",
        "attention-fwd" => "attention-fwd",
        "attention-bwd" => "attention-bwd",
        "mlp-fwd" => "mlp-fwd",
        "mlp-bwd" => "mlp-bwd",
        "moe-fwd" => "moe-fwd",
        "moe-bwd" => "moe-bwd",
        "other-fwd" => "other-fwd",
        "other-bwd" => "other-bwd",
        _ => "compute",
    }
}

/// Serialize a workload to the JSONL trace format.
pub fn write(w: &Workload) -> String {
    let mut out = String::new();
    out.push_str(
        &Json::obj(vec![
            ("type", Json::Str("header".into())),
            ("version", Json::Num(1.0)),
            ("ranks", Json::Num(w.programs.len() as f64)),
            ("collectives", Json::Num(w.collectives.len() as f64)),
        ])
        .to_string(),
    );
    out.push('\n');
    for c in &w.collectives {
        out.push_str(
            &Json::obj(vec![
                ("type", Json::Str("coll".into())),
                ("id", Json::Num(c.id as f64)),
                ("algo", Json::Str(algo_name(c.algo).into())),
                ("ranks", Json::Arr(c.ranks.iter().map(|r| Json::Num(*r as f64)).collect())),
                ("bytes", Json::Num(c.bytes_per_rank as f64)),
                ("kind", Json::Str(c.kind.name().into())),
                ("label", Json::Str(c.label.clone())),
            ])
            .to_string(),
        );
        out.push('\n');
    }
    for p in &w.programs {
        for op in &p.ops {
            let mut fields = vec![
                ("type", Json::Str("op".into())),
                ("rank", Json::Num(p.rank as f64)),
            ];
            match op {
                Op::Compute { work, label } => {
                    fields.push(("op", Json::Str("compute".into())));
                    fields.push(("label", Json::Str((*label).into())));
                    fields.push(("kind", Json::Num(kind_code(work.kind))));
                    fields.push(("hidden", Json::Num(work.hidden)));
                    fields.push(("ffn", Json::Num(work.ffn)));
                    fields.push(("heads", Json::Num(work.heads)));
                    fields.push(("seq", Json::Num(work.seq)));
                    fields.push(("mbs", Json::Num(work.mbs)));
                    fields.push(("experts", Json::Num(work.n_experts)));
                    fields.push(("topk", Json::Num(work.top_k)));
                    fields.push(("tp", Json::Num(work.tp)));
                    fields.push(("bwd", Json::Bool(work.is_bwd)));
                }
                Op::Collective { def_id } => {
                    fields.push(("op", Json::Str("coll".into())));
                    fields.push(("id", Json::Num(*def_id as f64)));
                }
                Op::Send { peer, bytes, msg } => {
                    fields.push(("op", Json::Str("send".into())));
                    fields.push(("peer", Json::Num(*peer as f64)));
                    fields.push(("bytes", Json::Num(*bytes as f64)));
                    fields.push(("msg", Json::Num(*msg as f64)));
                }
                Op::Recv { msg } => {
                    fields.push(("op", Json::Str("recv".into())));
                    fields.push(("msg", Json::Num(*msg as f64)));
                }
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
    }
    out
}

/// Parse a JSONL trace back into a [`Workload`] (validates on return).
pub fn parse(text: &str) -> anyhow::Result<Workload> {
    let mut programs: std::collections::BTreeMap<u32, Vec<Op>> = Default::default();
    let mut collectives = Vec::new();
    let mut saw_header = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        match v.req_str("type")? {
            "header" => {
                anyhow::ensure!(v.req_u64("version")? == 1, "unsupported trace version");
                saw_header = true;
            }
            "coll" => {
                let ranks = v
                    .req("ranks")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("line {}: ranks not array", lineno + 1))?
                    .iter()
                    .map(|r| r.as_u64().map(|x| x as u32))
                    .collect::<Option<Vec<u32>>>()
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad rank", lineno + 1))?;
                collectives.push(CollectiveDef {
                    id: v.req_u64("id")?,
                    algo: algo_from(v.req_str("algo")?)?,
                    ranks,
                    bytes_per_rank: v.req_u64("bytes")?,
                    kind: comm_from(v.req_str("kind")?)?,
                    label: v.opt_str("label", "").to_string(),
                });
            }
            "op" => {
                let rank = v.req_u64("rank")? as u32;
                let ops = programs.entry(rank).or_default();
                match v.req_str("op")? {
                    "compute" => ops.push(Op::Compute {
                        work: LayerWork {
                            kind: kind_from(v.req_u64("kind")?),
                            hidden: v.req_f64("hidden")?,
                            ffn: v.req_f64("ffn")?,
                            heads: v.req_f64("heads")?,
                            seq: v.req_f64("seq")?,
                            mbs: v.req_f64("mbs")?,
                            n_experts: v.req_f64("experts")?,
                            top_k: v.req_f64("topk")?,
                            tp: v.req_f64("tp")?,
                            is_bwd: v.req("bwd")?.as_bool().unwrap_or(false),
                        },
                        label: label_from(v.opt_str("label", "compute")),
                    }),
                    "coll" => ops.push(Op::Collective { def_id: v.req_u64("id")? }),
                    "send" => ops.push(Op::Send {
                        peer: v.req_u64("peer")? as u32,
                        bytes: v.req_u64("bytes")?,
                        msg: v.req_u64("msg")?,
                    }),
                    "recv" => ops.push(Op::Recv { msg: v.req_u64("msg")? }),
                    other => anyhow::bail!("line {}: unknown op '{other}'", lineno + 1),
                }
            }
            other => anyhow::bail!("line {}: unknown record type '{other}'", lineno + 1),
        }
    }
    anyhow::ensure!(saw_header, "trace missing header line");
    let w = Workload {
        programs: programs
            .into_iter()
            .map(|(rank, ops)| RankProgram { rank, ops })
            .collect(),
        collectives,
    };
    w.validate()?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::{FrameworkSpec, ParallelismSpec};
    use crate::config::presets;
    use crate::workload::aicb::{generate, WorkloadOptions};

    fn sample() -> Workload {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 8;
        m.micro_batch = 4;
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 2, dp: 1 }).unwrap();
        generate(&m, &c, &f, &WorkloadOptions::default()).unwrap()
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let w = sample();
        let text = write(&w);
        let w2 = parse(&text).unwrap();
        assert_eq!(w.programs.len(), w2.programs.len());
        assert_eq!(w.collectives.len(), w2.collectives.len());
        assert_eq!(w.op_counts(), w2.op_counts());
        // per-rank op sequences identical in kind
        for (a, b) in w.programs.iter().zip(&w2.programs) {
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.ops.len(), b.ops.len());
        }
        // byte-identical re-serialization (determinism)
        assert_eq!(text, write(&w2));
    }

    #[test]
    fn missing_header_rejected() {
        assert!(parse("{\"type\":\"coll\"}").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn bad_line_reports_lineno() {
        let err = parse("{\"type\":\"header\",\"version\":1}\nnot json").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }

    #[test]
    fn unknown_algo_rejected() {
        let text = "{\"type\":\"header\",\"version\":1}\n{\"type\":\"coll\",\"id\":0,\"algo\":\"warp\",\"ranks\":[0],\"bytes\":1,\"kind\":\"TP\",\"label\":\"\"}";
        assert!(parse(text).is_err());
    }

    #[test]
    fn parsed_workload_validates() {
        // parse() runs Workload::validate — a trace referencing a
        // missing collective fails.
        let text = "{\"type\":\"header\",\"version\":1}\n{\"type\":\"op\",\"rank\":0,\"op\":\"coll\",\"id\":77}";
        assert!(parse(text).is_err());
    }
}
