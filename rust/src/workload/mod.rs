//! Workload layer (system S3/S4, paper component **C1**).
//!
//! * [`op`] — the compute/communication op taxonomy and per-rank
//!   programs (the simulator's "workload file" contents).
//! * [`aicb`] — the AICB-like workload generator: expands a model +
//!   framework spec into per-rank programs with device-group-specific
//!   work ("generate distinct workload traces tailored to the device
//!   group's role in the parallelism strategy").
//! * [`schedule`] — the pipeline-schedule subsystem: GPipe (seed
//!   behavior), 1F1B and interleaved 1F1B orderings behind the
//!   [`schedule::PipelineSchedule`] trait, with peak-activation
//!   estimates for the planner's memory pruning.
//! * [`partition`] — non-uniform workload partitioning: layers ∝ stage
//!   compute power, batch shares ∝ group power, variable TP degrees
//!   (paper Fig 3).
//! * [`parser`] — workload-trace file format (write + parse; the
//!   "custom parser that registers the compute and communication
//!   events based on the device group's workload file").
//! * [`serve`] — the inference serving workload generator: request
//!   traces (explicit or seeded open-loop Poisson), prefill/decode op
//!   lowering, and the KV-cache memory model bounding concurrent
//!   residency per device group (DESIGN.md §27).

pub mod aicb;
pub mod op;
pub mod parser;
pub mod partition;
pub mod schedule;
pub mod serve;

pub use aicb::{generate, WorkloadOptions};
pub use op::{Op, RankProgram, Workload};
pub use partition::plan_hetero;
pub use schedule::{PipelineSchedule, ScheduleKind};
pub use serve::{Request, ServePolicy, ServeSpec};
