//! Pipeline-schedule subsystem: how a device group orders its
//! microbatches through the pipeline stages.
//!
//! The paper's heterogeneity results hinge on how pipeline stages on
//! unequal devices overlap compute and communication. This module
//! abstracts the microbatch ordering behind the [`PipelineSchedule`]
//! trait with three implementations:
//!
//! * [`GPipe`] — the seed generator's schedule: each microbatch runs its
//!   full forward chain then its full backward chain before the next
//!   microbatch starts. Kept bit-identical to the pre-refactor emission
//!   (`tests/integration_schedule.rs` enforces this against an inlined
//!   copy of the seed generator).
//! * [`OneFOneB`] — 1F1B: stage `s` runs `pp - 1 - s` warmup forwards,
//!   then alternates one-forward/one-backward, then drains the
//!   remaining backwards. Peak activation residency drops from `m`
//!   microbatches to `min(pp, m)`.
//! * [`Interleaved1F1B`] — Megatron-style interleaved 1F1B with a
//!   virtual-pipeline factor `vpp`: each physical stage hosts `vpp`
//!   chunks of its layers, forming a virtual pipeline of `pp * vpp`
//!   stages. Bubble time shrinks by ~`vpp` at the cost of
//!   `(vpp - 1) * pp` extra warmup chunk-activations and more p2p
//!   traffic (every chunk boundary is a transfer, with its own unique
//!   message tag — see [`crate::system::compiled`] tag validation).
//!
//! A schedule produces a per-group **emission order**: a sequence of
//! [`Cell`]s (one `(stage, chunk, microbatch, direction)` unit of work)
//! whose per-stage subsequence is exactly that stage's execution order.
//! The AICB generator ([`crate::workload::aicb`]) walks this order to
//! emit per-rank op streams; the discrete-event scheduler then derives
//! the actual timing from the data dependencies (p2p recvs block, TP
//! collectives rendezvous), so bubbles, warmup ramps and cooldown
//! drains emerge from the simulation rather than being asserted.
//!
//! Each schedule also reports a **peak activation residency** estimate
//! ([`PipelineSchedule::peak_in_flight`] /
//! [`ScheduleKind::peak_activation_bytes`]) that feeds the planner's
//! memory-pruning pass ([`crate::planner::candidates`]): on mixed
//! clusters the smallest device bounds what schedules are feasible,
//! which is exactly the schedule × partitioning interaction homogeneous
//! simulators cannot express.

use crate::config::model::ModelSpec;

/// Coarse per-layer activation residency factor: bytes held per
/// (token, hidden-unit) of a transformer layer, assuming bf16
/// activations with selective recomputation of the attention internals.
/// Deliberately conservative — the planner uses it to *prune*, so it
/// must under- rather than over-estimate feasibility losses.
pub const ACT_BYTES_PER_LAYER_FACTOR: u64 = 8;

/// One unit of pipeline work: one direction of one microbatch on one
/// (stage, chunk). `chunk` is the virtual-pipeline chunk index and is
/// always 0 for non-interleaved schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cell {
    /// Physical pipeline stage index within the device group.
    pub stage: u32,
    /// Virtual-pipeline chunk hosted by this stage (0 unless
    /// interleaved).
    pub chunk: u32,
    /// Microbatch index within the group's iteration share.
    pub mb: u64,
    /// `false` = forward, `true` = backward.
    pub bwd: bool,
}

impl Cell {
    /// Position in the virtual pipeline of `pp * vpp` stages: chunk-major
    /// (chunk `c` of stage `s` is virtual stage `c * pp + s`), so the
    /// forward pass wraps from the last physical stage back to the first
    /// between chunks.
    pub fn virtual_stage(&self, pp: u32) -> u32 {
        self.chunk * pp + self.stage
    }
}

/// A pipeline schedule: produces the per-group emission order and the
/// activation-residency estimate. Implementations must keep every
/// stage's subsequence of the emission order equal to that stage's
/// execution order, and must emit every `(stage, chunk, mb, direction)`
/// cell exactly once — `Workload::validate` and the compiled-workload
/// tag checks catch violations downstream.
pub trait PipelineSchedule {
    /// Human-readable schedule name (also the candidate-key token).
    fn name(&self) -> String;

    /// Virtual-pipeline factor: how many layer chunks each physical
    /// stage hosts (1 for non-interleaved schedules).
    fn vpp(&self) -> u32 {
        1
    }

    /// The full emission order for one device group of `pp` stages
    /// running `m` microbatches. Cells of one stage appear in that
    /// stage's execution order; cells of different stages may interleave
    /// arbitrarily (the event simulation derives real timing from data
    /// dependencies, not from this ordering).
    fn emission_order(&self, pp: u32, m: u64) -> Vec<Cell>;

    /// Peak number of full-microbatch activations resident on the
    /// worst-case stage, in microbatch units (fractional for
    /// interleaved schedules, whose unit of residency is a chunk).
    fn peak_in_flight(&self, pp: u32, m: u64) -> f64;
}

/// The seed schedule: per microbatch, forward through every stage then
/// backward through every stage. All `m` microbatch activations are
/// live on stage 0 in the worst case (classic GPipe memory behavior).
#[derive(Debug, Clone, Copy, Default)]
pub struct GPipe;

impl PipelineSchedule for GPipe {
    fn name(&self) -> String {
        "gpipe".into()
    }

    fn emission_order(&self, pp: u32, m: u64) -> Vec<Cell> {
        let mut cells = Vec::with_capacity((2 * pp as u64 * m) as usize);
        for mb in 0..m {
            for stage in 0..pp {
                cells.push(Cell { stage, chunk: 0, mb, bwd: false });
            }
            for stage in (0..pp).rev() {
                cells.push(Cell { stage, chunk: 0, mb, bwd: true });
            }
        }
        cells
    }

    fn peak_in_flight(&self, _pp: u32, m: u64) -> f64 {
        m as f64
    }
}

/// One-forward-one-backward: stage `s` runs `min(pp - 1 - s, m)` warmup
/// forwards, alternates forward/backward in steady state, then drains
/// the remaining backwards. In-flight microbatches per stage are
/// bounded by `pp - s` instead of `m`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OneFOneB;

impl PipelineSchedule for OneFOneB {
    fn name(&self) -> String {
        "1f1b".into()
    }

    fn emission_order(&self, pp: u32, m: u64) -> Vec<Cell> {
        let mut cells = Vec::with_capacity((2 * pp as u64 * m) as usize);
        for stage in 0..pp {
            let warmup = u64::from(pp - 1 - stage).min(m);
            let fwd = |mb: u64| Cell { stage, chunk: 0, mb, bwd: false };
            let bwd = |mb: u64| Cell { stage, chunk: 0, mb, bwd: true };
            for mb in 0..warmup {
                cells.push(fwd(mb));
            }
            for i in 0..(m - warmup) {
                cells.push(fwd(warmup + i));
                cells.push(bwd(i));
            }
            for mb in (m - warmup)..m {
                cells.push(bwd(mb));
            }
        }
        cells
    }

    fn peak_in_flight(&self, pp: u32, m: u64) -> f64 {
        m.min(u64::from(pp)) as f64
    }
}

/// Megatron-style interleaved 1F1B: each physical stage hosts `vpp`
/// layer chunks, forming a virtual pipeline of `pp * vpp` stages. The
/// per-stage order follows Megatron's construction — warmup of
/// `(pp - 1 - s) * 2 + (vpp - 1) * pp` chunk-forwards, then strict
/// 1F1B over chunk-microbatches, then the backward drain — computed for
/// the microbatch count rounded up to a multiple of `pp` (Megatron's
/// divisibility requirement) with the phantom microbatches filtered
/// out, which preserves a valid (deadlock-free) relative order for any
/// `m ≥ 1`.
#[derive(Debug, Clone, Copy)]
pub struct Interleaved1F1B {
    /// Virtual-pipeline factor (layer chunks per physical stage), ≥ 2.
    pub vpp: u32,
}

impl PipelineSchedule for Interleaved1F1B {
    fn name(&self) -> String {
        format!("interleaved:{}", self.vpp)
    }

    fn vpp(&self) -> u32 {
        self.vpp
    }

    fn emission_order(&self, pp: u32, m: u64) -> Vec<Cell> {
        let vpp = u64::from(self.vpp.max(1));
        let ppl = u64::from(pp);
        // chunk-microbatch group: pp microbatches across all vpp chunks
        let grp = ppl * vpp;
        let m_pad = m.div_ceil(ppl) * ppl;
        let total = m_pad * vpp;
        let mut cells = Vec::with_capacity((2 * ppl * m * vpp) as usize);
        for stage in 0..pp {
            let warmup = (u64::from(pp - 1 - stage) * 2 + (vpp - 1) * ppl).min(total);
            // the k-th forward / backward chunk-microbatch on any rank
            let fwd = |k: u64| Cell {
                stage,
                chunk: ((k % grp) / ppl) as u32,
                mb: (k / grp) * ppl + k % ppl,
                bwd: false,
            };
            let bwd = |k: u64| Cell {
                stage,
                chunk: (vpp - 1 - (k % grp) / ppl) as u32,
                mb: (k / grp) * ppl + k % ppl,
                bwd: true,
            };
            let seq = (0..warmup)
                .map(fwd)
                .chain((0..total - warmup).flat_map(|i| [fwd(warmup + i), bwd(i)]))
                .chain((total - warmup..total).map(bwd));
            // drop the phantom microbatches introduced by padding
            cells.extend(seq.filter(|c| c.mb < m));
        }
        cells
    }

    fn peak_in_flight(&self, pp: u32, m: u64) -> f64 {
        let vpp = u64::from(self.vpp.max(1));
        let warmup0 = u64::from(pp - 1) * 2 + (vpp - 1) * u64::from(pp);
        // chunk-activations on stage 0, converted to microbatch units
        (warmup0 + 1).min(m * vpp) as f64 / vpp as f64
    }
}

/// Value-level schedule selection: what [`crate::config::framework::FrameworkSpec`]
/// carries, what the planner crosses candidates with, and what
/// `--schedule gpipe|1f1b|interleaved:V` parses into.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ScheduleKind {
    /// The seed GPipe-style schedule (the default).
    #[default]
    GPipe,
    /// One-forward-one-backward.
    OneFOneB,
    /// Interleaved 1F1B with the given virtual-pipeline factor.
    Interleaved1F1B {
        /// Layer chunks per physical stage, ≥ 2.
        vpp: u32,
    },
}

impl ScheduleKind {
    /// Instantiate the schedule implementation behind this selection.
    pub fn schedule(&self) -> Box<dyn PipelineSchedule + Send + Sync> {
        match *self {
            ScheduleKind::GPipe => Box::new(GPipe),
            ScheduleKind::OneFOneB => Box::new(OneFOneB),
            ScheduleKind::Interleaved1F1B { vpp } => Box::new(Interleaved1F1B { vpp }),
        }
    }

    /// Stable name, identical to the CLI syntax (`gpipe`, `1f1b`,
    /// `interleaved:V`); used in candidate keys and reports. Allocation
    /// stays cheap (no boxing) because candidate keys are compared on
    /// the planner's sort path.
    pub fn name(&self) -> String {
        match *self {
            ScheduleKind::GPipe => "gpipe".into(),
            ScheduleKind::OneFOneB => "1f1b".into(),
            ScheduleKind::Interleaved1F1B { vpp } => format!("interleaved:{vpp}"),
        }
    }

    /// Basic sanity: the interleaved factor must be at least 2 (a
    /// 1-chunk interleave is just 1F1B with extra bookkeeping).
    pub fn validate(&self) -> anyhow::Result<()> {
        if let ScheduleKind::Interleaved1F1B { vpp } = self {
            anyhow::ensure!(
                *vpp >= 2,
                "interleaved schedule needs vpp >= 2, got {vpp} (use 1f1b instead)"
            );
        }
        Ok(())
    }

    /// Peak activation bytes resident per GPU for this schedule on a
    /// `(tp, pp)` sharding running `m` microbatches per device group.
    ///
    /// Coarse by design: [`ACT_BYTES_PER_LAYER_FACTOR`] scaled by
    /// `micro_batch × seq_len × hidden / tp` bytes per layer, times the
    /// stage's layer count (`ceil(num_layers / pp)`), times the
    /// schedule's [`PipelineSchedule::peak_in_flight`]. The planner adds
    /// this to the weights+grads+optimizer estimate when pruning.
    pub fn peak_activation_bytes(&self, model: &ModelSpec, tp: u32, pp: u32, m: u64) -> u64 {
        let layers_per_stage = u64::from(model.num_layers).div_ceil(u64::from(pp));
        let per_layer = model.micro_batch
            * model.seq_len
            * model.hidden_size
            * ACT_BYTES_PER_LAYER_FACTOR
            / u64::from(tp.max(1));
        let peak = self.schedule().peak_in_flight(pp, m);
        (peak * (layers_per_stage * per_layer) as f64) as u64
    }
}

impl std::str::FromStr for ScheduleKind {
    type Err = anyhow::Error;

    /// Parse `gpipe`, `1f1b`, `interleaved` (vpp 2) or `interleaved:V`.
    fn from_str(s: &str) -> anyhow::Result<ScheduleKind> {
        let kind = match s {
            "gpipe" => ScheduleKind::GPipe,
            "1f1b" => ScheduleKind::OneFOneB,
            "interleaved" => ScheduleKind::Interleaved1F1B { vpp: 2 },
            other => match other.strip_prefix("interleaved:") {
                Some(v) => ScheduleKind::Interleaved1F1B {
                    vpp: v.parse().map_err(|_| {
                        anyhow::anyhow!("bad interleaved factor '{v}' (want interleaved:V)")
                    })?,
                },
                None => anyhow::bail!(
                    "unknown schedule '{other}' (known: gpipe, 1f1b, interleaved:V)"
                ),
            },
        };
        kind.validate()?;
        Ok(kind)
    }
}

impl std::fmt::Display for ScheduleKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Per-stage execution orders extracted from the emission order.
    fn by_stage(cells: &[Cell], pp: u32) -> Vec<Vec<Cell>> {
        let mut v = vec![Vec::new(); pp as usize];
        for c in cells {
            v[c.stage as usize].push(*c);
        }
        v
    }

    /// Every (stage, chunk, mb, dir) exactly once; forward precedes
    /// backward of the same unit on the same stage.
    fn check_complete(kind: ScheduleKind, pp: u32, m: u64) {
        let sched = kind.schedule();
        let vpp = sched.vpp();
        let cells = sched.emission_order(pp, m);
        assert_eq!(cells.len() as u64, 2 * u64::from(pp) * u64::from(vpp) * m, "{kind}");
        let mut seen: HashMap<Cell, usize> = HashMap::new();
        for (i, c) in cells.iter().enumerate() {
            assert!(c.stage < pp && c.chunk < vpp && c.mb < m, "{kind}: {c:?}");
            assert!(seen.insert(*c, i).is_none(), "{kind}: duplicate {c:?}");
        }
        for stage in by_stage(&cells, pp) {
            for c in &stage {
                if c.bwd {
                    let f = Cell { bwd: false, ..*c };
                    assert!(
                        seen[&f] < seen[c],
                        "{kind}: backward {c:?} before its forward"
                    );
                }
            }
        }
    }

    #[test]
    fn all_schedules_emit_each_cell_once() {
        for kind in [
            ScheduleKind::GPipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved1F1B { vpp: 2 },
            ScheduleKind::Interleaved1F1B { vpp: 4 },
        ] {
            for (pp, m) in [(1, 1), (2, 2), (4, 3), (4, 8), (3, 7), (8, 2)] {
                check_complete(kind, pp, m);
            }
        }
    }

    #[test]
    fn gpipe_order_is_seed_order() {
        let cells = GPipe.emission_order(2, 2);
        let expect = [
            (0, 0, false),
            (1, 0, false),
            (1, 0, true),
            (0, 0, true),
            (0, 1, false),
            (1, 1, false),
            (1, 1, true),
            (0, 1, true),
        ];
        let got: Vec<(u32, u64, bool)> =
            cells.iter().map(|c| (c.stage, c.mb, c.bwd)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn one_f_one_b_warmup_steady_cooldown_counts() {
        let (pp, m) = (4u32, 8u64);
        let cells = OneFOneB.emission_order(pp, m);
        for (s, stage) in by_stage(&cells, pp).into_iter().enumerate() {
            let warmup = (pp as usize - 1 - s).min(m as usize);
            // first `warmup` cells are forwards, last `warmup` backwards
            assert!(stage[..warmup].iter().all(|c| !c.bwd), "stage {s}");
            assert!(stage[stage.len() - warmup..].iter().all(|c| c.bwd), "stage {s}");
            // steady state strictly alternates F, B
            let steady = &stage[warmup..stage.len() - warmup];
            for pair in steady.chunks(2) {
                assert!(!pair[0].bwd && pair[1].bwd, "stage {s}: {pair:?}");
            }
        }
    }

    #[test]
    fn one_f_one_b_in_flight_bounded_by_pp_stages() {
        let (pp, m) = (4u32, 16u64);
        let cells = OneFOneB.emission_order(pp, m);
        for (s, stage) in by_stage(&cells, pp).into_iter().enumerate() {
            let mut in_flight = 0i64;
            let mut peak = 0i64;
            for c in &stage {
                in_flight += if c.bwd { -1 } else { 1 };
                peak = peak.max(in_flight);
            }
            assert_eq!(in_flight, 0, "stage {s} leaks activations");
            assert!(peak as u64 <= u64::from(pp - s as u32), "stage {s}: peak {peak}");
        }
    }

    #[test]
    fn interleaved_in_flight_bounded_by_warmup() {
        let (pp, m, vpp) = (4u32, 8u64, 2u32);
        let cells = Interleaved1F1B { vpp }.emission_order(pp, m);
        for (s, stage) in by_stage(&cells, pp).into_iter().enumerate() {
            let bound = (pp as i64 - 1 - s as i64) * 2 + (vpp as i64 - 1) * pp as i64 + 1;
            let mut in_flight = 0i64;
            for c in &stage {
                in_flight += if c.bwd { -1 } else { 1 };
                assert!(in_flight <= bound, "stage {s}: {in_flight} > {bound}");
            }
            assert_eq!(in_flight, 0, "stage {s} leaks chunk activations");
        }
    }

    #[test]
    fn interleaved_chunk_order_matches_megatron_small_case() {
        // pp=2, vpp=2, m=2: rank 0 warms up all 4 forwards (chunk 0 of
        // mb 0,1 then chunk 1 of mb 0,1) and drains backwards starting
        // from the last virtual stage's chunk.
        let cells = Interleaved1F1B { vpp: 2 }.emission_order(2, 2);
        let s0: Vec<(u32, u64, bool)> = by_stage(&cells, 2)[0]
            .iter()
            .map(|c| (c.chunk, c.mb, c.bwd))
            .collect();
        assert_eq!(
            s0,
            vec![
                (0, 0, false),
                (0, 1, false),
                (1, 0, false),
                (1, 1, false),
                (1, 0, true),
                (1, 1, true),
                (0, 0, true),
                (0, 1, true),
            ]
        );
    }

    #[test]
    fn peak_in_flight_ordering() {
        // For m >= pp: GPipe holds everything, 1F1B holds pp, interleaved
        // sits between 1F1B and GPipe for realistic factors.
        let (pp, m) = (4u32, 32u64);
        let g = GPipe.peak_in_flight(pp, m);
        let o = OneFOneB.peak_in_flight(pp, m);
        let i = Interleaved1F1B { vpp: 2 }.peak_in_flight(pp, m);
        assert_eq!(g, m as f64);
        assert_eq!(o, pp as f64);
        assert!(o < i && i < g, "1f1b {o} < interleaved {i} < gpipe {g}");
    }

    #[test]
    fn parse_round_trips() {
        for s in ["gpipe", "1f1b", "interleaved:2", "interleaved:4"] {
            let k: ScheduleKind = s.parse().unwrap();
            assert_eq!(k.name(), s);
        }
        assert_eq!(
            "interleaved".parse::<ScheduleKind>().unwrap(),
            ScheduleKind::Interleaved1F1B { vpp: 2 }
        );
        assert!("interleaved:1".parse::<ScheduleKind>().is_err());
        assert!("interleaved:x".parse::<ScheduleKind>().is_err());
        assert!("pipedream".parse::<ScheduleKind>().is_err());
    }

    #[test]
    fn activation_bytes_scale_with_schedule() {
        let m = crate::config::presets::model("gpt-6.7b").unwrap();
        let g = ScheduleKind::GPipe.peak_activation_bytes(&m, 4, 2, 16);
        let o = ScheduleKind::OneFOneB.peak_activation_bytes(&m, 4, 2, 16);
        assert!(o < g, "1f1b {o} >= gpipe {g}");
        // sharding more TP shrinks the estimate
        let g8 = ScheduleKind::GPipe.peak_activation_bytes(&m, 8, 2, 16);
        assert!(g8 < g);
    }
}
