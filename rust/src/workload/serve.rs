//! Inference serving workload generator (DESIGN.md §27, ROADMAP
//! item 2): request traces for the serving scheduler.
//!
//! A [`ServeSpec`] describes serving traffic the same way
//! [`crate::system::failure::FaultSpec`] describes faults — as plain
//! data riding the scenario JSON (`"serving"` key) or built in code:
//!
//! * **explicit requests** — a trace of [`Request`]s with arrival time,
//!   prompt/output token counts, and a weight for weighted policies;
//! * **Poisson arrivals** — a seeded open-loop generator
//!   ([`poisson_trace`]) drawing exponential inter-arrival gaps. Like
//!   the PR-7 MTBF schedules, the draw uses **nested thinning**: every
//!   candidate arrival is drawn at the capped maximum rate
//!   ([`RATE_SCALE_CAP`] × the base rate) with *all* of its attributes,
//!   then kept with probability `scale / RATE_SCALE_CAP` from a
//!   per-candidate coin — so a lower-scale trace is an exact subset of
//!   a higher-scale one for the same seed
//!   (`tests/properties.rs::prop_serve_poisson_subset_across_rate_scales`).
//!
//! Each request lowers into a **prefill** op stream (one compute-bound
//! full-prompt forward pass, [`prefill_works`]) plus an iterative
//! **decode** stream (one memory-bandwidth-bound token step per output
//! token, [`decode_works`]), both priced through the existing per-arch
//! cost tables. Concurrent residency is bounded by the KV-cache memory
//! model: [`kv_bytes_per_token`] per resident token, against the
//! per-device-group budget [`serve_groups`] derives from GPU memory
//! capacity minus the model weights.

use crate::compute::cost::LayerWork;
use crate::config::cluster::ClusterSpec;
use crate::config::model::{LayerKind, ModelSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Cap on the Poisson rate-scale factor. Candidate arrivals are drawn
/// at `RATE_SCALE_CAP × rate_per_s` and thinned down, mirroring
/// `system::failure::SCALE_CAP`, so traces at different scales nest.
pub const RATE_SCALE_CAP: f64 = 16.0;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Arrival time, seconds from the start of the trace.
    pub arrival_s: f64,
    /// Prompt length in tokens (prefill work).
    pub prompt_tokens: u64,
    /// Tokens to generate (decode steps).
    pub output_tokens: u64,
    /// Priority weight for weighted policies (`wsrpt` divides the
    /// remaining-work key by it; higher = more urgent).
    pub weight: f64,
}

impl Request {
    /// Peak KV-cache residency of this request in tokens: the full
    /// prompt plus every generated token stays resident until the
    /// request retires (reserved in full at admission so a running
    /// request can never be evicted mid-flight).
    pub fn kv_tokens(&self) -> u64 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Request-level scheduling policy of the serving scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServePolicy {
    /// First-in-first-out by arrival index.
    #[default]
    Fifo,
    /// Shortest remaining processing time (total tokens), arrival-index
    /// tie-break.
    Srpt,
    /// Weighted SRPT: remaining tokens divided by the request weight.
    Wsrpt,
}

impl ServePolicy {
    /// Parse the CLI / scenario shorthand: `fifo | srpt | wsrpt`.
    pub fn parse(s: &str) -> anyhow::Result<ServePolicy> {
        match s {
            "fifo" => Ok(ServePolicy::Fifo),
            "srpt" => Ok(ServePolicy::Srpt),
            "wsrpt" => Ok(ServePolicy::Wsrpt),
            other => anyhow::bail!("unknown serving policy '{other}' (want fifo | srpt | wsrpt)"),
        }
    }

    /// Display name in the grammar [`ServePolicy::parse`] accepts.
    pub fn name(self) -> &'static str {
        match self {
            ServePolicy::Fifo => "fifo",
            ServePolicy::Srpt => "srpt",
            ServePolicy::Wsrpt => "wsrpt",
        }
    }
}

/// Seeded open-loop Poisson arrival process.
#[derive(Debug, Clone, PartialEq)]
pub struct PoissonSpec {
    /// Base arrival rate, requests per second (at `scale = 1`).
    pub rate_per_s: f64,
    /// Trace horizon in seconds — arrivals past it are dropped.
    pub horizon_s: f64,
    /// Rate multiplier in `[0, RATE_SCALE_CAP]`; the effective rate is
    /// `scale × rate_per_s` and lower-scale traces are exact subsets of
    /// higher-scale ones for the same seed.
    pub scale: f64,
    /// Mean prompt length; per-request lengths are drawn uniformly in
    /// `[0.5, 1.5) ×` the mean.
    pub prompt_tokens: u64,
    /// Mean output length (same `[0.5, 1.5)` spread).
    pub output_tokens: u64,
}

impl Default for PoissonSpec {
    fn default() -> Self {
        PoissonSpec {
            rate_per_s: 2.0,
            horizon_s: 20.0,
            scale: 1.0,
            prompt_tokens: 512,
            output_tokens: 64,
        }
    }
}

/// The serving workload spec: trace sources plus scheduler knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Explicit request trace (merged with any Poisson draw).
    pub requests: Vec<Request>,
    /// Optional Poisson arrival generator.
    pub poisson: Option<PoissonSpec>,
    /// Request-level scheduling policy.
    pub policy: ServePolicy,
    /// Continuous-batching cap: concurrent requests per device group.
    pub max_batch: u32,
    /// Fraction of the post-weights GPU memory usable for KV cache.
    pub kv_frac: f64,
    /// PRNG seed for the Poisson draw.
    pub seed: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            requests: Vec::new(),
            poisson: None,
            policy: ServePolicy::Fifo,
            max_batch: 32,
            kv_frac: 0.8,
            seed: 42,
        }
    }
}

fn strict_f64(v: &Json, key: &str, default: f64) -> anyhow::Result<f64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_f64().ok_or_else(|| anyhow::anyhow!("serving: `{key}` must be a number")),
    }
}

fn strict_u64(v: &Json, key: &str, default: u64) -> anyhow::Result<u64> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => {
            x.as_u64().ok_or_else(|| anyhow::anyhow!("serving: `{key}` must be an unsigned int"))
        }
    }
}

impl ServeSpec {
    /// True when the spec generates no traffic at all (and is therefore
    /// indistinguishable from no spec).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty() && self.poisson.is_none()
    }

    /// Sort explicit requests by arrival time (stable — equal-time
    /// requests keep their declaration order).
    pub fn normalize(&mut self) {
        self.requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    }

    /// Check the spec's own invariants (the cluster-dependent fit check
    /// — does every request's KV footprint fit some device group —
    /// happens in the scheduler, which knows the budgets).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, r) in self.requests.iter().enumerate() {
            anyhow::ensure!(
                r.arrival_s.is_finite() && r.arrival_s >= 0.0,
                "serving: requests[{i}]: arrival_s {} is not a finite non-negative number",
                r.arrival_s
            );
            anyhow::ensure!(
                r.prompt_tokens >= 1 && r.output_tokens >= 1,
                "serving: requests[{i}]: prompt_tokens and output_tokens must be >= 1"
            );
            anyhow::ensure!(
                r.weight.is_finite() && r.weight > 0.0,
                "serving: requests[{i}]: weight {} must be a positive finite number",
                r.weight
            );
        }
        if let Some(p) = &self.poisson {
            anyhow::ensure!(
                p.rate_per_s.is_finite() && p.rate_per_s > 0.0,
                "serving: poisson rate_per_s must be a positive number"
            );
            anyhow::ensure!(
                p.horizon_s.is_finite() && p.horizon_s > 0.0,
                "serving: poisson horizon_s must be a positive number of seconds"
            );
            anyhow::ensure!(
                p.scale.is_finite() && p.scale >= 0.0,
                "serving: poisson scale must be a finite non-negative number"
            );
            anyhow::ensure!(
                p.prompt_tokens >= 1 && p.output_tokens >= 1,
                "serving: poisson prompt_tokens and output_tokens must be >= 1"
            );
        }
        anyhow::ensure!(self.max_batch >= 1, "serving: max_batch must be >= 1");
        anyhow::ensure!(
            self.kv_frac.is_finite() && self.kv_frac > 0.0 && self.kv_frac <= 1.0,
            "serving: kv_frac must be in (0, 1]"
        );
        Ok(())
    }

    /// Stable cache-key marker: the empty string when the spec is empty
    /// (the serving layer is invisible when off), otherwise a
    /// `|serve:<hash>` suffix appended to the simulator's eval keys so
    /// serving-annotated scores never alias training scores on the same
    /// cluster shape.
    pub fn fingerprint(&self) -> String {
        if self.is_empty() {
            return String::new();
        }
        let mut s = format!(
            "p{};b{};k{};s{}",
            self.policy.name(),
            self.max_batch,
            self.kv_frac,
            self.seed
        );
        if let Some(p) = &self.poisson {
            s.push_str(&format!(
                ";poisson:{},{},{},{},{}",
                p.rate_per_s, p.horizon_s, p.scale, p.prompt_tokens, p.output_tokens
            ));
        }
        for r in &self.requests {
            s.push_str(&format!(
                ";{}@{}+{}x{}",
                r.arrival_s, r.prompt_tokens, r.output_tokens, r.weight
            ));
        }
        // FNV-1a over the canonical serialization
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in s.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("|serve:{h:016x}")
    }

    /// Parse a `"serving"` JSON object (scenario key).
    ///
    /// Recognized keys — all optional, but present-and-malformed is an
    /// error, never a silent default:
    ///
    /// * `"requests"`: array of `{"arrival_s", "prompt_tokens",
    ///   "output_tokens", "weight"}` (`weight` optional, default 1),
    /// * `"poisson"`: `{"rate_per_s", "horizon_s", "scale",
    ///   "prompt_tokens", "output_tokens"}` overriding
    ///   [`PoissonSpec::default`] (`rate_per_s` required),
    /// * `"policy"`: `"fifo" | "srpt" | "wsrpt"` (default fifo),
    /// * `"max_batch"`, `"kv_frac"`: scheduler knobs,
    /// * `"seed"`: PRNG seed for the Poisson draw (defaults to
    ///   `default_seed`, which scenario files wire to their own
    ///   `"seed"` key).
    pub fn from_json(v: &Json, default_seed: u64) -> anyhow::Result<ServeSpec> {
        anyhow::ensure!(
            v.get("requests").is_some() || v.get("poisson").is_some(),
            "serving: expected at least one of `requests`, `poisson`"
        );
        let mut spec = ServeSpec { seed: strict_u64(v, "seed", default_seed)?, ..Default::default() };
        if let Some(p) = v.get("policy") {
            let name = p
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("serving: `policy` must be a string"))?;
            spec.policy = ServePolicy::parse(name)?;
        }
        spec.max_batch = strict_u64(v, "max_batch", spec.max_batch as u64)? as u32;
        spec.kv_frac = strict_f64(v, "kv_frac", spec.kv_frac)?;
        if let Some(arr) = v.get("requests") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("serving: `requests` must be an array"))?;
            for (i, r) in arr.iter().enumerate() {
                let ctx = |err| anyhow::anyhow!("serving: requests[{i}]: {err}");
                spec.requests.push(Request {
                    arrival_s: r.req_f64("arrival_s").map_err(ctx)?,
                    prompt_tokens: r.req_u64("prompt_tokens").map_err(ctx)?,
                    output_tokens: r.req_u64("output_tokens").map_err(ctx)?,
                    weight: strict_f64(r, "weight", 1.0)
                        .map_err(|err| anyhow::anyhow!("serving: requests[{i}]: {err}"))?,
                });
            }
        }
        if let Some(p) = v.get("poisson") {
            let d = PoissonSpec::default();
            spec.poisson = Some(PoissonSpec {
                rate_per_s: p
                    .req_f64("rate_per_s")
                    .map_err(|err| anyhow::anyhow!("serving: poisson: {err}"))?,
                horizon_s: strict_f64(p, "horizon_s", d.horizon_s)?,
                scale: strict_f64(p, "scale", d.scale)?,
                prompt_tokens: strict_u64(p, "prompt_tokens", d.prompt_tokens)?,
                output_tokens: strict_u64(p, "output_tokens", d.output_tokens)?,
            });
        }
        spec.normalize();
        spec.validate()?;
        Ok(spec)
    }

    /// Materialize the full request trace: the Poisson draw (if any)
    /// merged with the explicit requests, sorted by arrival time with a
    /// stable tie-break. The position in the returned `Vec` is the
    /// request's **arrival index** — the deterministic tie-breaker every
    /// scheduler policy falls back to.
    pub fn materialize(&self) -> Vec<Request> {
        let mut all = self.requests.clone();
        if let Some(p) = &self.poisson {
            all.extend(poisson_trace(p, self.seed));
        }
        all.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        all
    }
}

/// Draw a Poisson request trace by nested thinning (see the module
/// docs): candidates arrive at `RATE_SCALE_CAP × rate_per_s`; every
/// candidate draws its prompt/output/weight attributes **and** its
/// keep-coin unconditionally, then survives iff
/// `coin × RATE_SCALE_CAP < scale`. Same seed + lower scale ⇒ an exact
/// subset of the higher-scale trace.
pub fn poisson_trace(spec: &PoissonSpec, seed: u64) -> Vec<Request> {
    let mut out = Vec::new();
    if spec.rate_per_s <= 0.0 || spec.horizon_s <= 0.0 {
        return out;
    }
    let scale = spec.scale.clamp(0.0, RATE_SCALE_CAP);
    let cap_rate = spec.rate_per_s * RATE_SCALE_CAP;
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    loop {
        let u = 1.0 - rng.f64(); // (0, 1] so ln is finite
        t += -u.ln() / cap_rate;
        if t > spec.horizon_s {
            break;
        }
        let u_prompt = rng.f64();
        let u_output = rng.f64();
        let u_weight = rng.f64();
        let keep = rng.f64() * RATE_SCALE_CAP < scale;
        if !keep {
            continue;
        }
        out.push(Request {
            arrival_s: t,
            prompt_tokens: ((spec.prompt_tokens as f64) * (0.5 + u_prompt)).round().max(1.0) as u64,
            output_tokens: ((spec.output_tokens as f64) * (0.5 + u_output)).round().max(1.0) as u64,
            weight: 0.5 + 1.5 * u_weight,
        });
    }
    out
}

/// KV-cache bytes per resident token across the whole model (all
/// layers, K + V): `2 × num_layers × hidden_size × dtype_bytes`. Under
/// TP the cache is sharded, so this is also the per-token total across
/// a TP group regardless of its degree.
pub fn kv_bytes_per_token(model: &ModelSpec) -> u64 {
    2 * model.num_layers as u64 * model.hidden_size * model.dtype_bytes
}

/// One serving device group: a whole node running the full model with
/// TP = the node's GPU count (PP = 1 — the latency-optimal serving
/// layout), plus its KV-cache admission budget.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeGroup {
    /// Node index in the cluster.
    pub node: u32,
    /// GPU model name of every rank in the group.
    pub gpu: String,
    /// TP degree = GPUs on the node.
    pub tp: u32,
    /// KV-cache admission budget in resident tokens.
    pub kv_budget_tokens: u64,
}

/// Derive the serving device groups for a cluster: one group per node
/// (heterogeneous nodes become independently-paced serving replicas).
/// The KV budget is the node's aggregate GPU memory minus the model
/// weights, scaled by `kv_frac`, divided by [`kv_bytes_per_token`].
/// Errors when the weights do not fit a node or the budget rounds to
/// zero tokens — a group that can never admit anything is a
/// configuration error, not a silent starvation.
pub fn serve_groups(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    kv_frac: f64,
) -> anyhow::Result<Vec<ServeGroup>> {
    let weight_bytes = model.param_count() * model.dtype_bytes;
    let per_token = kv_bytes_per_token(model);
    let mut groups = Vec::with_capacity(cluster.nodes.len());
    for (i, n) in cluster.nodes.iter().enumerate() {
        let mem = n.gpu.mem_capacity * n.gpus_per_node as u64;
        anyhow::ensure!(
            mem > weight_bytes,
            "serving: {} weights ({:.1} GB) do not fit node {i} ({} x {}, {:.1} GB)",
            model.name,
            weight_bytes as f64 / 1e9,
            n.gpus_per_node,
            n.gpu.name,
            mem as f64 / 1e9
        );
        let budget = ((mem - weight_bytes) as f64 * kv_frac) as u64 / per_token;
        anyhow::ensure!(
            budget >= 1,
            "serving: node {i} ({} x {}) has no KV budget left after {} weights",
            n.gpus_per_node,
            n.gpu.name,
            model.name
        );
        groups.push(ServeGroup {
            node: i as u32,
            gpu: n.gpu.name.clone(),
            tp: n.gpus_per_node,
            kv_budget_tokens: budget,
        });
    }
    Ok(groups)
}

/// The prefill op stream for one request: a full-prompt forward pass —
/// compute-bound GEMMs over `seq = prompt_tokens` — as (work,
/// multiplicity) pairs: the embedding once, then each per-block kind
/// `num_layers` times, all sharded across the group's TP degree.
pub fn prefill_works(model: &ModelSpec, prompt_tokens: u64, tp: u32) -> Vec<(LayerWork, u64)> {
    works(model, prompt_tokens as f64, 1.0, tp)
}

/// One decode step for a continuous batch of `batch` in-flight
/// requests: a single-token (`seq = 1`) forward pass whose roofline is
/// memory-bandwidth-bound (dominated by streaming the weights), so
/// batching amortizes it — the reason continuous batching wins.
pub fn decode_works(model: &ModelSpec, batch: u32, tp: u32) -> Vec<(LayerWork, u64)> {
    works(model, 1.0, batch as f64, tp)
}

fn works(model: &ModelSpec, seq: f64, mbs: f64, tp: u32) -> Vec<(LayerWork, u64)> {
    let (n_experts, top_k) = match model.moe {
        Some(m) => (m.num_experts as f64, m.top_k as f64),
        None => (0.0, 0.0),
    };
    let work = |kind: LayerKind| LayerWork {
        kind,
        hidden: model.hidden_size as f64,
        ffn: model.ffn_hidden as f64,
        heads: model.num_heads as f64,
        seq,
        mbs,
        n_experts,
        top_k,
        tp: tp as f64,
        is_bwd: false,
    };
    let mut out = vec![(work(LayerKind::Embedding), 1)];
    for kind in model.block_kinds() {
        out.push((work(kind), model.num_layers as u64));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn poisson(scale: f64) -> PoissonSpec {
        PoissonSpec { rate_per_s: 4.0, horizon_s: 10.0, scale, ..Default::default() }
    }

    #[test]
    fn poisson_trace_reproducible_and_sorted() {
        let a = poisson_trace(&poisson(1.0), 7);
        let b = poisson_trace(&poisson(1.0), 7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(!a.is_empty(), "4 req/s over 10s should draw something");
        for r in &a {
            assert!(r.arrival_s > 0.0 && r.arrival_s <= 10.0);
            assert!(r.prompt_tokens >= 1 && r.output_tokens >= 1);
            assert!(r.weight > 0.0);
        }
    }

    #[test]
    fn poisson_lower_scale_is_subset() {
        let hi = poisson_trace(&poisson(4.0), 11);
        let lo = poisson_trace(&poisson(1.0), 11);
        assert!(lo.len() < hi.len(), "{} vs {}", lo.len(), hi.len());
        for r in &lo {
            assert!(hi.contains(r), "low-scale request missing at high scale: {r:?}");
        }
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let t = poisson_trace(&poisson(1.0), 3);
        // 4 req/s x 10 s = 40 expected; allow a wide deterministic band
        assert!((20..=60).contains(&t.len()), "{}", t.len());
    }

    #[test]
    fn spec_empty_and_fingerprint() {
        let spec = ServeSpec::default();
        assert!(spec.is_empty());
        assert_eq!(spec.fingerprint(), "");
        let mut a = spec.clone();
        a.poisson = Some(PoissonSpec::default());
        assert!(!a.is_empty());
        let fa = a.fingerprint();
        assert!(fa.starts_with("|serve:"));
        let mut b = a.clone();
        b.policy = ServePolicy::Srpt;
        assert_ne!(fa, b.fingerprint(), "policy must change the fingerprint");
        let mut c = a.clone();
        c.seed += 1;
        assert_ne!(fa, c.fingerprint(), "seed must change the fingerprint");
    }

    #[test]
    fn from_json_parses_and_rejects() {
        let v = Json::parse(
            r#"{"policy": "wsrpt", "max_batch": 4,
                "requests": [{"arrival_s": 0.5, "prompt_tokens": 128,
                              "output_tokens": 16, "weight": 2.0}],
                "poisson": {"rate_per_s": 3.0, "horizon_s": 5.0}}"#,
        )
        .unwrap();
        let spec = ServeSpec::from_json(&v, 9).unwrap();
        assert_eq!(spec.policy, ServePolicy::Wsrpt);
        assert_eq!(spec.max_batch, 4);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.requests.len(), 1);
        assert_eq!(spec.poisson.as_ref().unwrap().rate_per_s, 3.0);

        for bad in [
            r#"{}"#,
            r#"{"requests": 3}"#,
            r#"{"requests": [{"arrival_s": -1, "prompt_tokens": 1, "output_tokens": 1}]}"#,
            r#"{"requests": [{"arrival_s": 0, "prompt_tokens": 0, "output_tokens": 1}]}"#,
            r#"{"poisson": {"rate_per_s": -2}}"#,
            r#"{"poisson": {"rate_per_s": 1}, "policy": "lifo"}"#,
            r#"{"poisson": {"rate_per_s": 1}, "max_batch": 0}"#,
            r#"{"poisson": {"rate_per_s": 1}, "kv_frac": 1.5}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(ServeSpec::from_json(&v, 0).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn materialize_merges_and_orders_by_arrival() {
        let spec = ServeSpec {
            requests: vec![
                Request { arrival_s: 5.0, prompt_tokens: 8, output_tokens: 2, weight: 1.0 },
                Request { arrival_s: 0.25, prompt_tokens: 4, output_tokens: 2, weight: 1.0 },
            ],
            poisson: Some(poisson(1.0)),
            ..Default::default()
        };
        let all = spec.materialize();
        assert!(all.len() > 2);
        assert!(all.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(all.iter().any(|r| r.prompt_tokens == 4));
    }

    #[test]
    fn kv_model_and_groups() {
        let m = presets::model("gpt-6.7b").unwrap();
        // 2 x layers x hidden x dtype
        assert_eq!(
            kv_bytes_per_token(&m),
            2 * m.num_layers as u64 * m.hidden_size * m.dtype_bytes
        );
        let c = presets::cluster_hetero(1, 1).unwrap();
        let groups = serve_groups(&m, &c, 0.8).unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].tp, 8);
        // the H100 node has more memory headroom than the A100 node
        let a100 = groups.iter().find(|g| g.gpu == "A100").unwrap();
        let h100 = groups.iter().find(|g| g.gpu == "H100").unwrap();
        assert!(h100.kv_budget_tokens > a100.kv_budget_tokens);
        assert!(a100.kv_budget_tokens >= 1);
    }

    #[test]
    fn groups_reject_oversized_model() {
        let m = presets::model("llama2-70b").unwrap();
        let mut c = presets::cluster_hetero(1, 0).unwrap();
        c.nodes[0].gpus_per_node = 2; // 2 x A100 = 80 GB < 70B weights
        let err = serve_groups(&m, &c, 0.8).unwrap_err();
        assert!(err.to_string().contains("do not fit"), "{err}");
    }

    #[test]
    fn prefill_is_compute_bound_decode_is_memory_bound() {
        use crate::compute::cost::NativeCostModel;
        let m = presets::model("llama2-70b").unwrap();
        let gpu = presets::gpu("H100").unwrap();
        // prefill: long-sequence GEMMs — compute-limited (t_compute wins)
        let (w, _) = prefill_works(&m, 512, 4)[2]; // an MLP block
        let (flops, bytes) = w.flops_bytes();
        assert!(
            flops / (gpu.peak_flops * gpu.eff_mlp) > bytes / (gpu.mem_bw * gpu.eff_mem),
            "prefill MLP should be compute-bound"
        );
        // decode: one token — memory-limited (weight streaming wins)
        let (w, _) = decode_works(&m, 1, 4)[2];
        let (flops, bytes) = w.flops_bytes();
        assert!(
            flops / (gpu.peak_flops * gpu.eff_mlp) < bytes / (gpu.mem_bw * gpu.eff_mem),
            "decode MLP should be memory-bound"
        );
        // batching amortizes the decode step: 8x batch costs far less
        // than 8x the single-request step
        let t1: f64 = decode_works(&m, 1, 4)
            .iter()
            .map(|(w, n)| NativeCostModel.time_seconds(w, &gpu) * *n as f64)
            .sum();
        let t8: f64 = decode_works(&m, 8, 4)
            .iter()
            .map(|(w, n)| NativeCostModel.time_seconds(w, &gpu) * *n as f64)
            .sum();
        assert!(t8 < 4.0 * t1, "batched decode step not amortized: {t8} vs {t1}");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [ServePolicy::Fifo, ServePolicy::Srpt, ServePolicy::Wsrpt] {
            assert_eq!(ServePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(ServePolicy::parse("edf").is_err());
    }
}
