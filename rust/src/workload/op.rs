//! Op taxonomy: what a rank does during one training iteration.

use crate::compute::cost::LayerWork;
use crate::system::collective::CollectiveDef;

/// One operation in a rank's program. Ranks execute their program in
/// order; `Collective` and `Recv` are blocking, `Send` is asynchronous
/// (NCCL-style non-blocking isend).
#[derive(Debug, Clone)]
pub enum Op {
    /// Local kernel execution; duration resolved via the cost table.
    Compute { work: LayerWork, label: &'static str },
    /// Participate in collective `def_id` (blocks until it completes).
    Collective { def_id: u64 },
    /// Point-to-point activation/gradient transfer to `peer`.
    Send { peer: u32, bytes: u64, msg: u64 },
    /// Block until message `msg` arrives.
    Recv { msg: u64 },
}

/// A rank's full program for one iteration.
#[derive(Debug, Clone, Default)]
pub struct RankProgram {
    /// Global rank executing this program.
    pub rank: u32,
    /// Ops in execution order.
    pub ops: Vec<Op>,
}

/// The complete workload: programs for every rank + the collective
/// definitions they reference.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    /// Per-rank programs, sorted by rank.
    pub programs: Vec<RankProgram>,
    /// Collective definitions referenced by `Op::Collective` ops.
    pub collectives: Vec<CollectiveDef>,
}

impl Workload {
    /// Look up a collective definition by id.
    pub fn collective(&self, id: u64) -> Option<&CollectiveDef> {
        self.collectives.iter().find(|c| c.id == id)
    }

    /// Count ops by coarse category: (compute, collective, p2p).
    pub fn op_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for p in &self.programs {
            for op in &p.ops {
                match op {
                    Op::Compute { .. } => c.0 += 1,
                    Op::Collective { .. } => c.1 += 1,
                    Op::Send { .. } | Op::Recv { .. } => c.2 += 1,
                }
            }
        }
        c
    }

    /// Validation invariants: every referenced collective exists; every
    /// rank in a collective's group has exactly one matching op per
    /// occurrence; sends and recvs pair up by message id.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.validate_inner(false)
    }

    /// [`Workload::validate`] for symmetry-folded workloads
    /// ([`crate::system::fold`]): folded ranks have no program, so
    /// collective participation is only required of program-bearing
    /// ranks — but every collective still needs at least one, or it
    /// could never launch.
    pub fn validate_folded(&self) -> anyhow::Result<()> {
        self.validate_inner(true)
    }

    fn validate_inner(&self, folded: bool) -> anyhow::Result<()> {
        use std::collections::HashMap;
        let defs: HashMap<u64, &CollectiveDef> =
            self.collectives.iter().map(|c| (c.id, c)).collect();
        // collective participation count per (def, rank)
        let mut part: HashMap<(u64, u32), usize> = HashMap::new();
        let mut sends: HashMap<u64, (u32, u32)> = HashMap::new(); // msg -> (src, dst)
        let mut recvs: HashMap<u64, u32> = HashMap::new();
        for p in &self.programs {
            for op in &p.ops {
                match op {
                    Op::Collective { def_id } => {
                        anyhow::ensure!(
                            defs.contains_key(def_id),
                            "rank {} references unknown collective {def_id}",
                            p.rank
                        );
                        *part.entry((*def_id, p.rank)).or_insert(0) += 1;
                    }
                    Op::Send { peer, msg, .. } => {
                        anyhow::ensure!(
                            sends.insert(*msg, (p.rank, *peer)).is_none(),
                            "duplicate send for message {msg}"
                        );
                    }
                    Op::Recv { msg } => {
                        anyhow::ensure!(
                            recvs.insert(*msg, p.rank).is_none(),
                            "duplicate recv for message {msg}"
                        );
                    }
                    Op::Compute { .. } => {}
                }
            }
        }
        let has_program: std::collections::HashSet<u32> =
            self.programs.iter().map(|p| p.rank).collect();
        for (id, def) in &defs {
            let counts: Vec<usize> =
                def.ranks.iter().map(|r| part.get(&(*id, *r)).copied().unwrap_or(0)).collect();
            if folded {
                // folded ranks legitimately sit out; every
                // program-bearing participant still shows up exactly once
                let ok = def
                    .ranks
                    .iter()
                    .zip(&counts)
                    .all(|(r, c)| if has_program.contains(r) { *c == 1 } else { *c == 0 });
                anyhow::ensure!(
                    ok && counts.iter().any(|c| *c == 1),
                    "folded collective {id} ({}) participation mismatch: {counts:?} over ranks {:?}",
                    def.label,
                    def.ranks
                );
            } else {
                anyhow::ensure!(
                    counts.iter().all(|c| *c == 1),
                    "collective {id} ({}) participation mismatch: {counts:?} over ranks {:?}",
                    def.label,
                    def.ranks
                );
            }
        }
        for (msg, (src, dst)) in &sends {
            match recvs.get(msg) {
                Some(r) if r == dst => {}
                Some(r) => anyhow::bail!("message {msg} sent {src}->{dst} but received by {r}"),
                None => anyhow::bail!("message {msg} sent {src}->{dst} but never received"),
            }
        }
        for msg in recvs.keys() {
            anyhow::ensure!(sends.contains_key(msg), "recv of message {msg} without a send");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::LayerKind;
    use crate::system::collective::{CollectiveAlgo, CommKind};

    fn lw() -> LayerWork {
        LayerWork {
            kind: LayerKind::Mlp,
            hidden: 64.0,
            ffn: 256.0,
            heads: 4.0,
            seq: 32.0,
            mbs: 1.0,
            n_experts: 0.0,
            top_k: 0.0,
            tp: 1.0,
            is_bwd: false,
        }
    }

    fn coll(id: u64, ranks: Vec<u32>) -> CollectiveDef {
        CollectiveDef {
            id,
            algo: CollectiveAlgo::AllReduceRing,
            ranks,
            bytes_per_rank: 1024,
            kind: CommKind::Tp,
            label: "t".into(),
        }
    }

    #[test]
    fn valid_workload_passes() {
        let w = Workload {
            programs: vec![
                RankProgram {
                    rank: 0,
                    ops: vec![
                        Op::Compute { work: lw(), label: "mlp" },
                        Op::Collective { def_id: 1 },
                        Op::Send { peer: 1, bytes: 10, msg: 7 },
                    ],
                },
                RankProgram {
                    rank: 1,
                    ops: vec![Op::Collective { def_id: 1 }, Op::Recv { msg: 7 }],
                },
            ],
            collectives: vec![coll(1, vec![0, 1])],
        };
        w.validate().unwrap();
        assert_eq!(w.op_counts(), (1, 2, 2));
    }

    #[test]
    fn missing_participant_rejected() {
        let w = Workload {
            programs: vec![RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 1 }] }],
            collectives: vec![coll(1, vec![0, 1])],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn unknown_collective_rejected() {
        let w = Workload {
            programs: vec![RankProgram { rank: 0, ops: vec![Op::Collective { def_id: 9 }] }],
            collectives: vec![],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn unmatched_send_rejected() {
        let w = Workload {
            programs: vec![RankProgram {
                rank: 0,
                ops: vec![Op::Send { peer: 1, bytes: 1, msg: 5 }],
            }],
            collectives: vec![],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn mismatched_recv_rank_rejected() {
        let w = Workload {
            programs: vec![
                RankProgram { rank: 0, ops: vec![Op::Send { peer: 1, bytes: 1, msg: 5 }] },
                RankProgram { rank: 2, ops: vec![Op::Recv { msg: 5 }] },
            ],
            collectives: vec![],
        };
        assert!(w.validate().is_err());
    }
}
