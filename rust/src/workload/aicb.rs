//! AICB-like workload generator (component **C1**).
//!
//! Expands (model, cluster, framework spec) into per-rank programs for
//! one training iteration under the framework's pipeline schedule
//! ([`crate::workload::schedule`]):
//!
//! * the schedule's emission order decides which `(stage, chunk,
//!   microbatch, direction)` cell each rank works on next (GPipe-style
//!   by default — bit-identical to the seed generator — or 1F1B /
//!   interleaved 1F1B);
//! * forward cell: embedding (first chunk of the embedding stage),
//!   attention / MLP (or MoE) blocks with Megatron-style TP allreduces
//!   (2 per layer per direction), MoE dispatch/combine all-to-alls,
//!   activation recv from / send to the adjacent virtual stage;
//! * backward cell: mirrored, with doubled FLOPs and reversed P2P
//!   direction;
//! * gradient synchronization: per-stage DP allreduce — slot-wise rings
//!   when the communicating groups agree on shapes, or a full
//!   [`crate::system::resharding`] plan when they do not (component C2).
//!
//! Emission is two-pass per device group: a first walk over the
//! schedule's cells allocates every p2p message tag (unique per
//! transfer, including per-virtual-stage transfers of interleaved
//! schedules — [`crate::system::compiled`] rejects reuse), a second
//! walk appends the ops to each rank's stream. Per-rank op order equals
//! the rank's execution order under the schedule; the event simulation
//! derives the actual overlap from the data dependencies.
//!
//! The generator emits *device-group-specific* work: each group's layer
//! count, TP degree and microbatch count come from its own plan entry,
//! which is exactly the paper's "distinct workload traces tailored to
//! the device group's role".

use std::collections::HashMap;

use crate::compute::cost::LayerWork;
use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::config::framework::{split_evenly, FrameworkSpec};
use crate::config::model::{LayerKind, ModelSpec};
use crate::system::collective::{select_allreduce_algo, CollectiveAlgo, CollectiveDef, CommKind};
use crate::system::device_group::DeviceGroups;
use crate::system::fold::FoldPlan;
use crate::system::resharding;

use super::op::{Op, RankProgram, Workload};

/// Scaling knobs for tractable simulation of large configs. Every cap
/// is reported in the workload summary — no silent truncation.
#[derive(Debug, Clone)]
pub struct WorkloadOptions {
    /// Cap microbatches simulated per device group (None = all).
    pub microbatch_limit: Option<u64>,
    /// Include per-layer Other (layernorm/residual) compute ops.
    pub include_other: bool,
    /// Emit MoE dispatch/combine all-to-alls for MoE models.
    pub moe_alltoall: bool,
    /// Emit the end-of-iteration DP gradient synchronization.
    pub dp_sync: bool,
}

impl Default for WorkloadOptions {
    fn default() -> Self {
        WorkloadOptions {
            microbatch_limit: None,
            include_other: true,
            moe_alltoall: true,
            dp_sync: true,
        }
    }
}

/// Generate the workload for one training iteration.
pub fn generate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    fw: &FrameworkSpec,
    opts: &WorkloadOptions,
) -> anyhow::Result<Workload> {
    generate_inner(model, cluster, fw, opts, None)
}

/// [`generate`] under a symmetry-fold plan ([`crate::system::fold`]):
/// programs are emitted only for class-representative device groups;
/// DP-sync collective defs keep their full rank lists (the folded
/// planner in [`crate::system::compiled`] needs them) but only
/// represented ranks carry the matching `Op::Collective`.
pub fn generate_folded(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    fw: &FrameworkSpec,
    opts: &WorkloadOptions,
    fold: &FoldPlan,
) -> anyhow::Result<Workload> {
    generate_inner(model, cluster, fw, opts, Some(fold))
}

fn generate_inner(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    fw: &FrameworkSpec,
    opts: &WorkloadOptions,
    fold: Option<&FoldPlan>,
) -> anyhow::Result<Workload> {
    fw.validate(model, cluster)?;
    let groups = DeviceGroups::derive(fw);
    let emitted = |gi: usize| fold.map_or(true, |f| f.represented[gi]);
    let mut ops: HashMap<u32, Vec<Op>> = HashMap::with_capacity(fw.total_ranks());
    for (gi, g) in fw.groups.iter().enumerate() {
        if !emitted(gi) {
            continue;
        }
        for r in g.ranks() {
            ops.insert(r, Vec::new());
        }
    }
    let mut colls: Vec<CollectiveDef> = Vec::new();
    let mut next_coll: u64 = 0;
    let mut next_msg: u64 = 0;

    let d = model.dtype_bytes;
    let mlp_kind = if model.moe.is_some() { LayerKind::Moe } else { LayerKind::Mlp };
    let (n_experts, top_k) = match model.moe {
        Some(m) => (m.num_experts as f64, m.top_k as f64),
        None => (0.0, 0.0),
    };

    let layer_work = |kind: LayerKind, mbs: u64, tp: u32, bwd: bool| LayerWork {
        kind,
        hidden: model.hidden_size as f64,
        ffn: model.ffn_hidden as f64,
        heads: model.num_heads as f64,
        seq: model.seq_len as f64,
        mbs: mbs as f64,
        n_experts,
        top_k,
        tp: tp as f64,
        is_bwd: bwd,
    };

    let sched = fw.schedule.schedule();
    let vpp = sched.vpp();

    for (gi, g) in fw.groups.iter().enumerate() {
        if !emitted(gi) {
            continue;
        }
        let mbs = g.micro_batch.min(g.batch_share);
        let mut m = g.num_microbatches();
        if let Some(limit) = opts.microbatch_limit {
            m = m.min(limit.max(1));
        }
        let act_bytes = mbs * model.seq_len * model.hidden_size * d;
        let pp = g.pp();
        let vstages = pp * vpp;
        let cells = sched.emission_order(pp, m);
        // layer count per (stage, chunk); earlier chunks take the
        // remainder when a stage's layers don't divide vpp
        let chunk_layers: Vec<Vec<u64>> = g
            .stages
            .iter()
            .map(|s| split_evenly(s.num_layers as u64, vpp as u64))
            .collect();

        // pre-size each rank's op stream from the schedule shape: one
        // cell emits ~6 ops per layer (2 computes + 2 allreduces +
        // other + MoE slack) plus boundary transfers — growing these
        // vectors from empty dominated generator time on big configs
        let max_layers =
            g.stages.iter().map(|s| s.num_layers).max().unwrap_or(1) as usize;
        let cells_per_stage = (vpp as usize) * (m as usize) * 2;
        let est_per_rank = cells_per_stage
            * (max_layers.div_ceil(vpp as usize) * 6 + 4);
        for r in g.ranks() {
            ops.get_mut(&r).unwrap().reserve(est_per_rank);
        }

        // ---- pass 1: allocate every p2p message tag at its receiving
        // cell, walking the emission order (for GPipe this reproduces
        // the seed generator's tag sequence exactly). Keyed by the
        // receiving cell's (microbatch, direction, virtual stage).
        let mut tags: HashMap<(u64, bool, u32), Vec<u64>> =
            HashMap::with_capacity(cells.len());
        for cell in &cells {
            let v = cell.virtual_stage(pp);
            let has_incoming = if cell.bwd {
                v + 1 < vstages // last virtual stage turns around locally
            } else {
                v > 0 // first virtual stage has no producer
            };
            if !has_incoming {
                continue;
            }
            let to = &g.stages[cell.stage as usize].ranks;
            // one tag per destination rank: slot-wise transfers have one
            // slot per destination, leader fan-out one message per
            // destination — either way `push_recvs` zips over `to`
            let t: Vec<u64> = (0..to.len())
                .map(|_| {
                    let x = next_msg;
                    next_msg += 1;
                    x
                })
                .collect();
            tags.insert((cell.mb, cell.bwd, v), t);
        }

        // fabric-aware algorithm choice per stage's TP allreduces:
        // flat ring on rail-only (the seed default, byte-identical),
        // hierarchical on switch/leaf-spine fabrics when the TP group
        // spans nodes regularly. Hoisted out of the cell loop — it
        // depends only on the stage's rank list, and cells revisit
        // each stage once per (chunk, microbatch, direction).
        let stage_tp_algo: Vec<CollectiveAlgo> =
            g.stages.iter().map(|s| select_allreduce_algo(cluster, &s.ranks)).collect();

        // ---- pass 2: emit ops, appending each cell's work to its
        // stage's rank streams in the schedule's execution order
        for cell in &cells {
            let stage = &g.stages[cell.stage as usize];
            let tp = stage.tp();
            let ranks = &stage.ranks;
            let tp_algo = stage_tp_algo[cell.stage as usize];
            let v = cell.virtual_stage(pp);
            let nlayers = chunk_layers[cell.stage as usize][cell.chunk as usize];
            let is_embed_cell = stage.has_embedding && cell.chunk == 0;
            let (s, mb) = (cell.stage, cell.mb);
            // label segment; identical to the seed format when vpp == 1
            let seg = if vpp > 1 {
                format!("s{s}c{}mb{mb}", cell.chunk)
            } else {
                format!("s{s}mb{mb}")
            };

            if !cell.bwd {
                // ---------------- forward cell ----------------
                if v > 0 {
                    push_recvs(&mut ops, ranks, &tags[&(mb, false, v)]);
                }
                if is_embed_cell {
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(LayerKind::Embedding, mbs, tp, false),
                            label: "embedding-fwd",
                        });
                    }
                }
                for _layer in 0..nlayers {
                    // attention block
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(LayerKind::Attention, mbs, tp, false),
                            label: "attention-fwd",
                        });
                    }
                    if tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            tp_algo,
                            ranks.clone(),
                            act_bytes,
                            CommKind::Tp,
                            format!("tp-ar-g{}{seg}-attn-f", g.id),
                        );
                    }
                    // MoE dispatch
                    if mlp_kind == LayerKind::Moe && opts.moe_alltoall && tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            CollectiveAlgo::AllToAll,
                            ranks.clone(),
                            act_bytes * model.moe.unwrap().top_k as u64,
                            CommKind::Ep,
                            format!("ep-a2a-g{}{seg}-disp-f", g.id),
                        );
                    }
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(mlp_kind, mbs, tp, false),
                            label: if mlp_kind == LayerKind::Moe { "moe-fwd" } else { "mlp-fwd" },
                        });
                    }
                    // MoE combine
                    if mlp_kind == LayerKind::Moe && opts.moe_alltoall && tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            CollectiveAlgo::AllToAll,
                            ranks.clone(),
                            act_bytes * model.moe.unwrap().top_k as u64,
                            CommKind::Ep,
                            format!("ep-a2a-g{}{seg}-comb-f", g.id),
                        );
                    }
                    if tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            tp_algo,
                            ranks.clone(),
                            act_bytes,
                            CommKind::Tp,
                            format!("tp-ar-g{}{seg}-mlp-f", g.id),
                        );
                    }
                    if opts.include_other {
                        for r in ranks {
                            ops.get_mut(r).unwrap().push(Op::Compute {
                                work: layer_work(LayerKind::Other, mbs, tp, false),
                                label: "other-fwd",
                            });
                        }
                    }
                }
                // pass the activation to the next virtual stage
                if v + 1 < vstages {
                    let to = &g.stages[((v + 1) % pp) as usize].ranks;
                    push_sends(&mut ops, ranks, to, act_bytes, &tags[&(mb, false, v + 1)]);
                }
            } else {
                // ---------------- backward cell ----------------
                if v + 1 < vstages {
                    push_recvs(&mut ops, ranks, &tags[&(mb, true, v)]);
                }
                for _layer in 0..nlayers {
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(mlp_kind, mbs, tp, true),
                            label: if mlp_kind == LayerKind::Moe { "moe-bwd" } else { "mlp-bwd" },
                        });
                    }
                    if tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            tp_algo,
                            ranks.clone(),
                            act_bytes,
                            CommKind::Tp,
                            format!("tp-ar-g{}{seg}-mlp-b", g.id),
                        );
                    }
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(LayerKind::Attention, mbs, tp, true),
                            label: "attention-bwd",
                        });
                    }
                    if tp > 1 {
                        emit_collective(
                            &mut ops,
                            &mut colls,
                            &mut next_coll,
                            tp_algo,
                            ranks.clone(),
                            act_bytes,
                            CommKind::Tp,
                            format!("tp-ar-g{}{seg}-attn-b", g.id),
                        );
                    }
                }
                if is_embed_cell {
                    for r in ranks {
                        ops.get_mut(r).unwrap().push(Op::Compute {
                            work: layer_work(LayerKind::Embedding, mbs, tp, true),
                            label: "embedding-bwd",
                        });
                    }
                }
                // pass the grad-activation to the previous virtual stage
                if v > 0 {
                    let to = &g.stages[((v - 1) % pp) as usize].ranks;
                    push_sends(&mut ops, ranks, to, act_bytes, &tags[&(mb, true, v - 1)]);
                }
            }
        }
    }

    // ---------------- DP gradient synchronization ----------------
    if opts.dp_sync {
        for sync in &groups.dp_sync {
            let stage_idx = sync.stage as usize;
            // gradient bytes of this stage (unsharded)
            let sample = &fw.groups.iter().find(|g| g.stages.len() > stage_idx).unwrap().stages
                [stage_idx];
            let full_bytes = stage_grad_bytes(model, sample.num_layers, sample.has_embedding);
            if resharding::group_needs_resharding(&sync.participants) {
                let plan =
                    resharding::plan(&sync.participants, full_bytes, sync.stage, &mut next_coll);
                for def in plan.all_defs() {
                    colls.push(def.clone());
                    for r in &def.ranks {
                        // folded ranks (no entry) sit the op out; their
                        // representatives carry it
                        if let Some(stream) = ops.get_mut(r) {
                            stream.push(Op::Collective { def_id: def.id });
                        }
                    }
                }
            } else {
                // slot-wise rings: ranks holding identical shards.
                // Gradient sync is reduce-scatter + all-gather (the two
                // DP collectives per iteration of paper Table 1).
                let tp = sync.participants[0].tp;
                for slot in 0..tp as usize {
                    let ranks: Vec<u32> =
                        sync.participants.iter().map(|p| p.ranks[slot]).collect();
                    for (algo, tag) in [
                        (CollectiveAlgo::ReduceScatter, "rs"),
                        (CollectiveAlgo::AllGather, "ag"),
                    ] {
                        let id = next_coll;
                        next_coll += 1;
                        let def = CollectiveDef {
                            id,
                            algo,
                            ranks: ranks.clone(),
                            bytes_per_rank: full_bytes / tp as u64,
                            kind: CommKind::Dp,
                            label: format!("dp-{tag}-s{}slot{slot}", sync.stage),
                        };
                        colls.push(def);
                        for r in &ranks {
                            // folded ranks (no entry) sit the op out
                            if let Some(stream) = ops.get_mut(r) {
                                stream.push(Op::Collective { def_id: id });
                            }
                        }
                    }
                }
            }
        }
    }

    let mut programs: Vec<RankProgram> = ops
        .into_iter()
        .map(|(rank, ops)| RankProgram { rank, ops })
        .collect();
    programs.sort_by_key(|p| p.rank);
    let w = Workload { programs, collectives: colls };
    if fold.is_some() {
        w.validate_folded()?;
    } else {
        w.validate()?;
    }
    Ok(w)
}

/// Per-stage gradient bytes (unsharded): stage layers + embedding.
pub fn stage_grad_bytes(model: &ModelSpec, num_layers: u32, has_embedding: bool) -> u64 {
    let h = model.hidden_size;
    let ffn = model.ffn_hidden;
    let mats = if model.gated_mlp { 3 } else { 2 };
    let mlp = match model.moe {
        Some(m) => m.num_experts as u64 * mats * h * ffn,
        None => mats * h * ffn,
    };
    let per_layer = 4 * h * h + mlp + 4 * h;
    let embed = if has_embedding { model.vocab_size * h } else { 0 };
    (num_layers as u64 * per_layer + embed) * model.grad_dtype_bytes
}

/// Blocking receives on the destination ranks of a stage-boundary
/// transfer, one per pre-allocated tag (slot-wise and leader fan-out
/// both receive one message per destination rank).
fn push_recvs(ops: &mut HashMap<u32, Vec<Op>>, to: &[u32], tags: &[u64]) {
    for (r, msg) in to.iter().zip(tags) {
        ops.get_mut(r).unwrap().push(Op::Recv { msg: *msg });
    }
}

/// Asynchronous sends for a stage-boundary transfer: slot-wise
/// (`bytes / slots` each) when the TP degrees match, leader fan-out of
/// the full activation otherwise. `tags` were allocated at the
/// receiving cell in schedule-emission order.
fn push_sends(
    ops: &mut HashMap<u32, Vec<Op>>,
    from: &[u32],
    to: &[u32],
    act_bytes: u64,
    tags: &[u64],
) {
    if from.len() == to.len() {
        let per = (act_bytes / from.len() as u64).max(1);
        for ((s, r), msg) in from.iter().zip(to.iter()).zip(tags) {
            ops.get_mut(s).unwrap().push(Op::Send { peer: *r, bytes: per, msg: *msg });
        }
    } else {
        let leader = from[0];
        for (r, msg) in to.iter().zip(tags) {
            ops.get_mut(&leader)
                .unwrap()
                .push(Op::Send { peer: *r, bytes: act_bytes, msg: *msg });
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn emit_collective(
    ops: &mut HashMap<u32, Vec<Op>>,
    colls: &mut Vec<CollectiveDef>,
    next_coll: &mut u64,
    algo: CollectiveAlgo,
    ranks: Vec<u32>,
    bytes_per_rank: u64,
    kind: CommKind,
    label: String,
) {
    let id = *next_coll;
    *next_coll += 1;
    for r in &ranks {
        ops.get_mut(r).unwrap().push(Op::Collective { def_id: id });
    }
    colls.push(CollectiveDef { id, algo, ranks, bytes_per_rank, kind, label });
}

/// Register every (compute op, GPU) pair of a workload in a cost table.
pub fn register_costs(
    w: &Workload,
    cluster: &ClusterSpec,
    table: &mut CostTable,
) -> anyhow::Result<()> {
    for p in &w.programs {
        let gpu = cluster
            .gpu_of_rank(p.rank)
            .ok_or_else(|| anyhow::anyhow!("rank {} outside cluster", p.rank))?;
        for op in &p.ops {
            if let Op::Compute { work, .. } = op {
                table.register(work, gpu);
            }
        }
    }
    table.evaluate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::{FrameworkSpec, ParallelismSpec};
    use crate::config::presets;
    use crate::workload::schedule::ScheduleKind;

    fn tiny_model() -> ModelSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 4;
        m
    }

    #[test]
    fn generates_valid_workload_tp_dp() {
        let m = tiny_model();
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        assert_eq!(w.programs.len(), 8);
        // TP allreduces present: 2 per layer per direction per mb per group
        let tp_colls = w.collectives.iter().filter(|c| c.kind == CommKind::Tp).count();
        // 2 groups * 2 mb * 4 layers * 4 = 64
        assert_eq!(tp_colls, 64);
        // DP sync: tp=4 slots x (reduce-scatter + all-gather)
        let dp_colls = w.collectives.iter().filter(|c| c.kind == CommKind::Dp).count();
        assert_eq!(dp_colls, 8);
    }

    #[test]
    fn pipeline_emits_p2p() {
        let m = tiny_model();
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 2, dp: 2 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let (_, _, p2p) = w.op_counts();
        // fwd + bwd per mb per group, slot-wise: 2 groups * 2 mb * 2 dirs * 2 slots * 2 (send+recv)
        assert_eq!(p2p, 32);
    }

    #[test]
    fn tp1_emits_no_tp_collectives() {
        let m = tiny_model();
        let c = presets::cluster("ampere", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 1, pp: 2, dp: 4 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        assert_eq!(w.collectives.iter().filter(|c| c.kind == CommKind::Tp).count(), 0);
        assert!(w.collectives.iter().any(|c| c.kind == CommKind::Dp));
    }

    #[test]
    fn moe_emits_alltoall() {
        let mut m = presets::model("mixtral-8x7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 8;
        m.micro_batch = 4;
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 1, dp: 4 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let ep = w.collectives.iter().filter(|c| c.kind == CommKind::Ep).count();
        // 4 groups * 1 mb * 2 layers * 2 a2a (fwd only) = 16
        assert_eq!(ep, 16);
    }

    #[test]
    fn microbatch_limit_caps_work() {
        let m = tiny_model(); // 2 microbatches per group
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
        let full = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let capped = generate(
            &m,
            &c,
            &f,
            &WorkloadOptions { microbatch_limit: Some(1), ..Default::default() },
        )
        .unwrap();
        assert!(capped.op_counts().0 < full.op_counts().0);
    }

    #[test]
    fn dp_sync_bytes_match_param_accounting() {
        let m = presets::model("llama2-70b").unwrap();
        // full model, one stage: grads = params * 4 bytes
        let b = stage_grad_bytes(&m, m.num_layers, true);
        let expect = m.param_count() * 4;
        let rel = (b as f64 - expect as f64).abs() / expect as f64;
        assert!(rel < 0.01, "{b} vs {expect}");
    }

    #[test]
    fn table1_tp_frequency_about_350() {
        // Llama-2 70B, TP=8 PP=8: TP collectives per rank per iteration
        let m = presets::model("llama2-70b").unwrap();
        let c = presets::cluster("hopper", 256).unwrap(); // 2048 GPUs
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 8, pp: 8, dp: 32 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        // count TP collectives rank 0 participates in
        let p0 = &w.programs[0];
        let tp_ids: std::collections::HashSet<u64> = w
            .collectives
            .iter()
            .filter(|c| c.kind == CommKind::Tp)
            .map(|c| c.id)
            .collect();
        let freq = p0
            .ops
            .iter()
            .filter(|op| matches!(op, Op::Collective { def_id } if tp_ids.contains(def_id)))
            .count();
        // paper Table 1: ~350 per iteration
        assert!((300..=400).contains(&freq), "TP freq {freq}");
    }

    #[test]
    fn one_f_one_b_reorders_but_preserves_op_multiset() {
        // 1F1B reorders each rank's cells; the work itself (computes,
        // collectives, stage-boundary transfers) is unchanged.
        let m = tiny_model();
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 2, dp: 2 }).unwrap();
        let gpipe = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let onef = generate(
            &m,
            &c,
            &f.clone().with_schedule(ScheduleKind::OneFOneB),
            &WorkloadOptions::default(),
        )
        .unwrap();
        // generate() runs Workload::validate, so pairing/participation
        // invariants already held; the multiset must match GPipe's
        assert_eq!(gpipe.op_counts(), onef.op_counts());
        assert_eq!(gpipe.collectives.len(), onef.collectives.len());
        assert_eq!(gpipe.programs.len(), onef.programs.len());
    }

    #[test]
    fn interleaved_adds_virtual_stage_p2p() {
        // vpp=2 doubles the virtual pipeline depth: pp*vpp-1 = 3
        // boundaries per microbatch per direction instead of pp-1 = 1.
        let m = tiny_model();
        let c = presets::cluster("hopper", 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 2, pp: 2, dp: 2 }).unwrap();
        let gpipe = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let inter = generate(
            &m,
            &c,
            &f.clone().with_schedule(ScheduleKind::Interleaved1F1B { vpp: 2 }),
            &WorkloadOptions::default(),
        )
        .unwrap();
        let (compute_g, coll_g, p2p_g) = gpipe.op_counts();
        let (compute_i, coll_i, p2p_i) = inter.op_counts();
        // same compute and collectives, 3x the stage-boundary traffic
        assert_eq!(compute_g, compute_i);
        assert_eq!(coll_g, coll_i);
        assert_eq!(p2p_i, 3 * p2p_g);
    }

    #[test]
    fn register_costs_covers_all_ops() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let f = FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 2, dp: 2 }).unwrap();
        let w = generate(&m, &c, &f, &WorkloadOptions::default()).unwrap();
        let mut t = CostTable::native();
        register_costs(&w, &c, &mut t).unwrap();
        // every compute op resolvable
        for p in &w.programs {
            let gpu = c.gpu_of_rank(p.rank).unwrap();
            for op in &p.ops {
                if let Op::Compute { work, .. } = op {
                    t.time(work, gpu).unwrap();
                }
            }
        }
    }
}
