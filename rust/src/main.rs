//! `hetsim` — heterogeneity-aware LLM training simulator CLI.
//!
//! Subcommands regenerate each paper artifact and run custom scenarios;
//! see `hetsim help`.

use anyhow::Result;
use hetsim::baselines;
use hetsim::compute::table::CostTable;
use hetsim::config::framework::ParallelismSpec;
use hetsim::config::{loader, presets};
use hetsim::report::{fig1, fig5, fig6, table1};
use hetsim::simulator::{CostBackend, SimulationBuilder};
use hetsim::system::collective::RingPolicy;
use hetsim::system::fold::FoldMode;
use hetsim::util::cli::{Args, Usage};
use hetsim::util::table::fmt_sig;
use hetsim::workload::aicb::WorkloadOptions;

fn usage() -> Usage {
    Usage {
        program: "hetsim",
        about: "heterogeneity-aware LLM training simulator (CS.DC 2025 reproduction)",
        commands: vec![
            ("simulate", "run a scenario: --config FILE | --model NAME --cluster SPEC [--tp N --pp N --dp N] [--fabric rail|switch|spine:S,OS] [--schedule gpipe|1f1b|interleaved:V] [--fold auto|off] [--faults FILE] [--iterations N --threads N]"),
            ("plan", "rank TPxPPxDPxschedule plans (+ variable per-group TP layouts on hetero clusters) [--model NAME --cluster SPEC --fabric rail|switch|spine:S,OS --search grid|bnb --threads N --mb-limit N (0=all) --top K --refine[=STEPS] --fold auto|off --objective time|goodput|goodput-ci --mc N [--horizon-s S --mtbf-scale X --seed N]]"),
            ("goodput", "rank plans by effective goodput under an MTBF fault schedule [--model NAME --cluster SPEC --fabric rail|switch|spine:S,OS --threads N --mb-limit N --top K --fold auto|off --horizon-s S --mtbf-scale X --seed N --mc N --rack-size N --domain-mtbf-h H]"),
            ("serve-sim", "simulate inference serving: goodput, TTFT/TBT, latency percentiles per device group: --config FILE | --model NAME --cluster SPEC [--fabric SPEC --policy fifo|srpt|wsrpt --rate R --horizon-s S --scale X --prompt-tokens N --output-tokens N --max-batch N --kv-frac F --seed N --threads N]"),
            ("bench", "planner/engine throughput ladders -> BENCH_plan.json [--quick --threads N --out FILE --baseline FILE --factor F]"),
            ("fig1", "hardware-evolution trend across generation presets"),
            ("fig5", "per-layer compute time across GPU generations [--backend native|pjrt]"),
            ("fig6", "FCT CCDF across interconnect configs [--nodes N --models a,b --mb-limit N]"),
            ("table1", "Llama-2 70B exposed-communication characteristics"),
            ("baselines", "compare event sim vs homogeneous + analytical baselines [--nodes N]"),
            ("help", "print this help"),
        ],
    }
}

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(args),
        Some("plan") => cmd_plan(args),
        Some("goodput") => cmd_goodput(args),
        Some("serve-sim") => cmd_serve_sim(args),
        Some("bench") => cmd_bench(args),
        Some("fig1") => cmd_fig1(args),
        Some("fig5") => cmd_fig5(args),
        Some("fig6") => cmd_fig6(args),
        Some("table1") => cmd_table1(args),
        Some("baselines") => cmd_baselines(args),
        Some("help") | None => {
            print!("{}", usage().render());
            Ok(())
        }
        Some(other) => {
            print!("{}", usage().render());
            anyhow::bail!("unknown command '{other}'")
        }
    }
}

fn cost_backend(args: &Args) -> Result<CostBackend> {
    match args.opt_or("backend", "native") {
        "native" => Ok(CostBackend::Native),
        "pjrt" => Ok(CostBackend::Pjrt),
        other => anyhow::bail!("--backend must be native|pjrt, got '{other}'"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "model", "cluster", "fabric", "tp", "pp", "dp", "schedule", "backend",
        "mb-limit", "hetero-partition", "naive-ring", "iterations", "threads", "fold", "faults",
    ])?;
    let (model, mut cluster, par, schedule, per_group_tp, fold, faults, seed) =
        if let Some(path) = args.opt("config") {
            let s = loader::load_scenario_file(std::path::Path::new(path))?;
            (
                s.model,
                s.cluster,
                Some(s.parallelism),
                Some(s.schedule),
                s.per_group_tp,
                s.fold,
                s.faults,
                s.seed,
            )
        } else {
            let model = presets::model(args.opt_or("model", "gpt-6.7b"))?;
            let cluster = loader::parse_cluster(&hetsim::util::json::Json::Str(
                args.opt_or("cluster", "hopper:4").to_string(),
            ))?;
            let par = match (args.opt("tp"), args.opt("pp"), args.opt("dp")) {
                (None, None, None) => None,
                _ => Some(ParallelismSpec {
                    tp: args.opt_u64("tp", 1)? as u32,
                    pp: args.opt_u64("pp", 1)? as u32,
                    dp: args.opt_u64("dp", 1)? as u32,
                }),
            };
            (model, cluster, par, None, None, FoldMode::Off, None, 42)
        };
    // --fabric overrides the cluster's (or the config file's) fabric
    if let Some(f) = args.opt("fabric") {
        cluster.fabric = hetsim::config::cluster::FabricSpec::parse(f)?;
    }
    // per-group TP scenarios carry their own device-group mapping,
    // built by the heterogeneity-aware partitioner (layers/batch
    // proportional to compute power)
    let framework = match &per_group_tp {
        Some(splits) => {
            Some(hetsim::workload::partition::plan_variable_tp(&model, &cluster, splits, true)?)
        }
        None => None,
    };
    // --fold overrides a config file's "fold" key
    let fold = match args.opt("fold") {
        Some(v) => FoldMode::parse(v)?,
        None => fold,
    };
    // --faults FILE overrides a config file's "faults" key; the file
    // holds one faults object (the same shape as the scenario key)
    let faults = match args.opt("faults") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
            let v = hetsim::util::json::Json::parse(&text)?;
            Some(hetsim::system::failure::FaultSpec::from_json(&v, &cluster, seed)?)
        }
        None => faults,
    };
    let mut b = SimulationBuilder::new(model, cluster)
        .cost_backend(cost_backend(args)?)
        .hetero_partitioning(args.flag("hetero-partition"))
        .fold(fold)
        .faults(faults)
        .workload_options(WorkloadOptions {
            microbatch_limit: args.opt("mb-limit").map(|v| v.parse()).transpose()?,
            ..Default::default()
        });
    if let Some(fw) = framework {
        b = b.framework(fw);
    }
    if args.flag("naive-ring") {
        b = b.ring_policy(RingPolicy::Naive);
    }
    if let Some(p) = par {
        b = b.parallelism(p);
    }
    // --schedule overrides a config file's "schedule" key
    if let Some(s) = args.opt("schedule") {
        b = b.schedule(s.parse()?);
    } else if let Some(s) = schedule {
        b = b.schedule(s);
    }
    let sim = b.build()?;
    let iterations = args.opt_u64("iterations", 1)? as usize;
    let report = if iterations > 1 {
        // the prepared simulation is shared immutably by the workers;
        // repeated runs double as a determinism self-check and a
        // simulator-throughput measurement
        let threads = args.opt_u64("threads", 0)? as usize;
        let t0 = std::time::Instant::now();
        let mut reports = sim.run_iterations_concurrent(iterations, threads)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = reports.remove(0);
        let identical = reports.iter().all(|r| {
            r.iteration_time == first.iteration_time
                && r.events_processed == first.events_processed
                && r.flows_completed == first.flows_completed
                && r.fault == first.fault
        });
        println!(
            "({iterations} concurrent iterations in {wall:.2}s wall-clock; \
             determinism check: {})",
            if identical { "all identical" } else { "DIVERGED" }
        );
        anyhow::ensure!(identical, "concurrent iterations diverged — determinism bug");
        first
    } else {
        sim.run_iteration()?
    };

    println!("model:            {}", report.model_name);
    println!("cluster:          {}", report.cluster_name);
    println!("iteration time:   {}", report.iteration_time);
    println!("flows completed:  {}", report.flows_completed);
    println!("events processed: {}", report.events_processed);
    if let Some(f) = &report.fault {
        println!(
            "fault:            {} on node {} at {} — iteration aborted, {} of work lost",
            f.kind.name(),
            f.node,
            f.at,
            f.lost_work
        );
    }
    let mut kinds: Vec<_> = report.fct_summary.iter().collect();
    kinds.sort_by_key(|(k, _)| **k);
    for (kind, s) in kinds {
        println!(
            "  {kind:8} flows={:6}  p50={}us p99.9={}us max={}us",
            s.count,
            fmt_sig(s.p50 * 1e6),
            fmt_sig(s.p999 * 1e6),
            fmt_sig(s.max * 1e6),
        );
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    args.check_known(&[
        "model", "cluster", "fabric", "threads", "mb-limit", "top", "refine", "fold", "goodput",
        "objective", "mc", "horizon-s", "mtbf-scale", "seed", "search",
    ])?;
    let model = presets::model(args.opt_or("model", "gpt-6.7b"))?;
    let mut cluster = loader::parse_cluster(&hetsim::util::json::Json::Str(
        args.opt_or("cluster", "hetero:1,1").to_string(),
    ))?;
    if let Some(f) = args.opt("fabric") {
        cluster.fabric = hetsim::config::cluster::FabricSpec::parse(f)?;
    }
    let mb_limit = args.opt_u64("mb-limit", 2)?;
    // --refine (bare flag: default budget) or --refine=STEPS / --refine STEPS
    let refine_steps = args.opt_u64_flag("refine", 64)?.unwrap_or(0);
    let opts = hetsim::planner::PlanOptions {
        // 0 = simulate every microbatch (full-fidelity ranking)
        microbatch_limit: if mb_limit == 0 { None } else { Some(mb_limit) },
        threads: args.opt_u64("threads", 0)? as usize,
        refine_steps,
        fold: FoldMode::parse(args.opt_or("fold", "off"))?,
    };
    let top = args.opt_u64("top", 10)? as usize;
    println!(
        "# plan search: {} on {} ({} GPUs, fabric {})\n",
        model.name,
        cluster.name,
        cluster.total_gpus(),
        cluster.fabric.name()
    );
    // --objective time|goodput|goodput-ci picks the ranking criterion;
    // --goodput is the pre-existing alias for --objective goodput
    let objective = match (args.opt("objective"), args.flag("goodput")) {
        (None, false) | (Some("time"), _) => "time",
        (None, true) | (Some("goodput"), _) => "goodput",
        (Some("goodput-ci"), _) => "goodput-ci",
        (Some(other), _) => {
            anyhow::bail!("--objective must be time|goodput|goodput-ci, got '{other}'")
        }
    };
    // --search grid (default, exhaustive) | bnb (bound-guided
    // branch-and-bound with incumbent-cutoff simulation, DESIGN.md §29
    // — same best plan, strictly fewer full simulations)
    let search_kind = args.opt_or("search", "grid");
    let mut report = match search_kind {
        "grid" => hetsim::planner::search(&model, &cluster, &opts)?,
        "bnb" => hetsim::planner::search_bnb(&model, &cluster, &opts)?,
        other => anyhow::bail!("--search must be grid|bnb, got '{other}'"),
    };
    // goodput objectives re-rank by effective goodput under an MTBF
    // schedule (DESIGN.md §26, §28); fault-free scores stay in the
    // table. goodput-ci scores each plan by the lower 95% confidence
    // bound over --mc Monte-Carlo trajectories (blast-radius-aware).
    if objective != "time" {
        let mc = match objective {
            "goodput-ci" => {
                let m = args.opt_u64("mc", 8)? as u32;
                anyhow::ensure!(m >= 1, "--objective goodput-ci needs --mc >= 1");
                m
            }
            _ => args.opt_u64("mc", 0)? as u32,
        };
        let gopts = hetsim::report::goodput::SweepOptions {
            plan: opts.clone(),
            horizon_s: args.opt_f64("horizon-s", 86_400.0)?,
            mtbf_scale: args.opt_f64("mtbf-scale", 1.0)?,
            seed: args.opt_u64("seed", 42)?,
            mc,
            // bnb extends incumbent pruning into the Monte-Carlo
            // ranking: dominated trajectory sets stop early
            mc_early_stop: search_kind == "bnb",
            ..Default::default()
        };
        hetsim::report::goodput::annotate(&mut report, &model, &cluster, &gopts);
        if mc > 0 {
            println!(
                "(re-ranked by lower 95% CI bound on goodput: {} trajectories, \
                 horizon {:.0}s, MTBF scale {}x, seed {})\n",
                mc, gopts.horizon_s, gopts.mtbf_scale, gopts.seed
            );
        } else {
            println!(
                "(re-ranked by effective goodput: horizon {:.0}s, MTBF scale {}x, seed {})\n",
                gopts.horizon_s, gopts.mtbf_scale, gopts.seed
            );
        }
    }
    print!("{}", report.render(top));
    let best = report.best();
    let speedup =
        report.baseline.iteration_time.as_secs() / best.iteration_time.as_secs();
    println!(
        "\nbest plan: {} — {} per iteration ({speedup:.2}x vs the uniform default)",
        best.candidate.key(),
        best.iteration_time
    );
    if let Some(r) = &report.refined {
        let rspeed = report.baseline.iteration_time.as_secs() / r.refined_time.as_secs();
        println!(
            "refined:   {} — {} per iteration ({rspeed:.2}x vs the uniform default)",
            r.spec.summary(),
            r.refined_time
        );
    }
    Ok(())
}

fn cmd_goodput(args: &Args) -> Result<()> {
    args.check_known(&[
        "model", "cluster", "fabric", "threads", "mb-limit", "top", "fold", "horizon-s",
        "mtbf-scale", "seed", "mc", "rack-size", "domain-mtbf-h",
    ])?;
    let model = presets::model(args.opt_or("model", "gpt-6.7b"))?;
    let mut cluster = loader::parse_cluster(&hetsim::util::json::Json::Str(
        args.opt_or("cluster", "hetero:1,1").to_string(),
    ))?;
    if let Some(f) = args.opt("fabric") {
        cluster.fabric = hetsim::config::cluster::FabricSpec::parse(f)?;
    }
    let mb_limit = args.opt_u64("mb-limit", 2)?;
    let horizon_s = args.opt_f64("horizon-s", 86_400.0)?;
    let mtbf_scale = args.opt_f64("mtbf-scale", 1.0)?;
    // --rack-size enables the correlated failure-domain process on top
    // of the per-node MTBF schedule (DESIGN.md §28); --domain-mtbf-h
    // sets the per-rack MTBF (default: half a year)
    let domains = if args.opt("rack-size").is_some() {
        Some(hetsim::system::failure::DomainSpec {
            rack_size: args.opt_u64("rack-size", 4)? as u32,
            mtbf_hours: args.opt_f64("domain-mtbf-h", 4380.0)?,
            horizon_s,
            scale: mtbf_scale,
        })
    } else {
        None
    };
    let opts = hetsim::report::goodput::SweepOptions {
        plan: hetsim::planner::PlanOptions {
            microbatch_limit: if mb_limit == 0 { None } else { Some(mb_limit) },
            threads: args.opt_u64("threads", 0)? as usize,
            refine_steps: 0,
            fold: FoldMode::parse(args.opt_or("fold", "off"))?,
        },
        top: args.opt_u64("top", 5)? as usize,
        horizon_s,
        mtbf_scale,
        seed: args.opt_u64("seed", 42)?,
        domains,
        mc: args.opt_u64("mc", 0)? as u32,
        ..Default::default()
    };
    println!(
        "# goodput sweep: {} on {} ({} GPUs, fabric {})\n",
        model.name,
        cluster.name,
        cluster.total_gpus(),
        cluster.fabric.name()
    );
    let rep = hetsim::report::goodput::sweep(&model, &cluster, &opts)?;
    print!("{}", rep.render());
    let best = rep.best();
    match &best.mc {
        Some(m) => println!(
            "\nbest by ci95-lo: {} — mean {:.1} tok/s, 95% CI [{:.1}, {:.1}] \
             over {} trajectories ({} halted)",
            best.plan, m.mean, m.ci95_lo, m.ci95_hi, m.trajectories, m.halted
        ),
        None => println!(
            "\nbest by goodput: {} — {:.1} useful tokens/s (availability {:.4})",
            best.plan, best.goodput.goodput_tokens_per_s, best.goodput.availability
        ),
    }
    Ok(())
}

fn cmd_serve_sim(args: &Args) -> Result<()> {
    use hetsim::workload::serve::{PoissonSpec, ServePolicy, ServeSpec};
    args.check_known(&[
        "config", "model", "cluster", "fabric", "policy", "rate", "horizon-s", "scale",
        "prompt-tokens", "output-tokens", "max-batch", "kv-frac", "seed", "threads",
    ])?;
    let (model, mut cluster, mut serving) = if let Some(path) = args.opt("config") {
        let s = loader::load_scenario_file(std::path::Path::new(path))?;
        let serving = s.serving.ok_or_else(|| {
            anyhow::anyhow!(
                "scenario {path} has no \"serving\" key (or it generates no requests)"
            )
        })?;
        (s.model, s.cluster, serving)
    } else {
        let model = presets::model(args.opt_or("model", "gpt-6.7b"))?;
        let cluster = loader::parse_cluster(&hetsim::util::json::Json::Str(
            args.opt_or("cluster", "hetero:1,1").to_string(),
        ))?;
        let serving = ServeSpec {
            poisson: Some(PoissonSpec {
                rate_per_s: args.opt_f64("rate", 2.0)?,
                horizon_s: args.opt_f64("horizon-s", 20.0)?,
                scale: args.opt_f64("scale", 1.0)?,
                prompt_tokens: args.opt_u64("prompt-tokens", 512)?,
                output_tokens: args.opt_u64("output-tokens", 64)?,
            }),
            seed: args.opt_u64("seed", 42)?,
            ..Default::default()
        };
        (model, cluster, serving)
    };
    // flags override the cluster's (or the config file's) settings
    if let Some(f) = args.opt("fabric") {
        cluster.fabric = hetsim::config::cluster::FabricSpec::parse(f)?;
    }
    if let Some(p) = args.opt("policy") {
        serving.policy = ServePolicy::parse(p)?;
    }
    serving.max_batch = args.opt_u64("max-batch", serving.max_batch as u64)? as u32;
    serving.kv_frac = args.opt_f64("kv-frac", serving.kv_frac)?;
    serving.validate()?;
    let threads = args.opt_u64("threads", 0)? as usize;

    let sim = hetsim::system::serve_scheduler::ServeSim::new(model, cluster, serving)?;
    println!(
        "# serve-sim: {} on {} ({} GPUs, fabric {}) — {} requests, policy {}\n",
        sim.model().name,
        sim.cluster().name,
        sim.cluster().total_gpus(),
        sim.cluster().fabric.name(),
        sim.requests().len(),
        sim.policy().name(),
    );
    let rep = sim.run(threads)?;
    print!("{}", rep.render());
    println!(
        "\ngoodput: {} tok/s across {} requests (makespan {} s, {} engine steps)",
        fmt_sig(rep.goodput_tok_s),
        rep.requests_total,
        fmt_sig(rep.makespan_s),
        rep.events,
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    args.check_known(&["quick", "threads", "out", "baseline", "factor"])?;
    let quick = args.flag("quick");
    let threads = args.opt_u64("threads", 0)? as usize;
    let factor = args.opt_f64("factor", 1.5)?;
    println!(
        "# hetsim bench ({} suite, {} threads)\n",
        if quick { "quick" } else { "full" },
        if threads == 0 { "all".to_string() } else { threads.to_string() }
    );
    let cases = hetsim::report::bench::run(quick, threads)?;
    print!("{}", hetsim::report::bench::render(&cases).markdown());

    let doc = hetsim::report::bench::to_json(&cases, quick);
    let out = match args.opt("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => hetsim::report::results_dir().join("BENCH_plan.json"),
    };
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&out, format!("{doc}\n"))?;
    println!("\njson: {}", out.display());

    if let Some(path) = args.opt("baseline") {
        let base = hetsim::util::json::Json::parse(&std::fs::read_to_string(path)?)?;
        let regressions =
            hetsim::report::bench::check_against_baseline(&cases, &base, factor);
        if regressions.is_empty() {
            println!("baseline check vs {path}: ok (allowed factor {factor}x)");
        } else {
            for r in &regressions {
                eprintln!("REGRESSION: {r}");
            }
            anyhow::bail!(
                "{} bench regression(s) vs baseline {path}",
                regressions.len()
            );
        }
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    args.check_known(&[])?;
    let rows = fig1::compute()?;
    let t = fig1::render(&rows);
    print!("{}", t.markdown());
    println!("\n{}", fig1::growth_summary(&rows));
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "fig1")?;
    println!("csv: {}", path.display());
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    args.check_known(&["backend", "out"])?;
    let mut table = match cost_backend(args)? {
        CostBackend::Native => CostTable::native(),
        CostBackend::Pjrt => CostTable::new(Box::new(hetsim::runtime::PjrtCostModel::load()?)),
    };
    let rows = fig5::compute(&mut table)?;
    let t = fig5::render(&rows);
    print!("{}", t.markdown());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "fig5")?;
    println!("\n[backend={}] csv: {}", table.evaluator_name(), path.display());
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    args.check_known(&["nodes", "models", "mb-limit", "out"])?;
    let nodes = args.opt_u64("nodes", 4)? as u32;
    let mb_limit = Some(args.opt_u64("mb-limit", 1)?);
    let models_arg = args.opt_or("models", "gpt-6.7b,gpt-13b,mixtral-8x7b").to_string();
    let models: Vec<&str> = models_arg.split(',').collect();
    println!(
        "# fig6: nodes={nodes} (paper: 16-32), microbatch_limit={mb_limit:?} — scaled for 1-core CI\n"
    );
    let cells = fig6::compute(nodes, mb_limit, &models)?;
    let t = fig6::render(&cells);
    print!("{}", t.markdown());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "fig6")?;
    std::fs::write(dir.join("fig6_ccdf.csv"), fig6::ccdf_csv(&cells))?;
    println!("\ncsv: {} + fig6_ccdf.csv", path.display());
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    args.check_known(&["out"])?;
    let rows = table1::compute()?;
    let t = table1::render(&rows);
    print!("{}", t.markdown());
    let dir = hetsim::report::results_dir();
    let path = t.write_csv(&dir, "table1")?;
    println!("\ncsv: {}", path.display());
    Ok(())
}

fn cmd_baselines(args: &Args) -> Result<()> {
    args.check_known(&["nodes", "model"])?;
    let nodes = (args.opt_u64("nodes", 2)? as u32).max(2);
    let model = presets::model(args.opt_or("model", "gpt-6.7b"))?;
    let cluster = presets::cluster_hetero(nodes / 2, nodes - nodes / 2)?;
    let world = cluster.total_gpus();
    let par = ParallelismSpec { tp: 8, pp: 1, dp: world / 8 };

    // heterogeneity-aware event simulation
    let sim = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(par)
        .workload_options(WorkloadOptions { microbatch_limit: Some(1), ..Default::default() })
        .build()?;
    let hetero = sim.run_iteration()?;

    let mut t = hetsim::util::table::Table::new(
        "Baselines — event sim vs homogeneous assumption vs analytical",
        &["configuration", "iteration time", "note"],
    );
    t.row(vec![
        "hetero-aware event sim".into(),
        hetero.iteration_time.human(),
        "ours".into(),
    ]);
    for (i, label) in
        [(0usize, "homogenized (A100)"), (cluster.nodes.len() - 1, "homogenized (H100)")]
    {
        let homo = baselines::homogenize(&cluster, i)?;
        let rep = SimulationBuilder::new(model.clone(), homo)
            .parallelism(par)
            .workload_options(WorkloadOptions { microbatch_limit: Some(1), ..Default::default() })
            .build()?
            .run_iteration()?;
        t.row(vec![label.into(), rep.iteration_time.human(), "SimAI-like".into()]);
    }
    // analytical estimate (Sailor-like)
    let est = baselines::analytical::estimate(&sim.workload, &cluster, &sim.cost, None)?;
    t.row(vec![
        "analytical (no contention)".into(),
        est.total.human(),
        "Sailor-like".into(),
    ]);
    print!("{}", t.markdown());
    Ok(())
}
