//! # HetSim — heterogeneity-aware LLM training simulator
//!
//! Reproduction of *"Simulating LLM training workloads for heterogeneous
//! compute and network infrastructure"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system. See `DESIGN.md` for the system inventory
//! and the experiment index.
//!
//! Layer map:
//! * [`engine`] — deterministic discrete-event simulation core (S1).
//! * [`config`] — model / cluster / framework descriptions (S2, paper
//!   abstractions A1 + A2, Tables 5–6).
//! * [`workload`] — AICB-like workload generation and non-uniform
//!   partitioning (S3, S4, component C1).
//! * [`system`] — device groups, hybrid parallelism, resharding, the
//!   heterogeneity-aware collective library and pipeline scheduler
//!   (S5–S8, components C1–C3).
//! * [`network`] — rail-only topology and flow-level network simulation
//!   with per-interconnect delays (S9, component C4).
//! * [`compute`] — per-layer compute-cost evaluation: PJRT-executed AOT
//!   artifact with a native Rust mirror for cross-checking (S10, C4).
//! * [`runtime`] — PJRT plumbing over the `xla` crate (S11).
//! * [`simulator`] — the facade that ties the layers into one
//!   reusable, thread-shareable prepared simulation.
//! * [`planner`] — parallelism-plan exploration over prepared
//!   simulations: enumerate, prune, evaluate concurrently and rank
//!   TP×PP×DP deployments (`hetsim plan`, S20).
//! * [`baselines`] — SimAI-like homogeneous, Sailor-like analytical and
//!   uniform-partitioning comparators (S12).
//! * [`report`] — regenerates the paper's Table 1, Fig 5, Fig 6 (S13).
//! * [`util`] — in-tree substrates for crates unavailable offline
//!   (S14–S19: json, cli, rng, stats, units, tables, prop testing,
//!   logging).

pub mod baselines;
pub mod compute;
pub mod config;
pub mod engine;
pub mod network;
pub mod planner;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod system;
pub mod util;
pub mod workload;

pub use simulator::{SimulationBuilder, SimulationReport};
