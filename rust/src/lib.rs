//! # HetSim — heterogeneity-aware LLM training simulator
//!
//! Reproduction of *"Simulating LLM training workloads for heterogeneous
//! compute and network infrastructure"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system. See `DESIGN.md` for the full system
//! inventory (S1–S21) and the experiment index, and the top-level
//! `README.md` for the CLI walkthrough.
//!
//! ## Architecture
//!
//! A simulation flows through the layers in this order:
//!
//! 1. **Describe** — [`config`]: model hyperparameters
//!    ([`config::model::ModelSpec`], paper Table 6), cluster & host
//!    topology ([`config::cluster::ClusterSpec`], Table 5) and the
//!    framework mapping ([`config::framework::FrameworkSpec`]: device
//!    groups, parallelism degrees, pipeline schedule). Presets carry
//!    the paper's exact configurations; [`config::loader`] reads the
//!    same structures from JSON scenario files.
//! 2. **Generate** — [`workload`]: the AICB-like generator expands the
//!    descriptions into per-rank op programs under a pipeline schedule
//!    ([`workload::schedule`]: GPipe / 1F1B / interleaved 1F1B), with
//!    non-uniform partitioning ([`workload::partition`], component C1)
//!    for heterogeneous clusters. [`workload::serve`] generates
//!    *inference* traffic instead: request traces (explicit or seeded
//!    Poisson) lowered to prefill/decode op streams under a KV-cache
//!    memory model.
//! 3. **Lower** — [`system`]: device groups, resharding (C2), the
//!    heterogeneity-aware collective library (C3) and
//!    [`system::compiled::CompiledWorkload`] — the dense, immutable
//!    simulation core (durations pre-resolved, collectives pre-planned,
//!    p2p tags validated unique).
//! 4. **Simulate** — [`engine`] (deterministic discrete-event core),
//!    [`network`] (configurable fabric topology — rail-only, single
//!    switch or leaf/spine — and fluid flow simulation with
//!    per-interconnect delays, C4) and [`compute`] (roofline cost
//!    model; [`runtime`] swaps in the PJRT-executed AOT artifact).
//! 5. **Consume** — [`simulator`] ties it into one reusable
//!    `Send + Sync` [`simulator::Simulation`]; [`planner`] sweeps
//!    TP×PP×DP×schedule deployments plus variable per-group TP layouts
//!    concurrently (`hetsim plan`) and polishes the winners by
//!    simulator-in-the-loop coordinate descent ([`planner::refine`],
//!    `hetsim plan --refine`); [`baselines`] and [`report`] reproduce
//!    the paper's comparisons and artifacts; [`util`] holds in-tree
//!    substrates for crates unavailable offline.
//!
//! ## Quickstart
//!
//! One simulated training iteration of GPT-6.7B on a mixed A100+H100
//! cluster, under a 1F1B pipeline schedule:
//!
//! ```no_run
//! use hetsim::config::framework::ParallelismSpec;
//! use hetsim::config::presets;
//! use hetsim::workload::schedule::ScheduleKind;
//! use hetsim::SimulationBuilder;
//!
//! fn main() -> anyhow::Result<()> {
//!     let model = presets::model("gpt-6.7b")?;
//!     let cluster = presets::cluster_hetero(1, 1)?; // 8×A100 + 8×H100
//!     let sim = SimulationBuilder::new(model, cluster)
//!         .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
//!         .schedule(ScheduleKind::OneFOneB)
//!         .build()?;
//!     let report = sim.run_iteration()?;
//!     println!("iteration time: {}", report.iteration_time);
//!     for (kind, s) in &report.fct_summary {
//!         println!("{kind}: {} flows, p50 {:.1}us", s.count, s.p50 * 1e6);
//!     }
//!     Ok(())
//! }
//! ```
//!
//! The same scenario from the command line:
//!
//! ```text
//! hetsim simulate --model gpt-6.7b --cluster hetero:1,1 \
//!     --tp 4 --pp 2 --dp 2 --schedule 1f1b
//! hetsim plan --model gpt-6.7b --cluster hetero:1,1   # rank all plans
//! ```
//!
//! Inference serving on the same cluster (DESIGN.md §27): Poisson
//! request arrivals, continuous batching with KV-budget admission,
//! goodput/TTFT/latency percentiles per device group:
//!
//! ```text
//! hetsim serve-sim --model fig3 --cluster fig3 --policy srpt
//! ```
//!
//! ## Documentation coverage
//!
//! Every public item of every module except [`runtime`] (whose surface
//! is gated on the optional `pjrt` feature) is documented and kept
//! that way by `missing_docs` warnings (promoted to errors by the
//! `cargo doc` CI job).

#[warn(missing_docs)]
pub mod baselines;
#[warn(missing_docs)]
pub mod compute;
#[warn(missing_docs)]
pub mod config;
#[warn(missing_docs)]
pub mod engine;
#[warn(missing_docs)]
pub mod network;
#[warn(missing_docs)]
pub mod planner;
#[warn(missing_docs)]
pub mod report;
pub mod runtime;
#[warn(missing_docs)]
pub mod simulator;
#[warn(missing_docs)]
pub mod system;
#[warn(missing_docs)]
pub mod util;
#[warn(missing_docs)]
pub mod workload;

pub use simulator::{EvalContext, EvalScore, ScoreOutcome, SimulationBuilder, SimulationReport};
