//! The HetSim facade: ties configuration, workload generation, cost
//! evaluation, the system scheduler and the network simulator into one
//! reproducible run (paper Fig 4's full pipeline).

use std::collections::HashMap;
use std::sync::Arc;

use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::config::framework::{FrameworkSpec, ParallelismSpec};
use crate::config::model::ModelSpec;
use crate::network::topology::Topology;
use crate::system::collective::RingPolicy;
use crate::system::compiled::CompiledWorkload;
use crate::system::scheduler::{Scheduler, SchedulerReport};
use crate::util::stats::{Samples, Summary};
use crate::util::units::Time;
use crate::workload::aicb::{self, WorkloadOptions};
use crate::workload::op::Workload;
use crate::workload::schedule::ScheduleKind;

/// How per-layer compute times are evaluated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostBackend {
    /// Pure-Rust roofline mirror (no artifacts needed).
    Native,
    /// AOT artifact via PJRT (requires `make artifacts`).
    Pjrt,
}

/// Builder for a simulation run.
pub struct SimulationBuilder {
    model: ModelSpec,
    cluster: ClusterSpec,
    framework: Option<FrameworkSpec>,
    parallelism: Option<ParallelismSpec>,
    options: WorkloadOptions,
    cost_backend: CostBackend,
    ring_policy: RingPolicy,
    hetero_partitioning: bool,
    schedule: Option<ScheduleKind>,
    record_trace: bool,
}

impl SimulationBuilder {
    /// Start a builder for `model` on `cluster` with the defaults:
    /// inferred parallelism, uniform mapping, GPipe schedule, native
    /// cost backend, hetero-aware rings, no trace.
    pub fn new(model: ModelSpec, cluster: ClusterSpec) -> Self {
        SimulationBuilder {
            model,
            cluster,
            framework: None,
            parallelism: None,
            options: WorkloadOptions::default(),
            cost_backend: CostBackend::Native,
            ring_policy: RingPolicy::HeteroAware,
            hetero_partitioning: false,
            schedule: None,
            record_trace: false,
        }
    }

    /// Explicit parallelism degrees (defaults to the model's Table-6
    /// deployment scaled to the cluster if unset).
    pub fn parallelism(mut self, par: ParallelismSpec) -> Self {
        self.parallelism = Some(par);
        self
    }

    /// Fully custom framework spec (device groups, non-uniform splits).
    pub fn framework(mut self, fw: FrameworkSpec) -> Self {
        self.framework = Some(fw);
        self
    }

    /// Use the heterogeneity-aware non-uniform partitioner (C1) instead
    /// of the uniform mapping.
    pub fn hetero_partitioning(mut self, on: bool) -> Self {
        self.hetero_partitioning = on;
        self
    }

    /// Pipeline schedule for every device group (`gpipe` when unset).
    /// Overrides whatever the resolved framework spec carries, so it
    /// composes with [`SimulationBuilder::framework`] and the
    /// heterogeneity-aware partitioner.
    pub fn schedule(mut self, s: ScheduleKind) -> Self {
        self.schedule = Some(s);
        self
    }

    /// Workload-generation knobs (microbatch caps, optional op classes).
    pub fn workload_options(mut self, opts: WorkloadOptions) -> Self {
        self.options = opts;
        self
    }

    /// Select how per-layer compute times are evaluated.
    pub fn cost_backend(mut self, b: CostBackend) -> Self {
        self.cost_backend = b;
        self
    }

    /// Select the collective ring-ordering policy.
    pub fn ring_policy(mut self, p: RingPolicy) -> Self {
        self.ring_policy = p;
        self
    }

    /// Record a per-rank busy-interval trace (needed for the
    /// compute/comm breakdown in reports).
    pub fn record_trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }

    /// Resolve the framework spec, generate the workload, evaluate the
    /// cost table.
    pub fn build(self) -> anyhow::Result<Simulation> {
        let par = match self.parallelism {
            Some(p) => p,
            None => infer_parallelism(&self.model, &self.cluster)?,
        };
        let mut fw = match self.framework {
            Some(f) => f,
            None if self.hetero_partitioning => {
                crate::workload::partition::plan_hetero(&self.model, &self.cluster, par)?
            }
            None => FrameworkSpec::uniform(&self.model, &self.cluster, par)?,
        };
        if let Some(s) = self.schedule {
            s.validate()?;
            fw.schedule = s;
        }
        let workload = aicb::generate(&self.model, &self.cluster, &fw, &self.options)?;
        let mut cost = match self.cost_backend {
            CostBackend::Native => CostTable::native(),
            CostBackend::Pjrt => {
                CostTable::new(Box::new(crate::runtime::PjrtCostModel::load()?))
            }
        };
        aicb::register_costs(&workload, &self.cluster, &mut cost)?;
        let topology = Arc::new(Topology::build(&self.cluster)?);
        let compiled =
            CompiledWorkload::compile(&workload, &self.cluster, &cost, self.ring_policy)?;
        Ok(Simulation {
            model: self.model,
            cluster: self.cluster,
            framework: fw,
            workload,
            cost,
            compiled,
            topology,
            ring_policy: self.ring_policy,
            record_trace: self.record_trace,
        })
    }
}

/// Pick parallelism degrees for a cluster: the model's paper deployment
/// if world sizes match, else TP=gpus_per_node, PP=1, DP=rest.
pub fn infer_parallelism(
    model: &ModelSpec,
    cluster: &ClusterSpec,
) -> anyhow::Result<ParallelismSpec> {
    let world = cluster.total_gpus();
    let preset = match model.name.as_str() {
        "GPT-6.7B" => Some(crate::config::presets::deployment("gpt-6.7b")?),
        "GPT-13B" => Some(crate::config::presets::deployment("gpt-13b")?),
        "Mixtral-8x7B" => Some(crate::config::presets::deployment("mixtral-8x7b")?),
        "Llama-2-70B" => Some(crate::config::presets::deployment("llama2-70b")?),
        _ => None,
    };
    if let Some(p) = preset {
        if p.world_size() == world {
            return Ok(p);
        }
    }
    let tp = cluster.gpus_per_node().clamp(1, 8);
    anyhow::ensure!(world % tp == 0, "cluster size {world} not divisible by tp {tp}");
    Ok(ParallelismSpec { tp, pp: 1, dp: world / tp })
}

/// A fully-prepared simulation: workload, evaluated cost table, built
/// network topology and the dense compiled core, runnable for one or
/// more iterations.
///
/// `Simulation` is `Send + Sync` — every run borrows the prepared state
/// immutably, so one build can back many concurrent runs (see
/// [`Simulation::run_iterations_concurrent`] and the planner's sweep).
pub struct Simulation {
    /// Model description the workload was generated from.
    pub model: ModelSpec,
    /// Cluster and host-topology description.
    pub cluster: ClusterSpec,
    /// Resolved device-group mapping, including the pipeline schedule.
    pub framework: FrameworkSpec,
    /// Generated per-rank programs plus collective definitions.
    pub workload: Workload,
    /// Evaluated compute-cost table (one entry per distinct op × GPU).
    pub cost: CostTable,
    /// Dense simulation core (durations resolved, collectives planned).
    pub compiled: CompiledWorkload,
    /// Built network graph, shared by all runs of this simulation.
    pub topology: Arc<Topology>,
    /// Fixed at build time (baked into `compiled`); private so it can't
    /// be mutated into silent disagreement with the compiled plan.
    ring_policy: RingPolicy,
    /// Whether runs record the per-rank busy-interval trace.
    pub record_trace: bool,
}

impl Simulation {
    /// Simulate one training iteration. Reuses the compiled core and
    /// topology — no per-run workload lowering or graph building.
    pub fn run_iteration(&self) -> anyhow::Result<SimulationReport> {
        let mut sched = Scheduler::prepared(&self.compiled, &self.cluster, self.topology.clone());
        sched.record_trace = self.record_trace;
        let rep = sched.run()?;
        Ok(SimulationReport::from_scheduler(self, rep))
    }

    /// Run `iterations` independent iterations concurrently on
    /// `threads` workers (0 = one per available core). Results come
    /// back in iteration order and are bit-identical to sequential runs
    /// — each run only borrows the shared prepared state.
    pub fn run_iterations_concurrent(
        &self,
        iterations: usize,
        threads: usize,
    ) -> anyhow::Result<Vec<SimulationReport>> {
        crate::util::par::parallel_map(iterations, threads, |_| self.run_iteration())
            .into_iter()
            .collect()
    }

    /// The ring policy this simulation was compiled with. Fixed at
    /// build time — use [`SimulationBuilder::ring_policy`] to change it.
    pub fn ring_policy(&self) -> RingPolicy {
        self.ring_policy
    }
}

/// The run summary consumed by reports and benches.
#[derive(Debug)]
pub struct SimulationReport {
    /// Name of the simulated model.
    pub model_name: String,
    /// Name of the simulated cluster.
    pub cluster_name: String,
    /// Simulated wall-clock time of the training iteration.
    pub iteration_time: Time,
    /// Network flows completed during the iteration.
    pub flows_completed: usize,
    /// Discrete events the engine processed.
    pub events_processed: u64,
    /// FCT summaries per communication kind (Fig 6's raw material).
    pub fct_summary: HashMap<&'static str, Summary>,
    /// Raw FCT samples per communication kind.
    pub fct_by_kind: HashMap<&'static str, Samples>,
    /// All FCT samples pooled across kinds.
    pub fct_all: Samples,
    /// Summed per-rank compute busy time (trace-derived).
    pub compute_busy: Time,
    /// Summed collective busy time (trace-derived).
    pub comm_busy: Time,
}

impl SimulationReport {
    fn from_scheduler(sim: &Simulation, rep: SchedulerReport) -> SimulationReport {
        let mut fct_by_kind = rep.fct_by_kind;
        let fct_summary =
            fct_by_kind.iter_mut().map(|(k, v)| (*k, Summary::of(v))).collect();
        SimulationReport {
            model_name: sim.model.name.clone(),
            cluster_name: sim.cluster.name.clone(),
            iteration_time: rep.iteration_time,
            flows_completed: rep.flows_completed,
            events_processed: rep.events_processed,
            fct_summary,
            fct_by_kind,
            fct_all: rep.fct_all,
            compute_busy: rep.compute_busy,
            comm_busy: rep.comm_busy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny(cluster: ClusterSpec) -> SimulationBuilder {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 16;
        m.micro_batch = 8;
        SimulationBuilder::new(m, cluster)
    }

    #[test]
    fn quickstart_homogeneous_run() {
        let rep = tiny(presets::cluster("hopper", 1).unwrap())
            .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
            .build()
            .unwrap()
            .run_iteration()
            .unwrap();
        assert!(rep.iteration_time > Time::ZERO);
        assert!(rep.flows_completed > 0);
        assert!(rep.fct_summary.contains_key("TP"));
        assert!(rep.fct_summary.contains_key("DP"));
    }

    #[test]
    fn hetero_slower_than_hopper_for_same_workload() {
        let run = |cluster| {
            tiny(cluster)
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let hopper = run(presets::cluster("hopper", 2).unwrap());
        let hetero = run(presets::cluster_hetero(1, 1).unwrap());
        assert!(hetero > hopper, "hetero {hetero} <= hopper {hopper}");
    }

    #[test]
    fn hetero_partitioning_beats_uniform_on_hetero_cluster() {
        let mk = |hetero_partitioning| {
            tiny(presets::cluster_hetero(1, 1).unwrap())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .hetero_partitioning(hetero_partitioning)
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let uniform = mk(false);
        let partitioned = mk(true);
        assert!(
            partitioned < uniform,
            "non-uniform partitioning should win: {partitioned} vs {uniform}"
        );
    }

    #[test]
    fn determinism_same_config_same_timeline() {
        let run = || {
            tiny(presets::cluster_hetero(1, 1).unwrap())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.iteration_time, b.iteration_time);
        assert_eq!(a.flows_completed, b.flows_completed);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn simulation_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Simulation>();
    }

    #[test]
    fn concurrent_iterations_are_deterministic() {
        let sim = tiny(presets::cluster_hetero(1, 1).unwrap())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .build()
            .unwrap();
        let sequential = sim.run_iteration().unwrap();
        let reports = sim.run_iterations_concurrent(4, 4).unwrap();
        assert_eq!(reports.len(), 4);
        for rep in &reports {
            assert_eq!(rep.iteration_time, sequential.iteration_time);
            assert_eq!(rep.flows_completed, sequential.flows_completed);
            assert_eq!(rep.events_processed, sequential.events_processed);
        }
    }

    #[test]
    fn schedules_run_to_completion_and_1f1b_shrinks_bubbles() {
        // pipeline-heavy scenario: tp=1, pp=4, 8 microbatches. GPipe
        // (seed behavior) runs microbatches strictly sequentially, so
        // any pipelining schedule must finish no later.
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 2;
        let run = |s: ScheduleKind| {
            SimulationBuilder::new(m.clone(), presets::cluster("hopper", 1).unwrap())
                .parallelism(ParallelismSpec { tp: 1, pp: 4, dp: 2 })
                .schedule(s)
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let gpipe = run(ScheduleKind::GPipe);
        let onef = run(ScheduleKind::OneFOneB);
        let inter = run(ScheduleKind::Interleaved1F1B { vpp: 2 });
        assert!(gpipe > Time::ZERO && onef > Time::ZERO && inter > Time::ZERO);
        assert!(onef < gpipe, "1f1b {onef} not faster than gpipe {gpipe}");
        assert!(inter < gpipe, "interleaved {inter} not faster than gpipe {gpipe}");
    }

    #[test]
    fn schedules_deterministic_on_hetero_cluster() {
        for s in [ScheduleKind::OneFOneB, ScheduleKind::Interleaved1F1B { vpp: 2 }] {
            let run = || {
                tiny(presets::cluster_hetero(1, 1).unwrap())
                    .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
                    .schedule(s)
                    .build()
                    .unwrap()
                    .run_iteration()
                    .unwrap()
            };
            let a = run();
            let b = run();
            assert_eq!(a.iteration_time, b.iteration_time, "{s}");
            assert_eq!(a.events_processed, b.events_processed, "{s}");
        }
    }

    #[test]
    fn infer_parallelism_matches_paper_when_possible() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap(); // 128 GPUs
        let p = infer_parallelism(&m, &c).unwrap();
        assert_eq!((p.tp, p.pp, p.dp), (4, 1, 32));
        // non-matching world size falls back
        let c2 = presets::cluster("hopper", 2).unwrap();
        let p2 = infer_parallelism(&m, &c2).unwrap();
        assert_eq!(p2.world_size(), 16);
    }
}
