//! The HetSim facade: ties configuration, workload generation, cost
//! evaluation, the system scheduler and the network simulator into one
//! reproducible run (paper Fig 4's full pipeline).
//!
//! ## Zero-rebuild candidate evaluation
//!
//! The planner scores thousands of candidate deployments, and each
//! score used to pay for a fresh [`Topology`], a fresh cost table and a
//! fresh compile. [`EvalContext`] hoists everything that does **not**
//! depend on the candidate out of that loop:
//!
//! * the built `Arc<Topology>` (a pure function of the cluster) is
//!   constructed once per search/refine run and shared by every build;
//! * the cost table is shared monotonically: each candidate build
//!   starts from a snapshot of all previously evaluated (op, GPU)
//!   entries ([`crate::compute::table::CostTable::share`]) and writes
//!   any new entries back, so a distinct descriptor row is evaluated
//!   once per run, not once per candidate;
//! * generated workloads + compiled cores are cached keyed by the
//!   [`crate::config::framework::FrameworkSpec::fingerprint`] of the
//!   resolved mapping, and full iteration scores are cached under the
//!   same key — re-scoring a revisited refinement state is a hash
//!   lookup.
//!
//! Entries are pure functions of their keys, so context sharing cannot
//! change any simulated result: `build_with_context` is bit-identical
//! to `build`, enforced by tests here and by the golden determinism
//! suite (`rust/tests/golden_plan.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::compute::table::CostTable;
use crate::config::cluster::ClusterSpec;
use crate::config::framework::{FrameworkSpec, ParallelismSpec};
use crate::config::model::ModelSpec;
use crate::network::topology::Topology;
use crate::system::collective::RingPolicy;
use crate::system::compiled::CompiledWorkload;
use crate::system::failure::{FaultReport, FaultSpec};
use crate::system::fold::{self, FoldMode, FoldPlan};
use crate::system::scheduler::{Scheduler, SchedulerReport};
use crate::util::stats::{Samples, Summary};
use crate::util::units::Time;
use crate::workload::aicb::{self, WorkloadOptions};
use crate::workload::op::Workload;
use crate::workload::schedule::ScheduleKind;
use crate::workload::serve::ServeSpec;

/// How per-layer compute times are evaluated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostBackend {
    /// Pure-Rust roofline mirror (no artifacts needed).
    Native,
    /// AOT artifact via PJRT (requires `make artifacts`).
    Pjrt,
}

/// Builder for a simulation run.
pub struct SimulationBuilder {
    model: ModelSpec,
    cluster: ClusterSpec,
    framework: Option<FrameworkSpec>,
    parallelism: Option<ParallelismSpec>,
    options: WorkloadOptions,
    cost_backend: CostBackend,
    ring_policy: RingPolicy,
    hetero_partitioning: bool,
    schedule: Option<ScheduleKind>,
    record_trace: bool,
    fold: FoldMode,
    faults: Option<FaultSpec>,
    serving: Option<ServeSpec>,
}

/// The builder's inputs after framework resolution — what every build
/// path (plain, context, score) consumes.
struct ResolvedBuild {
    model: ModelSpec,
    cluster: ClusterSpec,
    framework: FrameworkSpec,
    options: WorkloadOptions,
    cost_backend: CostBackend,
    ring_policy: RingPolicy,
    record_trace: bool,
    fold: FoldMode,
    faults: Option<FaultSpec>,
    serving: Option<ServeSpec>,
}

impl SimulationBuilder {
    /// Start a builder for `model` on `cluster` with the defaults:
    /// inferred parallelism, uniform mapping, GPipe schedule, native
    /// cost backend, hetero-aware rings, no trace.
    pub fn new(model: ModelSpec, cluster: ClusterSpec) -> Self {
        SimulationBuilder {
            model,
            cluster,
            framework: None,
            parallelism: None,
            options: WorkloadOptions::default(),
            cost_backend: CostBackend::Native,
            ring_policy: RingPolicy::HeteroAware,
            hetero_partitioning: false,
            schedule: None,
            record_trace: false,
            fold: FoldMode::Off,
            faults: None,
            serving: None,
        }
    }

    /// Explicit parallelism degrees (defaults to the model's Table-6
    /// deployment scaled to the cluster if unset).
    pub fn parallelism(mut self, par: ParallelismSpec) -> Self {
        self.parallelism = Some(par);
        self
    }

    /// Fully custom framework spec (device groups, non-uniform splits).
    pub fn framework(mut self, fw: FrameworkSpec) -> Self {
        self.framework = Some(fw);
        self
    }

    /// Use the heterogeneity-aware non-uniform partitioner (C1) instead
    /// of the uniform mapping.
    pub fn hetero_partitioning(mut self, on: bool) -> Self {
        self.hetero_partitioning = on;
        self
    }

    /// Pipeline schedule for every device group (`gpipe` when unset).
    /// Overrides whatever the resolved framework spec carries, so it
    /// composes with [`SimulationBuilder::framework`] and the
    /// heterogeneity-aware partitioner.
    pub fn schedule(mut self, s: ScheduleKind) -> Self {
        self.schedule = Some(s);
        self
    }

    /// Workload-generation knobs (microbatch caps, optional op classes).
    pub fn workload_options(mut self, opts: WorkloadOptions) -> Self {
        self.options = opts;
        self
    }

    /// Select how per-layer compute times are evaluated.
    pub fn cost_backend(mut self, b: CostBackend) -> Self {
        self.cost_backend = b;
        self
    }

    /// Select the collective ring-ordering policy.
    pub fn ring_policy(mut self, p: RingPolicy) -> Self {
        self.ring_policy = p;
        self
    }

    /// Record a per-rank busy-interval trace. Off by default — the
    /// cheap path — and the compute/comm busy breakdown no longer
    /// needs it (the scheduler accumulates those sums directly), so
    /// only timeline exports (Chrome trace, CSV) should turn it on.
    pub fn record_trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }

    /// Symmetry folding ([`crate::system::fold`], DESIGN.md §25):
    /// `Auto` simulates one representative device group per
    /// equivalence class and weights the report to the unfolded
    /// totals; `Off` (the default) is byte-identical to the classic
    /// path. `Auto` falls back to unfolded simulation whenever the
    /// deployment breaks the folding preconditions (pipeline stages,
    /// resharding, asymmetric fabric slices).
    pub fn fold(mut self, mode: FoldMode) -> Self {
        self.fold = mode;
        self
    }

    /// Inject a deterministic fault schedule ([`crate::system::failure`],
    /// DESIGN.md §26): the earliest scheduled fail-stop aborts the
    /// iteration at its fault time and straggler events stretch the
    /// slowed node's compute. An empty spec normalizes to no spec, so
    /// the fault layer is strictly zero-cost when unused —
    /// byte-identical reports, unchanged evaluation cache keys. A
    /// non-empty spec also refuses symmetry folding
    /// ([`crate::system::fold::classify_with_faults`]).
    pub fn faults(mut self, spec: Option<FaultSpec>) -> Self {
        self.faults = spec.filter(|s| !s.is_empty());
        self
    }

    /// Attach a serving workload ([`crate::workload::serve`],
    /// DESIGN.md §27), runnable via [`Simulation::run_serve`]. An empty
    /// spec normalizes to no spec, so the serving layer is strictly
    /// zero-cost when unused: byte-identical training reports and
    /// unchanged evaluation cache keys. A non-empty spec fingerprints
    /// into the eval key (cached [`EvalScore`]s never alias a training
    /// run with a serving run on the same cluster shape) and refuses
    /// symmetry folding, mirroring the fault layer.
    pub fn serving(mut self, spec: Option<ServeSpec>) -> Self {
        self.serving = spec.filter(|s| !s.is_empty());
        self
    }

    /// Resolve the parallelism degrees and device-group mapping.
    fn resolve(self) -> anyhow::Result<ResolvedBuild> {
        let par = match self.parallelism {
            Some(p) => p,
            None => infer_parallelism(&self.model, &self.cluster)?,
        };
        let mut fw = match self.framework {
            Some(f) => f,
            None if self.hetero_partitioning => {
                crate::workload::partition::plan_hetero(&self.model, &self.cluster, par)?
            }
            None => FrameworkSpec::uniform(&self.model, &self.cluster, par)?,
        };
        if let Some(s) = self.schedule {
            s.validate()?;
            fw.schedule = s;
        }
        // A serving workload refuses symmetry folding the same way
        // faults do: its per-node device groups are stateful and
        // independently paced, so no two are provably interchangeable.
        // Forcing `Off` here makes fold=auto bit-identical to fold=off
        // under serving for every build path (the fold-interaction
        // guard in tests/integration_serve.rs).
        let fold = if self.serving.is_some() { FoldMode::Off } else { self.fold };
        Ok(ResolvedBuild {
            model: self.model,
            cluster: self.cluster,
            framework: fw,
            options: self.options,
            cost_backend: self.cost_backend,
            ring_policy: self.ring_policy,
            record_trace: self.record_trace,
            fold,
            faults: self.faults,
            serving: self.serving,
        })
    }

    /// Resolve the framework spec, generate the workload, evaluate the
    /// cost table, build the topology, compile.
    pub fn build(self) -> anyhow::Result<Simulation> {
        let r = self.resolve()?;
        if let Some(spec) = &r.faults {
            spec.validate(&r.cluster)?;
        }
        if let Some(spec) = &r.serving {
            spec.validate()?;
        }
        let plan =
            fold::classify_with_faults(&r.cluster, &r.framework, r.fold, r.faults.as_ref());
        let workload = generate_workload(&r, plan.as_ref())?;
        let mut cost = match r.cost_backend {
            CostBackend::Native => CostTable::native(),
            CostBackend::Pjrt => {
                CostTable::new(Box::new(crate::runtime::PjrtCostModel::load()?))
            }
        };
        aicb::register_costs(&workload, &r.cluster, &mut cost)?;
        let topology = Arc::new(Topology::build(&r.cluster)?);
        let compiled = compile_workload(&workload, &r, &cost, &topology, plan.as_ref())?;
        Ok(Simulation {
            model: r.model,
            cluster: r.cluster,
            framework: r.framework,
            workload: Arc::new(workload),
            cost: Arc::new(cost),
            compiled: Arc::new(compiled),
            topology,
            ring_policy: r.ring_policy,
            record_trace: r.record_trace,
            faults: r.faults,
            serving: r.serving,
        })
    }

    /// [`SimulationBuilder::build`] against a shared [`EvalContext`]:
    /// reuses the context's topology, warm cost cache and (on a
    /// fingerprint hit) the cached workload + compiled core, so the
    /// per-candidate cost is workload emission + compile only — or
    /// nothing at all for a revisited mapping. Native cost backend
    /// only. The returned simulation is bit-identical to a plain
    /// `build()` of the same inputs.
    pub fn build_with_context(self, ctx: &EvalContext) -> anyhow::Result<Simulation> {
        anyhow::ensure!(
            self.cost_backend == CostBackend::Native,
            "EvalContext sharing supports the native cost backend only"
        );
        let r = self.resolve()?;
        ctx.check_inputs(&r.model, &r.cluster)?;
        if let Some(spec) = &r.faults {
            spec.validate(&r.cluster)?;
        }
        if let Some(spec) = &r.serving {
            spec.validate()?;
        }
        let key = eval_key(
            &r.framework,
            &r.options,
            r.ring_policy,
            r.fold,
            r.faults.as_ref(),
            r.serving.as_ref(),
        );
        let prepared = ctx.prepare(&r, &key)?;
        Ok(Simulation {
            model: r.model,
            cluster: r.cluster,
            framework: r.framework,
            workload: prepared.workload,
            cost: prepared.cost,
            compiled: prepared.compiled,
            topology: ctx.topology.clone(),
            ring_policy: r.ring_policy,
            record_trace: r.record_trace,
            faults: r.faults,
            serving: r.serving,
        })
    }

    /// Score one candidate against a shared [`EvalContext`]: build (or
    /// reuse) the compiled core and run one trace-free iteration,
    /// memoizing the [`EvalScore`] under the candidate's fingerprint —
    /// the planner's hot path. A revisited refinement state costs one
    /// hash lookup.
    pub fn score_with_context(self, ctx: &EvalContext) -> anyhow::Result<EvalScore> {
        match self.score_with_cutoff(ctx, None)? {
            ScoreOutcome::Complete(s) => Ok(s),
            // unreachable: with no cutoff the scheduler can never
            // report a cutoff hit
            ScoreOutcome::Cutoff => anyhow::bail!("cutoff hit with no cutoff set"),
        }
    }

    /// [`SimulationBuilder::score_with_context`] with an incumbent
    /// cutoff (the branch-and-bound hot path, DESIGN.md §29): the event
    /// loop abandons the run the moment its clock would pass `cutoff`
    /// *strictly*, returning [`ScoreOutcome::Cutoff`] — the candidate
    /// cannot beat the incumbent, so it stops paying for events.
    ///
    /// Correctness properties the planner relies on:
    /// - `cutoff = None` is bit-identical to plain scoring.
    /// - A run that completes under a finite cutoff is bit-identical to
    ///   the cutoff-free run (the peek never fired), so its score is
    ///   cutoff-independent and safe to memoize under the same key —
    ///   and a memoized score from an earlier cutoff-free run is safe
    ///   to return here.
    /// - Equality at the cutoff completes (strict `>` in the
    ///   scheduler), so a candidate tied with the incumbent stays
    ///   rankable.
    /// - An aborted run is **never** cached: its timing is partial.
    pub fn score_with_cutoff(
        self,
        ctx: &EvalContext,
        cutoff: Option<Time>,
    ) -> anyhow::Result<ScoreOutcome> {
        // scoring is the cheap path by construction: no trace recording
        debug_assert!(
            !self.record_trace,
            "score_with_context never records a trace; use build_with_context + \
             run_iteration for timeline exports"
        );
        anyhow::ensure!(
            self.cost_backend == CostBackend::Native,
            "EvalContext sharing supports the native cost backend only"
        );
        let r = self.resolve()?;
        ctx.check_inputs(&r.model, &r.cluster)?;
        if let Some(spec) = &r.faults {
            spec.validate(&r.cluster)?;
        }
        if let Some(spec) = &r.serving {
            spec.validate()?;
        }
        let key = eval_key(
            &r.framework,
            &r.options,
            r.ring_policy,
            r.fold,
            r.faults.as_ref(),
            r.serving.as_ref(),
        );
        if let Some(s) = ctx.scores.lock().unwrap().get(&key).copied() {
            ctx.score_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(ScoreOutcome::Complete(s));
        }
        let prepared = ctx.prepare(&r, &key)?;
        let mut sched = Scheduler::prepared(&prepared.compiled, &r.cluster, ctx.topology.clone());
        arm_faults(&mut sched, r.faults.as_ref(), &r.cluster);
        sched.cutoff = cutoff;
        let rep = sched.run()?;
        if rep.cutoff_hit {
            return Ok(ScoreOutcome::Cutoff);
        }
        let score = EvalScore {
            iteration_time: rep.iteration_time,
            compute_busy: rep.compute_busy,
            comm_busy: rep.comm_busy,
            flows_completed: rep.flows_completed,
            events_processed: rep.events_processed,
        };
        ctx.scores.lock().unwrap().entry(key).or_insert(score);
        Ok(ScoreOutcome::Complete(score))
    }
}

/// Outcome of a cutoff-aware scoring run
/// ([`SimulationBuilder::score_with_cutoff`]).
#[derive(Debug, Clone, Copy)]
pub enum ScoreOutcome {
    /// The simulation ran to completion at or under the cutoff; the
    /// score is exact — bit-identical to what cutoff-free scoring
    /// reports.
    Complete(EvalScore),
    /// The simulated clock passed the cutoff strictly and the run was
    /// abandoned: the candidate's iteration time provably exceeds the
    /// incumbent's, so nothing about it is cached or rankable.
    Cutoff,
}

/// Cache key of one candidate evaluation: the resolved mapping's
/// fingerprint plus every knob that changes the generated workload, its
/// compilation, or its simulated timeline. `Off` keys are unchanged
/// from the pre-folding layout so folded and unfolded cores never
/// alias, and the fault and serving fingerprints are empty for empty
/// specs so fault-free, serving-free keys are unchanged from the
/// earlier layouts. The serving suffix exists so a cached [`EvalScore`]
/// of a training candidate can never alias a serving-annotated
/// candidate sharing the same cluster shape (regression-tested by
/// `serving_spec_changes_eval_key` below).
fn eval_key(
    fw: &FrameworkSpec,
    opts: &WorkloadOptions,
    ring: RingPolicy,
    fold: FoldMode,
    faults: Option<&FaultSpec>,
    serving: Option<&ServeSpec>,
) -> String {
    format!(
        "{}|mb{}|o{}{}{}|{ring:?}{}{}{}",
        fw.fingerprint(),
        opts.microbatch_limit.map(|l| l.to_string()).unwrap_or_else(|| "all".into()),
        opts.include_other as u8,
        opts.moe_alltoall as u8,
        opts.dp_sync as u8,
        match fold {
            FoldMode::Off => "",
            FoldMode::Auto => "|fold",
        },
        faults.map(|f| f.fingerprint()).unwrap_or_default(),
        serving.map(|s| s.fingerprint()).unwrap_or_default(),
    )
}

/// Resolve the per-iteration fault view (window anchored at simulated
/// time zero) and arm the scheduler when anything is active. A spec
/// whose events all land outside the window leaves the scheduler
/// untouched — the run stays on the fault-free fast path.
fn arm_faults(sched: &mut Scheduler<'_>, spec: Option<&FaultSpec>, cluster: &ClusterSpec) {
    if let Some(spec) = spec {
        let f = spec.resolve_iteration(cluster, 0.0);
        if !f.is_noop() {
            sched.faults = Some(f);
        }
    }
}

/// Emit the per-rank op streams for one resolved candidate: folded when
/// a [`FoldPlan`] was classified, classic otherwise.
fn generate_workload(r: &ResolvedBuild, plan: Option<&FoldPlan>) -> anyhow::Result<Workload> {
    match plan {
        Some(p) => aicb::generate_folded(&r.model, &r.cluster, &r.framework, &r.options, p),
        None => aicb::generate(&r.model, &r.cluster, &r.framework, &r.options),
    }
}

/// Lower one resolved candidate to the dense core: class-folded DP flow
/// templates when a [`FoldPlan`] was classified, classic otherwise.
fn compile_workload(
    workload: &Workload,
    r: &ResolvedBuild,
    cost: &CostTable,
    topology: &Topology,
    plan: Option<&FoldPlan>,
) -> anyhow::Result<CompiledWorkload> {
    match plan {
        Some(p) => CompiledWorkload::compile_folded(
            workload,
            &r.cluster,
            cost,
            r.ring_policy,
            topology,
            p,
        ),
        None => CompiledWorkload::compile(workload, &r.cluster, cost, r.ring_policy),
    }
}

/// One cached candidate build (all shared, all immutable).
#[derive(Clone)]
struct CachedEval {
    workload: Arc<Workload>,
    cost: Arc<CostTable>,
    compiled: Arc<CompiledWorkload>,
}

/// Compiled-workload cache bound: full builds are large (op streams +
/// flow-step templates), so the build cache is flushed wholesale when
/// it fills — a flush only costs recompiles, never changes results.
/// Scores are a few machine words each and stay cached for the whole
/// run.
const BUILD_CACHE_CAP: usize = 64;

/// Everything a candidate evaluation can share: built once per
/// search/refine run, borrowed immutably by every worker thread (all
/// interior mutability is behind mutexes; all cached values are pure
/// functions of their keys, so sharing is invisible in the results).
/// See the module docs for the full contract.
pub struct EvalContext {
    model: ModelSpec,
    cluster: ClusterSpec,
    topology: Arc<Topology>,
    cost: Mutex<CostTable>,
    builds: Mutex<HashMap<String, CachedEval>>,
    scores: Mutex<HashMap<String, EvalScore>>,
    build_hits: AtomicU64,
    build_misses: AtomicU64,
    score_hits: AtomicU64,
}

impl EvalContext {
    /// Build the shared state for evaluating candidates of `model` on
    /// `cluster`: constructs the topology once; cost/build/score caches
    /// start empty and warm up as candidates are evaluated.
    pub fn new(model: &ModelSpec, cluster: &ClusterSpec) -> anyhow::Result<EvalContext> {
        Ok(EvalContext {
            model: model.clone(),
            cluster: cluster.clone(),
            topology: Arc::new(Topology::build(cluster)?),
            cost: Mutex::new(CostTable::native()),
            builds: Mutex::new(HashMap::new()),
            scores: Mutex::new(HashMap::new()),
            build_hits: AtomicU64::new(0),
            build_misses: AtomicU64::new(0),
            score_hits: AtomicU64::new(0),
        })
    }

    /// The shared built topology.
    pub fn topology(&self) -> Arc<Topology> {
        self.topology.clone()
    }

    /// Build-cache hits so far (workload + compile skipped entirely).
    pub fn build_cache_hits(&self) -> u64 {
        self.build_hits.load(Ordering::Relaxed)
    }

    /// Build-cache misses so far (full workload emission + compile).
    pub fn build_cache_misses(&self) -> u64 {
        self.build_misses.load(Ordering::Relaxed)
    }

    /// Score-cache hits so far (whole simulated iterations skipped).
    pub fn score_cache_hits(&self) -> u64 {
        self.score_hits.load(Ordering::Relaxed)
    }

    /// Distinct (op, GPU) cost entries evaluated so far across all
    /// candidates.
    pub fn cost_entries(&self) -> usize {
        self.cost.lock().unwrap().cached_len()
    }

    fn check_inputs(&self, model: &ModelSpec, cluster: &ClusterSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            *model == self.model,
            "EvalContext was built for model '{}' but used with a different model spec",
            self.model.name
        );
        anyhow::ensure!(
            *cluster == self.cluster,
            "EvalContext was built for cluster '{}' but used with a different cluster spec",
            self.cluster.name
        );
        Ok(())
    }

    /// Fetch or build the (workload, cost, compiled) triple for one
    /// resolved candidate. Misses run outside the cache locks; two
    /// workers racing on the same key both compute identical values and
    /// the first insert wins.
    fn prepare(&self, r: &ResolvedBuild, key: &str) -> anyhow::Result<CachedEval> {
        if let Some(hit) = self.builds.lock().unwrap().get(key).cloned() {
            self.build_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit);
        }
        self.build_misses.fetch_add(1, Ordering::Relaxed);
        let plan =
            fold::classify_with_faults(&r.cluster, &r.framework, r.fold, r.faults.as_ref());
        let workload = generate_workload(r, plan.as_ref())?;
        // warm-start from every entry any candidate evaluated so far
        let mut cost = self.cost.lock().unwrap().share();
        let before = cost.cached_len();
        aicb::register_costs(&workload, &r.cluster, &mut cost)?;
        if cost.cached_len() > before {
            self.cost.lock().unwrap().absorb(&cost);
        }
        let compiled = compile_workload(&workload, r, &cost, &self.topology, plan.as_ref())?;
        let entry = CachedEval {
            workload: Arc::new(workload),
            cost: Arc::new(cost),
            compiled: Arc::new(compiled),
        };
        let mut builds = self.builds.lock().unwrap();
        if builds.len() >= BUILD_CACHE_CAP {
            builds.clear();
        }
        Ok(builds.entry(key.to_string()).or_insert(entry).clone())
    }
}

/// The compact result of scoring one candidate with a full simulated
/// iteration — everything the planner ranks on, cacheable in a few
/// machine words.
#[derive(Debug, Clone, Copy)]
pub struct EvalScore {
    /// Simulated wall-clock time of the training iteration.
    pub iteration_time: Time,
    /// Summed per-rank compute busy time.
    pub compute_busy: Time,
    /// Summed collective busy time.
    pub comm_busy: Time,
    /// Network flows completed during the iteration.
    pub flows_completed: usize,
    /// Discrete events the engine processed.
    pub events_processed: u64,
}

/// Pick parallelism degrees for a cluster: the model's paper deployment
/// if world sizes match, else TP = the GCD of all node sizes (so TP
/// blocks align with node boundaries even on mixed-node-size clusters;
/// equal to gpus-per-node on uniform clusters), PP=1, DP=rest.
pub fn infer_parallelism(
    model: &ModelSpec,
    cluster: &ClusterSpec,
) -> anyhow::Result<ParallelismSpec> {
    let world = cluster.total_gpus();
    let preset = match model.name.as_str() {
        "GPT-6.7B" => Some(crate::config::presets::deployment("gpt-6.7b")?),
        "GPT-13B" => Some(crate::config::presets::deployment("gpt-13b")?),
        "Mixtral-8x7B" => Some(crate::config::presets::deployment("mixtral-8x7b")?),
        "Llama-2-70B" => Some(crate::config::presets::deployment("llama2-70b")?),
        _ => None,
    };
    if let Some(p) = preset {
        if p.world_size() == world {
            return Ok(p);
        }
    }
    // any divisor of the node-size GCD also divides the world size
    // (a sum of multiples); clamp to the paper's TP ceiling of 8
    let gcd = cluster.gcd_gpus_per_node().max(1);
    let tp = if gcd > 8 { (1..=8).rev().find(|t| gcd % t == 0).unwrap_or(1) } else { gcd };
    anyhow::ensure!(world % tp == 0, "cluster size {world} not divisible by tp {tp}");
    Ok(ParallelismSpec { tp, pp: 1, dp: world / tp })
}

/// A fully-prepared simulation: workload, evaluated cost table, built
/// network topology and the dense compiled core, runnable for one or
/// more iterations.
///
/// `Simulation` is `Send + Sync` — every run borrows the prepared state
/// immutably, so one build can back many concurrent runs (see
/// [`Simulation::run_iterations_concurrent`] and the planner's sweep).
/// The prepared pieces sit behind `Arc`s so an [`EvalContext`] can
/// share them across candidate builds without copying.
pub struct Simulation {
    /// Model description the workload was generated from.
    pub model: ModelSpec,
    /// Cluster and host-topology description.
    pub cluster: ClusterSpec,
    /// Resolved device-group mapping, including the pipeline schedule.
    pub framework: FrameworkSpec,
    /// Generated per-rank programs plus collective definitions.
    pub workload: Arc<Workload>,
    /// Evaluated compute-cost table (one entry per distinct op × GPU).
    pub cost: Arc<CostTable>,
    /// Dense simulation core (durations resolved, collectives planned).
    pub compiled: Arc<CompiledWorkload>,
    /// Built network graph, shared by all runs of this simulation.
    pub topology: Arc<Topology>,
    /// Fixed at build time (baked into `compiled`); private so it can't
    /// be mutated into silent disagreement with the compiled plan.
    ring_policy: RingPolicy,
    /// Whether runs record the per-rank busy-interval trace.
    pub record_trace: bool,
    /// Injected fault schedule; private because a non-empty spec also
    /// vetoed folding at build time, so mutating it after the fact
    /// could silently disagree with the compiled plan.
    faults: Option<FaultSpec>,
    /// Attached serving workload; private for the same reason as
    /// `faults` — a non-empty spec vetoed folding at build time.
    serving: Option<ServeSpec>,
}

impl Simulation {
    /// Simulate one training iteration. Reuses the compiled core and
    /// topology — no per-run workload lowering or graph building.
    pub fn run_iteration(&self) -> anyhow::Result<SimulationReport> {
        let mut sched = Scheduler::prepared(&self.compiled, &self.cluster, self.topology.clone());
        sched.record_trace = self.record_trace;
        arm_faults(&mut sched, self.faults.as_ref(), &self.cluster);
        let rep = sched.run()?;
        Ok(SimulationReport::from_scheduler(self, rep))
    }

    /// Run `iterations` independent iterations concurrently on
    /// `threads` workers (0 = one per available core). Results come
    /// back in iteration order and are bit-identical to sequential runs
    /// — each run only borrows the shared prepared state.
    pub fn run_iterations_concurrent(
        &self,
        iterations: usize,
        threads: usize,
    ) -> anyhow::Result<Vec<SimulationReport>> {
        crate::util::par::parallel_map(iterations, threads, |_| self.run_iteration())
            .into_iter()
            .collect()
    }

    /// The ring policy this simulation was compiled with. Fixed at
    /// build time — use [`SimulationBuilder::ring_policy`] to change it.
    pub fn ring_policy(&self) -> RingPolicy {
        self.ring_policy
    }

    /// Whether symmetry folding actually engaged for this build
    /// (requested via [`SimulationBuilder::fold`] *and* the deployment
    /// satisfied the folding preconditions).
    pub fn folded(&self) -> bool {
        self.compiled.fold.is_some()
    }

    /// The injected fault schedule this simulation was built with
    /// (`None` when the fault layer is off).
    pub fn fault_spec(&self) -> Option<&FaultSpec> {
        self.faults.as_ref()
    }

    /// The serving workload this simulation was built with (`None` when
    /// the serving layer is off).
    pub fn serving_spec(&self) -> Option<&ServeSpec> {
        self.serving.as_ref()
    }

    /// Run the attached serving trace to completion
    /// ([`crate::system::serve_scheduler::ServeSim`], DESIGN.md §27).
    /// `threads` parallelizes the per-group cost-table build only — the
    /// report is byte-identical for any value. Errors when no serving
    /// spec was attached ([`SimulationBuilder::serving`]).
    pub fn run_serve(&self, threads: usize) -> anyhow::Result<crate::report::serve::ServeReport> {
        let spec = self.serving.clone().ok_or_else(|| {
            anyhow::anyhow!("no serving workload attached; use SimulationBuilder::serving")
        })?;
        crate::system::serve_scheduler::ServeSim::new(
            self.model.clone(),
            self.cluster.clone(),
            spec,
        )?
        .run(threads)
    }
}

/// The run summary consumed by reports and benches.
#[derive(Debug)]
pub struct SimulationReport {
    /// Name of the simulated model.
    pub model_name: String,
    /// Name of the simulated cluster.
    pub cluster_name: String,
    /// Simulated wall-clock time of the training iteration.
    pub iteration_time: Time,
    /// Network flows completed during the iteration.
    pub flows_completed: usize,
    /// Discrete events the engine processed.
    pub events_processed: u64,
    /// FCT summaries per communication kind (Fig 6's raw material).
    pub fct_summary: HashMap<&'static str, Summary>,
    /// Raw FCT samples per communication kind.
    pub fct_by_kind: HashMap<&'static str, Samples>,
    /// All FCT samples pooled across kinds.
    pub fct_all: Samples,
    /// Summed per-rank compute busy time.
    pub compute_busy: Time,
    /// Summed collective busy time.
    pub comm_busy: Time,
    /// The injected fail-stop that aborted this iteration, if any
    /// (`None` for clean completions — the iteration ran to the end or
    /// finished before any scheduled fault).
    pub fault: Option<FaultReport>,
}

impl SimulationReport {
    fn from_scheduler(sim: &Simulation, rep: SchedulerReport) -> SimulationReport {
        let mut fct_by_kind = rep.fct_by_kind;
        let fct_summary =
            fct_by_kind.iter_mut().map(|(k, v)| (*k, Summary::of(v))).collect();
        SimulationReport {
            model_name: sim.model.name.clone(),
            cluster_name: sim.cluster.name.clone(),
            iteration_time: rep.iteration_time,
            flows_completed: rep.flows_completed,
            events_processed: rep.events_processed,
            fct_summary,
            fct_by_kind,
            fct_all: rep.fct_all,
            compute_busy: rep.compute_busy,
            comm_busy: rep.comm_busy,
            fault: rep.fault,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny(cluster: ClusterSpec) -> SimulationBuilder {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 16;
        m.micro_batch = 8;
        SimulationBuilder::new(m, cluster)
    }

    #[test]
    fn quickstart_homogeneous_run() {
        let rep = tiny(presets::cluster("hopper", 1).unwrap())
            .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
            .build()
            .unwrap()
            .run_iteration()
            .unwrap();
        assert!(rep.iteration_time > Time::ZERO);
        assert!(rep.flows_completed > 0);
        assert!(rep.fct_summary.contains_key("TP"));
        assert!(rep.fct_summary.contains_key("DP"));
    }

    #[test]
    fn hetero_slower_than_hopper_for_same_workload() {
        let run = |cluster| {
            tiny(cluster)
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let hopper = run(presets::cluster("hopper", 2).unwrap());
        let hetero = run(presets::cluster_hetero(1, 1).unwrap());
        assert!(hetero > hopper, "hetero {hetero} <= hopper {hopper}");
    }

    #[test]
    fn hetero_partitioning_beats_uniform_on_hetero_cluster() {
        let mk = |hetero_partitioning| {
            tiny(presets::cluster_hetero(1, 1).unwrap())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .hetero_partitioning(hetero_partitioning)
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let uniform = mk(false);
        let partitioned = mk(true);
        assert!(
            partitioned < uniform,
            "non-uniform partitioning should win: {partitioned} vs {uniform}"
        );
    }

    #[test]
    fn determinism_same_config_same_timeline() {
        let run = || {
            tiny(presets::cluster_hetero(1, 1).unwrap())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.iteration_time, b.iteration_time);
        assert_eq!(a.flows_completed, b.flows_completed);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn fold_auto_matches_off_exactly() {
        // 4 identical single-node TP groups under DP: Auto folds three
        // of them away yet must report the identical timeline and the
        // identical (unfolded) busy totals — the tentpole invariant.
        let run = |mode| {
            let sim = tiny(presets::cluster("hopper", 4).unwrap())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 4 })
                .fold(mode)
                .build()
                .unwrap();
            (sim.folded(), sim.run_iteration().unwrap())
        };
        let (off_folded, off) = run(FoldMode::Off);
        let (auto_folded, auto_) = run(FoldMode::Auto);
        assert!(!off_folded, "Off must never fold");
        assert!(auto_folded, "Auto must fold 4 identical replicas");
        assert_eq!(off.iteration_time, auto_.iteration_time);
        assert_eq!(off.compute_busy, auto_.compute_busy);
        assert_eq!(off.comm_busy, auto_.comm_busy);
        // the whole point: folded runs process strictly fewer events
        assert!(
            auto_.events_processed < off.events_processed,
            "folded {} >= unfolded {}",
            auto_.events_processed,
            off.events_processed
        );
    }

    #[test]
    fn fold_auto_falls_back_on_pipeline_stages() {
        // pp=2 breaks the folding preconditions; Auto must quietly run
        // the classic path and still agree with Off on everything.
        let run = |mode| {
            let sim = tiny(presets::cluster("hopper", 4).unwrap())
                .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 4 })
                .fold(mode)
                .build()
                .unwrap();
            (sim.folded(), sim.run_iteration().unwrap())
        };
        let (off_folded, off) = run(FoldMode::Off);
        let (auto_folded, auto_) = run(FoldMode::Auto);
        assert!(!off_folded && !auto_folded);
        assert_eq!(off.iteration_time, auto_.iteration_time);
        assert_eq!(off.events_processed, auto_.events_processed);
        assert_eq!(off.flows_completed, auto_.flows_completed);
    }

    #[test]
    fn injected_fail_stop_surfaces_in_the_report() {
        use crate::system::failure::{FaultEvent, FaultKind, FaultSpec};
        let mk = || {
            tiny(presets::cluster("hopper", 1).unwrap())
                .parallelism(ParallelismSpec { tp: 4, pp: 1, dp: 2 })
        };
        let clean = mk().build().unwrap().run_iteration().unwrap();
        assert!(clean.fault.is_none(), "no spec, no fault");
        let mut spec = FaultSpec::default();
        spec.events.push(FaultEvent {
            at_s: clean.iteration_time.as_secs() * 0.5,
            kind: FaultKind::NodeFail { node: 0 },
        });
        let rep = mk().faults(Some(spec)).build().unwrap().run_iteration().unwrap();
        let fault = rep.fault.expect("mid-iteration fail-stop must abort");
        assert_eq!(fault.node, 0);
        assert_eq!(rep.iteration_time, fault.at);
        assert_eq!(fault.lost_work, fault.at, "the whole partial iteration is lost");
        assert!(rep.iteration_time < clean.iteration_time);
        assert!(rep.events_processed < clean.events_processed);
    }

    #[test]
    fn simulation_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Simulation>();
        assert_send_sync::<EvalContext>();
    }

    #[test]
    fn concurrent_iterations_are_deterministic() {
        let sim = tiny(presets::cluster_hetero(1, 1).unwrap())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .build()
            .unwrap();
        let sequential = sim.run_iteration().unwrap();
        let reports = sim.run_iterations_concurrent(4, 4).unwrap();
        assert_eq!(reports.len(), 4);
        for rep in &reports {
            assert_eq!(rep.iteration_time, sequential.iteration_time);
            assert_eq!(rep.flows_completed, sequential.flows_completed);
            assert_eq!(rep.events_processed, sequential.events_processed);
        }
    }

    #[test]
    fn schedules_run_to_completion_and_1f1b_shrinks_bubbles() {
        // pipeline-heavy scenario: tp=1, pp=4, 8 microbatches. GPipe
        // (seed behavior) runs microbatches strictly sequentially, so
        // any pipelining schedule must finish no later.
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 2;
        let run = |s: ScheduleKind| {
            SimulationBuilder::new(m.clone(), presets::cluster("hopper", 1).unwrap())
                .parallelism(ParallelismSpec { tp: 1, pp: 4, dp: 2 })
                .schedule(s)
                .build()
                .unwrap()
                .run_iteration()
                .unwrap()
                .iteration_time
        };
        let gpipe = run(ScheduleKind::GPipe);
        let onef = run(ScheduleKind::OneFOneB);
        let inter = run(ScheduleKind::Interleaved1F1B { vpp: 2 });
        assert!(gpipe > Time::ZERO && onef > Time::ZERO && inter > Time::ZERO);
        assert!(onef < gpipe, "1f1b {onef} not faster than gpipe {gpipe}");
        assert!(inter < gpipe, "interleaved {inter} not faster than gpipe {gpipe}");
    }

    #[test]
    fn schedules_deterministic_on_hetero_cluster() {
        for s in [ScheduleKind::OneFOneB, ScheduleKind::Interleaved1F1B { vpp: 2 }] {
            let run = || {
                tiny(presets::cluster_hetero(1, 1).unwrap())
                    .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
                    .schedule(s)
                    .build()
                    .unwrap()
                    .run_iteration()
                    .unwrap()
            };
            let a = run();
            let b = run();
            assert_eq!(a.iteration_time, b.iteration_time, "{s}");
            assert_eq!(a.events_processed, b.events_processed, "{s}");
        }
    }

    #[test]
    fn infer_parallelism_matches_paper_when_possible() {
        let m = presets::model("gpt-6.7b").unwrap();
        let c = presets::cluster("hopper", 16).unwrap(); // 128 GPUs
        let p = infer_parallelism(&m, &c).unwrap();
        assert_eq!((p.tp, p.pp, p.dp), (4, 1, 32));
        // non-matching world size falls back
        let c2 = presets::cluster("hopper", 2).unwrap();
        let p2 = infer_parallelism(&m, &c2).unwrap();
        assert_eq!(p2.world_size(), 16);
    }

    // ---- EvalContext (zero-rebuild candidate evaluation) ----

    fn ctx_inputs() -> (ModelSpec, ClusterSpec) {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 2;
        m.global_batch = 16;
        m.micro_batch = 8;
        (m, presets::cluster_hetero(1, 1).unwrap())
    }

    #[test]
    fn context_build_matches_plain_build() {
        let (m, c) = ctx_inputs();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mk = || {
            SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        };
        let plain = mk().build().unwrap().run_iteration().unwrap();
        let shared = mk().build_with_context(&ctx).unwrap().run_iteration().unwrap();
        assert_eq!(plain.iteration_time, shared.iteration_time);
        assert_eq!(plain.flows_completed, shared.flows_completed);
        assert_eq!(plain.events_processed, shared.events_processed);
        assert_eq!(plain.compute_busy, shared.compute_busy);
        assert_eq!(plain.comm_busy, shared.comm_busy);
    }

    #[test]
    fn context_caches_repeat_builds_and_scores() {
        let (m, c) = ctx_inputs();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mk = || {
            SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        };
        let a = mk().score_with_context(&ctx).unwrap();
        assert_eq!(ctx.build_cache_misses(), 1);
        assert_eq!(ctx.score_cache_hits(), 0);
        let b = mk().score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 1, "second score must be a cache hit");
        assert_eq!(a.iteration_time, b.iteration_time);
        assert_eq!(a.events_processed, b.events_processed);
        // a different candidate misses (distinct fingerprint)
        let other = SimulationBuilder::new(m.clone(), c.clone())
            .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
            .score_with_context(&ctx)
            .unwrap();
        assert_eq!(ctx.build_cache_misses(), 2);
        assert!(other.iteration_time > Time::ZERO);
        assert!(ctx.cost_entries() > 0);
    }

    #[test]
    fn serving_spec_changes_eval_key() {
        // Regression: eval keys once fingerprinted only
        // schedule/faults/fold, so a serving-annotated candidate aliased
        // the training candidate of the same shape and returned its
        // cached EvalScore. The serving fingerprint suffix must split
        // them — and an empty spec must not.
        use crate::workload::serve::PoissonSpec;
        let (m, c) = ctx_inputs();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mk = || {
            SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
        };
        let serving = ServeSpec {
            poisson: Some(PoissonSpec { rate_per_s: 1.0, horizon_s: 1.0, ..Default::default() }),
            ..Default::default()
        };
        mk().score_with_context(&ctx).unwrap();
        mk().score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 1);
        // an explicitly-empty spec normalizes away: still the same key
        mk().serving(Some(ServeSpec::default())).score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 2, "empty serving spec must not change the key");
        // a non-empty spec must miss (no aliasing with the training score)
        mk().serving(Some(serving.clone())).score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 2, "serving candidate aliased the training score");
        // ...and be cached under its own key
        mk().serving(Some(serving.clone())).score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 3);
        // distinct serving specs get distinct keys
        let mut other = serving;
        other.seed += 1;
        mk().serving(Some(other)).score_with_context(&ctx).unwrap();
        assert_eq!(ctx.score_cache_hits(), 3);
    }

    #[test]
    fn serving_refuses_fold_and_run_serve_works() {
        use crate::workload::serve::{PoissonSpec, Request};
        let serving = ServeSpec {
            requests: vec![Request {
                arrival_s: 0.0,
                prompt_tokens: 64,
                output_tokens: 8,
                weight: 1.0,
            }],
            poisson: Some(PoissonSpec { rate_per_s: 2.0, horizon_s: 1.0, ..Default::default() }),
            ..Default::default()
        };
        let sim = tiny(presets::cluster("ampere", 2).unwrap())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .fold(FoldMode::Auto)
            .serving(Some(serving))
            .build()
            .unwrap();
        assert!(!sim.folded(), "serving must veto symmetry folding");
        assert!(sim.serving_spec().is_some());
        let rep = sim.run_serve(1).unwrap();
        assert!(rep.requests_total >= 1);
        // and a fold-less training iteration still runs on the side
        assert!(sim.run_iteration().unwrap().iteration_time > Time::ZERO);
        // no spec attached -> run_serve is an error, not a panic
        let plain = tiny(presets::cluster("ampere", 2).unwrap())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .build()
            .unwrap();
        assert!(plain.run_serve(1).is_err());
    }

    #[test]
    fn context_score_matches_full_run() {
        let (m, c) = ctx_inputs();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mk = || {
            SimulationBuilder::new(m.clone(), c.clone())
                .parallelism(ParallelismSpec { tp: 4, pp: 2, dp: 2 })
                .schedule(ScheduleKind::OneFOneB)
        };
        let score = mk().score_with_context(&ctx).unwrap();
        let full = mk().build().unwrap().run_iteration().unwrap();
        assert_eq!(score.iteration_time, full.iteration_time);
        assert_eq!(score.compute_busy, full.compute_busy);
        assert_eq!(score.comm_busy, full.comm_busy);
        assert_eq!(score.flows_completed, full.flows_completed);
        assert_eq!(score.events_processed, full.events_processed);
    }

    #[test]
    fn context_rejects_mismatched_inputs() {
        let (m, c) = ctx_inputs();
        let ctx = EvalContext::new(&m, &c).unwrap();
        let mut other = m.clone();
        other.num_layers += 2;
        let err = SimulationBuilder::new(other, c.clone())
            .parallelism(ParallelismSpec { tp: 8, pp: 1, dp: 2 })
            .build_with_context(&ctx)
            .unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
    }
}
