//! Scoped worker-pool parallel map (substrate S21).
//!
//! One shared pattern for every "evaluate N independent items on T
//! worker threads" need (concurrent simulation iterations, the
//! planner sweep): workers pull indices off an atomic counter inside
//! `std::thread::scope`, results land in index order. Determinism
//! contract: `f` must be a pure function of its index — then the
//! returned `Vec` is identical for any `threads` value.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Evaluate `f(0..n)` on a pool of `threads` workers (0 = one per
/// available core) and return the results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
    .min(n)
    .max(1);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("every claimed slot is written"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = parallel_map(100, 4, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_is_empty_output() {
        let out: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let one = parallel_map(37, 1, |i| i as u64 * i as u64);
        for threads in [2, 8, 0] {
            assert_eq!(one, parallel_map(37, threads, |i| i as u64 * i as u64));
        }
    }
}
