//! Summary statistics, percentiles and CCDF helpers (substrate S19) —
//! used for the paper's FCT distributions (Fig 6) and bench reporting.

/// Accumulates f64 samples and answers distribution queries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    data: Vec<f64>,
    sorted: bool,
}

impl Samples {
    /// An empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty sample set with pre-reserved capacity.
    pub fn with_capacity(n: usize) -> Self {
        Samples { data: Vec::with_capacity(n), sorted: false }
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.data.push(x);
        self.sorted = false;
    }

    /// Add many samples.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.data.extend(xs);
        self.sorted = false;
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when no samples were pushed.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.data.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Arithmetic mean (0 for an empty set).
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    /// Smallest sample (0 for an empty set).
    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.data.first().copied().unwrap_or(0.0)
    }

    /// Largest sample (0 for an empty set).
    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.data.last().copied().unwrap_or(0.0)
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Percentile by linear interpolation; `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.data.len();
        if n == 1 {
            return self.data[0];
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.data[lo] * (1.0 - frac) + self.data[hi] * frac
    }

    /// The 50th percentile.
    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Sample standard deviation (0 with fewer than 2 samples).
    pub fn stddev(&self) -> f64 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.data.len() - 1) as f64;
        var.sqrt()
    }

    /// CCDF curve: for each sample value v (ascending), P(X > v).
    /// Down-samples to at most `max_points` evenly spaced points.
    pub fn ccdf(&mut self, max_points: usize) -> Vec<(f64, f64)> {
        if self.data.is_empty() {
            return vec![];
        }
        self.ensure_sorted();
        let n = self.data.len();
        let step = (n / max_points.max(1)).max(1);
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let v = self.data[i];
            // fraction strictly greater than v
            let gt = n - self.data.partition_point(|x| *x <= v);
            out.push((v, gt as f64 / n as f64));
            i += step;
        }
        // always include the max point
        let last = self.data[n - 1];
        if out.last().map(|(v, _)| *v != last).unwrap_or(true) {
            out.push((last, 0.0));
        }
        out
    }

    /// The raw samples (sorted iff a sorted query ran last).
    pub fn values(&self) -> &[f64] {
        &self.data
    }
}

/// Fixed summary of a sample set (one row of a results table).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
    /// 99.9th percentile.
    pub p999: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set.
    pub fn of(samples: &mut Samples) -> Summary {
        Summary {
            count: samples.len(),
            mean: samples.mean(),
            min: samples.min(),
            p50: samples.percentile(50.0),
            p90: samples.percentile(90.0),
            p99: samples.percentile(99.0),
            p999: samples.percentile(99.9),
            max: samples.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples(xs: &[f64]) -> Samples {
        let mut s = Samples::new();
        s.extend(xs.iter().copied());
        s
    }

    #[test]
    fn empty_is_safe() {
        let mut s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.ccdf(10).is_empty());
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
        assert!((s.percentile(10.0) - 1.4).abs() < 1e-12);
    }

    #[test]
    fn percentile_monotone() {
        let mut s = samples(&[5.0, 1.0, 9.0, 3.0, 3.0, 7.0, 2.0]);
        let mut last = f64::NEG_INFINITY;
        for p in 0..=100 {
            let v = s.percentile(p as f64);
            assert!(v >= last);
            last = v;
        }
    }

    #[test]
    fn mean_and_stddev() {
        let s = samples(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn ccdf_monotone_decreasing() {
        let mut s = samples(&[1.0, 1.0, 2.0, 3.0, 3.0, 3.0, 10.0]);
        let curve = s.ccdf(100);
        for w in curve.windows(2) {
            assert!(w[1].0 >= w[0].0, "x ascending");
            assert!(w[1].1 <= w[0].1, "p descending");
        }
        assert_eq!(curve.last().unwrap().1, 0.0);
    }

    #[test]
    fn ccdf_values_correct() {
        let mut s = samples(&[1.0, 2.0, 3.0, 4.0]);
        let curve = s.ccdf(100);
        // P(X > 1) = 3/4 at v=1
        assert!((curve[0].1 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_ordered() {
        let mut s = Samples::new();
        s.extend((1..=1000).map(|i| i as f64));
        let sum = Summary::of(&mut s);
        assert_eq!(sum.count, 1000);
        assert!(sum.min <= sum.p50 && sum.p50 <= sum.p90);
        assert!(sum.p90 <= sum.p99 && sum.p99 <= sum.p999 && sum.p999 <= sum.max);
        assert!((sum.p999 - 999.001).abs() < 0.01);
    }
}
