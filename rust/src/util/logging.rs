//! Leveled logger (substrate S19). Level comes from `HETSIM_LOG`
//! (`error|warn|info|debug|trace`, default `warn`) so tests stay quiet.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious-but-survivable conditions (the default threshold).
    Warn = 1,
    /// Progress messages.
    Info = 2,
    /// Developer diagnostics.
    Debug = 3,
    /// Per-event firehose.
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Warn,
        }
    }
    /// Upper-case display name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static INIT: OnceLock<()> = OnceLock::new();

fn current_level() -> Level {
    INIT.get_or_init(|| {
        let lvl = std::env::var("HETSIM_LOG").map(|v| Level::from_str(&v)).unwrap_or(Level::Warn);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (CLI --verbose).
pub fn set_level(level: Level) {
    INIT.get_or_init(|| ());
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether messages at `level` currently pass the threshold.
pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Emit one message to stderr if `level` passes the threshold (the
/// `log_*!` macros call this).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:5}] {}: {}", level.name(), module, msg);
    }
}

/// Log at [`Level::Info`] with `format!`-style arguments.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`] with `format!`-style arguments.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`] with `format!`-style arguments.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("ERROR"), Level::Error);
        assert_eq!(Level::from_str("debug"), Level::Debug);
        assert_eq!(Level::from_str("bogus"), Level::Warn);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
    }

    #[test]
    fn ordering_is_sane() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }
}
