//! Physical units for the simulator (substrate S19).
//!
//! The engine keeps time as integer **picoseconds** (`Time`) so event
//! ordering never suffers floating-point drift; bandwidths are bytes/s
//! (`Bandwidth`) and sizes are bytes (`ByteSize`). Human-facing parsing
//! ("200Gbps", "4.4GB") and formatting live here too.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// Simulation time in integer picoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

/// Picoseconds per nanosecond.
pub const PS_PER_NS: u64 = 1_000;
/// Picoseconds per microsecond.
pub const PS_PER_US: u64 = 1_000_000;
/// Picoseconds per millisecond.
pub const PS_PER_MS: u64 = 1_000_000_000;
/// Picoseconds per second.
pub const PS_PER_S: u64 = 1_000_000_000_000;

impl Time {
    /// Time zero.
    pub const ZERO: Time = Time(0);
    /// The largest representable time.
    pub const MAX: Time = Time(u64::MAX);

    /// From integer picoseconds (exact).
    pub fn from_ps(ps: u64) -> Time {
        Time(ps)
    }
    /// From nanoseconds (rounded to the nearest picosecond).
    pub fn from_ns(ns: f64) -> Time {
        Time((ns * PS_PER_NS as f64).round() as u64)
    }
    /// From microseconds (rounded to the nearest picosecond).
    pub fn from_us(us: f64) -> Time {
        Time((us * PS_PER_US as f64).round() as u64)
    }
    /// From milliseconds (rounded to the nearest picosecond).
    pub fn from_ms(ms: f64) -> Time {
        Time((ms * PS_PER_MS as f64).round() as u64)
    }
    /// From seconds (rounded to the nearest picosecond).
    pub fn from_secs(s: f64) -> Time {
        Time((s * PS_PER_S as f64).round() as u64)
    }

    /// As integer picoseconds (exact).
    pub fn as_ps(self) -> u64 {
        self.0
    }
    /// As nanoseconds.
    pub fn as_ns(self) -> f64 {
        self.0 as f64 / PS_PER_NS as f64
    }
    /// As microseconds.
    pub fn as_us(self) -> f64 {
        self.0 as f64 / PS_PER_US as f64
    }
    /// Alias of [`Time::as_us`].
    pub fn as_micros(self) -> f64 {
        self.as_us()
    }
    /// As milliseconds.
    pub fn as_ms(self) -> f64 {
        self.0 as f64 / PS_PER_MS as f64
    }
    /// As seconds.
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / PS_PER_S as f64
    }

    /// Subtraction clamped at zero.
    pub fn saturating_sub(self, rhs: Time) -> Time {
        Time(self.0.saturating_sub(rhs.0))
    }

    /// Human-readable with adaptive unit.
    pub fn human(self) -> String {
        let ps = self.0;
        if ps < PS_PER_NS {
            format!("{ps}ps")
        } else if ps < PS_PER_US {
            format!("{:.2}ns", self.as_ns())
        } else if ps < PS_PER_MS {
            format!("{:.2}us", self.as_us())
        } else if ps < PS_PER_S {
            format!("{:.3}ms", self.as_ms())
        } else {
            format!("{:.4}s", self.as_secs())
        }
    }
}

impl Add for Time {
    type Output = Time;
    fn add(self, rhs: Time) -> Time {
        Time(self.0 + rhs.0)
    }
}
impl AddAssign for Time {
    fn add_assign(&mut self, rhs: Time) {
        self.0 += rhs.0;
    }
}
impl Sub for Time {
    type Output = Time;
    fn sub(self, rhs: Time) -> Time {
        Time(self.0 - rhs.0)
    }
}
impl Mul<u64> for Time {
    type Output = Time;
    fn mul(self, rhs: u64) -> Time {
        Time(self.0 * rhs)
    }
}
impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.human())
    }
}

/// Bandwidth in bytes per second.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    /// From gigabits per second.
    pub fn from_gbps(gigabits_per_sec: f64) -> Bandwidth {
        Bandwidth(gigabits_per_sec * 1e9 / 8.0)
    }
    /// From gigabytes per second.
    pub fn from_gbytes(gigabytes_per_sec: f64) -> Bandwidth {
        Bandwidth(gigabytes_per_sec * 1e9)
    }
    /// As bytes per second.
    pub fn bytes_per_sec(self) -> f64 {
        self.0
    }
    /// As gigabits per second.
    pub fn gbps(self) -> f64 {
        self.0 * 8.0 / 1e9
    }
    /// Time to serialize `bytes` at this bandwidth.
    pub fn transfer_time(self, bytes: u64) -> Time {
        if self.0 <= 0.0 {
            return Time::MAX;
        }
        Time::from_secs(bytes as f64 / self.0)
    }
}

impl Div<f64> for Bandwidth {
    type Output = Bandwidth;
    fn div(self, rhs: f64) -> Bandwidth {
        Bandwidth(self.0 / rhs)
    }
}
impl fmt::Display for Bandwidth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1}Gbps", self.gbps())
    }
}

/// Data size in bytes with human parsing/formatting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct ByteSize(pub u64);

impl ByteSize {
    /// `n` kibibytes.
    pub fn kib(n: u64) -> ByteSize {
        ByteSize(n * 1024)
    }
    /// `n` mebibytes.
    pub fn mib(n: u64) -> ByteSize {
        ByteSize(n * 1024 * 1024)
    }
    /// `n` gibibytes.
    pub fn gib(n: u64) -> ByteSize {
        ByteSize(n * 1024 * 1024 * 1024)
    }
    /// As raw bytes.
    pub fn bytes(self) -> u64 {
        self.0
    }
    /// Human-readable with adaptive unit.
    pub fn human(self) -> String {
        let b = self.0 as f64;
        if b < 1024.0 {
            format!("{}B", self.0)
        } else if b < 1024.0 * 1024.0 {
            format!("{:.1}KB", b / 1024.0)
        } else if b < 1024.0 * 1024.0 * 1024.0 {
            format!("{:.1}MB", b / (1024.0 * 1024.0))
        } else {
            format!("{:.2}GB", b / (1024.0 * 1024.0 * 1024.0))
        }
    }
}

impl fmt::Display for ByteSize {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.human())
    }
}

/// Parse strings like "200Gbps", "600GB/s", "4800Mbps" into a Bandwidth.
pub fn parse_bandwidth(s: &str) -> Option<Bandwidth> {
    let s = s.trim();
    let split = s.find(|c: char| c.is_alphabetic())?;
    let (num, unit) = s.split_at(split);
    let v: f64 = num.trim().parse().ok()?;
    match unit.trim().to_ascii_lowercase().as_str() {
        "gbps" | "gb/s(bits)" => Some(Bandwidth::from_gbps(v)),
        "mbps" => Some(Bandwidth::from_gbps(v / 1000.0)),
        "tbps" => Some(Bandwidth::from_gbps(v * 1000.0)),
        "gb/s" | "gbs" => Some(Bandwidth::from_gbytes(v)),
        "mb/s" => Some(Bandwidth::from_gbytes(v / 1000.0)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_roundtrip() {
        assert_eq!(Time::from_ns(30.66).as_ps(), 30_660);
        assert!((Time::from_us(1.5).as_ns() - 1500.0).abs() < 1e-9);
        assert!((Time::from_secs(2.0).as_ms() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn time_ordering_exact() {
        assert!(Time::from_ns(1.0) < Time::from_ns(1.001));
        assert_eq!(Time::from_ps(5) + Time::from_ps(7), Time::from_ps(12));
    }

    #[test]
    fn time_human_formats() {
        assert_eq!(Time::from_ps(500).human(), "500ps");
        assert_eq!(Time::from_ns(368.0).human(), "368.00ns");
        assert!(Time::from_secs(1.5).human().ends_with('s'));
    }

    #[test]
    fn bandwidth_gbps() {
        let nic = Bandwidth::from_gbps(200.0);
        assert!((nic.bytes_per_sec() - 25e9).abs() < 1.0);
        assert!((nic.gbps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_formula() {
        // paper §5: jumbo frame 9200 B at 4800 Gbps -> 9200*8/4800e9 s
        let nvlink = Bandwidth::from_gbps(4800.0);
        let t = nvlink.transfer_time(9200);
        let expect_ns = 9200.0 * 8.0 / 4800.0; // = 15.33 ns
        assert!((t.as_ns() - expect_ns).abs() < 0.01, "{}", t.as_ns());
    }

    #[test]
    fn zero_bandwidth_is_infinite_time() {
        assert_eq!(Bandwidth(0.0).transfer_time(1), Time::MAX);
    }

    #[test]
    fn bytesize_human() {
        assert_eq!(ByteSize(512).human(), "512B");
        assert_eq!(ByteSize::kib(67).human(), "67.0KB");
        assert_eq!(ByteSize::gib(4).human(), "4.00GB");
    }

    #[test]
    fn parse_bandwidth_variants() {
        assert!((parse_bandwidth("200Gbps").unwrap().gbps() - 200.0).abs() < 1e-9);
        assert!((parse_bandwidth("600GB/s").unwrap().bytes_per_sec() - 600e9).abs() < 1.0);
        assert!((parse_bandwidth("1000 Mbps").unwrap().gbps() - 1.0).abs() < 1e-9);
        assert!(parse_bandwidth("fast").is_none());
    }
}
