//! Deterministic PRNG (substrate S16; the `rand` crate is unavailable
//! offline). SplitMix64 for seeding, PCG32 (PCG-XSH-RR) as the main
//! generator — both well-known, reproducible across platforms.

/// PCG32 generator with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-rank determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next uniform 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi must exceed lo.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range [{lo},{hi})");
        let span = hi - lo;
        // Lemire-style rejection for unbiased bounded integers.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi) — hi must exceed lo.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range_u64(5, 10);
            assert!((5..10).contains(&x));
            seen_lo |= x == 5;
            seen_hi |= x == 9;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn range_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.range_usize(0, 8)] += 1;
        }
        for c in counts {
            let expect = n / 8;
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 5) as u64, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
        assert_ne!(v, (0..64).collect::<Vec<_>>()); // vanishing chance
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(21);
        let mut s1 = root.fork(1);
        let mut s2 = root.fork(2);
        let a: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
