//! In-tree substrates for functionality normally pulled from crates.io
//! (the offline registry only carries `xla`/`anyhow`/`thiserror`; see
//! DESIGN.md §4 Substitutions, systems S14–S19).

pub mod cli;
pub mod json;
pub mod logging;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
