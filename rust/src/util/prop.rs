//! Tiny property-based testing harness (substrate S17; `proptest` is
//! unavailable offline). Runs a property over N generated cases with a
//! deterministic per-case seed; on failure it retries with simpler
//! generator sizes (linear shrink over the `size` hint) and reports the
//! smallest failing seed/size.
//!
//! Used by `rust/tests/properties.rs` for the simulator invariants
//! (event ordering, partition conservation, resharding shapes, max-min
//! fairness, collective traffic conservation).

use super::rng::Rng;

/// Generation context handed to properties: a seeded RNG plus a size
/// hint in [1, max_size] that scales generated structures.
pub struct Gen {
    /// The case's deterministic RNG.
    pub rng: Rng,
    /// Size hint scaling generated structures.
    pub size: usize,
}

impl Gen {
    /// Vec of length [0, size] from a generator closure.
    pub fn vec<T>(&mut self, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let n = self.rng.range_usize(0, self.size + 1);
        (0..n).map(|_| f(&mut self.rng)).collect()
    }

    /// Non-empty Vec of length [1, size.max(1)].
    pub fn vec1<T>(&mut self, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let n = self.rng.range_usize(1, self.size.max(1) + 1);
        (0..n).map(|_| f(&mut self.rng)).collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    /// Generated cases per property.
    pub cases: usize,
    /// Largest size hint (cases ramp linearly up to it).
    pub max_size: usize,
    /// Base seed; each case derives its own from it.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, max_size: 64, seed: 0x4845_5453_494d }
    }
}

/// Result of a failed case, used in the panic message.
#[derive(Debug)]
pub struct Failure {
    /// Failing case index.
    pub case: usize,
    /// The case's derived seed (for reproduction).
    pub seed: u64,
    /// Smallest failing size hint found by shrinking.
    pub size: usize,
    /// The property's failure message.
    pub message: String,
}

/// Run `prop` over `cfg.cases` generated cases. The property returns
/// `Err(message)` (or panics) to signal failure; on failure we re-run at
/// smaller sizes with the same seed to find a smaller counterexample.
pub fn check(cfg: &Config, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        if let Err(msg) = run_one(&mut prop, seed, size) {
            // shrink: retry the same seed with smaller sizes
            let mut best = Failure { case, seed, size, message: msg };
            let mut s = size / 2;
            while s >= 1 {
                match run_one(&mut prop, seed, s) {
                    Err(msg) => {
                        best = Failure { case, seed, size: s, message: msg };
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (case {}, seed {:#x}, size {}): {}",
                best.case, best.seed, best.size, best.message
            );
        }
    }
}

fn run_one(
    prop: &mut impl FnMut(&mut Gen) -> Result<(), String>,
    seed: u64,
    size: usize,
) -> Result<(), String> {
    let mut g = Gen { rng: Rng::new(seed), size };
    prop(&mut g)
}

/// Run with default config.
pub fn check_default(prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    check(&Config::default(), prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default(|g| {
            let v = g.vec(|r| r.range_u64(0, 100));
            let sum: u64 = v.iter().sum();
            if sum <= 100 * v.len() as u64 {
                Ok(())
            } else {
                Err("sum bound violated".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        check_default(|g| {
            let v = g.vec1(|r| r.range_u64(0, 10));
            if v.len() < 5 {
                Ok(())
            } else {
                Err(format!("len {} >= 5", v.len()))
            }
        });
    }

    #[test]
    fn shrink_finds_smaller_size() {
        // capture the panic message and assert the reported size is small
        let result = std::panic::catch_unwind(|| {
            check(&Config { cases: 64, max_size: 64, seed: 5 }, |g| {
                if g.size >= 3 {
                    Err("size >= 3".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrink loop should have walked below the original size
        assert!(msg.contains("size 3") || msg.contains("size 4"), "{msg}");
    }

    #[test]
    fn sizes_scale_across_cases() {
        let mut max_seen = 0;
        check(&Config { cases: 50, max_size: 40, seed: 1 }, |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen >= 30, "sizes should approach max_size, saw {max_seen}");
    }
}
