//! Minimal JSON parser/serializer (substrate S14; `serde`/`serde_json`
//! are unavailable in the offline registry).
//!
//! Supports the full JSON data model with the restrictions HetSim needs:
//! numbers are `f64`, object key order is preserved (deterministic config
//! round-trips), and parse errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via a Vec of pairs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always an `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

/// Parse/access error.
#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    /// Malformed input, with the byte offset of the problem.
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    /// A required object key was absent.
    #[error("missing key: {0}")]
    MissingKey(String),
    /// A key held a value of the wrong type.
    #[error("type mismatch at {0}: expected {1}")]
    Type(String, &'static str),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup (None for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field accessors used by the config loader.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::MissingKey(key.into()))
    }
    /// Required number field.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or(JsonError::Type(key.into(), "number"))
    }
    /// Required unsigned-integer field.
    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.req(key)?.as_u64().ok_or(JsonError::Type(key.into(), "unsigned int"))
    }
    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or(JsonError::Type(key.into(), "string"))
    }
    /// Optional number field with a default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    /// Optional unsigned-integer field with a default.
    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }
    /// Optional string field with a default.
    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Build an object from pairs (helper for writers).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Sorted-key map view (for canonical comparisons in tests).
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(m) => Some(m.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected literal {s}"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad \\u".into()))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = &self.b[self.i..];
                    let txt = std::str::from_utf8(rest)
                        .map_err(|_| JsonError::Parse(self.i, "invalid utf-8".into()))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, format!("bad number '{txt}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A");
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"name":"hetsim","ws":[1,2,3],"f":1.25,"nested":{"x":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_carry_offsets() {
        match Json::parse("{\"a\": }") {
            Err(JsonError::Parse(off, _)) => assert!(off >= 6),
            other => panic!("expected parse error, got {other:?}"),
        }
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn req_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_str("n").is_err());
        assert_eq!(v.opt_u64("missing", 9), 9);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(m) = &v {
            let keys: Vec<&str> = m.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!();
        }
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }
}
