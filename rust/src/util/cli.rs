//! Command-line argument parser (substrate S15; `clap` is unavailable
//! offline). Supports subcommands, `--flag`, `--key value`, `--key=value`
//! and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First bare token (e.g. `simulate`).
    pub subcommand: Option<String>,
    /// Bare tokens after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). Arguments before the
    /// first `--`-prefixed token: the first is the subcommand, the rest
    /// are positional.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (skipping the program name).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// True when `--name` was given as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if given.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// The value of `--name`, or a default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// `--name` parsed as an unsigned integer, or a default.
    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an unsigned integer, got '{v}'")),
        }
    }

    /// `--name[=N]` flag-or-valued option: `Some(flag_default)` when
    /// given as a bare flag, `Some(N)` when given a value, `None` when
    /// absent (the `--refine[=STEPS]` pattern).
    pub fn opt_u64_flag(&self, name: &str, flag_default: u64) -> anyhow::Result<Option<u64>> {
        if self.flag(name) {
            return Ok(Some(flag_default));
        }
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow::anyhow!("--{name} expects an unsigned integer, got '{v}'")
            }),
        }
    }

    /// `--name` parsed as a float, or a default.
    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'"))
            }
        }
    }

    /// Error out on options not in the allowed set (typo protection).
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                anyhow::bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

/// Declarative usage text builder.
pub struct Usage {
    /// Binary name.
    pub program: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// `(command, help)` pairs.
    pub commands: Vec<(&'static str, &'static str)>,
}

impl Usage {
    /// Render the usage text.
    pub fn render(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.program, self.about, self.program);
        for (name, help) in &self.commands {
            s.push_str(&format!("  {name:<22} {help}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["simulate", "config.json", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["config.json", "extra"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["run", "--model", "gpt-6.7b", "--nodes=16"]);
        assert_eq!(a.opt("model"), Some("gpt-6.7b"));
        assert_eq!(a.opt("nodes"), Some("16"));
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("verbose"), None);
    }

    #[test]
    fn flag_before_option() {
        // --json is followed by another --opt, so it's a flag
        let a = parse(&["x", "--json", "--out", "f.csv"]);
        assert!(a.flag("json"));
        assert_eq!(a.opt("out"), Some("f.csv"));
    }

    #[test]
    fn numeric_accessors() {
        let a = parse(&["x", "--nodes", "32", "--ratio", "0.5"]);
        assert_eq!(a.opt_u64("nodes", 1).unwrap(), 32);
        assert!((a.opt_f64("ratio", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.opt_u64("missing", 7).unwrap(), 7);
        let bad = parse(&["x", "--nodes", "lots"]);
        assert!(bad.opt_u64("nodes", 1).is_err());
    }

    #[test]
    fn flag_or_valued_option() {
        let bare = parse(&["x", "--refine"]);
        assert_eq!(bare.opt_u64_flag("refine", 64).unwrap(), Some(64));
        let valued = parse(&["x", "--refine=16"]);
        assert_eq!(valued.opt_u64_flag("refine", 64).unwrap(), Some(16));
        let absent = parse(&["x"]);
        assert_eq!(absent.opt_u64_flag("refine", 64).unwrap(), None);
        let bad = parse(&["x", "--refine", "soon"]);
        assert!(bad.opt_u64_flag("refine", 64).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["x", "--tpyo", "1"]);
        assert!(a.check_known(&["model", "nodes"]).is_err());
        let b = parse(&["x", "--model", "m"]);
        assert!(b.check_known(&["model"]).is_ok());
    }

    #[test]
    fn usage_renders_commands() {
        let u = Usage {
            program: "hetsim",
            about: "simulator",
            commands: vec![("fig5", "per-layer compute time")],
        };
        let text = u.render();
        assert!(text.contains("fig5"));
        assert!(text.contains("per-layer compute time"));
    }
}
