//! Markdown / CSV table rendering (substrate S19) — every reproduced
//! paper table and figure is emitted through this module so outputs are
//! diffable and easy to paste into EXPERIMENTS.md.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Caption printed above the table.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each as wide as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch in '{}'", self.title);
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// GitHub-flavoured markdown.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        if !self.title.is_empty() {
            s.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        s.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for row in &self.rows {
            s.push_str(&fmt_row(row));
        }
        s
    }

    /// RFC-4180-ish CSV (quotes cells containing commas/quotes).
    pub fn csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }

    /// Write CSV to `results/<name>.csv`, creating the directory.
    pub fn write_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.csv())?;
        Ok(path)
    }
}

/// Format an f64 with engineering-friendly precision.
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        let mut t = Table::new("Demo", &["model", "time"]);
        t.row(vec!["gpt-6.7b".into(), "1.5ms".into()]);
        t.row(vec!["mixtral".into(), "2.0ms".into()]);
        t
    }

    #[test]
    fn markdown_structure() {
        let md = t().markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| model"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    fn markdown_alignment() {
        let md = t().markdown();
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{lens:?}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0), "0");
        assert_eq!(fmt_sig(12345.6), "12346");
        assert_eq!(fmt_sig(42.42), "42.4");
        assert_eq!(fmt_sig(1.23456), "1.235");
        assert!(fmt_sig(0.0001234).contains('e'));
    }

    #[test]
    fn write_csv_creates_file() {
        let dir = std::env::temp_dir().join("hetsim_table_test");
        let path = t().write_csv(&dir, "demo").unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("model,time"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
