//! Parallelism-plan exploration (system S20, the `hetsim plan`
//! subcommand): answers *"what is the best way to run model M on this
//! heterogeneous cluster?"* — the paper's headline use case ("an LLM
//! training deployer can draw inferences from our simulator and plan an
//! optimal deployment"), in the spirit of Helix's placement search and
//! HeteroSim's heterogeneity-aware computation planner.
//!
//! * [`candidates`] — enumerate every valid TP×PP×DP factorization of
//!   the cluster, crossed with uniform vs heterogeneity-aware
//!   partitioning, both ring policies and the pipeline-schedule set
//!   (GPipe / 1F1B / interleaved,
//!   [`crate::workload::schedule::ScheduleKind`]), with explicit
//!   pruning (cross-node TP, indivisible layers, device-memory
//!   including each schedule's peak-activation residency, batch
//!   floor); nothing is dropped silently — pruned candidates carry a
//!   typed [`candidates::PruneReason`].
//! * [`search`] — evaluate all candidates concurrently (each worker
//!   builds and runs its own full simulation; the inputs are shared
//!   immutably across threads) and rank them deterministically by
//!   predicted iteration time with a stable key tie-break, so the
//!   ranking is byte-identical across runs and worker counts.
//! * [`refine`] — simulator-in-the-loop coordinate descent
//!   (`hetsim plan --refine`): polish the top-ranked plans by moving
//!   layers off bottleneck stages and batch share off bottleneck
//!   groups, accepting only strictly-improving moves scored by full
//!   simulated iterations. The first subsystem where the simulator
//!   optimizes its own inputs.
//!
//! On heterogeneous clusters the candidate space includes **variable
//! per-group TP layouts** ([`candidates::TpLayout::PerNode`]): per-node
//! pipelines whose TP degrees need not match (the paper's Fig-3
//! TP=3/TP=1 vs TP=4 shape), validated against resharding feasibility
//! and memory, and refined like any other start.
//!
//! Large spaces don't need the exhaustive grid: [`bound`] computes an
//! admissible analytical lower bound per candidate and [`bnb`] turns it
//! into a deterministic branch-and-bound (`hetsim plan --search bnb`)
//! that prunes dominated candidates outright and aborts dominated
//! simulations at the incumbent cutoff, while provably reporting the
//! same best plan as the grid (DESIGN.md §29).

pub mod bnb;
pub mod bound;
pub mod candidates;
pub mod refine;
pub mod search;

pub use bnb::search_bnb;
pub use bound::Bounder;
pub use candidates::{
    enumerate, node_splits, schedules_for, Partitioning, PlanCandidate, PruneReason,
    PrunedCandidate, TpLayout,
};
pub use refine::{
    apply_move, candidate_moves, refine, refine_with_context, AppliedMove, Move, RefineOptions,
    RefinedPlan,
};
pub use search::{
    search, EvaluatedPlan, PlanOptions, PlanSearchReport, SearchStats, REFINE_STARTS,
};
