//! Simulator-in-the-loop plan refinement: coordinate descent over
//! layer assignments and batch shares, scored by full simulated
//! iterations.
//!
//! The closed-form heuristics ([`crate::workload::partition::plan_hetero`],
//! `plan_variable_tp`) split layers and batch proportionally to peak
//! compute power — they cannot see pipeline bubbles, collective
//! contention or resharding cost. The refiner can, because its
//! objective *is* the simulator: starting from a materialized plan it
//! repeatedly
//!
//! 1. enumerates every candidate move ([`candidate_moves`]) in a fixed
//!    order — shifting 1/2/4/8 layers between adjacent pipeline stages
//!    of each group, and shifting 1/2/4 microbatch-quanta of batch
//!    share between adjacent groups (either direction);
//! 2. simulates every resulting plan concurrently (the same scoped
//!    worker-pool substrate that backs
//!    [`crate::simulator::Simulation::run_iterations_concurrent`] and
//!    the planner sweep);
//! 3. accepts the move with the strictly smallest simulated iteration
//!    time (ties broken by the fixed enumeration order) and repeats
//!    until no move improves or the step budget is exhausted.
//!
//! **Determinism.** Each simulation is deterministic; moves are
//! enumerated in a fixed order; results come back in enumeration order
//! regardless of worker count ([`crate::util::par::parallel_map`]'s
//! contract); acceptance requires a *strict* improvement in integer
//! picoseconds with a first-index tie-break. Hence the refinement
//! trajectory — and the rendered report — is byte-identical across
//! runs and thread counts, and the strictly-decreasing objective
//! guarantees termination. `tests/integration_planner.rs` enforces
//! this across 1/4/8 workers.
//!
//! This is the first place the simulator optimizes its own inputs —
//! the capability the paper positions as the point of building a
//! heterogeneity-aware simulator ("an LLM training deployer can draw
//! inferences from our simulator and plan an optimal deployment").

use crate::config::cluster::ClusterSpec;
use crate::config::framework::FrameworkSpec;
use crate::config::model::ModelSpec;
use crate::simulator::{EvalContext, SimulationBuilder};
use crate::system::collective::RingPolicy;
use crate::system::fold::FoldMode;
use crate::util::par::parallel_map;
use crate::util::units::Time;
use crate::workload::aicb::WorkloadOptions;

/// One coordinate-descent move over a [`FrameworkSpec`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Move {
    /// Move `layers` transformer blocks from `from_stage` to the
    /// adjacent `to_stage` of device group `group` (conserves the
    /// group's layer total; every stage keeps ≥ 1 layer).
    Layers {
        /// Device-group id the stages belong to.
        group: u32,
        /// Donor stage index.
        from_stage: u32,
        /// Recipient stage index (`from_stage ± 1`).
        to_stage: u32,
        /// Blocks to move.
        layers: u32,
    },
    /// Move `samples` of batch share from `from_group` to `to_group`
    /// (conserves the global batch; every group keeps ≥ 1 sample).
    Batch {
        /// Donor device-group id.
        from_group: u32,
        /// Recipient device-group id.
        to_group: u32,
        /// Samples to move (multiples of the donor's microbatch size).
        samples: u64,
    },
}

impl Move {
    /// Compact human-readable form used in the refinement trajectory
    /// (`layers g0 s0->s1 x2`, `batch g1->g0 x8`).
    pub fn describe(&self) -> String {
        match self {
            Move::Layers { group, from_stage, to_stage, layers } => {
                format!("layers g{group} s{from_stage}->s{to_stage} x{layers}")
            }
            Move::Batch { from_group, to_group, samples } => {
                format!("batch g{from_group}->g{to_group} x{samples}")
            }
        }
    }
}

/// Enumerate every candidate move of `spec` in a fixed deterministic
/// order: layer shifts first (by group, then adjacent stage pair, then
/// direction, then step size 1/2/4/8), batch shifts second (by adjacent
/// group pair, then direction, then quantum 1×/2×/4× the donor's
/// microbatch size). Batch moves between *adjacent* groups span every
/// redistribution (any transfer decomposes into adjacent hops) while
/// keeping the move count linear in the group count — the all-pairs
/// alternative is quadratic and swamps high-DP plans. Only moves whose
/// donor keeps its floor (1 layer / 1 sample) are emitted; validation
/// against the model/cluster happens at apply time.
pub fn candidate_moves(spec: &FrameworkSpec) -> Vec<Move> {
    const LAYER_STEPS: [u32; 4] = [1, 2, 4, 8];
    const BATCH_MULTIPLIERS: [u64; 3] = [1, 2, 4];
    let mut moves = Vec::new();
    for g in &spec.groups {
        for s in 0..g.stages.len().saturating_sub(1) {
            let (a, b) = (s as u32, s as u32 + 1);
            for (from, to) in [(a, b), (b, a)] {
                let avail = g.stages[from as usize].num_layers;
                for step in LAYER_STEPS {
                    if avail > step {
                        moves.push(Move::Layers {
                            group: g.id,
                            from_stage: from,
                            to_stage: to,
                            layers: step,
                        });
                    }
                }
            }
        }
    }
    for pair in spec.groups.windows(2) {
        for (from, to) in [(&pair[0], &pair[1]), (&pair[1], &pair[0])] {
            for mult in BATCH_MULTIPLIERS {
                let samples = from.micro_batch.max(1) * mult;
                if from.batch_share > samples {
                    moves.push(Move::Batch {
                        from_group: from.id,
                        to_group: to.id,
                        samples,
                    });
                }
            }
        }
    }
    moves
}

/// Apply a move to a spec, returning the modified copy, or `None` when
/// the move is out of range for this spec (unknown group/stage, donor
/// at its floor) — [`candidate_moves`] never emits those for the spec
/// it was called on, but `apply_move` stays total for property tests.
pub fn apply_move(spec: &FrameworkSpec, mv: &Move) -> Option<FrameworkSpec> {
    let mut out = spec.clone();
    match *mv {
        Move::Layers { group, from_stage, to_stage, layers } => {
            let g = out.groups.iter_mut().find(|g| g.id == group)?;
            let n = g.stages.len() as u32;
            if from_stage >= n || to_stage >= n || from_stage == to_stage {
                return None;
            }
            if g.stages[from_stage as usize].num_layers <= layers {
                return None;
            }
            g.stages[from_stage as usize].num_layers -= layers;
            g.stages[to_stage as usize].num_layers += layers;
        }
        Move::Batch { from_group, to_group, samples } => {
            if from_group == to_group {
                return None;
            }
            let from = out.groups.iter().position(|g| g.id == from_group)?;
            let to = out.groups.iter().position(|g| g.id == to_group)?;
            if out.groups[from].batch_share <= samples {
                return None;
            }
            out.groups[from].batch_share -= samples;
            out.groups[to].batch_share += samples;
        }
    }
    Some(out)
}

/// Refinement knobs.
#[derive(Debug, Clone)]
pub struct RefineOptions {
    /// Accepted-move budget (each accepted move costs one round of
    /// concurrent candidate evaluations).
    pub max_steps: u64,
    /// Worker threads for move evaluation (0 = one per available core).
    pub threads: usize,
    /// Microbatch cap per device group during evaluation, mirroring
    /// [`crate::planner::PlanOptions::microbatch_limit`]. **A cap hides
    /// batch-share moves**: it truncates every group to the same
    /// simulated microbatch count, so only `None` (full batch) lets
    /// the refiner see batch redistribution — use the cap for fast
    /// layer-split-only polish, `--mb-limit 0` for the full Fig-3
    /// rediscovery.
    pub microbatch_limit: Option<u64>,
    /// Symmetry folding during move evaluation
    /// ([`crate::system::fold`]) — a pure throughput knob, mirroring
    /// [`crate::planner::PlanOptions::fold`]; scores are bit-identical
    /// either way. `Off` by default.
    pub fold: FoldMode,
}

impl Default for RefineOptions {
    fn default() -> Self {
        RefineOptions {
            max_steps: 64,
            threads: 0,
            microbatch_limit: Some(2),
            fold: FoldMode::Off,
        }
    }
}

/// One accepted move and the simulated iteration time after it.
#[derive(Debug, Clone)]
pub struct AppliedMove {
    /// The accepted move.
    pub mv: Move,
    /// Simulated iteration time of the plan after applying it.
    pub time: Time,
}

/// The refinement result: the polished spec plus its full trajectory.
#[derive(Debug, Clone)]
pub struct RefinedPlan {
    /// The refined framework spec (the starting spec if no move
    /// improved it).
    pub spec: FrameworkSpec,
    /// Simulated iteration time of the starting spec.
    pub initial_time: Time,
    /// Simulated iteration time of the refined spec (≤ `initial_time`
    /// by construction — moves are only accepted on strict
    /// improvement).
    pub refined_time: Time,
    /// Accepted moves, in order.
    pub moves: Vec<AppliedMove>,
    /// Total candidate simulations run (the refinement's cost).
    pub evaluations: u64,
}

impl RefinedPlan {
    /// `initial_time / refined_time` (≥ 1.0).
    pub fn improvement(&self) -> f64 {
        self.initial_time.as_secs() / self.refined_time.as_secs().max(f64::MIN_POSITIVE)
    }

    /// Render the deterministic refinement trajectory: start time,
    /// every accepted move, the final plan shape.
    pub fn render(&self) -> String {
        let mut s = format!(
            "refinement: {} moves accepted over {} evaluations\n  start    = {}\n",
            self.moves.len(),
            self.evaluations,
            self.initial_time.human(),
        );
        for (i, m) in self.moves.iter().enumerate() {
            s.push_str(&format!(
                "  move {:>3}: {} = {}\n",
                i + 1,
                m.mv.describe(),
                m.time.human()
            ));
        }
        s.push_str(&format!(
            "  refined  = {} ({:.2}x vs start)\n  plan: {}\n",
            self.refined_time.human(),
            self.improvement(),
            self.spec.summary(),
        ));
        s
    }
}

/// Simulate one spec under the refiner's evaluation conditions and
/// return its iteration time. Scored through the shared
/// [`EvalContext`]: the topology and cost entries are reused across
/// moves, trace recording stays off, and a revisited spec (moves that
/// keep losing get re-enumerated every round) costs one cache lookup
/// instead of a rebuild + re-simulation.
fn simulate(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    spec: &FrameworkSpec,
    ring: RingPolicy,
    opts: &RefineOptions,
    ctx: &EvalContext,
) -> anyhow::Result<Time> {
    let score = SimulationBuilder::new(model.clone(), cluster.clone())
        .parallelism(spec.base)
        .framework(spec.clone())
        .ring_policy(ring)
        .workload_options(WorkloadOptions {
            microbatch_limit: opts.microbatch_limit,
            ..Default::default()
        })
        .fold(opts.fold)
        .score_with_context(ctx)?;
    Ok(score.iteration_time)
}

/// Coordinate-descent refinement of `start` (see the module docs for
/// the algorithm and determinism argument). Moves that fail validation
/// or simulation are treated as non-improving and skipped — both
/// outcomes are themselves deterministic.
///
/// `start_time` seeds the starting iteration time when the caller
/// already simulated `start` under the same (ring, microbatch-limit)
/// conditions — the search's ranked candidates have — saving one full
/// simulation per refinement start; pass `None` to measure it here.
pub fn refine(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    start: &FrameworkSpec,
    ring: RingPolicy,
    start_time: Option<Time>,
    opts: &RefineOptions,
) -> anyhow::Result<RefinedPlan> {
    let ctx = EvalContext::new(model, cluster)?;
    refine_with_context(model, cluster, start, ring, start_time, opts, &ctx)
}

/// [`refine`] against a caller-provided [`EvalContext`] — the planner's
/// search shares one context between ranking and every refinement
/// start, so refinement inherits a warm topology, cost cache and the
/// ranked candidates' already-scored specs.
#[allow(clippy::too_many_arguments)]
pub fn refine_with_context(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    start: &FrameworkSpec,
    ring: RingPolicy,
    start_time: Option<Time>,
    opts: &RefineOptions,
    ctx: &EvalContext,
) -> anyhow::Result<RefinedPlan> {
    let mut spec = start.clone();
    let mut evaluations: u64 = 0;
    let mut best_time = match start_time {
        Some(t) => t,
        None => {
            evaluations += 1;
            simulate(model, cluster, &spec, ring, opts, ctx)?
        }
    };
    let initial_time = best_time;
    let mut moves: Vec<AppliedMove> = Vec::new();
    while (moves.len() as u64) < opts.max_steps {
        let mut candidates: Vec<(Move, FrameworkSpec)> = candidate_moves(&spec)
            .into_iter()
            .filter_map(|mv| apply_move(&spec, &mv).map(|s| (mv, s)))
            .filter(|(_, s)| s.validate(model, cluster).is_ok())
            .collect();
        if candidates.is_empty() {
            break;
        }
        let times: Vec<Option<Time>> = parallel_map(candidates.len(), opts.threads, |i| {
            simulate(model, cluster, &candidates[i].1, ring, opts, ctx).ok()
        });
        evaluations += candidates.len() as u64;
        // best strictly-improving move; ties break to the smallest
        // enumeration index (strict `<` below keeps the first)
        let mut best: Option<(usize, Time)> = None;
        for (i, t) in times.iter().enumerate() {
            if let Some(t) = t {
                let improves_best = match best {
                    None => true,
                    Some((_, bt)) => *t < bt,
                };
                if *t < best_time && improves_best {
                    best = Some((i, *t));
                }
            }
        }
        let Some((idx, time)) = best else { break };
        let (mv, next) = candidates.swap_remove(idx);
        spec = next;
        best_time = time;
        moves.push(AppliedMove { mv, time });
    }
    Ok(RefinedPlan { spec, initial_time, refined_time: best_time, moves, evaluations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::framework::ParallelismSpec;
    use crate::config::presets;
    use crate::workload::partition::{fig3_cluster, fig3_model, plan_variable_tp};

    fn fig3_start() -> (ModelSpec, ClusterSpec, FrameworkSpec) {
        let m = fig3_model().unwrap();
        let c = fig3_cluster().unwrap();
        let f = plan_variable_tp(&m, &c, &[vec![3, 1], vec![4]], true).unwrap();
        (m, c, f)
    }

    #[test]
    fn moves_enumerate_in_fixed_order_and_conserve() {
        let (m, c, f) = fig3_start();
        let moves = candidate_moves(&f);
        assert!(!moves.is_empty());
        // same spec → same move list
        assert_eq!(moves, candidate_moves(&f));
        let layers: u32 = f.groups[0].stages.iter().map(|s| s.num_layers).sum();
        let batch: u64 = f.groups.iter().map(|g| g.batch_share).sum();
        for mv in &moves {
            let next = apply_move(&f, mv).expect("emitted moves apply");
            next.validate(&m, &c).unwrap_or_else(|e| panic!("{}: {e}", mv.describe()));
            assert_eq!(
                next.groups[0].stages.iter().map(|s| s.num_layers).sum::<u32>(),
                layers,
                "{}",
                mv.describe()
            );
            assert_eq!(
                next.groups.iter().map(|g| g.batch_share).sum::<u64>(),
                batch,
                "{}",
                mv.describe()
            );
        }
    }

    #[test]
    fn apply_move_rejects_floor_violations() {
        let (_, _, f) = fig3_start();
        // group 1 has a single stage: no layer moves exist for it
        assert!(apply_move(
            &f,
            &Move::Layers { group: 1, from_stage: 0, to_stage: 1, layers: 1 }
        )
        .is_none());
        // draining a group below 1 sample is rejected
        let share = f.groups[1].batch_share;
        assert!(apply_move(
            &f,
            &Move::Batch { from_group: 1, to_group: 0, samples: share }
        )
        .is_none());
        assert!(
            apply_move(&f, &Move::Batch { from_group: 0, to_group: 0, samples: 1 }).is_none()
        );
    }

    #[test]
    fn refine_never_regresses_and_is_deterministic() {
        let (m, c, f) = fig3_start();
        let opts =
            RefineOptions { max_steps: 4, threads: 2, microbatch_limit: Some(1), ..Default::default() };
        let a = refine(&m, &c, &f, RingPolicy::HeteroAware, None, &opts).unwrap();
        assert!(a.refined_time <= a.initial_time);
        // every accepted move strictly improves on the previous time
        let mut last = a.initial_time;
        for m in &a.moves {
            assert!(m.time < last, "{} did not improve", m.mv.describe());
            last = m.time;
        }
        let b = refine(&m, &c, &f, RingPolicy::HeteroAware, None, &opts).unwrap();
        assert_eq!(a.render(), b.render());
    }

    #[test]
    fn refine_on_balanced_homogeneous_plan_terminates() {
        // a uniform plan on a homogeneous cluster is already balanced;
        // the refiner must stop quickly rather than wander
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        let c = presets::cluster("hopper", 1).unwrap();
        let f =
            FrameworkSpec::uniform(&m, &c, ParallelismSpec { tp: 4, pp: 1, dp: 2 }).unwrap();
        let opts =
            RefineOptions { max_steps: 8, threads: 2, microbatch_limit: Some(1), ..Default::default() };
        let r = refine(&m, &c, &f, RingPolicy::HeteroAware, None, &opts).unwrap();
        assert!(r.refined_time <= r.initial_time);
    }
}
