//! Bound-guided branch-and-bound plan search (DESIGN.md §29,
//! `hetsim plan --search bnb`).
//!
//! The exhaustive grid ([`super::search::search`]) pays one full
//! simulated iteration per enumerated candidate. This driver spends an
//! analytical lower bound ([`super::bound`]) per candidate first —
//! microseconds instead of milliseconds — and then visits candidates
//! **best-bound-first** while maintaining an *incumbent* (the best
//! fully simulated time so far):
//!
//! * a candidate whose bound exceeds the incumbent is **pruned** — by
//!   admissibility its simulated time could only be worse;
//! * a candidate that is simulated runs under an **incumbent cutoff**
//!   ([`crate::simulator::SimulationBuilder::score_with_cutoff`]): the
//!   event loop aborts the moment its clock passes the incumbent, so a
//!   loser stops paying for events it can never convert into a win.
//!
//! Because the bound never exceeds the simulated time, the true best
//! candidate can neither be pruned (its bound ≤ its time ≤ any
//! incumbent) nor aborted (strict `>` cutoff: a run *equal* to the
//! incumbent completes), so the reported best plan equals the
//! exhaustive grid best — `tests/properties.rs` and the `bnb_speedup`
//! bench both gate on this.
//!
//! ## Determinism across thread counts
//!
//! Candidates are ordered once by `(bound, enumeration index)` and then
//! consumed in fixed-size batches: each batch is filled by scanning
//! that order and discarding bound-pruned entries, the whole batch is
//! simulated concurrently against the *pre-batch* incumbent, and
//! results are folded back **sequentially in batch order**. No
//! decision ever depends on worker scheduling, so the ranked report is
//! byte-identical across 1/4/8 threads (same argument as the grid, plus
//! the batch discipline for the incumbent).
//!
//! Candidates the incumbent cutoff aborted are *not* ranked (their
//! timing is partial); the ranked table is therefore the
//! time-competitive subset of the grid's. Prune/abort counts are
//! reported in [`SearchStats`].

use crate::config::cluster::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::simulator::EvalContext;
use crate::util::par::parallel_map;
use crate::util::units::Time;

use super::bound::Bounder;
use super::search::{
    baseline_and_refine, enumerate_relaxed, evaluate_with_cutoff, rank, EvaluatedPlan,
    PlanOptions, PlanSearchReport, SearchStats,
};

/// Candidates simulated per deterministic batch. Small enough that the
/// incumbent tightens frequently (pruning power), large enough to keep
/// a typical worker pool busy.
pub const BATCH: usize = 8;

/// Bound-guided search: same inputs and report shape as
/// [`super::search::search`], strictly fewer full simulations, and the
/// identical best plan.
pub fn search_bnb(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    opts: &PlanOptions,
) -> anyhow::Result<PlanSearchReport> {
    let (candidates, pruned, memory_relaxed) = enumerate_relaxed(model, cluster, opts)?;
    let ctx = EvalContext::new(model, cluster)?;
    let n = candidates.len();

    // Lower bounds, sequentially (cheap: closed-form over the cost
    // table — no event loop). A candidate whose spec fails to
    // materialize gets a zero bound so it is evaluated — and fails —
    // exactly like it would under the grid, keeping the `failed` list
    // honest.
    let mut bounder = Bounder::new(&ctx.topology());
    let mut bounds: Vec<Time> = Vec::with_capacity(n);
    for cand in &candidates {
        let b = cand
            .framework(model, cluster)
            .and_then(|fw| bounder.bound(model, cluster, &fw, opts.microbatch_limit))
            .unwrap_or(Time::ZERO);
        bounds.push(b);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (bounds[i], i));

    let mut ranked: Vec<EvaluatedPlan> = Vec::new();
    let mut failed = Vec::new();
    let mut incumbent: Option<Time> = None;
    let mut bound_pruned = 0usize;
    let mut cutoff_aborted = 0usize;
    let mut full_sims = 0usize;

    let mut pos = 0;
    while pos < order.len() {
        // fill one batch, discarding candidates the incumbent already
        // dominates (strict >: a bound equal to the incumbent could
        // still tie on time and win the key tie-break)
        let mut batch: Vec<usize> = Vec::with_capacity(BATCH);
        while pos < order.len() && batch.len() < BATCH {
            let i = order[pos];
            pos += 1;
            match incumbent {
                Some(inc) if bounds[i] > inc => bound_pruned += 1,
                _ => batch.push(i),
            }
        }
        if batch.is_empty() {
            continue;
        }
        // simulate the whole batch against the pre-batch incumbent —
        // identical work regardless of worker count
        let cutoff = incumbent;
        let results = parallel_map(batch.len(), opts.threads, |j| {
            evaluate_with_cutoff(model, cluster, &candidates[batch[j]], opts, &ctx, cutoff)
        });
        // fold back sequentially in batch order
        for (&i, res) in batch.iter().zip(results) {
            match res {
                Ok(Some(ev)) => {
                    full_sims += 1;
                    if incumbent.map_or(true, |inc| ev.iteration_time < inc) {
                        incumbent = Some(ev.iteration_time);
                    }
                    ranked.push(ev);
                }
                Ok(None) => cutoff_aborted += 1,
                Err(e) => {
                    full_sims += 1;
                    failed.push((candidates[i].clone(), format!("{e:#}")));
                }
            }
        }
    }

    if ranked.is_empty() {
        let detail =
            failed.first().map(|(c, e)| format!("{}: {e}", c.key())).unwrap_or_default();
        anyhow::bail!("all {n} candidates failed to evaluate — {detail}");
    }
    rank(&mut ranked);

    let (baseline, refined) = baseline_and_refine(model, cluster, opts, &ctx, &ranked)?;
    Ok(PlanSearchReport {
        ranked,
        pruned,
        failed,
        baseline,
        refined,
        memory_relaxed,
        stats: Some(SearchStats {
            candidates: n,
            bound_pruned,
            cutoff_aborted,
            full_sims,
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::planner::search::search;

    fn tiny_model() -> ModelSpec {
        let mut m = presets::model("gpt-6.7b").unwrap();
        m.num_layers = 4;
        m.global_batch = 16;
        m.micro_batch = 8;
        m
    }

    #[test]
    fn bnb_matches_grid_best_with_fewer_full_sims() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() };
        let grid = search(&m, &c, &opts).unwrap();
        let bnb = search_bnb(&m, &c, &opts).unwrap();
        assert_eq!(bnb.best().candidate, grid.best().candidate);
        assert_eq!(bnb.best().iteration_time, grid.best().iteration_time);
        let st = bnb.stats.unwrap();
        assert_eq!(st.candidates, grid.ranked.len() + grid.failed.len());
        assert!(
            st.full_sims < st.candidates,
            "bnb ran {} full sims of {} candidates — nothing saved",
            st.full_sims,
            st.candidates
        );
        assert_eq!(st.full_sims + st.cutoff_aborted + st.bound_pruned, st.candidates);
    }

    #[test]
    fn bnb_report_is_thread_invariant() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let renders: Vec<String> = [1usize, 4, 8]
            .iter()
            .map(|&t| {
                let opts =
                    PlanOptions { microbatch_limit: Some(1), threads: t, ..Default::default() };
                search_bnb(&m, &c, &opts).unwrap().render(0)
            })
            .collect();
        assert_eq!(renders[0], renders[1]);
        assert_eq!(renders[0], renders[2]);
        assert!(renders[0].contains("bound-guided:"), "{}", renders[0]);
    }

    #[test]
    fn bnb_stats_render_mentions_counters() {
        let m = tiny_model();
        let c = presets::cluster_hetero(1, 1).unwrap();
        let opts = PlanOptions { microbatch_limit: Some(1), threads: 2, ..Default::default() };
        let rep = search_bnb(&m, &c, &opts).unwrap();
        let text = rep.render(3);
        assert!(text.contains("bound-pruned"), "{text}");
        assert!(text.contains("cutoff-aborted"), "{text}");
    }
}
